//! Shared-network bandwidth model for the DCI simulation.
//!
//! Transfers between two topology labels traverse the tree path between
//! them (up to the lowest common ancestor and back down). Every node has
//! an *uplink* with finite capacity; concurrent flows crossing a link
//! share its capacity equally (a coarse max–min model, in the spirit of
//! OptorSim-class grid simulators). The paper observes that "network
//! bandwidth within cluster and even more in WAN settings are
//! oversubscribed by a significant factor" — captured here by giving
//! WAN-level uplinks much lower capacity than intra-site links.
//!
//! Effective bandwidth is sampled when a flow starts (fixed for the flow
//! lifetime), which keeps the event count linear in the number of
//! transfers while preserving the contention *shape*: many concurrent
//! wide-area transfers slow each other down.

use crate::topology::Label;
use crate::util::Bytes;
use std::collections::BTreeMap;

/// Bandwidth in bytes/second.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct Bandwidth(pub f64);

impl Bandwidth {
    /// Megabytes per second.
    pub fn mbps(mb: f64) -> Bandwidth {
        Bandwidth(mb * 1024.0 * 1024.0)
    }
    /// Gigabits per second (network convention).
    pub fn gbit(g: f64) -> Bandwidth {
        Bandwidth(g * 1e9 / 8.0)
    }
    pub fn bytes_per_sec(self) -> f64 {
        self.0
    }
}

/// The network: per-uplink capacity and live flow counts.
#[derive(Debug)]
pub struct Network {
    /// Capacity of the uplink above each node (keyed by full label path).
    uplink: BTreeMap<String, Bandwidth>,
    /// Default capacity for unlisted uplinks.
    default_uplink: Option<Bandwidth>,
    /// Live flows per link.
    flows: BTreeMap<String, u32>,
    /// Loopback bandwidth when src == dst (shared-FS copy / local link).
    loopback: Bandwidth,
}

/// Handle for a started flow; pass back to [`Network::end_flow`].
#[derive(Debug, Clone)]
pub struct FlowHandle {
    links: Vec<String>,
}

impl Network {
    pub fn new() -> Network {
        Network {
            uplink: BTreeMap::new(),
            default_uplink: Some(Bandwidth::mbps(100.0)),
            flows: BTreeMap::new(),
            loopback: Bandwidth::mbps(400.0),
        }
    }

    pub fn set_uplink(&mut self, label: &str, bw: Bandwidth) {
        self.uplink.insert(Label::new(label).0, bw);
    }

    pub fn set_default_uplink(&mut self, bw: Bandwidth) {
        self.default_uplink = Some(bw);
    }

    pub fn set_loopback(&mut self, bw: Bandwidth) {
        self.loopback = bw;
    }

    fn capacity(&self, link: &str) -> Bandwidth {
        self.uplink
            .get(link)
            .copied()
            .or(self.default_uplink)
            .unwrap_or(Bandwidth::mbps(100.0))
    }

    /// Links (child-label keyed) crossed between `a` and `b`.
    pub fn path(&self, a: &Label, b: &Label) -> Vec<String> {
        let ac = a.components();
        let bc = b.components();
        let common = a.common_prefix_len(b);
        let mut links = Vec::new();
        for depth in common..ac.len() {
            links.push(ac[..=depth].join("/"));
        }
        for depth in common..bc.len() {
            links.push(bc[..=depth].join("/"));
        }
        links
    }

    /// Effective bandwidth a new flow from `a` to `b` would get right
    /// now: the bottleneck link's fair share.
    pub fn effective_bandwidth(&self, a: &Label, b: &Label) -> Bandwidth {
        let links = self.path(a, b);
        if links.is_empty() {
            return self.loopback;
        }
        let mut bw = f64::INFINITY;
        for link in &links {
            let cap = self.capacity(link).0;
            let sharers = (*self.flows.get(link).unwrap_or(&0) + 1) as f64;
            bw = bw.min(cap / sharers);
        }
        Bandwidth(bw)
    }

    /// Register a flow on the path; returns its handle.
    pub fn begin_flow(&mut self, a: &Label, b: &Label) -> FlowHandle {
        let links = self.path(a, b);
        for link in &links {
            *self.flows.entry(link.clone()).or_insert(0) += 1;
        }
        FlowHandle { links }
    }

    pub fn end_flow(&mut self, h: &FlowHandle) {
        for link in &h.links {
            if let Some(n) = self.flows.get_mut(link) {
                *n = n.saturating_sub(1);
                if *n == 0 {
                    self.flows.remove(link);
                }
            }
        }
    }

    /// Live flow count on the busiest link of the path (diagnostics).
    pub fn congestion(&self, a: &Label, b: &Label) -> u32 {
        self.path(a, b)
            .iter()
            .map(|l| *self.flows.get(l).unwrap_or(&0))
            .max()
            .unwrap_or(0)
    }

    /// Transfer duration for `size` at the *current* effective bandwidth
    /// (excluding protocol overheads, which the storage adaptor adds).
    pub fn transfer_secs(&self, a: &Label, b: &Label, size: Bytes) -> f64 {
        let bw = self.effective_bandwidth(a, b).0;
        if bw <= 0.0 {
            return f64::INFINITY;
        }
        size.as_f64() / bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l(s: &str) -> Label {
        Label::new(s)
    }

    #[test]
    fn bandwidth_units() {
        assert_eq!(Bandwidth::mbps(1.0).0, 1024.0 * 1024.0);
        assert!((Bandwidth::gbit(8.0).0 - 1e9).abs() < 1.0);
    }

    #[test]
    fn loopback_when_same_label() {
        let net = Network::new();
        let a = l("xsede/tacc/lonestar");
        assert!(net.path(&a, &a).is_empty());
        assert_eq!(net.effective_bandwidth(&a, &a).0, net.loopback.0);
    }

    #[test]
    fn path_crosses_expected_links() {
        let net = Network::new();
        let p = net.path(&l("xsede/tacc/lonestar"), &l("osg/purdue"));
        assert_eq!(
            p,
            vec!["xsede", "xsede/tacc", "xsede/tacc/lonestar", "osg", "osg/purdue"]
        );
    }

    #[test]
    fn bottleneck_is_min_capacity() {
        let mut net = Network::new();
        net.set_uplink("xsede", Bandwidth::mbps(1000.0));
        net.set_uplink("xsede/tacc", Bandwidth::mbps(1000.0));
        net.set_uplink("xsede/tacc/lonestar", Bandwidth::mbps(1000.0));
        net.set_uplink("osg", Bandwidth::mbps(10.0)); // WAN bottleneck
        net.set_uplink("osg/purdue", Bandwidth::mbps(1000.0));
        let bw = net.effective_bandwidth(&l("xsede/tacc/lonestar"), &l("osg/purdue"));
        assert_eq!(bw.0, Bandwidth::mbps(10.0).0);
    }

    #[test]
    fn concurrent_flows_share_fairly() {
        let mut net = Network::new();
        net.set_default_uplink(Bandwidth::mbps(100.0));
        let a = l("site-a/m1");
        let b = l("site-b/m2");
        let solo = net.effective_bandwidth(&a, &b).0;
        let h1 = net.begin_flow(&a, &b);
        let with_one = net.effective_bandwidth(&a, &b).0;
        let _h2 = net.begin_flow(&a, &b);
        let with_two = net.effective_bandwidth(&a, &b).0;
        assert!((with_one - solo / 2.0).abs() < 1.0);
        assert!((with_two - solo / 3.0).abs() < 1.0);
        net.end_flow(&h1);
        assert!((net.effective_bandwidth(&a, &b).0 - solo / 2.0).abs() < 1.0);
    }

    #[test]
    fn transfer_secs_scales_linearly() {
        let mut net = Network::new();
        net.set_default_uplink(Bandwidth::mbps(100.0));
        let a = l("x/m1");
        let b = l("y/m2");
        let t1 = net.transfer_secs(&a, &b, Bytes::gb(1));
        let t2 = net.transfer_secs(&a, &b, Bytes::gb(2));
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        // 1 GiB at 100 MiB/s ≈ 10.24 s.
        assert!((t1 - 10.24).abs() < 0.1, "t1={t1}");
    }

    #[test]
    fn flow_counts_never_negative_property() {
        crate::prop::check_default(
            |rng| {
                // Random interleaving of begin/end operations.
                (0..crate::prop::gen::usize_in(rng, 1, 40))
                    .map(|_| rng.chance(0.6))
                    .collect::<Vec<bool>>()
            },
            |ops| {
                let mut net = Network::new();
                let a = l("p/q");
                let b = l("r/s");
                let mut handles = Vec::new();
                for begin in ops {
                    if *begin {
                        handles.push(net.begin_flow(&a, &b));
                    } else if let Some(h) = handles.pop() {
                        net.end_flow(&h);
                    }
                }
                // Draining all handles must restore zero congestion.
                for h in handles.drain(..) {
                    net.end_flow(&h);
                }
                if net.congestion(&a, &b) == 0 {
                    Ok(())
                } else {
                    Err("residual flows".into())
                }
            },
        );
    }
}
