//! Shared-network bandwidth model for the DCI simulation — the data
//! plane's hottest path, rebuilt around **interned link ids**.
//!
//! Transfers between two topology labels traverse the tree path between
//! them (up to the lowest common ancestor and back down). Every node has
//! an *uplink* with finite capacity; concurrent flows crossing a link
//! share its capacity equally (a coarse max–min model, in the spirit of
//! OptorSim-class grid simulators). The paper observes that "network
//! bandwidth within cluster and even more in WAN settings are
//! oversubscribed by a significant factor" — captured here by giving
//! WAN-level uplinks much lower capacity than intra-site links.
//!
//! Effective bandwidth is sampled when a flow starts (fixed for the flow
//! lifetime), which keeps the event count linear in the number of
//! transfers while preserving the contention *shape*: many concurrent
//! wide-area transfers slow each other down.
//!
//! # Interned data plane (perf)
//!
//! The seed keyed every uplink capacity, live-flow counter, and path
//! segment by freshly `join("/")`-allocated `String`s in `BTreeMap`s —
//! a `Vec<String>` allocation per path query, on the path that runs
//! once per transfer event in every experiment replay. The engine is
//! now id-based:
//!
//! * labels intern to [`NodeId`]s in a [`crate::topology::NodeArena`];
//!   a **link id is the node id of its child endpoint** ([`LinkId`]);
//! * uplink capacities and live-flow counts live in dense `Vec`s
//!   indexed by link id (O(1), no tree lookups);
//! * `(src, dst)` paths are computed once and memoized
//!   ([`Network::path_ids`]); steady-state path access is one hash of
//!   the id pair returning a boxed id slice;
//! * [`Network::effective_bandwidth_id`], [`Network::begin_flow_id`],
//!   [`Network::end_flow`], and [`Network::congestion_id`] are
//!   **allocation-free post-memo** — they iterate the memoized slice
//!   and index the dense vectors;
//! * [`Network::begin_flow_priced_id`] samples the flow's bandwidth
//!   *and* registers it in one walk, killing the
//!   `transfer_cost`-then-`begin_flow` double traversal on transfer
//!   start (see `storage::simstore::transfer_cost_flow`);
//! * [`FlowHandle`] is two node ids (`Copy`); [`Network::end_flow`]
//!   re-reads the memoized path instead of carrying owned strings.
//!
//! The label-keyed methods (`effective_bandwidth`, `begin_flow`,
//! `congestion`, `path`, `transfer_secs`) are kept as **compat shims**:
//! they probe the arena per label prefix (string slicing, no
//! allocation) and are property-tested identical to both the id walk
//! and the retained seed implementation in [`reference`]. New code
//! should intern once via [`Network::node`] and stay on ids.

use crate::coordination::FxMap;
use crate::topology::{Label, NodeArena, NodeId};
use crate::util::Bytes;

/// Bandwidth in bytes/second.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct Bandwidth(pub f64);

impl Bandwidth {
    /// Megabytes per second.
    pub fn mbps(mb: f64) -> Bandwidth {
        Bandwidth(mb * 1024.0 * 1024.0)
    }
    /// Gigabits per second (network convention).
    pub fn gbit(g: f64) -> Bandwidth {
        Bandwidth(g * 1e9 / 8.0)
    }
    pub fn bytes_per_sec(self) -> f64 {
        self.0
    }
}

/// A link is the uplink above a topology node, identified by that
/// node's interned id.
pub type LinkId = NodeId;

/// The network: interned topology nodes, dense per-link capacity and
/// live-flow vectors, and a `(src, dst)` → link-id-path memo table.
#[derive(Debug, Clone)]
pub struct Network {
    arena: NodeArena,
    /// Uplink capacity override per node (bytes/s); `NaN` = unset
    /// (falls back to `default_uplink`). Indexed by [`LinkId`].
    cap: Vec<f64>,
    /// Live flows per link. Indexed by [`LinkId`].
    flows: Vec<u32>,
    /// Per-attempt transfer failure probability per link (default
    /// 0.0 — reliable). Indexed by [`LinkId`]; composed over a path by
    /// [`Network::path_failure_rate`].
    fail: Vec<f64>,
    /// Default capacity for unlisted uplinks.
    default_uplink: Option<Bandwidth>,
    /// Loopback bandwidth when src == dst (shared-FS copy / local link).
    loopback: Bandwidth,
    /// (src, dst) -> crossed link ids, a-side then b-side, each in
    /// increasing depth order (the id image of [`Network::path`]).
    path_memo: FxMap<(u32, u32), Box<[u32]>>,
}

/// Handle for a started flow; pass back to [`Network::end_flow`]. Two
/// interned endpoints — the path is re-read from the memo table, so the
/// handle is `Copy` and allocation-free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowHandle {
    a: NodeId,
    b: NodeId,
}

impl Default for Network {
    fn default() -> Self {
        Network::new()
    }
}

impl Network {
    pub fn new() -> Network {
        Network {
            arena: NodeArena::new(),
            cap: vec![f64::NAN],
            flows: vec![0],
            fail: vec![0.0],
            default_uplink: Some(Bandwidth::mbps(100.0)),
            loopback: Bandwidth::mbps(400.0),
            path_memo: FxMap::default(),
        }
    }

    /// Grow the dense vectors to cover nodes interned since last call.
    fn sync(&mut self) {
        while self.cap.len() < self.arena.len() {
            self.cap.push(f64::NAN);
            self.flows.push(0);
            self.fail.push(0.0);
        }
    }

    /// Intern a label (O(1) full-string hash once known). The returned
    /// id is valid for this `Network` only.
    pub fn node(&mut self, label: &Label) -> NodeId {
        let id = self.arena.intern(label);
        self.sync();
        id
    }

    /// Full label path of an interned node (diagnostics/tests).
    pub fn link_name(&self, l: LinkId) -> &str {
        self.arena.path_str(l)
    }

    pub fn set_uplink(&mut self, label: &str, bw: Bandwidth) {
        let id = self.node(&Label::new(label));
        self.cap[id.index()] = bw.0;
    }

    pub fn set_default_uplink(&mut self, bw: Bandwidth) {
        self.default_uplink = Some(bw);
    }

    pub fn set_loopback(&mut self, bw: Bandwidth) {
        self.loopback = bw;
    }

    /// Set the per-attempt failure probability of one link (the uplink
    /// above `label`). Clamped to `[0, 1]`.
    pub fn set_link_failure_rate(&mut self, label: &str, rate: f64) {
        let id = self.node(&Label::new(label));
        self.fail[id.index()] = rate.clamp(0.0, 1.0);
    }

    /// Probability that a single attempt crossing the `(a, b)` path
    /// fails due to link faults: `1 − Π (1 − fail_l)` over the crossed
    /// links. Loopback (`a == b`) never fails.
    pub fn path_failure_rate(&mut self, a: NodeId, b: NodeId) -> f64 {
        if a == b {
            return 0.0;
        }
        self.ensure_path(a, b);
        let links = &self.path_memo[&(a.0, b.0)];
        let mut ok = 1.0;
        for &l in links.iter() {
            ok *= 1.0 - self.fail[l as usize];
        }
        1.0 - ok
    }

    /// Label-keyed [`Network::path_failure_rate`] (interns).
    pub fn path_failure_rate_labels(&mut self, a: &Label, b: &Label) -> f64 {
        let ai = self.node(a);
        let bi = self.node(b);
        self.path_failure_rate(ai, bi)
    }

    /// Total live flow registrations across every link — zero when all
    /// started flows have been ended (leak detection in chaos tests).
    pub fn total_live_flows(&self) -> u64 {
        self.flows.iter().map(|&n| n as u64).sum()
    }

    fn default_cap(&self) -> f64 {
        self.default_uplink.unwrap_or(Bandwidth::mbps(100.0)).0
    }

    fn cap_at(&self, idx: usize) -> f64 {
        let c = self.cap[idx];
        if c.is_nan() {
            self.default_cap()
        } else {
            c
        }
    }

    /// Memoize the (a, b) link path if not yet known (the only
    /// allocation in the id plane; every later access is one hash of
    /// the id pair).
    fn ensure_path(&mut self, a: NodeId, b: NodeId) {
        if self.path_memo.contains_key(&(a.0, b.0)) {
            return;
        }
        let links = Self::compute_path(&self.arena, a, b);
        self.path_memo.insert((a.0, b.0), links);
    }

    fn compute_path(arena: &NodeArena, a: NodeId, b: NodeId) -> Box<[u32]> {
        let lca = arena.lca(a, b);
        let cd = arena.depth(lca);
        let hops = (arena.depth(a) - cd) + (arena.depth(b) - cd);
        let mut links: Vec<u32> = Vec::with_capacity(hops as usize);
        // a-side then b-side, each in increasing depth order — the id
        // image of the string `path()` ordering.
        for side in [a, b] {
            let start = links.len();
            let mut n = side;
            while n != lca {
                links.push(n.0);
                n = arena.parent(n);
            }
            links[start..].reverse();
        }
        links.into_boxed_slice()
    }

    /// Link ids crossed between `a` and `b`, from the memo table
    /// (allocates only the returned `Vec` — diagnostics and property
    /// tests; the flow/bandwidth paths iterate the memo slice
    /// directly).
    pub fn path_ids(&mut self, a: NodeId, b: NodeId) -> Vec<LinkId> {
        self.ensure_path(a, b);
        self.path_memo[&(a.0, b.0)].iter().map(|&i| NodeId(i)).collect()
    }

    /// Hop count of the memoized (a, b) path — the zero-alloc path
    /// query.
    pub fn path_hops(&mut self, a: NodeId, b: NodeId) -> u32 {
        self.ensure_path(a, b);
        self.path_memo[&(a.0, b.0)].len() as u32
    }

    /// Effective bandwidth a new flow from `a` to `b` would get right
    /// now: the bottleneck link's fair share. Allocation-free
    /// post-memo.
    pub fn effective_bandwidth_id(&mut self, a: NodeId, b: NodeId) -> Bandwidth {
        if a == b {
            return self.loopback;
        }
        self.ensure_path(a, b);
        let dcap = self.default_cap();
        let links = &self.path_memo[&(a.0, b.0)];
        let mut bw = f64::INFINITY;
        for &l in links.iter() {
            let idx = l as usize;
            let cap = if self.cap[idx].is_nan() { dcap } else { self.cap[idx] };
            let sharers = (self.flows[idx] + 1) as f64;
            bw = bw.min(cap / sharers);
        }
        Bandwidth(bw)
    }

    /// Register a flow on the (a, b) path; returns its handle.
    /// Allocation-free post-memo.
    pub fn begin_flow_id(&mut self, a: NodeId, b: NodeId) -> FlowHandle {
        if a != b {
            self.ensure_path(a, b);
            let links = &self.path_memo[&(a.0, b.0)];
            for &l in links.iter() {
                self.flows[l as usize] += 1;
            }
        }
        FlowHandle { a, b }
    }

    /// Sample the bandwidth a new (a, b) flow gets *and* register it,
    /// in one path walk — the transfer-start fast path (the seed
    /// walked the path twice: `transfer_cost` then `begin_flow`).
    /// Identical numbers to `effective_bandwidth_id` followed by
    /// `begin_flow_id`.
    pub fn begin_flow_priced_id(&mut self, a: NodeId, b: NodeId) -> (FlowHandle, Bandwidth) {
        if a == b {
            return (FlowHandle { a, b }, self.loopback);
        }
        self.ensure_path(a, b);
        let dcap = self.default_cap();
        let links = &self.path_memo[&(a.0, b.0)];
        let mut bw = f64::INFINITY;
        for &l in links.iter() {
            let idx = l as usize;
            let cap = if self.cap[idx].is_nan() { dcap } else { self.cap[idx] };
            // Each link appears once per path, so reading the count
            // before this flow's own increment matches the seed's
            // sample-then-register order exactly.
            let sharers = (self.flows[idx] + 1) as f64;
            bw = bw.min(cap / sharers);
            self.flows[idx] += 1;
        }
        (FlowHandle { a, b }, Bandwidth(bw))
    }

    /// Release a flow. Allocation-free: re-reads the memoized path the
    /// matching `begin_flow*` created.
    pub fn end_flow(&mut self, h: &FlowHandle) {
        if h.a == h.b {
            return;
        }
        self.ensure_path(h.a, h.b);
        let links = &self.path_memo[&(h.a.0, h.b.0)];
        for &l in links.iter() {
            let idx = l as usize;
            self.flows[idx] = self.flows[idx].saturating_sub(1);
        }
    }

    /// Live flow count on the busiest link of the path (diagnostics).
    pub fn congestion_id(&mut self, a: NodeId, b: NodeId) -> u32 {
        if a == b {
            return 0;
        }
        self.ensure_path(a, b);
        let links = &self.path_memo[&(a.0, b.0)];
        links.iter().map(|&l| self.flows[l as usize]).max().unwrap_or(0)
    }

    // ---- label-keyed compat shims ---------------------------------

    /// Walk the (a, b) label path calling `f(capacity, flows)` per
    /// link: per-prefix arena probes over string slices, no
    /// allocations. A prefix the arena has never seen carries the
    /// default capacity and zero flows — exactly what the seed's
    /// `BTreeMap` misses meant. Returns whether any link was visited.
    fn for_each_link_str<F: FnMut(f64, u32)>(&self, a: &Label, b: &Label, mut f: F) -> bool {
        let common = a.common_prefix_len(b);
        let mut any = false;
        for lab in [a, b] {
            let s = lab.0.as_str();
            if s.is_empty() {
                continue;
            }
            let mut depth = 0usize;
            let ends = s.match_indices('/').map(|(i, _)| i).chain(std::iter::once(s.len()));
            for end in ends {
                depth += 1;
                if depth <= common {
                    continue;
                }
                any = true;
                match self.arena.lookup_str(&s[..end]) {
                    Some(id) => f(self.cap_at(id.index()), self.flows[id.index()]),
                    None => f(self.default_cap(), 0),
                }
            }
        }
        any
    }

    /// Links (child-label keyed) crossed between `a` and `b`. Compat
    /// shim allocating one `String` per link — tests and diagnostics;
    /// hot paths use [`Network::path_ids`] / the memo slice.
    pub fn path(&self, a: &Label, b: &Label) -> Vec<String> {
        let ac = a.components();
        let bc = b.components();
        let common = a.common_prefix_len(b);
        let mut links = Vec::new();
        for depth in common..ac.len() {
            links.push(ac[..=depth].join("/"));
        }
        for depth in common..bc.len() {
            links.push(bc[..=depth].join("/"));
        }
        links
    }

    /// Label-keyed [`Network::effective_bandwidth_id`] (compat shim;
    /// allocation-free via per-prefix arena probes).
    pub fn effective_bandwidth(&self, a: &Label, b: &Label) -> Bandwidth {
        let mut bw = f64::INFINITY;
        let any = self.for_each_link_str(a, b, |cap, flows| {
            bw = bw.min(cap / (flows + 1) as f64);
        });
        if any {
            Bandwidth(bw)
        } else {
            self.loopback
        }
    }

    /// Label-keyed [`Network::begin_flow_id`] (compat shim; interns).
    pub fn begin_flow(&mut self, a: &Label, b: &Label) -> FlowHandle {
        let ai = self.node(a);
        let bi = self.node(b);
        self.begin_flow_id(ai, bi)
    }

    /// Label-keyed [`Network::congestion_id`] (compat shim).
    pub fn congestion(&self, a: &Label, b: &Label) -> u32 {
        let mut m = 0u32;
        self.for_each_link_str(a, b, |_, flows| m = m.max(flows));
        m
    }

    /// Transfer duration for `size` at the *current* effective bandwidth
    /// (excluding protocol overheads, which the storage adaptor adds).
    pub fn transfer_secs(&self, a: &Label, b: &Label, size: Bytes) -> f64 {
        let bw = self.effective_bandwidth(a, b).0;
        if bw <= 0.0 {
            return f64::INFINITY;
        }
        size.as_f64() / bw
    }
}

pub mod reference {
    //! The seed's string-keyed data plane, retained verbatim as the
    //! property-test oracle and the `perf_micro` "before" baseline:
    //! uplinks and flow counts in `BTreeMap<String, _>`, a
    //! `Vec<String>` allocated per path query. Nothing in the system
    //! runs on this — it exists so the interned engine can be proved
    //! identical and measured against.

    use super::Bandwidth;
    use crate::topology::Label;
    use crate::util::Bytes;
    use std::collections::BTreeMap;

    /// The seed `Network`: per-uplink capacity and live flow counts
    /// keyed by full label paths.
    #[derive(Debug, Clone)]
    pub struct StringNetwork {
        uplink: BTreeMap<String, Bandwidth>,
        default_uplink: Option<Bandwidth>,
        flows: BTreeMap<String, u32>,
        loopback: Bandwidth,
    }

    /// The seed flow handle: owned link strings.
    #[derive(Debug, Clone)]
    pub struct StringFlowHandle {
        links: Vec<String>,
    }

    impl Default for StringNetwork {
        fn default() -> Self {
            StringNetwork::new()
        }
    }

    impl StringNetwork {
        pub fn new() -> StringNetwork {
            StringNetwork {
                uplink: BTreeMap::new(),
                default_uplink: Some(Bandwidth::mbps(100.0)),
                flows: BTreeMap::new(),
                loopback: Bandwidth::mbps(400.0),
            }
        }

        pub fn set_uplink(&mut self, label: &str, bw: Bandwidth) {
            self.uplink.insert(Label::new(label).0, bw);
        }

        pub fn set_default_uplink(&mut self, bw: Bandwidth) {
            self.default_uplink = Some(bw);
        }

        pub fn set_loopback(&mut self, bw: Bandwidth) {
            self.loopback = bw;
        }

        fn capacity(&self, link: &str) -> Bandwidth {
            self.uplink
                .get(link)
                .copied()
                .or(self.default_uplink)
                .unwrap_or(Bandwidth::mbps(100.0))
        }

        pub fn path(&self, a: &Label, b: &Label) -> Vec<String> {
            let ac = a.components();
            let bc = b.components();
            let common = a.common_prefix_len(b);
            let mut links = Vec::new();
            for depth in common..ac.len() {
                links.push(ac[..=depth].join("/"));
            }
            for depth in common..bc.len() {
                links.push(bc[..=depth].join("/"));
            }
            links
        }

        pub fn effective_bandwidth(&self, a: &Label, b: &Label) -> Bandwidth {
            let links = self.path(a, b);
            if links.is_empty() {
                return self.loopback;
            }
            let mut bw = f64::INFINITY;
            for link in &links {
                let cap = self.capacity(link).0;
                let sharers = (*self.flows.get(link).unwrap_or(&0) + 1) as f64;
                bw = bw.min(cap / sharers);
            }
            Bandwidth(bw)
        }

        pub fn begin_flow(&mut self, a: &Label, b: &Label) -> StringFlowHandle {
            let links = self.path(a, b);
            for link in &links {
                *self.flows.entry(link.clone()).or_insert(0) += 1;
            }
            StringFlowHandle { links }
        }

        pub fn end_flow(&mut self, h: &StringFlowHandle) {
            for link in &h.links {
                if let Some(n) = self.flows.get_mut(link) {
                    *n = n.saturating_sub(1);
                    if *n == 0 {
                        self.flows.remove(link);
                    }
                }
            }
        }

        pub fn congestion(&self, a: &Label, b: &Label) -> u32 {
            self.path(a, b)
                .iter()
                .map(|l| *self.flows.get(l).unwrap_or(&0))
                .max()
                .unwrap_or(0)
        }

        pub fn transfer_secs(&self, a: &Label, b: &Label, size: Bytes) -> f64 {
            let bw = self.effective_bandwidth(a, b).0;
            if bw <= 0.0 {
                return f64::INFINITY;
            }
            size.as_f64() / bw
        }

        /// Live flow table (oracle comparisons).
        pub fn flows(&self) -> &BTreeMap<String, u32> {
            &self.flows
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l(s: &str) -> Label {
        Label::new(s)
    }

    #[test]
    fn bandwidth_units() {
        assert_eq!(Bandwidth::mbps(1.0).0, 1024.0 * 1024.0);
        assert!((Bandwidth::gbit(8.0).0 - 1e9).abs() < 1.0);
    }

    #[test]
    fn loopback_when_same_label() {
        let mut net = Network::new();
        let a = l("xsede/tacc/lonestar");
        assert!(net.path(&a, &a).is_empty());
        assert_eq!(net.effective_bandwidth(&a, &a).0, net.loopback.0);
        let ai = net.node(&a);
        assert!(net.path_ids(ai, ai).is_empty());
        assert_eq!(net.effective_bandwidth_id(ai, ai).0, net.loopback.0);
    }

    #[test]
    fn path_crosses_expected_links() {
        let net = Network::new();
        let p = net.path(&l("xsede/tacc/lonestar"), &l("osg/purdue"));
        assert_eq!(
            p,
            vec!["xsede", "xsede/tacc", "xsede/tacc/lonestar", "osg", "osg/purdue"]
        );
    }

    #[test]
    fn path_ids_mirror_string_path() {
        let mut net = Network::new();
        let a = l("xsede/tacc/lonestar");
        let b = l("osg/purdue");
        let (ai, bi) = (net.node(&a), net.node(&b));
        let by_id: Vec<String> = net
            .path_ids(ai, bi)
            .iter()
            .map(|&id| net.link_name(id).to_string())
            .collect();
        assert_eq!(by_id, net.path(&a, &b));
        assert_eq!(net.path_hops(ai, bi), 5);
        // Partial overlap: same site, different machine.
        let c = l("xsede/tacc/stampede");
        let ci = net.node(&c);
        let by_id: Vec<String> = net
            .path_ids(ai, ci)
            .iter()
            .map(|&id| net.link_name(id).to_string())
            .collect();
        assert_eq!(by_id, net.path(&a, &c));
        // Ancestor/descendant: one side of the walk is empty.
        let tacc = l("xsede/tacc");
        let ti = net.node(&tacc);
        let by_id: Vec<String> = net
            .path_ids(ti, ai)
            .iter()
            .map(|&id| net.link_name(id).to_string())
            .collect();
        assert_eq!(by_id, net.path(&tacc, &a));
    }

    #[test]
    fn bottleneck_is_min_capacity() {
        let mut net = Network::new();
        net.set_uplink("xsede", Bandwidth::mbps(1000.0));
        net.set_uplink("xsede/tacc", Bandwidth::mbps(1000.0));
        net.set_uplink("xsede/tacc/lonestar", Bandwidth::mbps(1000.0));
        net.set_uplink("osg", Bandwidth::mbps(10.0)); // WAN bottleneck
        net.set_uplink("osg/purdue", Bandwidth::mbps(1000.0));
        let bw = net.effective_bandwidth(&l("xsede/tacc/lonestar"), &l("osg/purdue"));
        assert_eq!(bw.0, Bandwidth::mbps(10.0).0);
        let (a, b) = (net.node(&l("xsede/tacc/lonestar")), net.node(&l("osg/purdue")));
        assert_eq!(net.effective_bandwidth_id(a, b).0, Bandwidth::mbps(10.0).0);
    }

    #[test]
    fn concurrent_flows_share_fairly() {
        let mut net = Network::new();
        net.set_default_uplink(Bandwidth::mbps(100.0));
        let a = l("site-a/m1");
        let b = l("site-b/m2");
        let solo = net.effective_bandwidth(&a, &b).0;
        let h1 = net.begin_flow(&a, &b);
        let with_one = net.effective_bandwidth(&a, &b).0;
        let _h2 = net.begin_flow(&a, &b);
        let with_two = net.effective_bandwidth(&a, &b).0;
        assert!((with_one - solo / 2.0).abs() < 1.0);
        assert!((with_two - solo / 3.0).abs() < 1.0);
        net.end_flow(&h1);
        assert!((net.effective_bandwidth(&a, &b).0 - solo / 2.0).abs() < 1.0);
    }

    #[test]
    fn priced_begin_equals_sample_then_register() {
        let mut net = Network::new();
        net.set_uplink("x", Bandwidth::mbps(50.0));
        let (a, b) = (net.node(&l("x/m1")), net.node(&l("y/m2")));
        // Pre-load one flow so sharers > 1.
        let _h0 = net.begin_flow_id(a, b);
        let sampled = net.effective_bandwidth_id(a, b);
        let (h, priced) = net.begin_flow_priced_id(a, b);
        assert_eq!(sampled.0.to_bits(), priced.0.to_bits());
        assert_eq!(net.congestion_id(a, b), 2);
        net.end_flow(&h);
        assert_eq!(net.congestion_id(a, b), 1);
        // Loopback: priced on self is the loopback rate, no flows.
        let (h_self, bw_self) = net.begin_flow_priced_id(a, a);
        assert_eq!(bw_self.0, net.loopback.0);
        net.end_flow(&h_self);
        assert_eq!(net.congestion_id(a, b), 1);
    }

    #[test]
    fn link_failure_rates_compose_over_the_path() {
        let mut net = Network::new();
        let (a, b) = (net.node(&l("xsede/tacc/lonestar")), net.node(&l("osg/purdue")));
        // Default: every link reliable.
        assert_eq!(net.path_failure_rate(a, b), 0.0);
        assert_eq!(net.path_failure_rate(a, a), 0.0);
        // One lossy WAN link.
        net.set_link_failure_rate("osg", 0.1);
        assert!((net.path_failure_rate(a, b) - 0.1).abs() < 1e-12);
        // Two independent lossy links compose: 1 - 0.9 * 0.8 = 0.28.
        net.set_link_failure_rate("xsede", 0.2);
        assert!((net.path_failure_rate(a, b) - 0.28).abs() < 1e-12);
        // A path avoiding both stays clean.
        let c = net.node(&l("xsede/tacc/stampede"));
        assert_eq!(net.path_failure_rate(a, c), 0.0);
        // Label shim agrees; rates clamp to [0, 1].
        assert!(
            (net.path_failure_rate_labels(&l("xsede/tacc/lonestar"), &l("osg/purdue")) - 0.28)
                .abs()
                < 1e-12
        );
        net.set_link_failure_rate("osg", 7.0);
        assert_eq!(net.path_failure_rate(a, b), 1.0);
    }

    #[test]
    fn total_live_flows_tracks_begin_end() {
        let mut net = Network::new();
        let (a, b) = (net.node(&l("x/m1")), net.node(&l("y/m2")));
        assert_eq!(net.total_live_flows(), 0);
        let h1 = net.begin_flow_id(a, b);
        let h2 = net.begin_flow_id(a, b);
        // 4 links on the path, 2 flows each.
        assert_eq!(net.total_live_flows(), 8);
        net.end_flow(&h1);
        net.end_flow(&h2);
        assert_eq!(net.total_live_flows(), 0);
    }

    #[test]
    fn transfer_secs_scales_linearly() {
        let mut net = Network::new();
        net.set_default_uplink(Bandwidth::mbps(100.0));
        let a = l("x/m1");
        let b = l("y/m2");
        let t1 = net.transfer_secs(&a, &b, Bytes::gb(1));
        let t2 = net.transfer_secs(&a, &b, Bytes::gb(2));
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        // 1 GiB at 100 MiB/s ≈ 10.24 s.
        assert!((t1 - 10.24).abs() < 0.1, "t1={t1}");
    }

    #[test]
    fn flow_counts_never_negative_property() {
        crate::prop::check_default(
            |rng| {
                // Random interleaving of begin/end operations.
                (0..crate::prop::gen::usize_in(rng, 1, 40))
                    .map(|_| rng.chance(0.6))
                    .collect::<Vec<bool>>()
            },
            |ops| {
                let mut net = Network::new();
                let a = l("p/q");
                let b = l("r/s");
                let mut handles = Vec::new();
                for begin in ops {
                    if *begin {
                        handles.push(net.begin_flow(&a, &b));
                    } else if let Some(h) = handles.pop() {
                        net.end_flow(&h);
                    }
                }
                // Draining all handles must restore zero congestion.
                for h in handles.drain(..) {
                    net.end_flow(&h);
                }
                if net.congestion(&a, &b) == 0 {
                    Ok(())
                } else {
                    Err("residual flows".into())
                }
            },
        );
    }

    /// Tentpole acceptance: on randomized topologies and random flow
    /// interleavings, the id plane, the label compat shims, and the
    /// retained seed engine ([`reference::StringNetwork`]) agree
    /// bitwise — paths, bandwidths, congestion, and the full live-flow
    /// table after every operation.
    #[test]
    fn id_plane_matches_string_reference_property() {
        use super::reference::{StringFlowHandle, StringNetwork};

        #[derive(Debug)]
        enum Op {
            Begin(usize, usize),
            End(usize),
            Check(usize, usize),
        }

        crate::prop::check_default(
            |rng| {
                let mk = |rng: &mut crate::rng::Rng| {
                    let depth = crate::prop::gen::usize_in(rng, 0, 5);
                    let parts: Vec<String> =
                        (0..depth).map(|d| format!("s{}", rng.below(3 + d as u64))).collect();
                    parts.join("/")
                };
                let labels: Vec<String> =
                    (0..crate::prop::gen::usize_in(rng, 2, 7)).map(|_| mk(rng)).collect();
                let uplinks: Vec<(String, f64)> = (0..crate::prop::gen::usize_in(rng, 0, 6))
                    .map(|_| (mk(rng), rng.range_f64(1.0, 500.0)))
                    .collect();
                let default_mb = rng.range_f64(10.0, 200.0);
                let n = labels.len();
                let ops: Vec<Op> = (0..crate::prop::gen::usize_in(rng, 1, 40))
                    .map(|_| {
                        let a = rng.below(n as u64) as usize;
                        let b = rng.below(n as u64) as usize;
                        match rng.below(3) {
                            0 => Op::Begin(a, b),
                            1 => Op::End(rng.below(64) as usize),
                            _ => Op::Check(a, b),
                        }
                    })
                    .collect();
                (labels, uplinks, default_mb, ops)
            },
            |(labels, uplinks, default_mb, ops)| {
                let labels: Vec<Label> = labels.iter().map(|s| Label::new(s)).collect();
                let mut net = Network::new();
                let mut sref = StringNetwork::new();
                net.set_default_uplink(Bandwidth::mbps(*default_mb));
                sref.set_default_uplink(Bandwidth::mbps(*default_mb));
                for (label, mb) in uplinks {
                    net.set_uplink(label, Bandwidth::mbps(*mb));
                    sref.set_uplink(label, Bandwidth::mbps(*mb));
                }
                let ids: Vec<NodeId> = labels.iter().map(|la| net.node(la)).collect();
                let mut handles: Vec<(FlowHandle, StringFlowHandle)> = Vec::new();
                for op in ops {
                    match op {
                        Op::Begin(a, b) => {
                            let h = net.begin_flow_id(ids[*a], ids[*b]);
                            let hr = sref.begin_flow(&labels[*a], &labels[*b]);
                            handles.push((h, hr));
                        }
                        Op::End(i) => {
                            if !handles.is_empty() {
                                let (h, hr) = handles.remove(i % handles.len());
                                net.end_flow(&h);
                                sref.end_flow(&hr);
                            }
                        }
                        Op::Check(..) => {}
                    }
                    // After every op: full agreement on paths, rates,
                    // and congestion for the checked pair (or the last
                    // touched pair for Begin/End).
                    let (a, b) = match op {
                        Op::Begin(a, b) | Op::Check(a, b) => (*a, *b),
                        Op::End(_) => (0, labels.len() - 1),
                    };
                    let (la, lb) = (&labels[a], &labels[b]);
                    let (ia, ib) = (ids[a], ids[b]);
                    let want = sref.effective_bandwidth(la, lb).0;
                    let got_id = net.effective_bandwidth_id(ia, ib).0;
                    let got_str = net.effective_bandwidth(la, lb).0;
                    if want.to_bits() != got_id.to_bits() {
                        return Err(format!("bw({la},{lb}): ref {want} != id {got_id}"));
                    }
                    if want.to_bits() != got_str.to_bits() {
                        return Err(format!("bw({la},{lb}): ref {want} != shim {got_str}"));
                    }
                    if sref.congestion(la, lb) != net.congestion_id(ia, ib)
                        || sref.congestion(la, lb) != net.congestion(la, lb)
                    {
                        return Err(format!("congestion({la},{lb}) diverges"));
                    }
                    let id_path: Vec<String> = net
                        .path_ids(ia, ib)
                        .iter()
                        .map(|&id| net.link_name(id).to_string())
                        .collect();
                    if id_path != sref.path(la, lb) {
                        return Err(format!(
                            "path({la},{lb}): id {id_path:?} != ref {:?}",
                            sref.path(la, lb)
                        ));
                    }
                }
                // Final flow tables agree: every reference entry matches
                // the dense vector, and every non-zero dense count has a
                // reference entry.
                for (link, n) in sref.flows() {
                    let id = net
                        .arena
                        .lookup_str(link)
                        .ok_or_else(|| format!("link {link} never interned"))?;
                    if net.flows[id.index()] != *n {
                        return Err(format!(
                            "flows[{link}]: dense {} != ref {n}",
                            net.flows[id.index()]
                        ));
                    }
                }
                for (idx, n) in net.flows.iter().enumerate() {
                    if *n > 0 {
                        let name = net.arena.path_str(NodeId(idx as u32));
                        if sref.flows().get(name).copied().unwrap_or(0) != *n {
                            return Err(format!("dense flows[{name}]={n} missing in ref"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
