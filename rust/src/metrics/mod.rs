//! Metrics collection and reporting: the paper's timing decomposition
//! (T_Q, T_S, T_X, T_R, T_C, T_D — §6.1), per-CU records, run
//! timelines (Fig. 13), plain-text tables, and CSV output.

use crate::util::{fmt_secs, mean, stddev};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Per-Compute-Unit record backing Figs. 10, 12, 13.
#[derive(Debug, Clone, Default)]
pub struct CuRecord {
    pub cu: String,
    pub machine: String,
    pub t_submitted: f64,
    pub t_start: f64,
    pub t_end: f64,
    pub staging_s: f64,
    pub compute_s: f64,
}

impl CuRecord {
    pub fn total_s(&self) -> f64 {
        self.t_end - self.t_start
    }

    /// Wait in queue before dispatch (the T_Q term of the timing
    /// decomposition): submission to the start of input staging.
    pub fn wait_s(&self) -> f64 {
        self.t_start - self.t_submitted
    }
}

/// A right-continuous step function recorded as `(t, value)` points:
/// the series holds `value` from `t` until the next point. Backs the
/// open-loop queueing telemetry (queue-depth and per-pilot busy-slot
/// series) and its time-weighted utilization means.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct StepSeries {
    pts: Vec<(f64, f64)>,
}

impl StepSeries {
    /// Record the value taking effect at `t`. Timestamps must be
    /// non-decreasing (the DES emits them in order; asserted in debug
    /// builds). Same-instant updates overwrite the previous point —
    /// only the settled level at each instant counts.
    pub fn push(&mut self, t: f64, v: f64) {
        if let Some(last) = self.pts.last_mut() {
            debug_assert!(t >= last.0, "StepSeries time went backwards");
            if last.0.to_bits() == t.to_bits() {
                last.1 = v;
                return;
            }
        }
        self.pts.push((t, v));
    }

    pub fn is_empty(&self) -> bool {
        self.pts.is_empty()
    }

    pub fn len(&self) -> usize {
        self.pts.len()
    }

    pub fn points(&self) -> &[(f64, f64)] {
        &self.pts
    }

    /// Last recorded value (0.0 when empty).
    pub fn last_value(&self) -> f64 {
        self.pts.last().map(|p| p.1).unwrap_or(0.0)
    }

    /// Maximum recorded value (0.0 when empty).
    pub fn max_value(&self) -> f64 {
        self.pts.iter().map(|p| p.1).fold(0.0, f64::max)
    }

    /// Time-weighted mean over the window `[a, b]`: the integral of
    /// the step function divided by the window length. The value in
    /// force at `a` is the last point at or before it (0.0 before the
    /// first point). Returns 0.0 for an empty or inverted window.
    pub fn time_weighted_mean(&self, a: f64, b: f64) -> f64 {
        if !(b > a) {
            return 0.0;
        }
        let mut integral = 0.0;
        let mut cur_t = a;
        let mut cur_v = 0.0;
        for &(t, v) in &self.pts {
            if t <= a {
                cur_v = v;
                continue;
            }
            if t >= b {
                break;
            }
            integral += cur_v * (t - cur_t);
            cur_t = t;
            cur_v = v;
        }
        integral += cur_v * (b - cur_t);
        integral / (b - a)
    }
}

/// Timeline event kinds for the Fig. 13 time series.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimelineEvent {
    PilotActive,
    CuStarted,
    CuFinished,
}

/// An experiment run's recorded facts.
#[derive(Debug, Default, Clone)]
pub struct RunMetrics {
    pub cu_records: Vec<CuRecord>,
    pub timeline: Vec<(f64, String, TimelineEvent)>,
    /// Named scalar results (T_D, T_R, makespan, …).
    pub scalars: BTreeMap<String, f64>,
    /// Named step-function series (`queue_depth`, `busy:<pilot>`, …).
    /// Empty unless a driver samples into it — the open-loop engine
    /// does when its telemetry switch is on.
    pub series: BTreeMap<String, StepSeries>,
}

impl RunMetrics {
    pub fn record_cu(&mut self, rec: CuRecord) {
        self.cu_records.push(rec);
    }

    pub fn mark(&mut self, t: f64, who: &str, ev: TimelineEvent) {
        self.timeline.push((t, who.to_string(), ev));
    }

    pub fn set_scalar(&mut self, name: &str, value: f64) {
        self.scalars.insert(name.to_string(), value);
    }

    /// Named scalar, `f64::NAN` when absent. The NaN is a sentinel for
    /// display code; arithmetic callers should use [`Self::try_scalar`]
    /// so a missing scalar can't silently poison a mean or total.
    pub fn scalar(&self, name: &str) -> f64 {
        *self.scalars.get(name).unwrap_or(&f64::NAN)
    }

    /// Named scalar, `None` when absent — the NaN-free accessor.
    pub fn try_scalar(&self, name: &str) -> Option<f64> {
        self.scalars.get(name).copied()
    }

    /// Record a step-series sample (the series is created on first
    /// use).
    pub fn sample_series(&mut self, name: &str, t: f64, v: f64) {
        self.series.entry(name.to_string()).or_default().push(t, v);
    }

    /// A recorded step series by name, if any samples landed in it.
    pub fn get_series(&self, name: &str) -> Option<&StepSeries> {
        self.series.get(name)
    }

    /// Per-CU wait in queue (T_Q), in record order.
    pub fn wait_times(&self) -> Vec<f64> {
        self.cu_records.iter().map(|r| r.wait_s()).collect()
    }

    /// Mean wait-in-queue across CU records (0.0 when empty).
    pub fn mean_wait(&self) -> f64 {
        mean(&self.wait_times())
    }

    /// Makespan across CU records (first submission to last finish).
    pub fn makespan(&self) -> f64 {
        let start = self
            .cu_records
            .iter()
            .map(|r| r.t_submitted)
            .fold(f64::INFINITY, f64::min);
        let end = self.cu_records.iter().map(|r| r.t_end).fold(0.0, f64::max);
        if start.is_finite() {
            (end - start).max(0.0)
        } else {
            0.0
        }
    }

    /// CUs per machine (Fig. 12 lower panel).
    pub fn distribution(&self) -> BTreeMap<String, usize> {
        let mut m = BTreeMap::new();
        for r in &self.cu_records {
            *m.entry(r.machine.clone()).or_insert(0) += 1;
        }
        m
    }

    /// Mean ± std of CU compute times per machine.
    pub fn runtime_stats(&self) -> BTreeMap<String, (f64, f64)> {
        let mut per: BTreeMap<String, Vec<f64>> = BTreeMap::new();
        for r in &self.cu_records {
            per.entry(r.machine.clone()).or_default().push(r.compute_s);
        }
        per.into_iter().map(|(k, v)| (k, (mean(&v), stddev(&v)))).collect()
    }

    /// Sampled "active CUs" curve: at each event timestamp, how many
    /// CUs are running (Fig. 13's Active CUs series). Deltas at the
    /// same timestamp are coalesced into one point holding the settled
    /// level — a same-instant finish/start pair contributes no
    /// transient dip or spike — and the sort is NaN-safe
    /// (`f64::total_cmp`), so a corrupt timestamp can't panic the
    /// metrics pass.
    pub fn active_curve(&self) -> Vec<(f64, i64)> {
        let mut deltas: Vec<(f64, i64)> = Vec::new();
        for (t, _, ev) in &self.timeline {
            match ev {
                TimelineEvent::CuStarted => deltas.push((*t, 1)),
                TimelineEvent::CuFinished => deltas.push((*t, -1)),
                _ => {}
            }
        }
        deltas.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut out: Vec<(f64, i64)> = Vec::new();
        let mut level = 0i64;
        for (t, d) in deltas {
            level += d;
            match out.last_mut() {
                Some(last) if last.0.total_cmp(&t).is_eq() => last.1 = level,
                _ => out.push((t, level)),
            }
        }
        out
    }

    /// Cumulative finished-CU curve per machine (Fig. 13 series).
    pub fn finished_curve(&self, machine: &str) -> Vec<(f64, u64)> {
        let mut ts: Vec<f64> = self
            .timeline
            .iter()
            .filter(|(_, who, ev)| *ev == TimelineEvent::CuFinished && who == machine)
            .map(|(t, _, _)| *t)
            .collect();
        // NaN-safe total order: a corrupt timestamp sorts last instead
        // of panicking the metrics pass (same contract as
        // `active_curve`).
        ts.sort_by(|a, b| a.total_cmp(b));
        ts.into_iter().enumerate().map(|(i, t)| (t, i as u64 + 1)).collect()
    }
}

/// Fixed-width plain-text table (the "prints the same rows the paper
/// reports" output device).
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// CSV form (same cells, comma-joined with quoting).
    pub fn to_csv(&self) -> String {
        fn esc(s: &str) -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write the CSV next to the experiment outputs.
    pub fn save_csv(&self, dir: &std::path::Path, name: &str) -> anyhow::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Convenience: seconds cell.
pub fn secs_cell(s: f64) -> String {
    format!("{} ({s:.0}s)", fmt_secs(s))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(machine: &str, sub: f64, start: f64, end: f64, staging: f64) -> CuRecord {
        CuRecord {
            cu: crate::util::next_id("cu"),
            machine: machine.into(),
            t_submitted: sub,
            t_start: start,
            t_end: end,
            staging_s: staging,
            compute_s: end - start - staging,
        }
    }

    #[test]
    fn makespan_and_distribution() {
        let mut m = RunMetrics::default();
        m.record_cu(rec("lonestar", 0.0, 10.0, 110.0, 20.0));
        m.record_cu(rec("lonestar", 0.0, 15.0, 95.0, 10.0));
        m.record_cu(rec("stampede", 5.0, 50.0, 300.0, 100.0));
        assert_eq!(m.makespan(), 300.0);
        let d = m.distribution();
        assert_eq!(d["lonestar"], 2);
        assert_eq!(d["stampede"], 1);
        let stats = m.runtime_stats();
        assert!((stats["lonestar"].0 - 75.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = RunMetrics::default();
        assert_eq!(m.makespan(), 0.0);
        assert!(m.distribution().is_empty());
        assert!(m.scalar("absent").is_nan());
        assert_eq!(m.try_scalar("absent"), None);
    }

    #[test]
    fn try_scalar_is_the_nan_free_accessor() {
        let mut m = RunMetrics::default();
        m.set_scalar("t_d", 12.5);
        assert_eq!(m.try_scalar("t_d"), Some(12.5));
        assert_eq!(m.scalar("t_d"), 12.5);
        // The NaN sentinel never leaks through try_scalar, so summing
        // over present scalars stays finite even when one is missing.
        let total: f64 = ["t_d", "absent"].iter().filter_map(|k| m.try_scalar(k)).sum();
        assert_eq!(total, 12.5);
    }

    #[test]
    fn finished_curve_tolerates_nan_timestamps() {
        let mut m = RunMetrics::default();
        m.mark(f64::NAN, "lonestar", TimelineEvent::CuFinished);
        m.mark(3.0, "lonestar", TimelineEvent::CuFinished);
        // Must not panic; the finite point sorts first.
        let curve = m.finished_curve("lonestar");
        assert_eq!(curve.len(), 2);
        assert_eq!(curve[0], (3.0, 1));
    }

    #[test]
    fn active_curve_tracks_concurrency() {
        let mut m = RunMetrics::default();
        m.mark(1.0, "a", TimelineEvent::CuStarted);
        m.mark(2.0, "b", TimelineEvent::CuStarted);
        m.mark(3.0, "a", TimelineEvent::CuFinished);
        m.mark(4.0, "b", TimelineEvent::CuFinished);
        let curve = m.active_curve();
        assert_eq!(curve, vec![(1.0, 1), (2.0, 2), (3.0, 1), (4.0, 0)]);
    }

    #[test]
    fn active_curve_coalesces_same_instant_deltas() {
        let mut m = RunMetrics::default();
        m.mark(1.0, "a", TimelineEvent::CuStarted);
        m.mark(2.0, "a", TimelineEvent::CuFinished);
        m.mark(2.0, "b", TimelineEvent::CuStarted);
        m.mark(3.0, "b", TimelineEvent::CuFinished);
        // The finish/start pair at t=2 is one net point at level 1 —
        // no transient 0 between them.
        assert_eq!(m.active_curve(), vec![(1.0, 1), (2.0, 1), (3.0, 0)]);
    }

    #[test]
    fn active_curve_peak_ignores_transient_same_instant_levels() {
        let mut m = RunMetrics::default();
        m.mark(1.0, "a", TimelineEvent::CuStarted);
        m.mark(2.0, "b", TimelineEvent::CuStarted);
        m.mark(2.0, "a", TimelineEvent::CuFinished);
        let curve = m.active_curve();
        // The start/finish pair at t=2 settles at level 1; the old
        // implementation emitted a phantom peak of 2.
        assert_eq!(curve, vec![(1.0, 1), (2.0, 1)]);
        assert_eq!(curve.iter().map(|&(_, l)| l).max(), Some(1));
    }

    #[test]
    fn active_curve_tolerates_nan_timestamps() {
        let mut m = RunMetrics::default();
        m.mark(f64::NAN, "a", TimelineEvent::CuStarted);
        m.mark(1.0, "b", TimelineEvent::CuStarted);
        // Must not panic; both points survive (NaN sorts last under
        // the total order).
        assert_eq!(m.active_curve().len(), 2);
    }

    #[test]
    fn step_series_time_weighted_mean_and_extremes() {
        let mut s = StepSeries::default();
        s.push(0.0, 0.0);
        s.push(10.0, 4.0);
        s.push(20.0, 2.0);
        // [0,10): 0, [10,20): 4, [20,30): 2 → mean over [0,30] = 2.
        assert!((s.time_weighted_mean(0.0, 30.0) - 2.0).abs() < 1e-12);
        // A window starting mid-segment picks up the value in force.
        assert!((s.time_weighted_mean(15.0, 25.0) - 3.0).abs() < 1e-12);
        assert_eq!(s.max_value(), 4.0);
        assert_eq!(s.last_value(), 2.0);
        assert_eq!(s.time_weighted_mean(5.0, 5.0), 0.0);
        // Same-instant update settles to the last value pushed.
        s.push(20.0, 7.0);
        assert_eq!(s.last_value(), 7.0);
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn wait_accessors_follow_records() {
        let mut m = RunMetrics::default();
        m.record_cu(rec("lonestar", 0.0, 10.0, 110.0, 20.0));
        m.record_cu(rec("lonestar", 5.0, 35.0, 95.0, 10.0));
        assert_eq!(m.wait_times(), vec![10.0, 30.0]);
        assert_eq!(m.mean_wait(), 20.0);
        assert_eq!(RunMetrics::default().mean_wait(), 0.0);
    }

    #[test]
    fn finished_curve_is_cumulative_per_machine() {
        let mut m = RunMetrics::default();
        m.mark(5.0, "lonestar", TimelineEvent::CuFinished);
        m.mark(9.0, "lonestar", TimelineEvent::CuFinished);
        m.mark(7.0, "stampede", TimelineEvent::CuFinished);
        assert_eq!(m.finished_curve("lonestar"), vec![(5.0, 1), (9.0, 2)]);
        assert_eq!(m.finished_curve("stampede"), vec![(7.0, 1)]);
        assert!(m.finished_curve("trestles").is_empty());
    }

    #[test]
    fn table_renders_aligned_and_csv_quotes() {
        let mut t = Table::new("Fig 7", &["backend", "T_S (s)"]);
        t.row(vec!["SRM/GridFTP".into(), "12.5".into()]);
        t.row(vec!["a,b".into(), "1".into()]);
        let rendered = t.render();
        assert!(rendered.contains("== Fig 7 =="));
        assert!(rendered.contains("SRM/GridFTP"));
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_saves_to_disk() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into()]);
        let dir = std::env::temp_dir().join(format!("pd-metrics-{}", std::process::id()));
        let p = t.save_csv(&dir, "test").unwrap();
        assert!(std::fs::read_to_string(p).unwrap().contains("1"));
        let _ = std::fs::remove_dir_all(dir);
    }
}
