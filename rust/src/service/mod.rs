//! The Pilot-API (paper §4.3): `PilotComputeService`,
//! `PilotDataService`, and the `ComputeDataService` workload manager.
//!
//! This is the *local execution mode* of the system: Pilot-Computes are
//! real agent threads on this host pulling Compute-Units from the
//! coordination store's queues, Pilot-Data are real directories managed
//! through the `file://` adaptor, and Compute-Units execute real work
//! through a pluggable [`Executor`] — either a shell command or the
//! PJRT-compiled alignment pipeline (`runtime::AlignExecutor`). Python
//! is never on this path.
//!
//! The sim driver in [`crate::experiments`] reuses the same scheduler,
//! state machines, and store against simulated time; this module is the
//! wall-clock counterpart, which is exactly the paper's
//! interoperability claim: one abstraction, several infrastructures.
//!
//! Coordination is **event-driven** end to end (paper §4.2): agents
//! park in a blocking two-queue pop
//! ([`crate::coordination::events`]), store outages park them on the
//! availability wait, `wait_all` parks on a progress condvar, and
//! shutdown wakes everyone via queue sentinels + a waiter broadcast.
//! There is no fixed-interval sleep/poll loop anywhere on this path —
//! idle cost is zero regardless of agent count.
//!
//! A Pilot-Compute marshals *multiple* resource slots (paper §3–4), so
//! its agent is a **worker pool**: `min(cores, worker cap)` identical
//! worker threads (cap = `PD_MAX_WORKERS`, default 32 — a pilot
//! marshaling thousands of cores does not spawn thousands of 1:1 OS
//! threads) all parked in the same blocking pop over [own queue,
//! global queue]. The store's wake-one handoff delivers each pushed CU
//! to exactly one of them (no thundering herd across the pool), so a
//! pilot executes up to `min(cores, cap)` CUs concurrently and
//! throughput scales with slots, not with pilot count. `busy_slots` is
//! shared pool state maintained under the manager-state lock at
//! dispatch/completion and mirrored into the store's pilot record,
//! keeping the scheduler's free-slot filtering and the durable view
//! consistent; a **slot semaphore** in `run_cu` (condvar wait until
//! `busy + need ≤ cores`) keeps `busy ≤ cores` even when workers are
//! fewer than slots or CUs span multiple cores.

use crate::coordination::events::Event;
use crate::coordination::{keys, Store};
use crate::datamgmt::{self, LossCause, ModeKind};
use crate::pilot::{
    ManagerState, PilotCompute, PilotComputeDescription, PilotData, PilotDataDescription,
    PilotState,
};
use crate::scheduler::{AffinityScheduler, Placement, SchedContext, Scheduler};
use crate::storage::localfs::LocalFs;
use crate::storage::BackendKind;
use crate::topology::{Label, Topology};
use crate::unit::{ComputeUnit, ComputeUnitDescription, CuState, DataUnit, DataUnitDescription, DuState};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Sentinel pushed onto an agent's own queue to wake it without
/// handing it work (shutdown). Never a valid CU id.
const AGENT_WAKE: &str = "__agent_wake__";

/// Default ceiling on OS worker threads per pilot pool (override with
/// the `PD_MAX_WORKERS` env var or [`PilotSystem::set_worker_cap`]).
/// A pilot marshaling thousands of cores should not spawn thousands of
/// 1:1 threads; the slot semaphore in `run_cu` keeps `busy ≤ cores`
/// regardless of how many workers drive the slots.
pub const DEFAULT_WORKER_CAP: u32 = 32;

/// Default agent-liveness lease TTL in milliseconds (override with
/// [`PilotSystem::set_heartbeat_ttl_ms`]). An agent pool refreshes its
/// pilot's heartbeat key (`pd:pilot:hb:<id>`) at every queue
/// interaction; a lease older than the TTL marks the agent dead at
/// dispatch time, so no new work is routed onto a queue nothing pops.
/// Generous by default: an *idle* pool parks in the blocking pop
/// without refreshing, so the TTL must exceed the longest expected
/// idle gap between submissions.
pub const DEFAULT_HB_TTL_MS: u64 = 30_000;

/// Result of executing one Compute-Unit.
#[derive(Debug, Clone, Default)]
pub struct ExecResult {
    pub stdout: String,
    /// Seconds of pure execution (excluding staging).
    pub compute_s: f64,
}

/// Pluggable CU execution engine.
pub trait Executor: Send + Sync {
    fn execute(&self, cu: &ComputeUnitDescription, sandbox: &Path) -> anyhow::Result<ExecResult>;
}

/// Runs the CU's executable as a real subprocess in the sandbox.
pub struct ShellExecutor;

impl Executor for ShellExecutor {
    fn execute(&self, cu: &ComputeUnitDescription, sandbox: &Path) -> anyhow::Result<ExecResult> {
        let t0 = Instant::now();
        let out = std::process::Command::new(&cu.executable)
            .args(&cu.arguments)
            .current_dir(sandbox)
            .output()
            .map_err(|e| anyhow::anyhow!("spawn {}: {e}", cu.executable))?;
        if !out.status.success() {
            anyhow::bail!(
                "{} exited with {}: {}",
                cu.executable,
                out.status,
                String::from_utf8_lossy(&out.stderr)
            );
        }
        Ok(ExecResult {
            stdout: String::from_utf8_lossy(&out.stdout).into_owned(),
            compute_s: t0.elapsed().as_secs_f64(),
        })
    }
}

/// Shared system context behind the three API facades.
pub struct PilotSystem {
    pub store: Store,
    pub topo: Topology,
    state: Mutex<ManagerState>,
    /// Signaled whenever a CU reaches a terminal state (paired with
    /// `state`); `wait_all` blocks on it instead of polling.
    progress: Condvar,
    /// DU id -> (pd id, label) of each replica.
    locations: Mutex<BTreeMap<String, Vec<(String, Label)>>>,
    /// PD id -> local filesystem store.
    pd_fs: Mutex<BTreeMap<String, LocalFs>>,
    scheduler: Box<dyn Scheduler>,
    executor: Arc<dyn Executor>,
    workdir: PathBuf,
    shutdown: AtomicBool,
    agents: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Max worker threads per pilot pool: a pilot spawns
    /// `min(cores, worker_cap)` workers (ROADMAP: no 1:1 OS threads
    /// for very large `cores`). Slots are still accounted in cores —
    /// the semaphore in `run_cu` enforces `busy ≤ cores`.
    worker_cap: AtomicU32,
    /// Pilot id -> workers actually spawned (the cap may change after
    /// creation, so shutdown must not recompute it): one shutdown
    /// sentinel per worker, no O(cores) pushes, no sentinel residue.
    pool_sizes: Mutex<BTreeMap<String, u32>>,
    /// Per-pilot slot condvars (paired with the `state` mutex): a CU
    /// completion wakes only the completing pilot's gated/slot-waiting
    /// workers — O(own pool), not O(every parked worker of every
    /// pilot). `progress` stays the global workload-level signal for
    /// `wait_all`.
    slot_cvs: Mutex<BTreeMap<String, Arc<Condvar>>>,
    /// The data-management execution mode applied at DU submit (local
    /// analogue of the sim driver's [`crate::datamgmt::ExecutionMode`]
    /// engine): `PreStage` fans affinity-labelled DUs out to one PD
    /// per distinct label in the affinity subtree; `AutoReplicate`
    /// tops every DU up to N replicas on affinity-ranked PDs.
    data_mode: Mutex<ModeKind>,
    /// Agent-liveness lease TTL (ms) — see [`DEFAULT_HB_TTL_MS`].
    hb_ttl_ms: AtomicU64,
    /// Subscription on the data-plane loss channel
    /// (`keys::DATA_LOST_PREFIX`) — the same wire protocol the sim
    /// driver speaks: replica losses are published with their cause,
    /// and [`ComputeDataService::drain_data_losses`] turns each into
    /// the active execution mode's repair.
    data_events: Mutex<Receiver<Event>>,
}

impl PilotSystem {
    /// Create a system with the default affinity scheduler and a given
    /// executor. `workdir` hosts CU sandboxes.
    pub fn new(workdir: impl Into<PathBuf>, executor: Arc<dyn Executor>) -> Arc<PilotSystem> {
        let store = Store::new();
        let data_events = store.subscribe_prefix(keys::DATA_LOST_PREFIX);
        Arc::new(PilotSystem {
            store,
            topo: Topology::new(),
            state: Mutex::new(ManagerState::new()),
            progress: Condvar::new(),
            locations: Mutex::new(BTreeMap::new()),
            pd_fs: Mutex::new(BTreeMap::new()),
            scheduler: Box::new(AffinityScheduler::new(None)),
            executor,
            workdir: workdir.into(),
            shutdown: AtomicBool::new(false),
            agents: Mutex::new(Vec::new()),
            worker_cap: AtomicU32::new(
                std::env::var("PD_MAX_WORKERS")
                    .ok()
                    .and_then(|s| s.parse().ok())
                    .filter(|&n| n >= 1)
                    .unwrap_or(DEFAULT_WORKER_CAP),
            ),
            pool_sizes: Mutex::new(BTreeMap::new()),
            slot_cvs: Mutex::new(BTreeMap::new()),
            data_mode: Mutex::new(ModeKind::OnDemand),
            hb_ttl_ms: AtomicU64::new(DEFAULT_HB_TTL_MS),
            data_events: Mutex::new(data_events),
        })
    }

    /// Select the data-management execution mode applied to DUs
    /// submitted after this call (default: [`ModeKind::OnDemand`]).
    pub fn set_execution_mode(&self, mode: ModeKind) {
        *self.data_mode.lock().unwrap() = mode;
    }

    /// The currently selected execution mode.
    pub fn execution_mode(&self) -> ModeKind {
        *self.data_mode.lock().unwrap()
    }

    /// The slot condvar of one pilot's pool (created on first use).
    /// Fetched *before* taking the `state` lock — the `slot_cvs` lock
    /// never nests inside it.
    fn slot_cv(&self, pilot_id: &str) -> Arc<Condvar> {
        self.slot_cvs
            .lock()
            .unwrap()
            .entry(pilot_id.to_string())
            .or_insert_with(|| Arc::new(Condvar::new()))
            .clone()
    }

    /// Per-pilot worker-thread ceiling (see [`DEFAULT_WORKER_CAP`]).
    pub fn worker_cap(&self) -> u32 {
        self.worker_cap.load(Ordering::Relaxed)
    }

    /// Override the worker-thread ceiling for pilots created after
    /// this call.
    pub fn set_worker_cap(&self, cap: u32) {
        self.worker_cap.store(cap.max(1), Ordering::Relaxed);
    }

    /// Live agent worker threads across all pilots (tests/diagnostics).
    pub fn agent_count(&self) -> usize {
        self.agents.lock().unwrap().len()
    }

    /// Agent-liveness lease TTL in milliseconds (see
    /// [`DEFAULT_HB_TTL_MS`]).
    pub fn heartbeat_ttl_ms(&self) -> u64 {
        self.hb_ttl_ms.load(Ordering::Relaxed)
    }

    /// Override the lease TTL. Size it above the longest expected idle
    /// gap between submissions: an idle pool parks in the blocking pop
    /// and does not refresh until the next queue interaction.
    pub fn set_heartbeat_ttl_ms(&self, ms: u64) {
        self.hb_ttl_ms.store(ms.max(1), Ordering::Relaxed);
    }

    /// Refresh a pilot's liveness lease (best effort — a mid-outage
    /// write is retried at the next queue interaction, and the lease
    /// check treats an unreachable store as inconclusive).
    fn touch_heartbeat(&self, pilot_id: &str) {
        let _ = self.store.set(&keys::pilot_hb(pilot_id), &format!("{:.3}", Self::now_s()));
    }

    /// Is the pilot's lease fresh? A missing key is stale (the agent
    /// never heartbeat, or was already reaped); an unparseable value is
    /// stale (a corrupt lease proves nothing about liveness); a store
    /// outage is *fresh* — an unreachable store says nothing about the
    /// agent, and BigJob agents ride out transient store failures, so
    /// reaping on outage would kill healthy pilots wholesale.
    fn lease_fresh(&self, pilot_id: &str) -> bool {
        match self.store.get(&keys::pilot_hb(pilot_id)) {
            Ok(Some(v)) => v
                .parse::<f64>()
                .map(|hb| (Self::now_s() - hb) * 1000.0 <= self.heartbeat_ttl_ms() as f64)
                .unwrap_or(false),
            Ok(None) => false,
            Err(_) => true,
        }
    }

    /// Declare one agent dead: mark the pilot `Failed`, zero its slot
    /// accounting, and reclaim every CU parked on its own queue back
    /// onto the global queue where surviving agents pull. The
    /// wall-clock twin of the sim driver's pilot teardown — queued work
    /// is never stranded while a live pilot remains. (Work the dead
    /// process held *mid-CU* cannot be reclaimed here: its sandbox and
    /// slot state died with it; the CU surfaces through `wait_all`
    /// timeouts and the caller's retry, as in BigJob.)
    fn reap_pilot(&self, pilot_id: &str) {
        {
            let mut st = self.state.lock().unwrap();
            let Some(p) = st.pilots.get_mut(pilot_id) else { return };
            if p.state.is_terminal() {
                return;
            }
            let _ = p.transition(PilotState::Failed);
            p.busy_slots = 0;
            st.reset_queue_depth(pilot_id);
        }
        let _ = self.store.hset(&keys::pilot(pilot_id), "busy", "0");
        let _ = self.store.del(&keys::pilot_hb(pilot_id));
        // Drain the dead agent's own queue — nothing will ever pop it.
        let own = keys::pilot_queue(pilot_id);
        while let Ok(Some(cu)) = self.store.lpop(&own) {
            if cu == AGENT_WAKE {
                continue;
            }
            let _ = self.store.rpush(keys::GLOBAL_QUEUE, &cu);
        }
        self.slot_cv(pilot_id).notify_all();
        self.progress.notify_all();
    }

    /// Sweep every non-terminal pilot's lease and reap the dead ones;
    /// returns the reaped ids. `submit_compute_unit` performs the same
    /// check inline for the pilot it is about to dispatch to (so a
    /// stale agent cannot capture *new* work); this sweep additionally
    /// reclaims CUs already sitting on dead agents' queues.
    pub fn reap_dead_agents(&self) -> Vec<String> {
        let ids: Vec<String> = {
            let st = self.state.lock().unwrap();
            st.pilots
                .values()
                .filter(|p| !p.state.is_terminal())
                .map(|p| p.id.clone())
                .collect()
        };
        let mut reaped = Vec::new();
        for id in ids {
            if !self.lease_fresh(&id) {
                self.reap_pilot(&id);
                reaped.push(id);
            }
        }
        reaped
    }

    pub fn compute_service(self: &Arc<Self>) -> PilotComputeService {
        PilotComputeService { sys: self.clone() }
    }

    pub fn data_service(self: &Arc<Self>) -> PilotDataService {
        PilotDataService { sys: self.clone() }
    }

    pub fn compute_data_service(self: &Arc<Self>) -> ComputeDataService {
        ComputeDataService { sys: self.clone() }
    }

    /// Stop all agent workers and join their threads. Workers block in
    /// the store (a queue pop, or the availability wait during an
    /// outage) rather than polling a flag, so shutdown wakes them
    /// explicitly: one sentinel **per worker** on each pilot's own
    /// queue — the wake-one handoff delivers each sentinel to exactly
    /// one parked worker of that pool — plus a waiter broadcast for
    /// workers parked on an outage. A worker that is mid-CU re-checks
    /// the shutdown flag when it finishes; its unconsumed sentinel is
    /// inert residue in the dropped store.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // One sentinel per *spawned* worker (recorded at create time —
        // the cap may have changed since), so a cores=10k pilot does
        // not trigger 10k pushes or leave sentinel residue behind.
        let pilots: Vec<(String, u32)> = {
            let sizes = self.pool_sizes.lock().unwrap();
            sizes.iter().map(|(id, n)| (id.clone(), *n)).collect()
        };
        for (id, workers) in &pilots {
            for _ in 0..*workers {
                // Fails only while the store is down — those workers
                // are in `wait_available` and get the wake_waiters
                // broadcast.
                let _ = self.store.rpush(&keys::pilot_queue(id), AGENT_WAKE);
            }
        }
        self.store.wake_waiters();
        // Workers parked in a slot gate/semaphore re-check the
        // shutdown flag on their pool's condvar signal (see `run_cu`
        // and `worker_loop`).
        for cv in self.slot_cvs.lock().unwrap().values() {
            cv.notify_all();
        }
        self.progress.notify_all();
        let mut agents = self.agents.lock().unwrap();
        for h in agents.drain(..) {
            let _ = h.join();
        }
    }

    pub fn cu_state(&self, cu_id: &str) -> Option<CuState> {
        self.state.lock().unwrap().cus.get(cu_id).map(|c| c.state)
    }

    pub fn du_state(&self, du_id: &str) -> Option<DuState> {
        self.state.lock().unwrap().dus.get(du_id).map(|d| d.state)
    }

    pub fn cu_error(&self, cu_id: &str) -> Option<String> {
        self.state.lock().unwrap().cus.get(cu_id).and_then(|c| c.error.clone())
    }

    /// Snapshot of per-CU records (for reporting).
    pub fn cu_records(&self) -> Vec<crate::metrics::CuRecord> {
        let st = self.state.lock().unwrap();
        st.cus
            .values()
            .map(|c| crate::metrics::CuRecord {
                cu: c.id.clone(),
                machine: c.pilot.clone().unwrap_or_default(),
                t_submitted: c.t_submitted,
                t_start: c.t_started_staging,
                t_end: c.t_finished,
                staging_s: c.staging_s,
                compute_s: c.run_s(),
            })
            .collect()
    }

    /// Block until every submitted CU is terminal or `timeout` expires.
    /// Event-driven: parks on the `progress` condvar (signaled by every
    /// terminal CU transition) instead of the seed's 5 ms poll loop.
    pub fn wait_all(&self, timeout: Duration) -> anyhow::Result<()> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if st.workload_finished() {
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                let pending: Vec<String> = st
                    .cus
                    .values()
                    .filter(|c| !c.state.is_terminal())
                    .map(|c| format!("{}:{}", c.id, c.state.name()))
                    .collect();
                anyhow::bail!("wait_all timed out; pending: {pending:?}");
            }
            let (g, _) = self.progress.wait_timeout(st, deadline - now).unwrap();
            st = g;
        }
    }

    fn now_s() -> f64 {
        use std::time::{SystemTime, UNIX_EPOCH};
        SystemTime::now().duration_since(UNIX_EPOCH).unwrap().as_secs_f64()
    }

    /// Stage input DUs into the sandbox; returns staged file count.
    fn stage_inputs(&self, cu: &ComputeUnitDescription, sandbox: &Path) -> anyhow::Result<usize> {
        let locations = self.locations.lock().unwrap();
        let pd_fs = self.pd_fs.lock().unwrap();
        let mut n = 0;
        for du in &cu.input_data {
            let locs = locations
                .get(du)
                .ok_or_else(|| anyhow::anyhow!("input DU '{du}' has no replica"))?;
            let (pd_id, _) = locs
                .first()
                .ok_or_else(|| anyhow::anyhow!("input DU '{du}' replica list empty"))?;
            let fs = pd_fs
                .get(pd_id)
                .ok_or_else(|| anyhow::anyhow!("pd '{pd_id}' has no filesystem"))?;
            n += fs.stage_into_sandbox(du, sandbox)?;
        }
        Ok(n)
    }

    /// Collect files created by the CU (anything not staged in) into
    /// its output DUs.
    fn stage_outputs(
        &self,
        cu: &ComputeUnitDescription,
        sandbox: &Path,
        staged: &[String],
    ) -> anyhow::Result<()> {
        if cu.output_data.is_empty() {
            return Ok(());
        }
        let locations = self.locations.lock().unwrap();
        let pd_fs = self.pd_fs.lock().unwrap();
        for entry in std::fs::read_dir(sandbox)? {
            let entry = entry?;
            if !entry.path().is_file() {
                continue;
            }
            let name = entry.file_name().to_string_lossy().to_string();
            if staged.contains(&name) {
                continue;
            }
            for du in &cu.output_data {
                let Some(locs) = locations.get(du) else { continue };
                for (pd_id, _) in locs {
                    if let Some(fs) = pd_fs.get(pd_id) {
                        fs.put_file(du, &name, &entry.path())?;
                    }
                }
            }
        }
        // Output DUs now hold at least one replica.
        drop(locations);
        drop(pd_fs);
        let mut st = self.state.lock().unwrap();
        for du in &cu.output_data {
            if let Some(d) = st.dus.get_mut(du) {
                if d.state == DuState::Pending {
                    let _ = d.transition(DuState::Running);
                }
            }
        }
        Ok(())
    }

    /// One worker's handling of one CU id pulled from a queue. Slot
    /// accounting lives here so acquire/release always pair: the CU's
    /// cores are added to the pilot's shared `busy_slots` when the CU
    /// is accepted and subtracted when it reaches a terminal state.
    /// Both edges are mirrored into the store's pilot record (best
    /// effort — a mid-outage mirror is retried by the next edge).
    ///
    /// With the pool capped below `cores` (see [`DEFAULT_WORKER_CAP`])
    /// a worker is no longer 1:1 with a slot, so acquisition is a
    /// **slot semaphore**: the worker parks on the `progress` condvar
    /// until `busy + need ≤ cores` (every completion signals it). The
    /// requested cores are clamped to the pilot's total — local mode
    /// treats `cores` as advisory (seed semantics: a global-queue CU
    /// larger than this pilot still runs here, taking the whole
    /// pilot), which also makes the wait deadlock-free: `need ≤ cores`
    /// always, so an idle pilot can always admit the CU. Only the sim
    /// driver enforces strict fit, where a silent global requeue
    /// cannot starve.
    fn run_cu(&self, pilot_id: &str, cu_id: &str) {
        let slot_cv = self.slot_cv(pilot_id);
        let (descr, cores) = {
            let mut st = self.state.lock().unwrap();
            let Some(cu) = st.cus.get_mut(cu_id) else { return };
            cu.pilot = Some(pilot_id.to_string());
            cu.t_started_staging = Self::now_s();
            let _ = cu.transition(CuState::StagingInput);
            let descr = cu.description.clone();
            let need = {
                let total =
                    st.pilots.get(pilot_id).map(|p| p.description.cores.max(1)).unwrap_or(1);
                descr.cores.max(1).min(total)
            };
            let busy_now = loop {
                let Some(p) = st.pilots.get_mut(pilot_id) else { break None };
                // Shutdown must not strand a worker in the slot wait;
                // admit and let the CU drain.
                if p.busy_slots + need <= p.description.cores
                    || self.shutdown.load(Ordering::SeqCst)
                {
                    p.busy_slots += need;
                    break Some(p.busy_slots);
                }
                st = slot_cv.wait(st).unwrap();
            };
            // Mirror under the state lock so concurrent workers'
            // dispatch/completion edges reach the store in the same
            // order they updated the shared counter (state→store is
            // the only lock-nesting direction in this module).
            if let Some(b) = busy_now {
                let _ = self.store.hset(&keys::pilot(pilot_id), "busy", &b.to_string());
            }
            (descr, need)
        };
        let sandbox = self.workdir.join("sandbox").join(cu_id);
        let result: anyhow::Result<ExecResult> = (|| {
            std::fs::create_dir_all(&sandbox)?;
            let t0 = Instant::now();
            self.stage_inputs(&descr, &sandbox)?;
            let staged: Vec<String> = std::fs::read_dir(&sandbox)?
                .filter_map(|e| e.ok())
                .map(|e| e.file_name().to_string_lossy().to_string())
                .collect();
            let staging_s = t0.elapsed().as_secs_f64();
            {
                let mut st = self.state.lock().unwrap();
                if let Some(cu) = st.cus.get_mut(cu_id) {
                    cu.staging_s = staging_s;
                    cu.t_started_run = Self::now_s();
                    cu.transition(CuState::Running)?;
                }
            }
            let res = self.executor.execute(&descr, &sandbox)?;
            {
                let mut st = self.state.lock().unwrap();
                if let Some(cu) = st.cus.get_mut(cu_id) {
                    cu.transition(CuState::StagingOutput)?;
                }
            }
            self.stage_outputs(&descr, &sandbox, &staged)?;
            Ok(res)
        })();

        let mut st = self.state.lock().unwrap();
        let busy_now = st.pilots.get_mut(pilot_id).map(|p| {
            p.busy_slots = p.busy_slots.saturating_sub(cores);
            p.busy_slots
        });
        if let Some(cu) = st.cus.get_mut(cu_id) {
            cu.t_finished = Self::now_s();
            match result {
                Ok(_) => {
                    let _ = cu.transition(CuState::Done);
                }
                Err(e) => {
                    cu.error = Some(e.to_string());
                    // Force-fail regardless of intermediate state.
                    cu.state = CuState::Failed;
                }
            }
        }
        let final_state = st.cus.get(cu_id).map(|c| c.state);
        // Mirror the slot release while still holding the state lock
        // (state→store is the only nesting direction in this module),
        // so anyone who observes the CU terminal also finds the store's
        // busy count already drained.
        if let Some(b) = busy_now {
            let _ = self.store.hset(&keys::pilot(pilot_id), "busy", &b.to_string());
        }
        drop(st);
        // Slots freed: wake this pool's gated/slot-waiting workers
        // (O(own pool) — other pilots' parked workers are not touched).
        slot_cv.notify_all();
        // Terminal transition: wake `wait_all` waiters and notify
        // subscribers — a per-CU key event plus the legacy broadcast
        // channel.
        self.progress.notify_all();
        if let Some(state) = final_state {
            let _ = self.store.publish_k(&keys::cu_key(cu_id), state.name());
            let _ = self.store.publish(keys::STATE_CHANNEL, &format!("{cu_id}:{state:?}"));
        }
    }

    /// Main loop of one worker in a pilot's agent pool (the pool has
    /// one worker per slot): §4.2's two-queue pull protocol as **one
    /// blocking pop** over [own queue, global queue] in priority
    /// order — every worker of the pool parks in the store's event
    /// layer until work (or a shutdown sentinel) arrives, and the
    /// wake-one handoff hands each push to exactly one of them. No
    /// fixed-interval polling anywhere: empty queues block on a
    /// condvar, and a store outage parks the worker on the
    /// availability wait (woken by recovery or shutdown), matching how
    /// BigJob agents ride out transient Redis failures.
    fn worker_loop(self: Arc<Self>, pilot_id: String) {
        let own_queue = keys::pilot_queue_key(&pilot_id);
        let global = keys::global_queue_key();
        let slot_cv = self.slot_cv(&pilot_id);
        while !self.shutdown.load(Ordering::SeqCst) {
            // Refresh the liveness lease at every queue interaction: a
            // live pool keeps the lease fresh as long as work flows,
            // and only a dead process lets it lapse. (No heartbeat
            // thread, no fixed-interval timer — the lease rides the
            // event-driven loop, which is why the TTL must cover idle
            // gaps; see `DEFAULT_HB_TTL_MS`.)
            self.touch_heartbeat(&pilot_id);
            // Don't compete for work while the pilot has no free slot:
            // a saturated pilot's spare workers must not capture global
            // CUs that an idle pilot could run (head-of-line blocking).
            // Park on this pool's slot condvar until a completion frees
            // a slot; work pushed meanwhile is picked up by the
            // blocking pop's initial queue recheck, so nothing is lost
            // while no worker of this pool is parked in the pop.
            {
                let mut st = self.state.lock().unwrap();
                while !self.shutdown.load(Ordering::SeqCst)
                    && st.pilots.get(&pilot_id).map_or(false, |p| p.free_slots() == 0)
                {
                    st = slot_cv.wait(st).unwrap();
                }
            }
            match self.store.blpop_any(&[&own_queue, global], None) {
                Ok(Some((queue_idx, cu_id))) => {
                    if cu_id == AGENT_WAKE {
                        continue; // loop re-checks the shutdown flag
                    }
                    if queue_idx == 0 {
                        self.state.lock().unwrap().note_queue_pop(&pilot_id);
                    }
                    // Slot accounting (busy_slots up/down + store
                    // mirror) happens inside run_cu, under the state
                    // lock shared by every worker of the pool.
                    self.run_cu(&pilot_id, &cu_id);
                }
                Ok(None) => {} // unreachable: no deadline was set
                Err(_) => {
                    // Store outage: block until it recovers (or we are
                    // shut down) — event-driven, not a retry sleep.
                    self.store.wait_available(|| self.shutdown.load(Ordering::SeqCst));
                    // Re-sync the busy mirror on recovery: completion
                    // edges that fired during the outage lost their
                    // hset, and an idle pilot has no further edge to
                    // retry it — a reconnecting manager would otherwise
                    // inherit phantom busy_slots from the stale mirror.
                    if !self.shutdown.load(Ordering::SeqCst) {
                        let st = self.state.lock().unwrap();
                        if let Some(b) = st.pilots.get(&pilot_id).map(|p| p.busy_slots) {
                            let _ = self.store.hset(
                                &keys::pilot(&pilot_id),
                                "busy",
                                &b.to_string(),
                            );
                        }
                    }
                }
            }
        }
    }

    /// Busy slots of a pilot right now (tests, diagnostics).
    pub fn pilot_busy_slots(&self, pilot_id: &str) -> Option<u32> {
        self.state.lock().unwrap().pilots.get(pilot_id).map(|p| p.busy_slots)
    }
}

/// Factory for Pilot-Computes (paper: "instantiation of Pilot-Computes
/// are done via a factory class, the Pilot-Compute Service").
pub struct PilotComputeService {
    sys: Arc<PilotSystem>,
}

impl PilotComputeService {
    /// Start a pilot: registers it, marks it Active, and spawns its
    /// agent **worker pool** — `min(cores, worker cap)` worker threads
    /// (see [`DEFAULT_WORKER_CAP`]), all parked in the same blocking
    /// two-queue pop. The wake-one handoff hands each pushed CU to
    /// exactly one worker, so a pilot executes up to `min(cores, cap)`
    /// CUs concurrently while the slot semaphore keeps the cores-level
    /// invariant `busy ≤ cores`.
    pub fn create_pilot(&self, descr: PilotComputeDescription) -> anyhow::Result<String> {
        if descr.cores == 0 {
            anyhow::bail!("pilot must have at least one core");
        }
        let workers = descr.cores.min(self.sys.worker_cap()).max(1);
        let mut pilot = PilotCompute::new(descr);
        pilot.transition(PilotState::Queued)?;
        pilot.transition(PilotState::Active)?;
        pilot.t_active = PilotSystem::now_s();
        let id = pilot.id.clone();
        self.sys.state.lock().unwrap().add_pilot(pilot);
        // Initial liveness lease, so the dispatch-time check never
        // mistakes a freshly created pilot for a dead one.
        self.sys.touch_heartbeat(&id);
        self.sys.pool_sizes.lock().unwrap().insert(id.clone(), workers);
        for w in 0..workers {
            let sys = self.sys.clone();
            let tid = id.clone();
            let handle = std::thread::Builder::new()
                .name(format!("agent-{id}-w{w}"))
                .spawn(move || sys.worker_loop(tid))?;
            self.sys.agents.lock().unwrap().push(handle);
        }
        Ok(id)
    }

    pub fn cancel(&self, pilot_id: &str) -> anyhow::Result<()> {
        let mut st = self.sys.state.lock().unwrap();
        let p = st
            .pilots
            .get_mut(pilot_id)
            .ok_or_else(|| anyhow::anyhow!("unknown pilot '{pilot_id}'"))?;
        p.transition(PilotState::Canceled)
    }
}

/// Factory for Pilot-Data.
pub struct PilotDataService {
    sys: Arc<PilotSystem>,
}

impl PilotDataService {
    /// Provision a Pilot-Data. Local mode accepts `file://` URLs; the
    /// path component is the storage root.
    pub fn create_pilot_data(&self, descr: PilotDataDescription) -> anyhow::Result<String> {
        let pd = PilotData::new(descr)?;
        if pd.url.kind != BackendKind::LocalFs {
            anyhow::bail!(
                "local execution mode supports file:// Pilot-Data (got {})",
                pd.url.kind.scheme()
            );
        }
        let fs = LocalFs::open(&pd.url.path)?;
        let id = pd.id.clone();
        let mut pd = pd;
        pd.transition(PilotState::Queued)?;
        pd.transition(PilotState::Active)?;
        self.sys.pd_fs.lock().unwrap().insert(id.clone(), fs);
        self.sys.state.lock().unwrap().add_pd(pd);
        Ok(id)
    }

    /// Label of a PD (for affinity-aware DU placement).
    pub fn affinity_of(&self, pd_id: &str) -> Option<Label> {
        self.sys.state.lock().unwrap().pilot_datas.get(pd_id).map(|p| p.affinity())
    }
}

/// The workload manager: applications submit CU/DU descriptions; the
/// service schedules them onto pilots ("the application can continue
/// without needing to wait for BigJob to finish the placement").
pub struct ComputeDataService {
    sys: Arc<PilotSystem>,
}

impl ComputeDataService {
    /// Submit a Data-Unit into a specific Pilot-Data, ingesting file
    /// content from `FileRef::src` paths (or creating empty DUs for
    /// outputs). The selected execution mode
    /// ([`PilotSystem::set_execution_mode`]) then replicates the DU
    /// proactively — pre-staging across its affinity subtree, or
    /// topping it up to the auto-replication target.
    pub fn submit_data_unit(
        &self,
        descr: DataUnitDescription,
        pd_id: &str,
    ) -> anyhow::Result<String> {
        let id = self.submit_data_unit_inner(descr, pd_id)?;
        self.apply_execution_mode(&id);
        Ok(id)
    }

    /// The mode-free submit path (shared by [`Self::put_data_unit`],
    /// which must write its byte blobs before replication copies them).
    fn submit_data_unit_inner(
        &self,
        descr: DataUnitDescription,
        pd_id: &str,
    ) -> anyhow::Result<String> {
        let label = {
            let st = self.sys.state.lock().unwrap();
            st.pilot_datas
                .get(pd_id)
                .ok_or_else(|| anyhow::anyhow!("unknown pilot-data '{pd_id}'"))?
                .affinity()
        };
        let mut du = DataUnit::new(descr);
        du.transition(DuState::Pending)?;
        {
            let pd_fs = self.sys.pd_fs.lock().unwrap();
            let fs = pd_fs
                .get(pd_id)
                .ok_or_else(|| anyhow::anyhow!("pd '{pd_id}' has no filesystem"))?;
            for f in &du.description().files {
                match &f.src {
                    Some(src) => fs.put_file(&du.id, &f.name, Path::new(src))?,
                    None => {} // declared-only (output container)
                }
            }
        }
        if du.description().files.iter().any(|f| f.src.is_some()) {
            du.transition(DuState::Running)?;
        }
        let id = du.id.clone();
        self.sys
            .locations
            .lock()
            .unwrap()
            .entry(id.clone())
            .or_default()
            .push((pd_id.to_string(), label.clone()));
        {
            let mut st = self.sys.state.lock().unwrap();
            st.note_replica(&id, &label);
            st.add_du(du);
        }
        Ok(id)
    }

    /// In-memory convenience: create a DU from byte blobs.
    pub fn put_data_unit(
        &self,
        name: &str,
        files: &[(&str, &[u8])],
        pd_id: &str,
    ) -> anyhow::Result<String> {
        let descr = DataUnitDescription {
            name: name.to_string(),
            files: files
                .iter()
                .map(|(n, bytes)| crate::unit::FileRef::sized(n, crate::util::Bytes::b(bytes.len() as u64)))
                .collect(),
            affinity: None,
        };
        let du = self.submit_data_unit_inner(descr, pd_id)?;
        {
            let pd_fs = self.sys.pd_fs.lock().unwrap();
            let fs = pd_fs.get(pd_id).unwrap();
            for (n, bytes) in files {
                fs.put(&du, n, bytes)?;
            }
        }
        if let Some(d) = self.sys.state.lock().unwrap().dus.get_mut(&du) {
            if d.state == DuState::Pending {
                let _ = d.transition(DuState::Running);
            }
        }
        // Replicate only after the blobs are on disk, so the mode's
        // copies are complete.
        self.apply_execution_mode(&du);
        Ok(du)
    }

    /// Apply the system's execution mode to a freshly submitted DU.
    /// Local-mode counterpart of the sim driver's policy dispatch —
    /// same semantics, against the service's `file://` Pilot-Data set.
    /// Best-effort, like the sim's action dispatch: the DU is already
    /// durably placed when this runs, so a failed proactive replica
    /// must not turn the whole submit into an error (retrying callers
    /// would duplicate live data).
    fn apply_execution_mode(&self, du_id: &str) {
        match self.sys.execution_mode() {
            ModeKind::OnDemand => {}
            ModeKind::PreStage => {
                let affinity = {
                    let st = self.sys.state.lock().unwrap();
                    st.dus.get(du_id).and_then(|d| d.description().affinity.clone())
                };
                let Some(affinity) = affinity else { return };
                let covered: std::collections::BTreeSet<String> = {
                    let locations = self.sys.locations.lock().unwrap();
                    locations
                        .get(du_id)
                        .map(|v| v.iter().map(|(_, l)| l.0.clone()).collect())
                        .unwrap_or_default()
                };
                let candidates: Vec<(String, Label)> = {
                    let st = self.sys.state.lock().unwrap();
                    st.pilot_datas
                        .values()
                        .filter(|p| p.affinity().within(&affinity))
                        .map(|p| (p.id.clone(), p.affinity()))
                        .collect()
                };
                let mut covered = covered;
                for (pd, label) in candidates {
                    if covered.contains(&label.0) {
                        continue;
                    }
                    // Best-effort: a failed copy leaves that label
                    // uncovered but the submit stands.
                    if self.replicate(du_id, &pd).is_ok() {
                        covered.insert(label.0.clone());
                    }
                }
            }
            ModeKind::AutoReplicate { replicas } => {
                let (origin, existing) = {
                    let locations = self.sys.locations.lock().unwrap();
                    let locs = locations.get(du_id).cloned().unwrap_or_default();
                    let origin = locs
                        .first()
                        .map(|(_, l)| l.clone())
                        .unwrap_or_else(|| Label::new(""));
                    let pds: std::collections::BTreeSet<String> =
                        locs.iter().map(|(pd, _)| pd.clone()).collect();
                    (origin, pds)
                };
                let mut candidates: Vec<(String, Label)> = {
                    let st = self.sys.state.lock().unwrap();
                    st.pilot_datas
                        .values()
                        .filter(|p| !existing.contains(&p.id))
                        .map(|p| (p.id.clone(), p.affinity()))
                        .collect()
                };
                datamgmt::rank_targets_by_affinity(&self.sys.topo, &origin, &mut candidates);
                let mut need = (replicas as usize).saturating_sub(existing.len());
                for (pd, _) in candidates {
                    if need == 0 {
                        break;
                    }
                    // Best-effort; a failed candidate does not consume
                    // the budget, the next-ranked PD is tried instead.
                    if self.replicate(du_id, &pd).is_ok() {
                        need -= 1;
                    }
                }
            }
        }
    }

    /// Replicate a DU into another Pilot-Data (local copy).
    pub fn replicate(&self, du_id: &str, dst_pd: &str) -> anyhow::Result<()> {
        let (src_pd, label) = {
            let locations = self.sys.locations.lock().unwrap();
            let locs = locations
                .get(du_id)
                .ok_or_else(|| anyhow::anyhow!("unknown DU '{du_id}'"))?;
            let (src, _) = locs.first().ok_or_else(|| anyhow::anyhow!("DU has no replica"))?;
            let st = self.sys.state.lock().unwrap();
            let label = st
                .pilot_datas
                .get(dst_pd)
                .ok_or_else(|| anyhow::anyhow!("unknown pilot-data '{dst_pd}'"))?
                .affinity();
            (src.clone(), label)
        };
        {
            let pd_fs = self.sys.pd_fs.lock().unwrap();
            let src_fs = pd_fs.get(&src_pd).unwrap();
            let dst_fs = pd_fs
                .get(dst_pd)
                .ok_or_else(|| anyhow::anyhow!("pd '{dst_pd}' has no filesystem"))?;
            for (name, _) in src_fs.list(du_id)? {
                let content = src_fs.get(du_id, &name)?;
                dst_fs.put(du_id, &name, &content)?;
            }
        }
        self.sys
            .locations
            .lock()
            .unwrap()
            .get_mut(du_id)
            .unwrap()
            .push((dst_pd.to_string(), label.clone()));
        self.sys.state.lock().unwrap().note_replica(du_id, &label);
        Ok(())
    }

    /// Report that a replica of `du_id` at `pd_id` is gone (disk
    /// failure, eviction, operator action): drop it from the location
    /// index — keeping the scheduler's replica-label view honest, the
    /// label is removed only when no other PD at that label still
    /// holds the DU — and publish the loss *with its cause* on the
    /// store's `pd:data:lost:` channel, the same wire protocol the sim
    /// driver speaks. [`Self::drain_data_losses`] (or any other
    /// subscriber) turns the event into the active mode's repair.
    pub fn report_replica_lost(&self, du_id: &str, pd_id: &str, cause: LossCause) {
        let removed_label = {
            let mut locations = self.sys.locations.lock().unwrap();
            let Some(locs) = locations.get_mut(du_id) else { return };
            let Some(pos) = locs.iter().position(|(pd, _)| pd == pd_id) else { return };
            let (_, label) = locs.remove(pos);
            let still_at_label = locs.iter().any(|(_, l)| l.0 == label.0);
            (!still_at_label).then_some(label)
        };
        if let Some(label) = removed_label {
            self.sys.state.lock().unwrap().drop_replica(du_id, &label);
        }
        let _ = self.sys.store.publish(
            &format!("{}{du_id}", keys::DATA_LOST_PREFIX),
            &format!("{pd_id} {}", cause.wire_name()),
        );
    }

    /// Consume loss events published since the last drain and apply
    /// the active execution mode's repair to each affected DU — the
    /// local twin of the sim driver's data-event drain. `Outage`
    /// losses re-run the mode's proactive placement (`AutoReplicate`
    /// tops the DU back up to N, `PreStage` re-covers its affinity
    /// subtree); `Evicted` losses are deliberate capacity decisions
    /// and are not repaired (re-placing one would thrash the PD that
    /// just shed it). Returns the number of loss events consumed.
    pub fn drain_data_losses(&self) -> usize {
        let mut lost: Vec<(String, LossCause)> = Vec::new();
        {
            let rx = self.sys.data_events.lock().unwrap();
            while let Ok(ev) = rx.try_recv() {
                let Some(du) = ev.key.strip_prefix(keys::DATA_LOST_PREFIX) else { continue };
                let Some((_pd, cause)) = ev.payload.rsplit_once(' ') else { continue };
                let Some(cause) = LossCause::from_wire(cause) else { continue };
                lost.push((du.to_string(), cause));
            }
        }
        let n = lost.len();
        for (du, cause) in lost {
            match cause {
                LossCause::Outage => self.apply_execution_mode(&du),
                LossCause::Evicted => {}
            }
        }
        n
    }

    /// Read one file out of a DU (first replica).
    pub fn fetch(&self, du_id: &str, name: &str) -> anyhow::Result<Vec<u8>> {
        let locations = self.sys.locations.lock().unwrap();
        let locs = locations
            .get(du_id)
            .ok_or_else(|| anyhow::anyhow!("unknown DU '{du_id}'"))?;
        let (pd, _) = locs.first().ok_or_else(|| anyhow::anyhow!("DU has no replica"))?;
        let pd_fs = self.sys.pd_fs.lock().unwrap();
        pd_fs.get(pd).unwrap().get(du_id, name)
    }

    pub fn list(&self, du_id: &str) -> anyhow::Result<Vec<(String, crate::util::Bytes)>> {
        let locations = self.sys.locations.lock().unwrap();
        let locs = locations
            .get(du_id)
            .ok_or_else(|| anyhow::anyhow!("unknown DU '{du_id}'"))?;
        let (pd, _) = locs.first().ok_or_else(|| anyhow::anyhow!("DU has no replica"))?;
        let pd_fs = self.sys.pd_fs.lock().unwrap();
        pd_fs.get(pd).unwrap().list(du_id)
    }

    /// Submit a Compute-Unit: run it through the scheduler and enqueue.
    pub fn submit_compute_unit(&self, descr: ComputeUnitDescription) -> anyhow::Result<String> {
        let mut cu = ComputeUnit::new(descr);
        cu.t_submitted = PilotSystem::now_s();
        let id = cu.id.clone();

        // O(1) context assembly from the manager's incremental indexes
        // (the seed rebuilt the DU-location map and polled a store
        // `llen` per pilot on every submit).
        let placement = {
            let st = self.sys.state.lock().unwrap();
            let ctx = SchedContext::from_state(&self.sys.topo, &st);
            // The wall-clock service has no simulated clock to park a
            // Delay on (`ctx.now` stays 0.0), so a delaying scheduler
            // is resolved inline: re-place until its skip-count
            // fallback — bounded by `max_delay_rounds` — accepts a
            // slot or goes global. The extra iteration cap is a
            // defensive bound on third-party `Scheduler` impls that
            // delay forever; the leftover `Delay` then routes to the
            // global queue below, exactly as before.
            let mut p = self.sys.scheduler.place(&cu, &ctx);
            let mut rounds = 0u32;
            while matches!(p, Placement::Delay(_)) && rounds < 8 {
                p = self.sys.scheduler.place(&cu, &ctx);
                rounds += 1;
            }
            p
        };

        let enqueue = |queue: &str, cu: ComputeUnit| -> anyhow::Result<()> {
            let mut cu = cu;
            cu.transition(CuState::Queued)?;
            self.sys.state.lock().unwrap().add_cu(cu);
            if let Err(e) = self.sys.store.rpush(queue, &id) {
                // Store unavailable: the CU can never be pulled — mark
                // it Failed so waiters don't hang, and surface the
                // error to the caller (who may retry once the store
                // recovers, as BigJob clients do).
                {
                    let mut st = self.sys.state.lock().unwrap();
                    if let Some(c) = st.cus.get_mut(&id) {
                        c.state = CuState::Failed;
                        c.error = Some(format!("enqueue failed: {e}"));
                    }
                }
                self.sys.progress.notify_all();
                anyhow::bail!("enqueue failed: {e}");
            }
            Ok(())
        };
        match placement {
            Placement::Pilot(pilot_id) => {
                // Lease-based liveness check at dispatch: routing a CU
                // onto the queue of an agent whose heartbeat lapsed
                // would strand it (nothing pops a dead agent's queue).
                // Reap the dead pilot — reclaiming anything already
                // parked on its queue — and fall back to the global
                // queue, where surviving agents pull.
                if !self.sys.lease_fresh(&pilot_id) {
                    self.sys.reap_pilot(&pilot_id);
                    enqueue(keys::GLOBAL_QUEUE, cu)?;
                    return Ok(id);
                }
                // Pre-account the push: the agent thread may pop (and
                // decrement) the instant the rpush lands, so counting
                // after the fact could leak the counter upward.
                self.sys.state.lock().unwrap().note_queue_push(&pilot_id);
                if let Err(e) = enqueue(&keys::pilot_queue(&pilot_id), cu) {
                    self.sys.state.lock().unwrap().note_queue_pop(&pilot_id);
                    return Err(e);
                }
            }
            Placement::Global | Placement::Delay(_) => enqueue(keys::GLOBAL_QUEUE, cu)?,
            Placement::Unschedulable(reason) => {
                cu.transition(CuState::Unschedulable)?;
                cu.error = Some(reason.clone());
                self.sys.state.lock().unwrap().add_cu(cu);
                self.sys.progress.notify_all();
                anyhow::bail!("CU unschedulable: {reason}");
            }
        }
        Ok(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("pd-svc-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn local_pd(dir: &Path, name: &str, affinity: &str) -> PilotDataDescription {
        PilotDataDescription {
            service_url: format!("file://localhost{}/{name}", dir.display()),
            size: crate::util::Bytes::gb(1),
            affinity: Some(Label::new(affinity)),
        }
    }

    fn one_core_pilot(affinity: &str) -> PilotComputeDescription {
        PilotComputeDescription {
            service_url: "fork://localhost".into(),
            cores: 2,
            walltime_s: 600.0,
            affinity: Some(Label::new(affinity)),
        }
    }

    /// Executor that reads `in.txt` and writes `out.txt` uppercased.
    struct UppercaseExecutor;
    impl Executor for UppercaseExecutor {
        fn execute(&self, _cu: &ComputeUnitDescription, sandbox: &Path) -> anyhow::Result<ExecResult> {
            let input = std::fs::read_to_string(sandbox.join("in.txt"))?;
            std::fs::write(sandbox.join("out.txt"), input.to_uppercase())?;
            Ok(ExecResult { stdout: String::new(), compute_s: 0.0 })
        }
    }

    #[test]
    fn end_to_end_du_cu_pipeline() {
        let dir = tmpdir("e2e");
        let sys = PilotSystem::new(&dir, Arc::new(UppercaseExecutor));
        let pcs = sys.compute_service();
        let pds = sys.data_service();
        let cds = sys.compute_data_service();

        let pd = pds.create_pilot_data(local_pd(&dir, "pd0", "local/here")).unwrap();
        pcs.create_pilot(one_core_pilot("local/here")).unwrap();

        let input = cds.put_data_unit("in", &[("in.txt", b"hello pilot-data")], &pd).unwrap();
        let output = cds
            .submit_data_unit(
                DataUnitDescription { name: "out".into(), files: vec![], affinity: None },
                &pd,
            )
            .unwrap();
        let cu = cds
            .submit_compute_unit(ComputeUnitDescription {
                executable: "builtin:uppercase".into(),
                cores: 1,
                input_data: vec![input],
                output_data: vec![output.clone()],
                ..Default::default()
            })
            .unwrap();

        sys.wait_all(Duration::from_secs(10)).unwrap();
        assert_eq!(sys.cu_state(&cu), Some(CuState::Done), "err={:?}", sys.cu_error(&cu));
        let out = cds.fetch(&output, "out.txt").unwrap();
        assert_eq!(out, b"HELLO PILOT-DATA");
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    /// Lease-based liveness: a pilot whose agent process died (stale
    /// heartbeat, no worker threads) is reaped at dispatch time — the
    /// new CU falls back to the global queue and CUs already parked on
    /// the dead agent's own queue are reclaimed with it, so a healthy
    /// pilot finishes the whole workload.
    #[test]
    fn stale_heartbeat_pilot_is_reaped_and_its_cus_reclaimed() {
        let dir = tmpdir("reap");
        let sys = PilotSystem::new(dir.join("work"), Arc::new(UppercaseExecutor));
        sys.set_heartbeat_ttl_ms(50);
        let pds = sys.data_service();
        let cds = sys.compute_data_service();
        let pd = pds.create_pilot_data(local_pd(&dir, "pd1", "site/a")).unwrap();
        let du = cds.put_data_unit("in", &[("in.txt", b"abc")], &pd).unwrap();

        // A pilot whose agent process died: the record looks Active,
        // but no worker threads back it and its lease is ancient.
        // (Registered directly — `create_pilot` would spawn a live
        // pool, which is exactly what a dead agent does not have.)
        let zombie = {
            let mut p = PilotCompute::new(one_core_pilot("site/a"));
            p.transition(PilotState::Queued).unwrap();
            p.transition(PilotState::Active).unwrap();
            let id = p.id.clone();
            sys.state.lock().unwrap().add_pilot(p);
            sys.store.set(&keys::pilot_hb(&id), "0").unwrap();
            id
        };

        // A CU already parked on the dead agent's own queue.
        let orphan = {
            let mut cu = ComputeUnit::new(ComputeUnitDescription {
                executable: "builtin:uppercase".into(),
                cores: 1,
                input_data: vec![du.clone()],
                ..Default::default()
            });
            cu.transition(CuState::Queued).unwrap();
            let id = cu.id.clone();
            let mut st = sys.state.lock().unwrap();
            st.add_cu(cu);
            st.note_queue_push(&zombie);
            drop(st);
            sys.store.rpush(&keys::pilot_queue(&zombie), &id).unwrap();
            id
        };

        // The scheduler picks the zombie (only pilot, data on site),
        // but the stale lease reaps it and reroutes to the global
        // queue — reclaiming the orphan too.
        let cu2 = cds
            .submit_compute_unit(ComputeUnitDescription {
                executable: "builtin:uppercase".into(),
                cores: 1,
                input_data: vec![du.clone()],
                ..Default::default()
            })
            .unwrap();
        assert_eq!(
            sys.state.lock().unwrap().pilots[&zombie].state,
            PilotState::Failed,
            "stale lease marks the pilot dead"
        );
        assert_eq!(sys.store.llen(&keys::pilot_queue(&zombie)).unwrap(), 0);
        assert_eq!(sys.store.llen(keys::GLOBAL_QUEUE).unwrap(), 2);
        assert!(
            sys.store.get(&keys::pilot_hb(&zombie)).unwrap().is_none(),
            "reap clears the lease key"
        );

        // A healthy pilot drains both reclaimed CUs off the global
        // queue.
        sys.compute_service().create_pilot(one_core_pilot("site/a")).unwrap();
        sys.wait_all(Duration::from_secs(10)).unwrap();
        assert_eq!(sys.cu_state(&orphan), Some(CuState::Done), "err={:?}", sys.cu_error(&orphan));
        assert_eq!(sys.cu_state(&cu2), Some(CuState::Done), "err={:?}", sys.cu_error(&cu2));
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    /// The `pd:data:lost:` loss channel, ported from the sim driver:
    /// an `Outage` loss is published with its cause and repaired by
    /// the active mode at the next drain; an `Evicted` loss is a
    /// deliberate capacity decision and stays lost.
    #[test]
    fn lost_replica_outage_is_repaired_via_loss_channel() {
        let dir = tmpdir("loss");
        let sys = PilotSystem::new(dir.join("work"), Arc::new(UppercaseExecutor));
        sys.set_execution_mode(ModeKind::AutoReplicate { replicas: 2 });
        let pds = sys.data_service();
        let cds = sys.compute_data_service();
        let a = pds.create_pilot_data(local_pd(&dir, "pd-a", "site/a")).unwrap();
        let b = pds.create_pilot_data(local_pd(&dir, "pd-b", "site/b")).unwrap();
        let du = cds.put_data_unit("blob", &[("x.txt", b"payload")], &a).unwrap();
        let n_replicas =
            |du: &str| sys.locations.lock().unwrap().get(du).map_or(0, |v| v.len());
        assert_eq!(n_replicas(&du), 2, "auto-replicate placed a second copy on {b}");

        // Outage loss: published on the channel, repaired at the drain.
        cds.report_replica_lost(&du, &b, LossCause::Outage);
        assert_eq!(n_replicas(&du), 1, "loss drops the location entry");
        assert_eq!(cds.drain_data_losses(), 1);
        assert_eq!(n_replicas(&du), 2, "outage loss re-replicated to target");
        assert_eq!(cds.fetch(&du, "x.txt").unwrap(), b"payload");

        // Evicted loss: not repaired.
        cds.report_replica_lost(&du, &b, LossCause::Evicted);
        assert_eq!(cds.drain_data_losses(), 1);
        assert_eq!(n_replicas(&du), 1, "eviction is not repaired");
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn shell_executor_runs_real_commands() {
        let dir = tmpdir("shell");
        let sys = PilotSystem::new(&dir, Arc::new(ShellExecutor));
        let pcs = sys.compute_service();
        let cds = sys.compute_data_service();
        pcs.create_pilot(one_core_pilot("x")).unwrap();
        let cu = cds
            .submit_compute_unit(ComputeUnitDescription {
                executable: "/bin/sh".into(),
                arguments: vec!["-c".into(), "echo ok > shell-out.txt".into()],
                cores: 1,
                ..Default::default()
            })
            .unwrap();
        sys.wait_all(Duration::from_secs(10)).unwrap();
        assert_eq!(sys.cu_state(&cu), Some(CuState::Done), "err={:?}", sys.cu_error(&cu));
        assert!(dir.join("sandbox").join(&cu).join("shell-out.txt").exists());
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn failing_cu_is_marked_failed_with_error() {
        let dir = tmpdir("fail");
        let sys = PilotSystem::new(&dir, Arc::new(ShellExecutor));
        sys.compute_service().create_pilot(one_core_pilot("x")).unwrap();
        let cu = sys
            .compute_data_service()
            .submit_compute_unit(ComputeUnitDescription {
                executable: "/bin/sh".into(),
                arguments: vec!["-c".into(), "exit 3".into()],
                cores: 1,
                ..Default::default()
            })
            .unwrap();
        sys.wait_all(Duration::from_secs(10)).unwrap();
        assert_eq!(sys.cu_state(&cu), Some(CuState::Failed));
        assert!(sys.cu_error(&cu).unwrap().contains("exit"));
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_input_du_fails_cu() {
        let dir = tmpdir("noinput");
        let sys = PilotSystem::new(&dir, Arc::new(ShellExecutor));
        sys.compute_service().create_pilot(one_core_pilot("x")).unwrap();
        let cu = sys
            .compute_data_service()
            .submit_compute_unit(ComputeUnitDescription {
                executable: "/bin/true".into(),
                cores: 1,
                input_data: vec!["du-does-not-exist".into()],
                ..Default::default()
            })
            .unwrap();
        sys.wait_all(Duration::from_secs(10)).unwrap();
        assert_eq!(sys.cu_state(&cu), Some(CuState::Failed));
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn unschedulable_constraint_is_rejected_at_submit() {
        let dir = tmpdir("unsched");
        let sys = PilotSystem::new(&dir, Arc::new(ShellExecutor));
        sys.compute_service().create_pilot(one_core_pilot("osg/purdue")).unwrap();
        let res = sys.compute_data_service().submit_compute_unit(ComputeUnitDescription {
            executable: "/bin/true".into(),
            cores: 1,
            affinity: Some(Label::new("xsede/tacc")),
            ..Default::default()
        });
        assert!(res.is_err());
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    /// The execution-mode engine's local dispatch: `PreStage` fans an
    /// affinity-labelled DU out to one PD per distinct label in its
    /// affinity subtree at submit, with complete file content.
    #[test]
    fn prestage_mode_fans_out_at_submit() {
        let dir = tmpdir("mode-prestage");
        let sys = PilotSystem::new(&dir, Arc::new(ShellExecutor));
        sys.set_execution_mode(ModeKind::PreStage);
        assert_eq!(sys.execution_mode(), ModeKind::PreStage);
        let pds = sys.data_service();
        let cds = sys.compute_data_service();
        let a = pds.create_pilot_data(local_pd(&dir, "a", "site/a")).unwrap();
        let _b = pds.create_pilot_data(local_pd(&dir, "b", "site/b")).unwrap();
        let _c = pds.create_pilot_data(local_pd(&dir, "c", "site/b")).unwrap(); // same label as b
        let _far = pds.create_pilot_data(local_pd(&dir, "far", "elsewhere/x")).unwrap();
        let du = cds
            .submit_data_unit(
                DataUnitDescription {
                    name: "shared".into(),
                    files: vec![],
                    affinity: Some(Label::new("site")),
                },
                &a,
            )
            .unwrap();
        // One replica per distinct label within `site`: a + (b|c), the
        // out-of-subtree PD untouched.
        let locs = sys.locations.lock().unwrap().get(&du).unwrap().clone();
        assert_eq!(locs.len(), 2, "locs={locs:?}");
        assert!(locs.iter().any(|(_, l)| l.0 == "site/a"));
        assert!(locs.iter().any(|(_, l)| l.0 == "site/b"));
        // An unlabelled DU stays on-demand — and its blobs are intact.
        let plain = cds.put_data_unit("plain", &[("f.txt", b"payload")], &a).unwrap();
        assert_eq!(sys.locations.lock().unwrap().get(&plain).unwrap().len(), 1);
        assert_eq!(cds.fetch(&plain, "f.txt").unwrap(), b"payload");
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    /// `AutoReplicate` tops a submitted DU up to N replicas on
    /// affinity-ranked PDs, and the replicas carry the byte content
    /// (put_data_unit replicates only after the blobs land).
    #[test]
    fn auto_replicate_mode_tops_up_at_submit() {
        let dir = tmpdir("mode-autorepl");
        let sys = PilotSystem::new(&dir, Arc::new(ShellExecutor));
        sys.set_execution_mode(ModeKind::AutoReplicate { replicas: 2 });
        let pds = sys.data_service();
        let cds = sys.compute_data_service();
        let a = pds.create_pilot_data(local_pd(&dir, "a", "site/a")).unwrap();
        let near = pds.create_pilot_data(local_pd(&dir, "near", "site/a")).unwrap();
        let _far = pds.create_pilot_data(local_pd(&dir, "far", "elsewhere/x")).unwrap();
        let du = cds.put_data_unit("d", &[("f.bin", b"replicated")], &a).unwrap();
        let locs = sys.locations.lock().unwrap().get(&du).unwrap().clone();
        assert_eq!(locs.len(), 2, "locs={locs:?}");
        // Affinity ranking picks the co-located PD over the far one.
        assert!(locs.iter().any(|(pd, _)| *pd == near), "locs={locs:?}");
        // The second replica holds the content: fetch works even after
        // the original is forgotten.
        sys.locations.lock().unwrap().get_mut(&du).unwrap().retain(|(pd, _)| *pd != a);
        assert_eq!(cds.fetch(&du, "f.bin").unwrap(), b"replicated");
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn replication_copies_du_between_pds() {
        let dir = tmpdir("repl");
        let sys = PilotSystem::new(&dir, Arc::new(ShellExecutor));
        let pds = sys.data_service();
        let cds = sys.compute_data_service();
        let a = pds.create_pilot_data(local_pd(&dir, "a", "site/a")).unwrap();
        let b = pds.create_pilot_data(local_pd(&dir, "b", "site/b")).unwrap();
        let du = cds.put_data_unit("d", &[("f.bin", b"payload")], &a).unwrap();
        cds.replicate(&du, &b).unwrap();
        // Both PDs now hold the file; fetch still works after dropping A.
        let locs = sys.locations.lock().unwrap().get(&du).unwrap().len();
        assert_eq!(locs, 2);
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    /// Executor that parks every call until `expected` calls are
    /// inside `execute` simultaneously — a deterministic proof of
    /// pool concurrency with no wall-clock sensitivity: a serial
    /// (single-slot) agent would never assemble the quorum and every
    /// CU would fail on the gate timeout.
    struct GateExecutor {
        expected: u32,
        inside: Mutex<u32>,
        cv: Condvar,
    }

    impl GateExecutor {
        fn new(expected: u32) -> GateExecutor {
            GateExecutor { expected, inside: Mutex::new(0), cv: Condvar::new() }
        }
    }

    impl Executor for GateExecutor {
        fn execute(&self, _cu: &ComputeUnitDescription, _sandbox: &Path) -> anyhow::Result<ExecResult> {
            let mut n = self.inside.lock().unwrap();
            *n += 1;
            self.cv.notify_all();
            let deadline = Instant::now() + Duration::from_secs(20);
            while *n < self.expected {
                let now = Instant::now();
                if now >= deadline {
                    anyhow::bail!("only {} of {} CUs became concurrent", *n, self.expected);
                }
                let (g, _) = self.cv.wait_timeout(n, deadline - now).unwrap();
                n = g;
            }
            Ok(ExecResult::default())
        }
    }

    fn n_core_pilot(cores: u32, affinity: &str) -> PilotComputeDescription {
        PilotComputeDescription {
            service_url: "fork://localhost".into(),
            cores,
            walltime_s: 600.0,
            affinity: Some(Label::new(affinity)),
        }
    }

    /// Tentpole acceptance: a pilot with `cores = N` executes up to N
    /// CUs concurrently in local mode.
    #[test]
    fn multi_slot_pilot_runs_n_cus_concurrently() {
        const N: u32 = 4;
        let dir = tmpdir("slots");
        let sys = PilotSystem::new(&dir, Arc::new(GateExecutor::new(N)));
        let pilot = sys.compute_service().create_pilot(n_core_pilot(N, "x")).unwrap();
        let cds = sys.compute_data_service();
        let mut ids = Vec::new();
        for _ in 0..N {
            ids.push(
                cds.submit_compute_unit(ComputeUnitDescription {
                    executable: "builtin:gate".into(),
                    cores: 1,
                    ..Default::default()
                })
                .unwrap(),
            );
        }
        // The gate only opens once all N CUs are inside execute() at
        // the same time, so completion itself proves N-way concurrency.
        sys.wait_all(Duration::from_secs(30)).unwrap();
        for id in &ids {
            assert_eq!(sys.cu_state(id), Some(CuState::Done), "err={:?}", sys.cu_error(id));
        }
        assert_eq!(sys.pilot_busy_slots(&pilot), Some(0), "busy_slots must drain to 0");
        // The dispatch mirror left the drained count in the store too.
        assert_eq!(
            sys.store.hget(&keys::pilot(&pilot), "busy").unwrap().as_deref(),
            Some("0")
        );
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    /// Executor that records its own live/peak concurrency — lets a
    /// test assert how many CUs were ever inside `execute` at once.
    struct PeakExecutor {
        /// (currently inside, peak ever inside)
        state: Mutex<(u32, u32)>,
        dwell: Duration,
    }

    impl PeakExecutor {
        fn new(dwell: Duration) -> PeakExecutor {
            PeakExecutor { state: Mutex::new((0, 0)), dwell }
        }

        fn peak(&self) -> u32 {
            self.state.lock().unwrap().1
        }
    }

    impl Executor for PeakExecutor {
        fn execute(&self, _cu: &ComputeUnitDescription, _sandbox: &Path) -> anyhow::Result<ExecResult> {
            {
                let mut s = self.state.lock().unwrap();
                s.0 += 1;
                s.1 = s.1.max(s.0);
            }
            std::thread::sleep(self.dwell);
            self.state.lock().unwrap().0 -= 1;
            Ok(ExecResult::default())
        }
    }

    /// ROADMAP satellite: a pilot with `cores` ≫ the worker cap spawns
    /// only `cap` OS threads, still completes everything, never runs
    /// more than `cap` CUs at once, and drains `busy_slots` to 0.
    #[test]
    fn capped_pool_bounds_threads_and_concurrency() {
        let dir = tmpdir("cap");
        let exec = Arc::new(PeakExecutor::new(Duration::from_millis(60)));
        let sys = PilotSystem::new(&dir, exec.clone());
        sys.set_worker_cap(3);
        let pilot = sys.compute_service().create_pilot(n_core_pilot(64, "x")).unwrap();
        assert_eq!(sys.agent_count(), 3, "cores ≫ cap must spawn cap workers");
        let cds = sys.compute_data_service();
        let mut ids = Vec::new();
        for _ in 0..9 {
            ids.push(
                cds.submit_compute_unit(ComputeUnitDescription {
                    executable: "builtin:peak".into(),
                    cores: 1,
                    ..Default::default()
                })
                .unwrap(),
            );
        }
        sys.wait_all(Duration::from_secs(20)).unwrap();
        for id in &ids {
            assert_eq!(sys.cu_state(id), Some(CuState::Done), "err={:?}", sys.cu_error(id));
        }
        let peak = exec.peak();
        assert!(peak <= 3, "peak concurrency {peak} exceeded the 3-worker cap");
        assert!(peak >= 2, "capped pool never ran concurrently");
        assert_eq!(sys.pilot_busy_slots(&pilot), Some(0), "busy_slots must drain to 0");
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    /// The slot semaphore keeps `busy ≤ cores` when CUs span multiple
    /// cores: two 3-core CUs on a 4-core pilot must serialize even
    /// though the pool has a free worker thread for each.
    #[test]
    fn slot_semaphore_serializes_wide_cus() {
        let dir = tmpdir("semaphore");
        let exec = Arc::new(PeakExecutor::new(Duration::from_millis(150)));
        let sys = PilotSystem::new(&dir, exec.clone());
        let pilot = sys.compute_service().create_pilot(n_core_pilot(4, "x")).unwrap();
        let cds = sys.compute_data_service();
        let mut ids = Vec::new();
        for _ in 0..2 {
            ids.push(
                cds.submit_compute_unit(ComputeUnitDescription {
                    executable: "builtin:peak".into(),
                    cores: 3,
                    ..Default::default()
                })
                .unwrap(),
            );
        }
        sys.wait_all(Duration::from_secs(20)).unwrap();
        for id in &ids {
            assert_eq!(sys.cu_state(id), Some(CuState::Done), "err={:?}", sys.cu_error(id));
        }
        assert_eq!(exec.peak(), 1, "3+3 cores on a 4-core pilot must not overlap");
        assert_eq!(sys.pilot_busy_slots(&pilot), Some(0));
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    /// Executor that sleeps a fixed unit — the acceptance's wall-time
    /// shape: N unit-cost CUs on an N-slot pilot take ≈ 1 unit.
    struct SleepExecutor(Duration);

    impl Executor for SleepExecutor {
        fn execute(&self, _cu: &ComputeUnitDescription, _sandbox: &Path) -> anyhow::Result<ExecResult> {
            std::thread::sleep(self.0);
            Ok(ExecResult::default())
        }
    }

    #[test]
    fn n_unit_cost_cus_take_about_one_unit_of_wall_time() {
        const N: usize = 6;
        let unit = Duration::from_millis(300);
        let dir = tmpdir("walltime");
        let sys = PilotSystem::new(&dir, Arc::new(SleepExecutor(unit)));
        sys.compute_service().create_pilot(n_core_pilot(N as u32, "x")).unwrap();
        let cds = sys.compute_data_service();
        let t0 = Instant::now();
        let mut ids = Vec::new();
        for _ in 0..N {
            ids.push(
                cds.submit_compute_unit(ComputeUnitDescription {
                    executable: "builtin:sleep".into(),
                    cores: 1,
                    ..Default::default()
                })
                .unwrap(),
            );
        }
        sys.wait_all(Duration::from_secs(20)).unwrap();
        let elapsed = t0.elapsed();
        for id in &ids {
            assert_eq!(sys.cu_state(id), Some(CuState::Done), "err={:?}", sys.cu_error(id));
        }
        // Serial execution would take N units (1.8 s); allow generous
        // CI slack while still ruling out serialization.
        assert!(
            elapsed < unit * 4,
            "{N} unit-cost CUs took {elapsed:?} on a {N}-slot pilot (serial would be {:?})",
            unit * N as u32
        );
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    /// Executor that reports entry on a channel, then dwells — so a
    /// test can inject a store outage while CUs are verifiably
    /// mid-execution.
    struct NotifyingSleepExecutor {
        entered: Mutex<std::sync::mpsc::Sender<()>>,
        dwell: Duration,
    }

    impl Executor for NotifyingSleepExecutor {
        fn execute(&self, _cu: &ComputeUnitDescription, _sandbox: &Path) -> anyhow::Result<ExecResult> {
            let _ = self.entered.lock().unwrap().send(());
            std::thread::sleep(self.dwell);
            Ok(ExecResult::default())
        }
    }

    /// Fault injection (ISSUE 3 satellite): outage mid-execution with
    /// multi-slot workers busy — in-flight CUs complete cleanly,
    /// busy_slots drains to 0, parked workers surface Unavailable and
    /// wait, and recovery (outage guard drop, then snapshot restore)
    /// resumes dispatch.
    #[test]
    fn outage_mid_execution_drains_cleanly_and_recovers() {
        let dir = tmpdir("outage-slots");
        let (tx, rx) = std::sync::mpsc::channel();
        let sys = PilotSystem::new(
            &dir,
            Arc::new(NotifyingSleepExecutor {
                entered: Mutex::new(tx),
                dwell: Duration::from_millis(200),
            }),
        );
        let pilot = sys.compute_service().create_pilot(n_core_pilot(2, "x")).unwrap();
        let cds = sys.compute_data_service();
        let submit = |cds: &ComputeDataService| {
            cds.submit_compute_unit(ComputeUnitDescription {
                executable: "builtin:notify-sleep".into(),
                cores: 1,
                ..Default::default()
            })
        };
        let snap = sys.store.snapshot();
        let a = submit(&cds).unwrap();
        let b = submit(&cds).unwrap();
        // Both workers are inside the executor: the outage hits
        // mid-execution with the whole pool busy.
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
        {
            let _outage = crate::faults::ScopedOutage::inject(&sys.store);
            // In-flight CUs run to completion against the dead store
            // (state lives under the manager lock; store mirrors and
            // publishes are best-effort).
            sys.wait_all(Duration::from_secs(10)).unwrap();
            assert_eq!(sys.cu_state(&a), Some(CuState::Done), "err={:?}", sys.cu_error(&a));
            assert_eq!(sys.cu_state(&b), Some(CuState::Done), "err={:?}", sys.cu_error(&b));
            assert_eq!(sys.pilot_busy_slots(&pilot), Some(0), "busy_slots leaked");
            // Submitting against the dead store fails cleanly.
            assert!(submit(&cds).is_err(), "enqueue must fail while the store is down");
        } // guard drop restores availability and wakes parked workers
        let c = submit(&cds).unwrap();
        sys.wait_all(Duration::from_secs(10)).unwrap();
        assert_eq!(sys.cu_state(&c), Some(CuState::Done), "dispatch did not resume");
        // Second outage, recovered via snapshot restore (the paper's
        // "restart the Redis server" path): restore clears the down
        // flag and wakes `wait_available` parkers.
        sys.store.set_down(true);
        sys.store.restore(&snap).unwrap();
        assert!(!sys.store.is_down());
        let d = submit(&cds).unwrap();
        sys.wait_all(Duration::from_secs(10)).unwrap();
        assert_eq!(sys.cu_state(&d), Some(CuState::Done), "dispatch dead after restore");
        assert_eq!(sys.pilot_busy_slots(&pilot), Some(0));
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn many_cus_distribute_across_pilot_slots() {
        let dir = tmpdir("many");
        let sys = PilotSystem::new(&dir, Arc::new(ShellExecutor));
        let pcs = sys.compute_service();
        pcs.create_pilot(one_core_pilot("x")).unwrap();
        pcs.create_pilot(one_core_pilot("y")).unwrap();
        let cds = sys.compute_data_service();
        let mut ids = Vec::new();
        for i in 0..12 {
            ids.push(
                cds.submit_compute_unit(ComputeUnitDescription {
                    executable: "/bin/sh".into(),
                    arguments: vec!["-c".into(), format!("echo {i} > o.txt")],
                    cores: 1,
                    ..Default::default()
                })
                .unwrap(),
            );
        }
        sys.wait_all(Duration::from_secs(30)).unwrap();
        for id in &ids {
            assert_eq!(sys.cu_state(id), Some(CuState::Done));
        }
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
    }
}
