//! Shared emission path for the harness-less bench targets
//! (`benches/*.rs`, `harness = false`).
//!
//! Every bench ends the same way: collect `(name, value)` rows while
//! printing human-readable progress, then flatten them into one JSON
//! object and write it where CI's artifact-upload step expects it.
//! That tail (plus the `PD_BENCH_QUICK` tier check and the per-bench
//! `PD_BENCH_*_OUT` path override) used to be copy-pasted into each
//! target; this module is the single copy.
//!
//! ```no_run
//! let mut results: Vec<(String, f64)> = Vec::new();
//! results.push(("tier_1 events_per_sec".into(), 1.5e6));
//! pilot_data::util::bench_out::emit("PD_BENCH_X_OUT", "BENCH_x.json", &results);
//! ```

use crate::json::Json;

/// True when `PD_BENCH_QUICK` is set — benches drop to their reduced
/// CI smoke tiers (fewer iterations / smaller grids), keeping the
/// emitted JSON schema identical to a full run.
pub fn quick() -> bool {
    std::env::var("PD_BENCH_QUICK").is_ok()
}

/// Resolve the output path for a bench: the value of `env_var` when
/// set, else `default` (the committed `BENCH_*.json` name CI uploads).
pub fn out_path(env_var: &str, default: &str) -> String {
    std::env::var(env_var).unwrap_or_else(|_| default.to_string())
}

/// Flatten `results` name→value rows into one JSON object and write it
/// to [`out_path`]`(env_var, default)`, printing the `[json]` trailer
/// the bench logs always end with. Duplicate names keep the last
/// value (the object is a map). Write failures are reported on stderr
/// but do not panic — a bench run's measurements still printed.
pub fn emit(env_var: &str, default: &str, results: &[(String, f64)]) {
    let out = out_path(env_var, default);
    let mut obj = Json::obj();
    for (name, v) in results {
        obj = obj.set(name.as_str(), *v);
    }
    match std::fs::write(&out, obj.to_string_pretty()) {
        Ok(()) => println!("\n[json] {out}"),
        Err(e) => eprintln!("\n[json] failed to write {out}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_flattens_rows_into_the_json_object() {
        let dir = std::env::temp_dir().join("pd_bench_out_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_emit_test.json");
        let path_s = path.to_str().unwrap().to_string();
        // Unset env var falls back to the default path.
        assert_eq!(out_path("PD_BENCH_OUT_TEST_UNSET_VAR", &path_s), path_s);
        let rows = vec![
            ("alpha events_per_sec".to_string(), 1.5e6),
            ("beta wall_s".to_string(), 0.25),
        ];
        emit("PD_BENCH_OUT_TEST_UNSET_VAR", &path_s, &rows);
        let parsed = crate::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("alpha events_per_sec").and_then(|j| j.as_f64()), Some(1.5e6));
        assert_eq!(parsed.get("beta wall_s").and_then(|j| j.as_f64()), Some(0.25));
        let _ = std::fs::remove_file(&path);
    }
}
