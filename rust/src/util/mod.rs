//! Small shared utilities: unique ids, byte/size formatting, duration
//! formatting, a dependency-free CLI argument parser, and the shared
//! BENCH-JSON emission helper for the harness-less bench targets.

pub mod bench_out;
pub mod cli;

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic process-wide counter used to mint unique entity ids.
static ID_COUNTER: AtomicU64 = AtomicU64::new(1);

/// Mint a unique id with the given prefix, e.g. `du-000017`,
/// `cu-000042`, `pilot-000003`. Mirrors the paper's URL-style unique
/// entity names (`redis://host/bigjob:pd:<uuid>` etc.) without
/// requiring a live coordination server at construction time.
///
/// The counter is zero-padded so the ids' *lexicographic* order equals
/// their creation order — scheduler tie-breaks and `BTreeMap`
/// iteration sort by id, and an unpadded `pilot-10` would sort before
/// `pilot-9`, making entity ordering (and thus placement traces)
/// depend on how many ids other tests happened to mint first. The
/// width covers the first 10^9 ids per process; a counter beyond that
/// would reintroduce the ordering skew, so it is asserted against.
pub fn next_id(prefix: &str) -> String {
    let n = ID_COUNTER.fetch_add(1, Ordering::Relaxed);
    debug_assert!(n < 1_000_000_000, "id counter exceeded the zero-padded width");
    format!("{prefix}-{n:09}")
}

/// Reset the id counter (test determinism only).
pub fn reset_ids_for_test() {
    ID_COUNTER.store(1, Ordering::Relaxed);
}

/// Bytes, with human-friendly construction and display.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Bytes(pub u64);

impl Bytes {
    pub const fn b(n: u64) -> Self {
        Bytes(n)
    }
    pub const fn kb(n: u64) -> Self {
        Bytes(n * 1024)
    }
    pub const fn mb(n: u64) -> Self {
        Bytes(n * 1024 * 1024)
    }
    pub const fn gb(n: u64) -> Self {
        Bytes(n * 1024 * 1024 * 1024)
    }
    pub fn as_u64(self) -> u64 {
        self.0
    }
    pub fn as_f64(self) -> f64 {
        self.0 as f64
    }
    /// Gigabytes as a float (for rate math).
    pub fn gib(self) -> f64 {
        self.0 as f64 / (1024.0 * 1024.0 * 1024.0)
    }
    /// Megabytes as a float.
    pub fn mib(self) -> f64 {
        self.0 as f64 / (1024.0 * 1024.0)
    }
    pub fn saturating_sub(self, other: Bytes) -> Bytes {
        Bytes(self.0.saturating_sub(other.0))
    }
}

impl std::ops::Add for Bytes {
    type Output = Bytes;
    fn add(self, rhs: Bytes) -> Bytes {
        Bytes(self.0 + rhs.0)
    }
}

impl std::iter::Sum for Bytes {
    fn sum<I: Iterator<Item = Bytes>>(iter: I) -> Bytes {
        Bytes(iter.map(|b| b.0).sum())
    }
}

impl fmt::Display for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let b = self.0 as f64;
        if self.0 >= 1024 * 1024 * 1024 {
            write!(f, "{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
        } else if self.0 >= 1024 * 1024 {
            write!(f, "{:.2} MiB", b / (1024.0 * 1024.0))
        } else if self.0 >= 1024 {
            write!(f, "{:.2} KiB", b / 1024.0)
        } else {
            write!(f, "{} B", self.0)
        }
    }
}

impl fmt::Debug for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

/// Format a duration given in (possibly simulated) seconds as `1h02m03s`.
/// Negative finite inputs clamp to zero on every path (the h/m branches
/// already truncated them, but the sub-10s branch used to print the raw
/// `-5.00s`); `-0.0` normalizes to `0.00s`.
pub fn fmt_secs(secs: f64) -> String {
    if !secs.is_finite() {
        return format!("{secs}");
    }
    let secs = if secs > 0.0 { secs } else { 0.0 };
    let total = secs.round() as u64;
    let (h, m, s) = (total / 3600, (total % 3600) / 60, total % 60);
    if h > 0 {
        format!("{h}h{m:02}m{s:02}s")
    } else if m > 0 {
        format!("{m}m{s:02}s")
    } else if secs < 10.0 {
        format!("{secs:.2}s")
    } else {
        format!("{s}s")
    }
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation of a slice (0.0 for len < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank) of an unsorted slice; p in [0, 100]
/// (out-of-range p clamps). Nearest-rank means the value at 1-based
/// sorted rank ⌈p/100 · N⌉, with p = 0 mapping to the minimum — so
/// p50 of `[1, 2, 3, 4]` is 2, and p100 is always the maximum. The
/// sort uses `f64::total_cmp`, so NaN inputs sort last instead of
/// panicking; they can only surface if p reaches into them.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let p = p.clamp(0.0, 100.0);
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_unique_and_prefixed() {
        let a = next_id("du");
        let b = next_id("du");
        assert_ne!(a, b);
        assert!(a.starts_with("du-"));
        // Lexicographic order == creation order (zero-padding): the
        // scheduler's id tie-break depends on this.
        assert!(a < b, "{a} must sort before {b}");
    }

    #[test]
    fn bytes_display_scales() {
        assert_eq!(format!("{}", Bytes::b(10)), "10 B");
        assert_eq!(format!("{}", Bytes::kb(2)), "2.00 KiB");
        assert_eq!(format!("{}", Bytes::mb(3)), "3.00 MiB");
        assert_eq!(format!("{}", Bytes::gb(4)), "4.00 GiB");
    }

    #[test]
    fn bytes_arith() {
        assert_eq!(Bytes::kb(1) + Bytes::kb(1), Bytes::kb(2));
        assert_eq!(Bytes::kb(1).saturating_sub(Bytes::mb(1)), Bytes::b(0));
        let total: Bytes = vec![Bytes::b(1), Bytes::b(2)].into_iter().sum();
        assert_eq!(total, Bytes::b(3));
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(3.5), "3.50s");
        assert_eq!(fmt_secs(75.0), "1m15s");
        assert_eq!(fmt_secs(3723.0), "1h02m03s");
    }

    #[test]
    fn fmt_secs_clamps_negatives_uniformly() {
        // Every branch clamps, not just the h/m ones.
        assert_eq!(fmt_secs(-5.0), "0.00s");
        assert_eq!(fmt_secs(-75.0), "0.00s");
        assert_eq!(fmt_secs(-3723.0), "0.00s");
        assert_eq!(fmt_secs(-0.0), "0.00s");
    }

    #[test]
    fn stats_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 50.0), 2.0);
        assert_eq!(percentile(&[5.0], 99.0), 5.0);
    }

    #[test]
    fn percentile_nearest_rank_edges() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 25.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 2.0);
        assert_eq!(percentile(&v, 75.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        // Out-of-range p clamps instead of indexing out of bounds.
        assert_eq!(percentile(&v, 120.0), 4.0);
        assert_eq!(percentile(&v, -10.0), 1.0);
        assert_eq!(percentile(&[5.0], 1.0), 5.0);
        // total_cmp sorts NaN last; finite percentiles never touch it.
        assert_eq!(percentile(&[f64::NAN, 2.0, 1.0], 50.0), 2.0);
    }
}
