//! Dependency-free CLI argument parsing (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments; unknown flags are errors so typos fail loudly.

use std::collections::BTreeMap;

/// Parsed command line: positionals in order, options by name.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw arguments. `flag_names` lists boolean flags (take no
    /// value); everything else starting `--` consumes a value.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        flag_names: &[&str],
    ) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        anyhow::anyhow!("option --{rest} requires a value")
                    })?;
                    out.options.insert(rest.to_string(), v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("invalid value for --{name}: {e}")),
        }
    }

    pub fn opt_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt_parse(name)?.unwrap_or(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_positionals_options_flags() {
        let a = Args::parse(sv(&["exp", "fig7", "--seed", "42", "--verbose", "--out=o.csv"]), &["verbose"]).unwrap();
        assert_eq!(a.positional, vec!["exp", "fig7"]);
        assert_eq!(a.opt("seed"), Some("42"));
        assert!(a.flag("verbose"));
        assert_eq!(a.opt("out"), Some("o.csv"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(sv(&["--seed"]), &[]).is_err());
    }

    #[test]
    fn typed_parse() {
        let a = Args::parse(sv(&["--n", "8"]), &[]).unwrap();
        assert_eq!(a.opt_parse_or::<u64>("n", 1).unwrap(), 8);
        assert_eq!(a.opt_parse_or::<u64>("m", 5).unwrap(), 5);
        let bad = Args::parse(sv(&["--n", "x"]), &[]).unwrap();
        assert!(bad.opt_parse::<u64>("n").is_err());
    }
}
