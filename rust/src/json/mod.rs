//! From-scratch JSON support (serde is unavailable offline).
//!
//! The Pilot-API describes Pilots, Compute-Units and Data-Units with
//! JSON documents (Compute-Unit-Descriptions, Data-Unit-Descriptions,
//! Pilot-Descriptions — §4.3 of the paper), and the coordination store
//! snapshots its state as JSON. This module provides the [`Json`] value
//! type, a recursive-descent parser, and a serializer with optional
//! pretty-printing.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic — important for snapshot diffing and golden tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style insertion; panics if `self` is not an object.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// String field accessor with a contextual error.
    pub fn str_field(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    pub fn u64_field_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Json::as_u64).unwrap_or(default)
    }

    pub fn f64_field_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, level + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

/// Parse a JSON document. Rejects trailing garbage.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing characters at offset {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at offset {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at offset {}", other.map(|b| b as char), self.pos),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at offset {}", self.pos)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = s
            .parse()
            .map_err(|e| anyhow::anyhow!("invalid number '{s}' at offset {start}: {e}"))?;
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let s = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected ',' or ']' found {:?}", other.map(|b| b as char)),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("expected ',' or '}}' found {:?}", other.map(|b| b as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string_compact()).unwrap(), v, "src={src}");
        }
    }

    #[test]
    fn parses_nested_document() {
        let src = r#"{"executable":"/bin/bwa","args":["aln","-t","4"],"cores":2,
                      "input_data":["du-1"],"affinity":"us-east/tacc/lonestar","ok":true}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.str_field("executable").unwrap(), "/bin/bwa");
        assert_eq!(v.u64_field_or("cores", 0), 2);
        assert_eq!(v.get("args").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn roundtrip_pretty_and_compact_agree() {
        let v = Json::obj()
            .set("name", "pd-1")
            .set("size", 8u64)
            .set("repl", vec!["a", "b"])
            .set("nested", Json::obj().set("x", 1.5));
        let c = parse(&v.to_string_compact()).unwrap();
        let p = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(c, v);
        assert_eq!(p, v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".to_string()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("nulL").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
    }
}
