//! The evaluation workload: BWA-style genome read alignment.
//!
//! The paper evaluates Pilot-Data with BWA over (i) an 8 GB reference
//! genome + index shared by all tasks and (ii) partitioned read files
//! (Fig. 9: 2 GB reads → 8 tasks × 256 MB; Fig. 11: 1024 tasks × 1 GB
//! reads, 9 GB consumed per task). This module provides
//!
//! * real small-scale data: synthetic genome + sampled reads with
//!   errors, encoded as `runtime::payload` files for the local
//!   execution mode (the end-to-end example);
//! * sim-scale workload builders producing the DU/CU ensembles of the
//!   Fig. 9 and Fig. 11 experiments with the paper's data footprints;
//! * the task cost model used by the sim driver.

pub mod mapreduce;
pub mod openloop;

use crate::rng::Rng;
use crate::unit::{ComputeUnitDescription, DataUnitDescription, FileRef};
use crate::util::Bytes;

/// Synthetic genome: `len` base codes in {0,1,2,3}.
pub fn synth_genome(rng: &mut Rng, len: usize) -> Vec<u8> {
    (0..len).map(|_| rng.below(4) as u8).collect()
}

/// Sample `n` reads of length `read_len` uniformly from the genome,
/// flipping each base with probability `err_rate`. Returns (reads,
/// true_positions).
pub fn sample_reads(
    rng: &mut Rng,
    genome: &[u8],
    n: usize,
    read_len: usize,
    err_rate: f64,
) -> (Vec<Vec<u8>>, Vec<usize>) {
    sample_reads_lattice(rng, genome, n, read_len, err_rate, 1)
}

/// Like [`sample_reads`] but with start positions restricted to a
/// `lattice`-base grid — pairs with the seed kernel's shift lattice
/// (`SHIFT_STRIDE` in `python/compile/kernels/ref.py`) so an exact
/// shifted placement always exists.
pub fn sample_reads_lattice(
    rng: &mut Rng,
    genome: &[u8],
    n: usize,
    read_len: usize,
    err_rate: f64,
    lattice: usize,
) -> (Vec<Vec<u8>>, Vec<usize>) {
    assert!(genome.len() >= read_len, "genome shorter than read");
    assert!(lattice >= 1);
    let mut reads = Vec::with_capacity(n);
    let mut positions = Vec::with_capacity(n);
    let slots = (genome.len() - read_len) / lattice + 1;
    for _ in 0..n {
        let pos = rng.below(slots as u64) as usize * lattice;
        let mut read: Vec<u8> = genome[pos..pos + read_len].to_vec();
        for b in read.iter_mut() {
            if rng.chance(err_rate) {
                *b = ((*b + 1 + rng.below(3) as u8) % 4) as u8;
            }
        }
        reads.push(read);
        positions.push(pos);
    }
    (reads, positions)
}

/// Tile the genome into overlapping windows of `win_len` at `stride`.
pub fn extract_windows(genome: &[u8], win_len: usize, stride: usize) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    let mut start = 0;
    while start + win_len <= genome.len() {
        out.push(genome[start..start + win_len].to_vec());
        start += stride;
    }
    out
}

/// Encode base codes as the f32 row-major payload the runtime expects.
pub fn encode_f32(rows: &[Vec<u8>]) -> Vec<f32> {
    rows.iter().flat_map(|r| r.iter().map(|&b| b as f32)).collect()
}

/// Compute the fraction of reads whose best window contains their true
/// sampling position (the end-to-end accuracy metric).
pub fn window_hit_rate(
    positions: &[usize],
    best_windows: &[f32],
    win_len: usize,
    stride: usize,
    read_len: usize,
) -> f64 {
    let mut hits = 0usize;
    for (i, &pos) in positions.iter().enumerate() {
        let w = best_windows[i] as usize;
        let (ws, we) = (w * stride, w * stride + win_len);
        if pos >= ws && pos + read_len <= we {
            hits += 1;
        }
    }
    hits as f64 / positions.len().max(1) as f64
}

/// The BWA ensemble of Fig. 9: one shared reference DU (genome +
/// index) and `tasks` read-chunk DUs with per-task CUs.
pub struct BwaEnsemble {
    pub reference: DataUnitDescription,
    pub read_chunks: Vec<DataUnitDescription>,
    pub cu_template: ComputeUnitDescription,
}

/// Build the Fig. 9-scale ensemble: `tasks` tasks, `reads_total` of
/// read data partitioned evenly, reference of `ref_size`.
pub fn bwa_ensemble(tasks: usize, reads_total: Bytes, ref_size: Bytes) -> BwaEnsemble {
    let chunk = Bytes(reads_total.0 / tasks as u64);
    let reference = DataUnitDescription {
        name: "bwa-reference".into(),
        files: vec![
            FileRef::sized("genome.fa", Bytes(ref_size.0 * 3 / 4)),
            FileRef::sized("genome.bwt", Bytes(ref_size.0 / 8)),
            FileRef::sized("genome.sa", Bytes(ref_size.0 / 8)),
        ],
        affinity: None,
    };
    let read_chunks = (0..tasks)
        .map(|i| DataUnitDescription {
            name: format!("reads-{i:04}"),
            files: vec![FileRef::sized(&format!("chunk{i:04}.fq"), chunk)],
            affinity: None,
        })
        .collect();
    // Per-task: scan the reference (+ its chunk) once -> I/O bytes;
    // CPU scales with chunk size relative to the 256 MiB reference
    // chunk of Fig. 9.
    let cpu = crate::config::bwa_cpu_secs_per_chunk() * chunk.as_f64()
        / Bytes::mb(256).as_f64();
    let cu_template = ComputeUnitDescription {
        executable: "bwa".into(),
        arguments: vec!["aln".into()],
        cores: 2,
        cpu_secs_hint: cpu,
        io_bytes_hint: ref_size + chunk,
        ..Default::default()
    };
    BwaEnsemble { reference, read_chunks, cu_template }
}

/// Cell-parameterized variant of [`bwa_ensemble`] for the sweep
/// harness (`crate::experiments::sweep`): same footprint math, but the
/// shared reference carries a caller-chosen affinity label (the
/// pre-stage/auto-replicate policies fan out over it) and the per-CU
/// core count is a sweep knob instead of the paper's fixed 2.
pub fn sweep_ensemble(
    tasks: usize,
    reads_total: Bytes,
    ref_size: Bytes,
    ref_affinity: &str,
    cu_cores: u32,
) -> BwaEnsemble {
    assert!(cu_cores >= 1, "CUs need at least one core");
    let mut ens = bwa_ensemble(tasks, reads_total, ref_size);
    ens.reference.affinity = Some(crate::topology::Label::new(ref_affinity));
    ens.cu_template.cores = cu_cores;
    ens
}

/// Task cost model (sim mode): pure CPU time scaled by machine speed +
/// shared-FS scan time at the task's current bandwidth share.
pub fn task_runtime_s(
    cpu_secs_hint: f64,
    io_bytes_hint: Bytes,
    speed_factor: f64,
    fs_share_bytes_per_s: f64,
) -> f64 {
    let io = if fs_share_bytes_per_s > 0.0 {
        io_bytes_hint.as_f64() / fs_share_bytes_per_s
    } else {
        f64::INFINITY
    };
    cpu_secs_hint * speed_factor + io
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn genome_and_reads_are_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        assert_eq!(synth_genome(&mut r1, 100), synth_genome(&mut r2, 100));
    }

    #[test]
    fn reads_come_from_genome_when_error_free() {
        let mut rng = Rng::new(6);
        let genome = synth_genome(&mut rng, 1000);
        let (reads, pos) = sample_reads(&mut rng, &genome, 20, 50, 0.0);
        for (read, p) in reads.iter().zip(&pos) {
            assert_eq!(read.as_slice(), &genome[*p..*p + 50]);
        }
    }

    #[test]
    fn error_rate_perturbs_reads() {
        let mut rng = Rng::new(7);
        let genome = synth_genome(&mut rng, 2000);
        let (reads, pos) = sample_reads(&mut rng, &genome, 50, 100, 0.1);
        let mut mismatches = 0usize;
        for (read, p) in reads.iter().zip(&pos) {
            mismatches += read
                .iter()
                .zip(&genome[*p..*p + 100])
                .filter(|(a, b)| a != b)
                .count();
        }
        let rate = mismatches as f64 / (50.0 * 100.0);
        assert!((rate - 0.1).abs() < 0.03, "rate={rate}");
    }

    #[test]
    fn windows_tile_the_genome() {
        let genome: Vec<u8> = (0..100).map(|i| (i % 4) as u8).collect();
        let w = extract_windows(&genome, 20, 10);
        assert_eq!(w.len(), 9);
        assert_eq!(w[0].as_slice(), &genome[0..20]);
        assert_eq!(w[8].as_slice(), &genome[80..100]);
    }

    #[test]
    fn encode_f32_flattens_row_major() {
        let rows = vec![vec![0u8, 1], vec![2, 3]];
        assert_eq!(encode_f32(&rows), vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn hit_rate_full_and_zero() {
        // Window 0 covers [0, 20); read at pos 2 len 10 fits.
        assert_eq!(window_hit_rate(&[2], &[0.0], 20, 10, 10), 1.0);
        // Wrong window.
        assert_eq!(window_hit_rate(&[50], &[0.0], 20, 10, 10), 0.0);
    }

    #[test]
    fn ensemble_matches_paper_fig9_footprint() {
        let e = bwa_ensemble(8, Bytes::gb(2), Bytes::gb(8));
        assert_eq!(e.read_chunks.len(), 8);
        assert_eq!(e.read_chunks[0].total_size(), Bytes::mb(256));
        let ref_total = e.reference.total_size();
        assert_eq!(ref_total, Bytes::gb(8));
        // Per-task consumption ≈ 8.25 GiB (ref + chunk).
        let per_task = e.cu_template.io_bytes_hint;
        assert_eq!(per_task, Bytes::gb(8) + Bytes::mb(256));
        assert!((e.cu_template.cpu_secs_hint - crate::config::bwa_cpu_secs_per_chunk()).abs() < 1.0);
    }

    #[test]
    fn ensemble_matches_paper_fig11_footprint() {
        // 1024 tasks x 1 GB reads; 9 GB consumed per task.
        let e = bwa_ensemble(1024, Bytes::gb(1024), Bytes::gb(8));
        assert_eq!(e.read_chunks.len(), 1024);
        assert_eq!(e.read_chunks[0].total_size(), Bytes::gb(1));
        assert_eq!(e.cu_template.io_bytes_hint, Bytes::gb(9));
        assert_eq!(e.cu_template.cores, 2); // "For each tasks two cores"
    }

    #[test]
    fn sweep_ensemble_parameterizes_affinity_and_cores() {
        let e = sweep_ensemble(4, Bytes::gb(1), Bytes::gb(8), "grid", 1);
        assert_eq!(e.reference.affinity, Some(crate::topology::Label::new("grid")));
        assert_eq!(e.cu_template.cores, 1);
        // Footprint math is unchanged from the paper ensemble.
        assert_eq!(e.read_chunks.len(), 4);
        assert_eq!(e.reference.total_size(), Bytes::gb(8));
    }

    #[test]
    fn cost_model_io_dominates_when_share_small() {
        let fast = task_runtime_s(100.0, Bytes::gb(9), 1.0, 1e9);
        let slow = task_runtime_s(100.0, Bytes::gb(9), 1.0, 16e6);
        assert!(slow > 5.0 * fast, "fast={fast} slow={slow}");
        assert!(task_runtime_s(1.0, Bytes::gb(1), 1.0, 0.0).is_infinite());
    }
}
