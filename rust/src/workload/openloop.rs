//! Open-loop stochastic workload generation.
//!
//! Every other workload in the repo is a *closed batch*: all CUs and
//! DUs exist at t=0 and the run ends when the backlog drains. Real
//! pilot deployments are *open-loop* — work arrives over time from a
//! population of users, and the interesting regimes (backlog growth,
//! utilization knees, the ρ = 1 stability boundary) only appear under
//! arrival-driven load. This module provides the generator side:
//!
//! * [`ArrivalProcess`] — when the next submission lands: Poisson
//!   (exponential inter-arrival), deterministic rate, or a diurnal
//!   rate-modulated Poisson process sampled exactly by thinning;
//! * [`Dist`] — how service demands and DU sizes are drawn, including
//!   the heavy-tailed log-normal runtimes seen in production traces;
//! * [`TenantSpec`]/[`OpenLoopSpec`]/[`OpenLoopRun`] — a multi-tenant
//!   population in which every tenant draws from its own
//!   [`Rng::stream`], so adding or removing one tenant never perturbs
//!   the arrival/demand sequences of the others;
//! * Erlang closed forms ([`erlang_c`], [`mmc_mean_wait`]) — the
//!   analytic M/M/c oracle that `experiments::openloop` validates the
//!   whole DES pipeline against.
//!
//! The DES side lives in `experiments::simdrive`: an `ArrivalDue`
//! event asks the [`OpenLoopRun`] for the next [`ArrivalBatch`] and
//! feeds it through the normal submission path inside simulated time.

use crate::rng::Rng;
use crate::unit::{ComputeUnitDescription, DataUnitDescription, FileRef};
use crate::util::Bytes;

/// When a tenant's next arrival lands. All rates are arrivals per
/// simulated second.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Poisson process: exponential inter-arrival with mean `1/rate`.
    Poisson { rate: f64 },
    /// Deterministic: exactly `1/rate` between arrivals.
    Deterministic { rate: f64 },
    /// Rate-modulated (inhomogeneous) Poisson: the instantaneous rate
    /// swings sinusoidally around `base_rate` with relative
    /// `amplitude` in [0, 1] and period `period_s` — the diurnal load
    /// shape. Sampled by thinning (Lewis & Shedler): candidates at the
    /// peak rate are accepted with probability `rate(t)/rate_peak`,
    /// which preserves the exact inhomogeneous-Poisson law.
    Diurnal { base_rate: f64, amplitude: f64, period_s: f64 },
}

impl ArrivalProcess {
    /// Draw the delay from an arrival at `t` (seconds since the
    /// open-loop start) to this tenant's next arrival.
    pub fn next_interval(&self, rng: &mut Rng, t: f64) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => {
                assert!(*rate > 0.0, "Poisson rate must be positive");
                rng.exp(1.0 / rate)
            }
            ArrivalProcess::Deterministic { rate } => {
                assert!(*rate > 0.0, "deterministic rate must be positive");
                1.0 / rate
            }
            ArrivalProcess::Diurnal { base_rate, amplitude, period_s } => {
                assert!(*base_rate > 0.0 && *period_s > 0.0);
                assert!((0.0..=1.0).contains(amplitude), "amplitude in [0, 1]");
                let peak = base_rate * (1.0 + amplitude);
                let mut at = t;
                let mut waited = 0.0;
                loop {
                    let step = rng.exp(1.0 / peak);
                    waited += step;
                    at += step;
                    let rate_at = base_rate
                        * (1.0 + amplitude * (std::f64::consts::TAU * at / period_s).sin());
                    if rng.f64() < rate_at / peak {
                        return waited;
                    }
                }
            }
        }
    }

    /// Long-run mean arrival rate (the sinusoidal modulation averages
    /// out over whole periods).
    pub fn mean_rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Deterministic { rate } => *rate,
            ArrivalProcess::Diurnal { base_rate, .. } => *base_rate,
        }
    }
}

/// How a scalar demand (service seconds, DU bytes) is drawn.
#[derive(Debug, Clone)]
pub enum Dist {
    Fixed(f64),
    /// Exponential with the given mean — the M/M/c service law.
    Exp { mean: f64 },
    /// Log-normal parameterized by the mean/std of the *underlying*
    /// normal — the heavy-tailed runtime/size model.
    LogNormal { mu: f64, sigma: f64 },
}

impl Dist {
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            Dist::Fixed(v) => *v,
            Dist::Exp { mean } => rng.exp(*mean),
            Dist::LogNormal { mu, sigma } => rng.lognormal(*mu, *sigma),
        }
    }

    /// Analytic mean (for load math and reporting).
    pub fn mean(&self) -> f64 {
        match self {
            Dist::Fixed(v) => *v,
            Dist::Exp { mean } => *mean,
            Dist::LogNormal { mu, sigma } => (mu + sigma * sigma / 2.0).exp(),
        }
    }
}

/// One tenant of the multi-tenant open-loop population.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Stable name. Keys the tenant's independent RNG stream: the
    /// stream is a pure function of (base seed, name), so a population
    /// change never perturbs this tenant's draws.
    pub name: String,
    pub arrivals: ArrivalProcess,
    /// Service demand per CU (`cpu_secs_hint`; on a speed-1.0 machine
    /// with no I/O this *is* the service time).
    pub service: Dist,
    /// CUs per arrival (≥ 1; a burst arrives as one batch submission).
    pub batch: usize,
    /// Cores per CU.
    pub cores: u32,
    /// Data each arrival brings: `None` is compute-only (the M/M/c
    /// shape — inputs pre-placed or absent); `Some((size_dist, pd))`
    /// pre-places one fresh DU of sampled size on pilot-data store
    /// `pd` per arrival and wires it as every batch CU's input.
    pub du: Option<(Dist, String)>,
}

impl TenantSpec {
    /// Compute-only tenant with Poisson arrivals and exponential
    /// service — the building block of the M/M/c validation.
    pub fn poisson(name: &str, rate: f64, mean_service_s: f64) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            arrivals: ArrivalProcess::Poisson { rate },
            service: Dist::Exp { mean: mean_service_s },
            batch: 1,
            cores: 1,
            du: None,
        }
    }
}

/// The whole open-loop workload: a tenant population plus stopping
/// rules. At least one stopping rule must be set, or arrivals would
/// never end.
#[derive(Debug, Clone)]
pub struct OpenLoopSpec {
    pub tenants: Vec<TenantSpec>,
    /// Stop a tenant's arrivals once its count reaches this bound.
    pub max_arrivals_per_tenant: Option<u64>,
    /// Stop all arrivals past `start + horizon_s` of simulated time.
    pub horizon_s: Option<f64>,
}

/// One arrival's submission payload, produced by
/// [`OpenLoopRun::next_batch`].
#[derive(Debug, Clone)]
pub struct ArrivalBatch {
    /// DU descriptions to pre-place on the named PD before the CUs
    /// submit. The minted id of `dus[i]` is substituted for the
    /// placeholder `@i` in the CUs' `input_data`.
    pub dus: Vec<(DataUnitDescription, String)>,
    pub cus: Vec<ComputeUnitDescription>,
    /// Delay to this tenant's next arrival; `None` once a stopping
    /// rule has been reached.
    pub next_in: Option<f64>,
}

/// Live generator state: per-tenant RNG streams and arrival counters.
/// Deliberately sim-agnostic — the driver owns the clock and asks for
/// batches at the times this generator dictated, so the whole arrival
/// schedule is a pure function of (spec, seed).
#[derive(Debug, Clone)]
pub struct OpenLoopRun {
    spec: OpenLoopSpec,
    /// Simulated time of `start_open_loop` (arrival t=0).
    t0: f64,
    tenants: Vec<TenantState>,
}

#[derive(Debug, Clone)]
struct TenantState {
    rng: Rng,
    arrivals: u64,
}

impl OpenLoopRun {
    pub fn new(spec: OpenLoopSpec, seed: u64, t0: f64) -> OpenLoopRun {
        assert!(!spec.tenants.is_empty(), "open-loop spec needs at least one tenant");
        assert!(
            spec.max_arrivals_per_tenant.is_some() || spec.horizon_s.is_some(),
            "open-loop spec needs a stopping rule (max arrivals or horizon)"
        );
        let base = Rng::new(seed);
        let tenants = spec
            .tenants
            .iter()
            .map(|t| TenantState {
                rng: base.stream(&format!("openloop:{}", t.name)),
                arrivals: 0,
            })
            .collect();
        OpenLoopRun { spec, t0, tenants }
    }

    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }

    pub fn arrivals(&self, tenant: usize) -> u64 {
        self.tenants[tenant].arrivals
    }

    pub fn total_arrivals(&self) -> u64 {
        self.tenants.iter().map(|t| t.arrivals).sum()
    }

    /// Delay from the open-loop start to tenant `i`'s first arrival.
    pub fn first_delay(&mut self, i: usize) -> f64 {
        let spec = &self.spec.tenants[i];
        spec.arrivals.next_interval(&mut self.tenants[i].rng, 0.0)
    }

    /// Generate the batch due now for tenant `i` plus the delay to its
    /// next arrival. `now` is absolute simulated time. The next
    /// interval is always drawn — even past a stopping rule — so each
    /// tenant's stream position stays a pure function of its arrival
    /// count.
    pub fn next_batch(&mut self, i: usize, now: f64) -> ArrivalBatch {
        let spec = &self.spec.tenants[i];
        let st = &mut self.tenants[i];
        st.arrivals += 1;
        let mut dus = Vec::new();
        let input: Vec<String> = match &spec.du {
            Some((size, pd)) => {
                let bytes = size.sample(&mut st.rng).max(1.0);
                dus.push((
                    DataUnitDescription {
                        name: format!("ol-{}-{:06}", spec.name, st.arrivals),
                        files: vec![FileRef::sized("payload.bin", Bytes(bytes as u64))],
                        affinity: None,
                    },
                    pd.clone(),
                ));
                vec!["@0".to_string()]
            }
            None => Vec::new(),
        };
        let cus = (0..spec.batch.max(1))
            .map(|k| ComputeUnitDescription {
                executable: format!("openloop:{}", spec.name),
                arguments: vec![format!("--arrival={}:{k}", st.arrivals)],
                cores: spec.cores.max(1),
                input_data: input.clone(),
                output_data: Vec::new(),
                affinity: None,
                cpu_secs_hint: spec.service.sample(&mut st.rng),
                io_bytes_hint: Bytes(0),
            })
            .collect();
        let rel_now = now - self.t0;
        let next = spec.arrivals.next_interval(&mut st.rng, rel_now);
        let capped = self.spec.max_arrivals_per_tenant.is_some_and(|m| st.arrivals >= m);
        let past_horizon = self.spec.horizon_s.is_some_and(|h| rel_now + next > h);
        ArrivalBatch {
            dus,
            cus,
            next_in: if capped || past_horizon { None } else { Some(next) },
        }
    }
}

/// Erlang-C probability that an arrival must wait, for `c` servers at
/// offered load `a = λ/μ` (requires `a < c`). Computed from the
/// numerically stable Erlang-B recursion `B(0) = 1`,
/// `B(k) = a·B(k−1) / (k + a·B(k−1))`, then
/// `C = B / (1 − ρ·(1 − B))` with `ρ = a/c`.
pub fn erlang_c(c: usize, a: f64) -> f64 {
    assert!(c > 0, "Erlang-C needs at least one server");
    assert!((0.0..c as f64).contains(&a), "Erlang-C needs 0 ≤ a < c, got a={a} c={c}");
    let mut b = 1.0;
    for k in 1..=c {
        b = a * b / (k as f64 + a * b);
    }
    let rho = a / c as f64;
    b / (1.0 - rho * (1.0 - b))
}

/// Mean wait in queue W_q of an M/M/c system:
/// `W_q = C(c, λ/μ) / (c·μ − λ)`. Requires λ < c·μ (stable system).
pub fn mmc_mean_wait(lambda: f64, mu: f64, c: usize) -> f64 {
    erlang_c(c, lambda / mu) / (c as f64 * mu - lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mean;

    #[test]
    fn erlang_c_matches_known_values() {
        // c = 1 reduces to M/M/1: P(wait) = ρ.
        for rho in [0.1, 0.5, 0.9] {
            assert!((erlang_c(1, rho) - rho).abs() < 1e-12, "rho={rho}");
        }
        // c = 4, a = 3.6 (ρ = 0.9): standard-table value ≈ 0.7878.
        assert!((erlang_c(4, 3.6) - 0.7878).abs() < 1e-3);
        // No load, no waiting.
        assert_eq!(erlang_c(4, 0.0), 0.0);
        // Mean wait: M/M/1 with λ=0.5, μ=1 → W_q = ρ/(μ−λ) = 1.
        assert!((mmc_mean_wait(0.5, 1.0, 1) - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "0 ≤ a < c")]
    fn erlang_c_rejects_unstable_load() {
        erlang_c(2, 2.0);
    }

    #[test]
    fn poisson_intervals_have_the_right_mean() {
        let p = ArrivalProcess::Poisson { rate: 0.5 };
        let mut rng = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| p.next_interval(&mut rng, 0.0)).collect();
        assert!((mean(&xs) - 2.0).abs() < 0.1, "mean={}", mean(&xs));
    }

    #[test]
    fn deterministic_intervals_are_exact() {
        let p = ArrivalProcess::Deterministic { rate: 4.0 };
        let mut rng = Rng::new(12);
        for _ in 0..10 {
            assert_eq!(p.next_interval(&mut rng, 0.0), 0.25);
        }
    }

    #[test]
    fn diurnal_long_run_rate_matches_base() {
        // Thinning preserves the mean rate over whole periods: count
        // arrivals over many periods and compare with base_rate · T.
        let p = ArrivalProcess::Diurnal { base_rate: 1.0, amplitude: 0.8, period_s: 100.0 };
        let mut rng = Rng::new(13);
        let horizon = 20_000.0;
        let mut t = 0.0;
        let mut n = 0u64;
        while t < horizon {
            t += p.next_interval(&mut rng, t);
            n += 1;
        }
        let rate = n as f64 / horizon;
        assert!((rate - 1.0).abs() < 0.05, "measured rate {rate}");
    }

    #[test]
    fn dist_means_are_consistent() {
        let mut rng = Rng::new(14);
        for d in [
            Dist::Fixed(3.0),
            Dist::Exp { mean: 5.0 },
            Dist::LogNormal { mu: 1.0, sigma: 0.5 },
        ] {
            let xs: Vec<f64> = (0..30_000).map(|_| d.sample(&mut rng)).collect();
            let m = mean(&xs);
            assert!(
                (m - d.mean()).abs() < 0.06 * d.mean().max(1.0),
                "{d:?}: measured {m} vs analytic {}",
                d.mean()
            );
        }
    }

    /// Walk a tenant's whole arrival schedule without a simulator:
    /// the generator is sim-agnostic, so times and demands unroll from
    /// the stream alone.
    fn unroll(run: &mut OpenLoopRun, i: usize) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut t = run.first_delay(i);
        loop {
            let batch = run.next_batch(i, t);
            for cu in &batch.cus {
                out.push((t.to_bits(), cu.cpu_secs_hint.to_bits()));
            }
            match batch.next_in {
                Some(d) => t += d,
                None => return out,
            }
        }
    }

    #[test]
    fn removing_a_tenant_leaves_the_others_streams_unchanged() {
        let spec_for = |names: &[&str]| OpenLoopSpec {
            tenants: names.iter().map(|n| TenantSpec::poisson(n, 0.2, 30.0)).collect(),
            max_arrivals_per_tenant: Some(25),
            horizon_s: None,
        };
        let mut all = OpenLoopRun::new(spec_for(&["alice", "bob", "carol"]), 99, 0.0);
        let mut fewer = OpenLoopRun::new(spec_for(&["alice", "carol"]), 99, 0.0);
        // alice is index 0 in both; carol moves from 2 to 1. Bit-exact
        // either way: streams key off names, not population order.
        assert_eq!(unroll(&mut all, 0), unroll(&mut fewer, 0));
        assert_eq!(unroll(&mut all, 2), unroll(&mut fewer, 1));
    }

    #[test]
    fn batches_carry_du_payloads_when_configured() {
        let spec = OpenLoopSpec {
            tenants: vec![TenantSpec {
                name: "data".into(),
                arrivals: ArrivalProcess::Deterministic { rate: 1.0 },
                service: Dist::Fixed(5.0),
                batch: 3,
                cores: 2,
                du: Some((Dist::LogNormal { mu: 10.0, sigma: 1.0 }, "scratch".into())),
            }],
            max_arrivals_per_tenant: Some(2),
            horizon_s: None,
        };
        let mut run = OpenLoopRun::new(spec, 7, 0.0);
        let b = run.next_batch(0, 1.0);
        assert_eq!(b.dus.len(), 1);
        assert_eq!(b.dus[0].1, "scratch");
        assert_eq!(b.cus.len(), 3);
        for cu in &b.cus {
            assert_eq!(cu.input_data, vec!["@0".to_string()]);
            assert_eq!(cu.cores, 2);
            assert_eq!(cu.cpu_secs_hint, 5.0);
        }
        let b2 = run.next_batch(0, 2.0);
        assert!(b2.next_in.is_none(), "arrival cap must stop the schedule");
    }

    #[test]
    fn horizon_stops_the_schedule() {
        let spec = OpenLoopSpec {
            tenants: vec![TenantSpec::poisson("t", 1.0, 10.0)],
            max_arrivals_per_tenant: None,
            horizon_s: Some(50.0),
        };
        let mut run = OpenLoopRun::new(spec, 21, 0.0);
        let times = unroll(&mut run, 0);
        let last = f64::from_bits(times.last().unwrap().0);
        assert!(last <= 50.0, "arrival at {last} past the horizon");
        assert!(times.len() > 10, "expected a few dozen arrivals, got {}", times.len());
    }
}
