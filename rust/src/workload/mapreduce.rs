//! Pilot-MapReduce: the MapReduce pattern on top of the Pilot-API
//! (paper §7: "we also successfully showed that Pilot-Data efficiently
//! supports other application patterns, e.g. dynamic workflows or
//! MapReduce", citing Pilot-MapReduce [48]).
//!
//! The framework is deliberately thin — exactly the paper's point: the
//! Pilot abstraction supplies resource management, data movement and
//! co-placement; MapReduce is ~200 lines of orchestration on top:
//!
//!  1. partition the input Data-Unit into M map-input DUs;
//!  2. submit M map CUs; each emits `(key, value)` lines, hashed into
//!     R intermediate partition files;
//!  3. group intermediates per partition into transient DUs (the
//!     "dynamic data" usage mode);
//!  4. submit R reduce CUs; gather their outputs into the result DU.

use crate::service::{ComputeDataService, ExecResult, Executor, PilotSystem};
use crate::unit::{ComputeUnitDescription, DataUnitDescription};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

/// A user-defined map function: input line -> list of (key, value).
pub type MapFn = dyn Fn(&str) -> Vec<(String, String)> + Send + Sync;
/// A user-defined reduce function: key + all values -> output value.
pub type ReduceFn = dyn Fn(&str, &[String]) -> String + Send + Sync;

/// Executor that runs registered rust functions by name — the
/// local-mode analogue of shipping a python callable with the CU.
/// Executables named `fn:<name>` dispatch to the registry; anything
/// else is an error (compose with ShellExecutor if needed).
pub struct FnExecutor {
    fns: BTreeMap<String, Box<dyn Fn(&Path) -> anyhow::Result<()> + Send + Sync>>,
}

impl FnExecutor {
    pub fn new() -> FnExecutor {
        FnExecutor { fns: BTreeMap::new() }
    }

    pub fn register(
        mut self,
        name: &str,
        f: impl Fn(&Path) -> anyhow::Result<()> + Send + Sync + 'static,
    ) -> FnExecutor {
        self.fns.insert(name.to_string(), Box::new(f));
        self
    }
}

impl Default for FnExecutor {
    fn default() -> Self {
        Self::new()
    }
}

impl Executor for FnExecutor {
    fn execute(&self, cu: &ComputeUnitDescription, sandbox: &Path) -> anyhow::Result<ExecResult> {
        let name = cu
            .executable
            .strip_prefix("fn:")
            .ok_or_else(|| anyhow::anyhow!("FnExecutor expects fn:<name>, got '{}'", cu.executable))?;
        let f = self
            .fns
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no registered function '{name}'"))?;
        let t0 = std::time::Instant::now();
        f(sandbox)?;
        Ok(ExecResult { stdout: String::new(), compute_s: t0.elapsed().as_secs_f64() })
    }
}

/// Deterministic partition hash (FNV-1a) — stable across runs.
pub fn partition_of(key: &str, partitions: usize) -> usize {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    (h % partitions as u64) as usize
}

/// Configuration of a MapReduce job.
pub struct MapReduceJob {
    pub maps: usize,
    pub reduces: usize,
    pub map_fn: Arc<MapFn>,
    pub reduce_fn: Arc<ReduceFn>,
}

/// Build the executor for a job (register `fn:map` / `fn:reduce`).
pub fn job_executor(job: &MapReduceJob) -> FnExecutor {
    let map_fn = job.map_fn.clone();
    let reduces = job.reduces;
    let reduce_fn = job.reduce_fn.clone();
    FnExecutor::new()
        .register("map", move |sandbox| {
            let input = std::fs::read_to_string(sandbox.join("input.txt"))?;
            let mut parts: Vec<String> = vec![String::new(); reduces];
            for line in input.lines() {
                for (k, v) in map_fn(line) {
                    parts[partition_of(&k, reduces)].push_str(&format!("{k}\t{v}\n"));
                }
            }
            for (r, content) in parts.iter().enumerate() {
                std::fs::write(sandbox.join(format!("part-{r:03}.txt")), content)?;
            }
            Ok(())
        })
        .register("reduce", move |sandbox| {
            // All staged files matching merged-*.txt belong to this
            // partition.
            let mut grouped: BTreeMap<String, Vec<String>> = BTreeMap::new();
            for entry in std::fs::read_dir(sandbox)? {
                let entry = entry?;
                let name = entry.file_name().to_string_lossy().to_string();
                if !name.starts_with("merged-") {
                    continue;
                }
                for line in std::fs::read_to_string(entry.path())?.lines() {
                    if let Some((k, v)) = line.split_once('\t') {
                        grouped.entry(k.to_string()).or_default().push(v.to_string());
                    }
                }
            }
            let mut out = String::new();
            for (k, vs) in &grouped {
                out.push_str(&format!("{k}\t{}\n", reduce_fn(k, vs)));
            }
            std::fs::write(sandbox.join("reduced.txt"), out)?;
            Ok(())
        })
}

/// Run a MapReduce job over `input` text on an existing Pilot system
/// (whose executor must come from [`job_executor`]). Returns the
/// final key -> value map.
pub fn run(
    sys: &Arc<PilotSystem>,
    cds: &ComputeDataService,
    pd: &str,
    job: &MapReduceJob,
    input: &str,
) -> anyhow::Result<BTreeMap<String, String>> {
    // ---- Phase 1: partition input into M map DUs ----
    let lines: Vec<&str> = input.lines().collect();
    let per_map = lines.len().div_ceil(job.maps.max(1)).max(1);
    let mut map_outputs = Vec::new();
    for (i, chunk) in lines.chunks(per_map).enumerate() {
        let text = chunk.join("\n");
        let in_du = cds.put_data_unit(
            &format!("mr-map-in-{i}"),
            &[("input.txt", text.as_bytes())],
            pd,
        )?;
        let out_du = cds.submit_data_unit(
            DataUnitDescription { name: format!("mr-map-out-{i}"), ..Default::default() },
            pd,
        )?;
        cds.submit_compute_unit(ComputeUnitDescription {
            executable: "fn:map".into(),
            cores: 1,
            input_data: vec![in_du],
            output_data: vec![out_du.clone()],
            ..Default::default()
        })?;
        map_outputs.push(out_du);
    }
    sys.wait_all(Duration::from_secs(120))?;

    // ---- Phase 2: shuffle — group per reduce partition ----
    // (transient intermediate DUs: created here, dropped after reduce)
    let mut reduce_inputs = Vec::new();
    for r in 0..job.reduces {
        let mut files: Vec<(String, Vec<u8>)> = Vec::new();
        for (m, out_du) in map_outputs.iter().enumerate() {
            let content = cds.fetch(out_du, &format!("part-{r:03}.txt"))?;
            files.push((format!("merged-{m:03}.txt"), content));
        }
        let refs: Vec<(&str, &[u8])> =
            files.iter().map(|(n, c)| (n.as_str(), c.as_slice())).collect();
        reduce_inputs.push(cds.put_data_unit(&format!("mr-shuffle-{r}"), &refs, pd)?);
    }

    // ---- Phase 3: R reduce CUs ----
    let mut reduce_outputs = Vec::new();
    for (r, in_du) in reduce_inputs.iter().enumerate() {
        let out_du = cds.submit_data_unit(
            DataUnitDescription { name: format!("mr-reduce-out-{r}"), ..Default::default() },
            pd,
        )?;
        cds.submit_compute_unit(ComputeUnitDescription {
            executable: "fn:reduce".into(),
            cores: 1,
            input_data: vec![in_du.clone()],
            output_data: vec![out_du.clone()],
            ..Default::default()
        })?;
        reduce_outputs.push(out_du);
    }
    sys.wait_all(Duration::from_secs(120))?;

    // ---- Phase 4: gather ----
    let mut result = BTreeMap::new();
    for out_du in &reduce_outputs {
        let text = String::from_utf8(cds.fetch(out_du, "reduced.txt")?)?;
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('\t') {
                result.insert(k.to_string(), v.to_string());
            }
        }
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wordcount_job(maps: usize, reduces: usize) -> MapReduceJob {
        MapReduceJob {
            maps,
            reduces,
            map_fn: Arc::new(|line: &str| {
                line.split_whitespace().map(|w| (w.to_lowercase(), "1".to_string())).collect()
            }),
            reduce_fn: Arc::new(|_k: &str, vs: &[String]| vs.len().to_string()),
        }
    }

    fn run_wordcount(maps: usize, reduces: usize, pilots: u32) -> BTreeMap<String, String> {
        let dir = std::env::temp_dir().join(format!(
            "pd-mr-{maps}-{reduces}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let job = wordcount_job(maps, reduces);
        let sys = PilotSystem::new(&dir, Arc::new(job_executor(&job)));
        let pds = sys.data_service();
        let cds = sys.compute_data_service();
        let pd = pds.create_pilot_data(crate::pd_desc(&dir, "mr", "local/a")).unwrap();
        for i in 0..pilots {
            sys.compute_service()
                .create_pilot(crate::pilot_desc(&format!("local/p{i}")))
                .unwrap();
        }
        let input = "the pilot flies the plane\nthe data follows the pilot\npilot data pilot";
        let out = run(&sys, &cds, &pd, &job, input).unwrap();
        sys.shutdown();
        let _ = std::fs::remove_dir_all(dir);
        out
    }

    #[test]
    fn wordcount_is_correct() {
        let out = run_wordcount(2, 2, 2);
        assert_eq!(out["the"], "4");
        assert_eq!(out["pilot"], "4");
        assert_eq!(out["data"], "2");
        assert_eq!(out["plane"], "1");
    }

    #[test]
    fn results_invariant_to_partitioning() {
        let a = run_wordcount(1, 1, 1);
        let b = run_wordcount(3, 4, 3);
        assert_eq!(a, b);
    }

    #[test]
    fn partition_hash_is_stable_and_in_range() {
        for key in ["alpha", "beta", "gamma", ""] {
            let p = partition_of(key, 7);
            assert!(p < 7);
            assert_eq!(p, partition_of(key, 7));
        }
    }

    #[test]
    fn fn_executor_rejects_unknown() {
        let ex = FnExecutor::new();
        let cu = ComputeUnitDescription { executable: "fn:nope".into(), ..Default::default() };
        assert!(ex.execute(&cu, Path::new("/tmp")).is_err());
        let cu2 = ComputeUnitDescription { executable: "/bin/true".into(), ..Default::default() };
        assert!(ex.execute(&cu2, Path::new("/tmp")).is_err());
    }
}
