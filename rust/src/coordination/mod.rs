//! Distributed coordination & communication service — the in-process
//! Redis equivalent.
//!
//! BigJob keeps its complete state in a shared in-memory data store
//! (Redis): the Pilot-Manager and the Pilot-Agents exchange control
//! data through "a defined set of Redis data structures and protocols"
//! (paper §4.2) — agent resource info, CU queues (one global + one per
//! pilot), and entity state. The store persists snapshots so both the
//! application and the Pilot-Manager can disconnect and re-connect, and
//! both survive transient store failures.
//!
//! This module is a from-scratch implementation of exactly that service
//! surface: string KV, hashes, list-queues, pub/sub, key scans,
//! JSON snapshots, and injectable transient failure for fault-tolerance
//! tests.

use crate::json::Json;
use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};

/// Errors surfaced by store operations.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum StoreError {
    /// The store is unreachable (injected transient failure) — callers
    /// are expected to retry, as BigJob agents do.
    #[error("coordination store unavailable")]
    Unavailable,
    #[error("wrong type for key '{0}'")]
    WrongType(String),
}

#[derive(Debug, Clone, PartialEq)]
enum Value {
    Str(String),
    Hash(BTreeMap<String, String>),
    List(VecDeque<String>),
}

#[derive(Default)]
struct Inner {
    data: BTreeMap<String, Value>,
    subs: BTreeMap<String, Vec<Sender<String>>>,
    down: bool,
    ops: u64,
}

/// Cloneable handle to the shared store (the "connection").
#[derive(Clone)]
pub struct Store {
    inner: Arc<Mutex<Inner>>,
}

impl Default for Store {
    fn default() -> Self {
        Self::new()
    }
}

impl Store {
    pub fn new() -> Store {
        Store { inner: Arc::new(Mutex::new(Inner::default())) }
    }

    fn guard(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn check_up(inner: &mut Inner) -> Result<(), StoreError> {
        inner.ops += 1;
        if inner.down {
            Err(StoreError::Unavailable)
        } else {
            Ok(())
        }
    }

    /// Inject / clear a transient outage.
    pub fn set_down(&self, down: bool) {
        self.guard().down = down;
    }

    pub fn is_down(&self) -> bool {
        self.guard().down
    }

    /// Total operations served (metrics / perf assertions).
    pub fn op_count(&self) -> u64 {
        self.guard().ops
    }

    // ---- string KV ----

    pub fn set(&self, key: &str, value: &str) -> Result<(), StoreError> {
        let mut g = self.guard();
        Self::check_up(&mut g)?;
        g.data.insert(key.to_string(), Value::Str(value.to_string()));
        Ok(())
    }

    pub fn get(&self, key: &str) -> Result<Option<String>, StoreError> {
        let mut g = self.guard();
        Self::check_up(&mut g)?;
        match g.data.get(key) {
            None => Ok(None),
            Some(Value::Str(s)) => Ok(Some(s.clone())),
            Some(_) => Err(StoreError::WrongType(key.to_string())),
        }
    }

    pub fn del(&self, key: &str) -> Result<bool, StoreError> {
        let mut g = self.guard();
        Self::check_up(&mut g)?;
        Ok(g.data.remove(key).is_some())
    }

    /// Keys with the given prefix (BigJob scans `bigjob:pilot:*`-style
    /// namespaces on re-connect).
    pub fn keys_with_prefix(&self, prefix: &str) -> Result<Vec<String>, StoreError> {
        let mut g = self.guard();
        Self::check_up(&mut g)?;
        Ok(g.data.keys().filter(|k| k.starts_with(prefix)).cloned().collect())
    }

    // ---- hashes (entity state: pilots, CUs, DUs) ----

    pub fn hset(&self, key: &str, field: &str, value: &str) -> Result<(), StoreError> {
        let mut g = self.guard();
        Self::check_up(&mut g)?;
        match g.data.entry(key.to_string()).or_insert_with(|| Value::Hash(BTreeMap::new())) {
            Value::Hash(h) => {
                h.insert(field.to_string(), value.to_string());
                Ok(())
            }
            _ => Err(StoreError::WrongType(key.to_string())),
        }
    }

    pub fn hget(&self, key: &str, field: &str) -> Result<Option<String>, StoreError> {
        let mut g = self.guard();
        Self::check_up(&mut g)?;
        match g.data.get(key) {
            None => Ok(None),
            Some(Value::Hash(h)) => Ok(h.get(field).cloned()),
            Some(_) => Err(StoreError::WrongType(key.to_string())),
        }
    }

    pub fn hgetall(&self, key: &str) -> Result<BTreeMap<String, String>, StoreError> {
        let mut g = self.guard();
        Self::check_up(&mut g)?;
        match g.data.get(key) {
            None => Ok(BTreeMap::new()),
            Some(Value::Hash(h)) => Ok(h.clone()),
            Some(_) => Err(StoreError::WrongType(key.to_string())),
        }
    }

    // ---- list queues (global CU queue + per-pilot queues) ----

    pub fn rpush(&self, key: &str, value: &str) -> Result<usize, StoreError> {
        let mut g = self.guard();
        Self::check_up(&mut g)?;
        match g.data.entry(key.to_string()).or_insert_with(|| Value::List(VecDeque::new())) {
            Value::List(l) => {
                l.push_back(value.to_string());
                Ok(l.len())
            }
            _ => Err(StoreError::WrongType(key.to_string())),
        }
    }

    pub fn lpop(&self, key: &str) -> Result<Option<String>, StoreError> {
        let mut g = self.guard();
        Self::check_up(&mut g)?;
        match g.data.get_mut(key) {
            None => Ok(None),
            Some(Value::List(l)) => Ok(l.pop_front()),
            Some(_) => Err(StoreError::WrongType(key.to_string())),
        }
    }

    pub fn llen(&self, key: &str) -> Result<usize, StoreError> {
        let mut g = self.guard();
        Self::check_up(&mut g)?;
        match g.data.get(key) {
            None => Ok(0),
            Some(Value::List(l)) => Ok(l.len()),
            Some(_) => Err(StoreError::WrongType(key.to_string())),
        }
    }

    // ---- pub/sub (state-change notifications) ----

    pub fn subscribe(&self, channel: &str) -> Receiver<String> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.guard().subs.entry(channel.to_string()).or_default().push(tx);
        rx
    }

    pub fn publish(&self, channel: &str, message: &str) -> Result<usize, StoreError> {
        let mut g = self.guard();
        Self::check_up(&mut g)?;
        let mut delivered = 0;
        if let Some(subs) = g.subs.get_mut(channel) {
            subs.retain(|tx| tx.send(message.to_string()).is_ok());
            delivered = subs.len();
        }
        Ok(delivered)
    }

    // ---- durability ----

    /// Serialize the full store state to JSON (Redis RDB-equivalent).
    pub fn snapshot(&self) -> Json {
        let g = self.guard();
        let mut obj = std::collections::BTreeMap::new();
        for (k, v) in &g.data {
            let jv = match v {
                Value::Str(s) => Json::obj().set("t", "s").set("v", s.as_str()),
                Value::Hash(h) => {
                    let mut hm = std::collections::BTreeMap::new();
                    for (f, val) in h {
                        hm.insert(f.clone(), Json::Str(val.clone()));
                    }
                    Json::obj().set("t", "h").set("v", Json::Obj(hm))
                }
                Value::List(l) => Json::obj().set(
                    "t",
                    "l",
                ).set(
                    "v",
                    Json::Arr(l.iter().map(|s| Json::Str(s.clone())).collect()),
                ),
            };
            obj.insert(k.clone(), jv);
        }
        Json::Obj(obj)
    }

    /// Restore state from a snapshot, replacing current contents —
    /// "the ability to quickly restart the Redis server (if necessary
    /// on another resource)".
    pub fn restore(&self, snap: &Json) -> anyhow::Result<()> {
        let Json::Obj(map) = snap else {
            anyhow::bail!("snapshot must be an object");
        };
        let mut data = BTreeMap::new();
        for (k, entry) in map {
            let t = entry.str_field("t")?;
            let v = entry
                .get("v")
                .ok_or_else(|| anyhow::anyhow!("snapshot entry '{k}' missing v"))?;
            let value = match t {
                "s" => Value::Str(v.as_str().unwrap_or_default().to_string()),
                "h" => {
                    let Json::Obj(hm) = v else {
                        anyhow::bail!("hash entry '{k}' not an object");
                    };
                    Value::Hash(
                        hm.iter()
                            .map(|(f, x)| (f.clone(), x.as_str().unwrap_or_default().to_string()))
                            .collect(),
                    )
                }
                "l" => Value::List(
                    v.as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|x| x.as_str().unwrap_or_default().to_string())
                        .collect(),
                ),
                other => anyhow::bail!("unknown snapshot type '{other}'"),
            };
            data.insert(k.clone(), value);
        }
        let mut g = self.guard();
        g.data = data;
        g.down = false;
        Ok(())
    }

    /// Persist a snapshot to disk and reload it — used by the fault
    /// tolerance tests and the local-mode manager checkpoint.
    pub fn save_to(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.snapshot().to_string_pretty())?;
        Ok(())
    }

    pub fn load_from(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let text = std::fs::read_to_string(path)?;
        self.restore(&crate::json::parse(&text)?)
    }
}

/// Well-known key-space layout (mirrors BigJob's Redis schema).
pub mod keys {
    pub fn pilot(id: &str) -> String {
        format!("pd:pilot:{id}")
    }
    pub fn cu(id: &str) -> String {
        format!("pd:cu:{id}")
    }
    pub fn du(id: &str) -> String {
        format!("pd:du:{id}")
    }
    /// The global CU queue any agent may pull from.
    pub const GLOBAL_QUEUE: &str = "pd:queue:global";
    /// The agent-specific queue of one pilot.
    pub fn pilot_queue(pilot_id: &str) -> String {
        format!("pd:queue:pilot:{pilot_id}")
    }
    pub const STATE_CHANNEL: &str = "pd:events";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_roundtrip_and_delete() {
        let s = Store::new();
        s.set("a", "1").unwrap();
        assert_eq!(s.get("a").unwrap(), Some("1".to_string()));
        assert!(s.del("a").unwrap());
        assert!(!s.del("a").unwrap());
        assert_eq!(s.get("a").unwrap(), None);
    }

    #[test]
    fn hashes_hold_entity_state() {
        let s = Store::new();
        let k = keys::cu("cu-1");
        s.hset(&k, "state", "Queued").unwrap();
        s.hset(&k, "pilot", "pilot-3").unwrap();
        assert_eq!(s.hget(&k, "state").unwrap(), Some("Queued".to_string()));
        let all = s.hgetall(&k).unwrap();
        assert_eq!(all.len(), 2);
        assert_eq!(s.hgetall("absent").unwrap().len(), 0);
    }

    #[test]
    fn type_confusion_is_an_error() {
        let s = Store::new();
        s.set("k", "v").unwrap();
        assert_eq!(s.hget("k", "f"), Err(StoreError::WrongType("k".into())));
        assert_eq!(s.lpop("k"), Err(StoreError::WrongType("k".into())));
        s.rpush("q", "x").unwrap();
        assert_eq!(s.get("q"), Err(StoreError::WrongType("q".into())));
    }

    #[test]
    fn queues_are_fifo() {
        let s = Store::new();
        for i in 0..5 {
            s.rpush(keys::GLOBAL_QUEUE, &format!("cu-{i}")).unwrap();
        }
        assert_eq!(s.llen(keys::GLOBAL_QUEUE).unwrap(), 5);
        assert_eq!(s.lpop(keys::GLOBAL_QUEUE).unwrap(), Some("cu-0".to_string()));
        assert_eq!(s.lpop(keys::GLOBAL_QUEUE).unwrap(), Some("cu-1".to_string()));
        assert_eq!(s.lpop("empty").unwrap(), None);
    }

    #[test]
    fn pubsub_delivers_to_all_subscribers() {
        let s = Store::new();
        let r1 = s.subscribe(keys::STATE_CHANNEL);
        let r2 = s.subscribe(keys::STATE_CHANNEL);
        let n = s.publish(keys::STATE_CHANNEL, "cu-1:Running").unwrap();
        assert_eq!(n, 2);
        assert_eq!(r1.try_recv().unwrap(), "cu-1:Running");
        assert_eq!(r2.try_recv().unwrap(), "cu-1:Running");
    }

    #[test]
    fn dropped_subscribers_are_pruned() {
        let s = Store::new();
        {
            let _r = s.subscribe("ch");
        } // receiver dropped
        let n = s.publish("ch", "x").unwrap();
        assert_eq!(n, 0);
    }

    #[test]
    fn outage_fails_ops_then_recovers() {
        let s = Store::new();
        s.set("a", "1").unwrap();
        s.set_down(true);
        assert_eq!(s.get("a"), Err(StoreError::Unavailable));
        assert_eq!(s.set("b", "2"), Err(StoreError::Unavailable));
        s.set_down(false);
        // State survived the transient outage.
        assert_eq!(s.get("a").unwrap(), Some("1".to_string()));
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let s = Store::new();
        s.set("k", "v").unwrap();
        s.hset("h", "f1", "x").unwrap();
        s.rpush("q", "a").unwrap();
        s.rpush("q", "b").unwrap();
        let snap = s.snapshot();

        let fresh = Store::new();
        fresh.restore(&snap).unwrap();
        assert_eq!(fresh.get("k").unwrap(), Some("v".to_string()));
        assert_eq!(fresh.hget("h", "f1").unwrap(), Some("x".to_string()));
        assert_eq!(fresh.lpop("q").unwrap(), Some("a".to_string()));
        assert_eq!(fresh.lpop("q").unwrap(), Some("b".to_string()));
    }

    #[test]
    fn save_load_file_roundtrip() {
        let s = Store::new();
        s.set("k", "v").unwrap();
        let path = std::env::temp_dir().join(format!("pd-store-{}.json", std::process::id()));
        s.save_to(&path).unwrap();
        let fresh = Store::new();
        fresh.load_from(&path).unwrap();
        assert_eq!(fresh.get("k").unwrap(), Some("v".to_string()));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn keyspace_prefix_scan() {
        let s = Store::new();
        s.hset(&keys::pilot("p1"), "state", "Active").unwrap();
        s.hset(&keys::pilot("p2"), "state", "New").unwrap();
        s.hset(&keys::cu("c1"), "state", "New").unwrap();
        let pilots = s.keys_with_prefix("pd:pilot:").unwrap();
        assert_eq!(pilots.len(), 2);
    }

    #[test]
    fn concurrent_queue_consumers_split_work() {
        let s = Store::new();
        for i in 0..100 {
            s.rpush("q", &format!("{i}")).unwrap();
        }
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Ok(Some(v)) = s.lpop("q") {
                    got.push(v);
                }
                got
            }));
        }
        let mut all: Vec<String> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_by_key(|v| v.parse::<u32>().unwrap());
        assert_eq!(all.len(), 100, "each item consumed exactly once");
        assert_eq!(all[0], "0");
        assert_eq!(all[99], "99");
    }

    #[test]
    fn snapshot_property_roundtrip() {
        crate::prop::check_default(
            |rng| {
                let s = Store::new();
                for i in 0..crate::prop::gen::usize_in(rng, 0, 10) {
                    match rng.below(3) {
                        0 => s.set(&format!("k{i}"), &crate::prop::gen::ascii_string(rng, 12)).unwrap(),
                        1 => s.hset(&format!("h{i}"), "f", &crate::prop::gen::ascii_string(rng, 12)).unwrap(),
                        _ => {
                            s.rpush(&format!("q{i}"), &crate::prop::gen::ascii_string(rng, 12)).unwrap();
                        }
                    }
                }
                s.snapshot()
            },
            |snap| {
                let fresh = Store::new();
                fresh.restore(snap).map_err(|e| e.to_string())?;
                if fresh.snapshot() == *snap {
                    Ok(())
                } else {
                    Err("snapshot not idempotent".into())
                }
            },
        );
    }
}
