//! Distributed coordination & communication service — the in-process
//! Redis equivalent.
//!
//! BigJob keeps its complete state in a shared in-memory data store
//! (Redis): the Pilot-Manager and the Pilot-Agents exchange control
//! data through "a defined set of Redis data structures and protocols"
//! (paper §4.2) — agent resource info, CU queues (one global + one per
//! pilot), and entity state. The store persists snapshots so both the
//! application and the Pilot-Manager can disconnect and re-connect, and
//! both survive transient store failures. The P* model paper makes the
//! coordination layer an explicit first-class element whose overhead
//! bounds pilot throughput — which is why this module is engineered as
//! a hot path, not a toy KV map.
//!
//! # Architecture (sharding + interning + record cache)
//!
//! The store is split into [`SHARDS`] independent lock stripes; a key's
//! stripe is chosen by a fast FxHash of its bytes, so unrelated keys
//! (different pilots' queues, different entities' hashes) never contend
//! on one mutex. Within a stripe the data lives in a `HashMap` with the
//! same FxHash — O(1) per op instead of the former global
//! `Mutex<BTreeMap>`'s O(log n) under one lock.
//!
//! Callers on the hot path intern their keys once into [`Key`] handles
//! (an `Arc<str>` plus the precomputed stripe index) via [`Key::new`]
//! or the `keys::*_key` helpers; the `*_k` method variants then avoid
//! the per-operation `format!`/`to_string` allocations the old API
//! forced. The plain `&str` API is kept as a thin compatibility layer
//! over the same stripes.
//!
//! CU/DU descriptions are written once and read many times, so the
//! store also keeps a **typed record cache**: [`Store::cu_description`]
//! / [`Store::du_description`] parse the JSON `descr` field once,
//! memoize the typed value behind an `Arc`, and invalidate on any write
//! to that record ([`Store::hset`] of `descr`, [`Store::del`],
//! [`Store::restore`]). Cold-path operations (snapshots, prefix scans)
//! stay deterministic by collecting into ordered maps.
//!
//! The service surface: string KV, hashes, list-queues, key scans,
//! JSON snapshots, and injectable transient failure for
//! fault-tolerance tests. The **event layer** — per-stripe pub/sub on
//! interned keys, prefix and Redis-style glob pattern subscriptions
//! (with `unsubscribe`), and BLPOP-style blocking pops with deadline
//! support — lives in [`events`]; every `rpush` fans a keyspace event
//! out to subscribers and wakes blocked poppers, which is what lets
//! agents react instead of polling. Queue-namespace pushes use a
//! **wake-one handoff** (at most one waiter claimed per push — O(1)
//! under a parked multi-slot worker pool); see [`events`] for the
//! per-waiter delivery-state protocol that keeps multi-queue pops
//! loss-free.

pub mod events;

use crate::json::Json;
use events::EventHub;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::hash::{BuildHasherDefault, Hasher};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Number of independent lock stripes (power of two).
pub const SHARDS: usize = 16;

/// Errors surfaced by store operations.
#[derive(Debug, PartialEq)]
pub enum StoreError {
    /// The store is unreachable (injected transient failure) — callers
    /// are expected to retry, as BigJob agents do.
    Unavailable,
    WrongType(String),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Unavailable => f.write_str("coordination store unavailable"),
            StoreError::WrongType(k) => write!(f, "wrong type for key '{k}'"),
        }
    }
}

impl std::error::Error for StoreError {}

/// FxHash (Firefox/rustc hash): multiply-xor, very fast on the short
/// `pd:*` keys this store sees. Not DoS-resistant — irrelevant here.
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

const FX_SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl Hasher for FxHasher {
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            let v = u64::from_le_bytes(c.try_into().unwrap());
            self.hash = (self.hash.rotate_left(5) ^ v).wrapping_mul(FX_SEED);
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut v = 0u64;
            for (i, &b) in rem.iter().enumerate() {
                v |= (b as u64) << (8 * i);
            }
            self.hash = (self.hash.rotate_left(5) ^ v).wrapping_mul(FX_SEED);
        }
    }

    fn write_u8(&mut self, b: u8) {
        self.hash = (self.hash.rotate_left(5) ^ b as u64).wrapping_mul(FX_SEED);
    }

    fn finish(&self) -> u64 {
        self.hash
    }
}

/// `HashMap` wired to [`FxHasher`].
pub type FxMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;

fn stripe_of(key: &str) -> usize {
    let mut h = FxHasher::default();
    h.write(key.as_bytes());
    // Use the high bits: Fx mixes poorly in the low bits for short keys.
    (h.finish() >> 56) as usize & (SHARDS - 1)
}

/// An interned store key: the text plus its precomputed lock stripe.
/// Clone is an `Arc` refcount bump; producing one per entity (not per
/// operation) removes the `format!` traffic from the coordination hot
/// path.
#[derive(Clone, Debug)]
pub struct Key {
    text: Arc<str>,
    stripe: usize,
}

impl Key {
    pub fn new(text: &str) -> Key {
        Key { text: Arc::from(text), stripe: stripe_of(text) }
    }

    pub fn as_str(&self) -> &str {
        &self.text
    }
}

impl From<&str> for Key {
    fn from(s: &str) -> Key {
        Key::new(s)
    }
}

impl std::fmt::Display for Key {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.text)
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Value {
    Str(String),
    Hash(BTreeMap<String, String>),
    List(VecDeque<String>),
}

#[derive(Default)]
struct Shard {
    data: FxMap<Arc<str>, Value>,
}

/// Typed, parse-once cache of CU/DU description records. `generation`
/// advances on every invalidation; a miss that parsed under an older
/// generation must not populate the cache (its source text may have
/// been superseded while it was parsing outside the lock).
#[derive(Default)]
struct DescrCache {
    generation: u64,
    cus: FxMap<String, Arc<crate::unit::ComputeUnitDescription>>,
    dus: FxMap<String, Arc<crate::unit::DataUnitDescription>>,
}

struct Inner {
    shards: Vec<Mutex<Shard>>,
    hub: EventHub,
    descr: Mutex<DescrCache>,
    down: AtomicBool,
    ops: AtomicU64,
}

/// Cloneable handle to the shared store (the "connection").
#[derive(Clone)]
pub struct Store {
    inner: Arc<Inner>,
}

impl Default for Store {
    fn default() -> Self {
        Self::new()
    }
}

impl Store {
    pub fn new() -> Store {
        Store {
            inner: Arc::new(Inner {
                shards: (0..SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
                hub: EventHub::new(),
                descr: Mutex::new(DescrCache::default()),
                down: AtomicBool::new(false),
                ops: AtomicU64::new(0),
            }),
        }
    }

    /// Count the op and fail if a transient outage is injected.
    fn begin(&self) -> Result<(), StoreError> {
        self.inner.ops.fetch_add(1, Ordering::Relaxed);
        if self.inner.down.load(Ordering::Relaxed) {
            Err(StoreError::Unavailable)
        } else {
            Ok(())
        }
    }

    fn stripe(&self, idx: usize) -> MutexGuard<'_, Shard> {
        self.inner.shards[idx].lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Inject / clear a transient outage. Either transition wakes
    /// every blocked waiter: poppers surface [`StoreError::Unavailable`]
    /// (a dropped connection unblocks a Redis `BLPOP` the same way),
    /// availability waiters observe the recovery.
    pub fn set_down(&self, down: bool) {
        self.inner.down.store(down, Ordering::Relaxed);
        self.wake_waiters();
    }

    pub fn is_down(&self) -> bool {
        self.inner.down.load(Ordering::Relaxed)
    }

    /// Total operations served (metrics / perf assertions).
    pub fn op_count(&self) -> u64 {
        self.inner.ops.load(Ordering::Relaxed)
    }

    // ---- string KV ----

    fn set_at(&self, idx: usize, key: &str, value: &str) -> Result<(), StoreError> {
        self.begin()?;
        {
            let mut g = self.stripe(idx);
            match g.data.get_mut(key) {
                Some(v) => *v = Value::Str(value.to_string()),
                None => {
                    g.data.insert(Arc::from(key), Value::Str(value.to_string()));
                }
            }
        }
        // A whole-value overwrite of an entity record drops any cached
        // typed description for it.
        self.invalidate_descr(key);
        Ok(())
    }

    pub fn set(&self, key: &str, value: &str) -> Result<(), StoreError> {
        self.set_at(stripe_of(key), key, value)
    }

    pub fn set_k(&self, key: &Key, value: &str) -> Result<(), StoreError> {
        self.set_at(key.stripe, &key.text, value)
    }

    fn get_at(&self, idx: usize, key: &str) -> Result<Option<String>, StoreError> {
        self.begin()?;
        let g = self.stripe(idx);
        match g.data.get(key) {
            None => Ok(None),
            Some(Value::Str(s)) => Ok(Some(s.clone())),
            Some(_) => Err(StoreError::WrongType(key.to_string())),
        }
    }

    pub fn get(&self, key: &str) -> Result<Option<String>, StoreError> {
        self.get_at(stripe_of(key), key)
    }

    pub fn get_k(&self, key: &Key) -> Result<Option<String>, StoreError> {
        self.get_at(key.stripe, &key.text)
    }

    pub fn del(&self, key: &str) -> Result<bool, StoreError> {
        self.begin()?;
        let removed = self.stripe(stripe_of(key)).data.remove(key).is_some();
        if removed {
            self.invalidate_descr(key);
        }
        Ok(removed)
    }

    /// Keys with the given prefix (BigJob scans `bigjob:pilot:*`-style
    /// namespaces on re-connect). Sorted for deterministic iteration.
    pub fn keys_with_prefix(&self, prefix: &str) -> Result<Vec<String>, StoreError> {
        self.begin()?;
        let mut out = Vec::new();
        for idx in 0..SHARDS {
            let g = self.stripe(idx);
            out.extend(g.data.keys().filter(|k| k.starts_with(prefix)).map(|k| k.to_string()));
        }
        out.sort();
        Ok(out)
    }

    // ---- hashes (entity state: pilots, CUs, DUs) ----

    fn hset_at(&self, idx: usize, key: &str, field: &str, value: &str) -> Result<(), StoreError> {
        self.begin()?;
        {
            let mut g = self.stripe(idx);
            match g.data.get_mut(key) {
                Some(Value::Hash(h)) => {
                    h.insert(field.to_string(), value.to_string());
                }
                Some(_) => return Err(StoreError::WrongType(key.to_string())),
                None => {
                    let mut h = BTreeMap::new();
                    h.insert(field.to_string(), value.to_string());
                    g.data.insert(Arc::from(key), Value::Hash(h));
                }
            }
        }
        if field == "descr" {
            self.invalidate_descr(key);
        }
        Ok(())
    }

    pub fn hset(&self, key: &str, field: &str, value: &str) -> Result<(), StoreError> {
        self.hset_at(stripe_of(key), key, field, value)
    }

    pub fn hset_k(&self, key: &Key, field: &str, value: &str) -> Result<(), StoreError> {
        self.hset_at(key.stripe, &key.text, field, value)
    }

    /// Redis HSETNX: write only if the field is absent; returns whether
    /// a write happened. Lets immutable records (e.g. `descr`) be
    /// checkpointed repeatedly without re-serializing churn.
    pub fn hset_if_absent(
        &self,
        key: &str,
        field: &str,
        value: impl FnOnce() -> String,
    ) -> Result<bool, StoreError> {
        self.begin()?;
        let mut g = self.stripe(stripe_of(key));
        match g.data.get_mut(key) {
            Some(Value::Hash(h)) => {
                if h.contains_key(field) {
                    Ok(false)
                } else {
                    h.insert(field.to_string(), value());
                    Ok(true)
                }
            }
            Some(_) => Err(StoreError::WrongType(key.to_string())),
            None => {
                let mut h = BTreeMap::new();
                h.insert(field.to_string(), value());
                g.data.insert(Arc::from(key), Value::Hash(h));
                Ok(true)
            }
        }
    }

    fn hget_at(&self, idx: usize, key: &str, field: &str) -> Result<Option<String>, StoreError> {
        self.begin()?;
        let g = self.stripe(idx);
        match g.data.get(key) {
            None => Ok(None),
            Some(Value::Hash(h)) => Ok(h.get(field).cloned()),
            Some(_) => Err(StoreError::WrongType(key.to_string())),
        }
    }

    pub fn hget(&self, key: &str, field: &str) -> Result<Option<String>, StoreError> {
        self.hget_at(stripe_of(key), key, field)
    }

    pub fn hget_k(&self, key: &Key, field: &str) -> Result<Option<String>, StoreError> {
        self.hget_at(key.stripe, &key.text, field)
    }

    pub fn hgetall(&self, key: &str) -> Result<BTreeMap<String, String>, StoreError> {
        self.begin()?;
        let g = self.stripe(stripe_of(key));
        match g.data.get(key) {
            None => Ok(BTreeMap::new()),
            Some(Value::Hash(h)) => Ok(h.clone()),
            Some(_) => Err(StoreError::WrongType(key.to_string())),
        }
    }

    // ---- list queues (global CU queue + per-pilot queues) ----

    fn rpush_at(
        &self,
        idx: usize,
        key: &str,
        value: &str,
        notify: bool,
    ) -> Result<usize, StoreError> {
        self.begin()?;
        let len = {
            let mut g = self.stripe(idx);
            match g.data.get_mut(key) {
                Some(Value::List(l)) => {
                    l.push_back(value.to_string());
                    l.len()
                }
                Some(_) => return Err(StoreError::WrongType(key.to_string())),
                None => {
                    let mut l = VecDeque::new();
                    l.push_back(value.to_string());
                    g.data.insert(Arc::from(key), Value::List(l));
                    1
                }
            }
        };
        if notify {
            // Data lock released above: wake blocking pops on this key
            // and fan a keyspace event out to subscribers.
            self.notify_push(idx, key, value);
        }
        Ok(len)
    }

    pub fn rpush(&self, key: &str, value: &str) -> Result<usize, StoreError> {
        self.rpush_at(stripe_of(key), key, value, true)
    }

    pub fn rpush_k(&self, key: &Key, value: &str) -> Result<usize, StoreError> {
        self.rpush_at(key.stripe, &key.text, value, true)
    }

    /// Push back an element the caller just popped — the agent-side
    /// "doesn't fit right now" path — **without** waking blocking pops
    /// or publishing a queue event. Net queue state gained no new
    /// work, so a wakeup would be a guaranteed no-op; in the sim
    /// driver it would even livelock (push-back → wake → pop →
    /// push-back …).
    pub fn requeue_k(&self, key: &Key, value: &str) -> Result<usize, StoreError> {
        self.rpush_at(key.stripe, &key.text, value, false)
    }

    fn lpop_at(&self, idx: usize, key: &str) -> Result<Option<String>, StoreError> {
        self.begin()?;
        let mut g = self.stripe(idx);
        match g.data.get_mut(key) {
            None => Ok(None),
            Some(Value::List(l)) => Ok(l.pop_front()),
            Some(_) => Err(StoreError::WrongType(key.to_string())),
        }
    }

    pub fn lpop(&self, key: &str) -> Result<Option<String>, StoreError> {
        self.lpop_at(stripe_of(key), key)
    }

    pub fn lpop_k(&self, key: &Key) -> Result<Option<String>, StoreError> {
        self.lpop_at(key.stripe, &key.text)
    }

    fn llen_at(&self, idx: usize, key: &str) -> Result<usize, StoreError> {
        self.begin()?;
        let g = self.stripe(idx);
        match g.data.get(key) {
            None => Ok(0),
            Some(Value::List(l)) => Ok(l.len()),
            Some(_) => Err(StoreError::WrongType(key.to_string())),
        }
    }

    pub fn llen(&self, key: &str) -> Result<usize, StoreError> {
        self.llen_at(stripe_of(key), key)
    }

    pub fn llen_k(&self, key: &Key) -> Result<usize, StoreError> {
        self.llen_at(key.stripe, &key.text)
    }

    // ---- typed record cache ----

    fn invalidate_descr(&self, key: &str) {
        if let Some(id) = key.strip_prefix("pd:cu:") {
            let mut c = self.inner.descr.lock().unwrap_or_else(|e| e.into_inner());
            c.generation = c.generation.wrapping_add(1);
            c.cus.remove(id);
        } else if let Some(id) = key.strip_prefix("pd:du:") {
            let mut c = self.inner.descr.lock().unwrap_or_else(|e| e.into_inner());
            c.generation = c.generation.wrapping_add(1);
            c.dus.remove(id);
        }
    }

    /// The typed Compute-Unit-Description stored under `pd:cu:<id>`,
    /// parsed from JSON at most once per write ("json parse CUD" leaves
    /// the hot path). Returns `None` when the record or its `descr`
    /// field is absent.
    pub fn cu_description(
        &self,
        cu_id: &str,
    ) -> anyhow::Result<Option<Arc<crate::unit::ComputeUnitDescription>>> {
        // Cache hits are still store operations: count them and honor
        // injected outages so fault-tolerance behavior is uniform.
        self.begin()?;
        let gen_at_read = {
            let c = self.inner.descr.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(d) = c.cus.get(cu_id) {
                return Ok(Some(d.clone()));
            }
            c.generation
        };
        let Some(text) = self.hget(&keys::cu(cu_id), "descr")? else {
            return Ok(None);
        };
        let parsed = crate::unit::ComputeUnitDescription::from_json(&crate::json::parse(&text)?)?;
        let d = Arc::new(parsed);
        let mut c = self.inner.descr.lock().unwrap_or_else(|e| e.into_inner());
        // Populate only if no invalidation raced our out-of-lock read;
        // a superseded parse is still fine to *return* (point-in-time
        // value), just not to memoize.
        if c.generation == gen_at_read {
            c.cus.insert(cu_id.to_string(), d.clone());
        }
        Ok(Some(d))
    }

    /// The typed Data-Unit-Description stored under `pd:du:<id>`
    /// (see [`Store::cu_description`]).
    pub fn du_description(
        &self,
        du_id: &str,
    ) -> anyhow::Result<Option<Arc<crate::unit::DataUnitDescription>>> {
        self.begin()?;
        let gen_at_read = {
            let c = self.inner.descr.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(d) = c.dus.get(du_id) {
                return Ok(Some(d.clone()));
            }
            c.generation
        };
        let Some(text) = self.hget(&keys::du(du_id), "descr")? else {
            return Ok(None);
        };
        let parsed = crate::unit::DataUnitDescription::from_json(&crate::json::parse(&text)?)?;
        let d = Arc::new(parsed);
        let mut c = self.inner.descr.lock().unwrap_or_else(|e| e.into_inner());
        if c.generation == gen_at_read {
            c.dus.insert(du_id.to_string(), d.clone());
        }
        Ok(Some(d))
    }

    // ---- pub/sub and blocking pops live in [`events`] ----

    // ---- durability ----

    /// Serialize the full store state to JSON (Redis RDB-equivalent).
    /// Deterministic: keys are emitted in sorted order regardless of
    /// stripe layout. Atomic: every stripe is locked (in index order —
    /// the only multi-stripe acquisition path, so no lock-order
    /// inversion) before any is read, so concurrent writers cannot
    /// tear the image.
    pub fn snapshot(&self) -> Json {
        let guards: Vec<MutexGuard<'_, Shard>> = (0..SHARDS).map(|i| self.stripe(i)).collect();
        let mut obj = std::collections::BTreeMap::new();
        for g in &guards {
            for (k, v) in &g.data {
                let jv = match v {
                    Value::Str(s) => Json::obj().set("t", "s").set("v", s.as_str()),
                    Value::Hash(h) => {
                        let mut hm = std::collections::BTreeMap::new();
                        for (f, val) in h {
                            hm.insert(f.clone(), Json::Str(val.clone()));
                        }
                        Json::obj().set("t", "h").set("v", Json::Obj(hm))
                    }
                    Value::List(l) => Json::obj()
                        .set("t", "l")
                        .set("v", Json::Arr(l.iter().map(|s| Json::Str(s.clone())).collect())),
                };
                obj.insert(k.to_string(), jv);
            }
        }
        Json::Obj(obj)
    }

    /// Restore state from a snapshot, replacing current contents —
    /// "the ability to quickly restart the Redis server (if necessary
    /// on another resource)".
    pub fn restore(&self, snap: &Json) -> anyhow::Result<()> {
        let Json::Obj(map) = snap else {
            anyhow::bail!("snapshot must be an object");
        };
        let mut shards: Vec<FxMap<Arc<str>, Value>> =
            (0..SHARDS).map(|_| FxMap::default()).collect();
        for (k, entry) in map {
            let t = entry.str_field("t")?;
            let v = entry
                .get("v")
                .ok_or_else(|| anyhow::anyhow!("snapshot entry '{k}' missing v"))?;
            let value = match t {
                "s" => Value::Str(v.as_str().unwrap_or_default().to_string()),
                "h" => {
                    let Json::Obj(hm) = v else {
                        anyhow::bail!("hash entry '{k}' not an object");
                    };
                    Value::Hash(
                        hm.iter()
                            .map(|(f, x)| (f.clone(), x.as_str().unwrap_or_default().to_string()))
                            .collect(),
                    )
                }
                "l" => Value::List(
                    v.as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|x| x.as_str().unwrap_or_default().to_string())
                        .collect(),
                ),
                other => anyhow::bail!("unknown snapshot type '{other}'"),
            };
            shards[stripe_of(k)].insert(Arc::from(k.as_str()), value);
        }
        // Swap all stripes in under one all-stripe acquisition so no
        // reader observes a half-restored store. The typed cache is
        // cleared while the stripe guards are still held — otherwise a
        // reader could hit a stale pre-restore description against
        // post-restore data. (Stripe→descr is the only nested lock
        // order in this module; no path holds descr while taking a
        // stripe.)
        {
            let mut guards: Vec<MutexGuard<'_, Shard>> =
                (0..SHARDS).map(|i| self.stripe(i)).collect();
            for (idx, data) in shards.into_iter().enumerate() {
                guards[idx].data = data;
            }
            let mut c = self.inner.descr.lock().unwrap_or_else(|e| e.into_inner());
            c.generation = c.generation.wrapping_add(1);
            c.cus.clear();
            c.dus.clear();
        }
        self.inner.down.store(false, Ordering::Relaxed);
        // Restored queues may hold data and the store is reachable
        // again: wake blocked poppers and availability waiters so they
        // re-check against the new state.
        self.wake_waiters();
        Ok(())
    }

    /// Persist a snapshot to disk and reload it — used by the fault
    /// tolerance tests and the local-mode manager checkpoint.
    pub fn save_to(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.snapshot().to_string_pretty())?;
        Ok(())
    }

    pub fn load_from(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let text = std::fs::read_to_string(path)?;
        self.restore(&crate::json::parse(&text)?)
    }
}

/// Well-known key-space layout (mirrors BigJob's Redis schema). The
/// `*_key` variants return interned [`Key`] handles for hot-path
/// callers that reuse them across operations.
pub mod keys {
    use super::Key;
    use std::sync::OnceLock;

    pub fn pilot(id: &str) -> String {
        format!("pd:pilot:{id}")
    }
    pub fn cu(id: &str) -> String {
        format!("pd:cu:{id}")
    }
    pub fn du(id: &str) -> String {
        format!("pd:du:{id}")
    }
    /// Prefix of every queue key — the namespace pattern subscriptions
    /// ([`super::Store::subscribe_prefix`]) watch for queue activity.
    pub const QUEUE_PREFIX: &str = "pd:queue:";
    /// Prefix of the agent-specific pilot queues.
    pub const PILOT_QUEUE_PREFIX: &str = "pd:queue:pilot:";
    /// The global CU queue any agent may pull from.
    pub const GLOBAL_QUEUE: &str = "pd:queue:global";
    /// Prefix of data-plane loss notifications: a replica of DU `x`
    /// disappearing (capacity eviction, storage outage) is published on
    /// `pd:data:lost:<x>` with the PD name as payload. The sim driver's
    /// execution-mode engine subscribes here and turns each loss into a
    /// repair decision — the outage-repair path rides the same event
    /// layer as the queue wakeups.
    pub const DATA_LOST_PREFIX: &str = "pd:data:lost:";
    /// Prefix of data-plane availability notifications: a PD coming
    /// (back) online publishes on `pd:data:avail:<pd>`. The
    /// execution-mode engine subscribes here to re-balance replicas
    /// onto recovered storage.
    pub const DATA_AVAIL_PREFIX: &str = "pd:data:avail:";
    /// Prefix of pilot liveness leases: each agent refreshes
    /// `pd:pilot:hb:<id>` with a wall-clock timestamp (millis); the
    /// manager treats a lease older than its TTL as a dead agent and
    /// reclaims that pilot's queued CUs to the global queue.
    pub const PILOT_HB_PREFIX: &str = "pd:pilot:hb:";
    /// The liveness lease key of one pilot.
    pub fn pilot_hb(pilot_id: &str) -> String {
        format!("{PILOT_HB_PREFIX}{pilot_id}")
    }
    /// The agent-specific queue of one pilot.
    pub fn pilot_queue(pilot_id: &str) -> String {
        format!("{PILOT_QUEUE_PREFIX}{pilot_id}")
    }
    pub const STATE_CHANNEL: &str = "pd:events";

    /// Interned handle for [`GLOBAL_QUEUE`].
    pub fn global_queue_key() -> &'static Key {
        static K: OnceLock<Key> = OnceLock::new();
        K.get_or_init(|| Key::new(GLOBAL_QUEUE))
    }

    /// Interned handle for a pilot's agent queue — mint once per pilot.
    pub fn pilot_queue_key(pilot_id: &str) -> Key {
        Key::new(&pilot_queue(pilot_id))
    }

    /// Interned handle for a CU record — mint once per CU.
    pub fn cu_key(id: &str) -> Key {
        Key::new(&cu(id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_roundtrip_and_delete() {
        let s = Store::new();
        s.set("a", "1").unwrap();
        assert_eq!(s.get("a").unwrap(), Some("1".to_string()));
        assert!(s.del("a").unwrap());
        assert!(!s.del("a").unwrap());
        assert_eq!(s.get("a").unwrap(), None);
    }

    #[test]
    fn hashes_hold_entity_state() {
        let s = Store::new();
        let k = keys::cu("cu-1");
        s.hset(&k, "state", "Queued").unwrap();
        s.hset(&k, "pilot", "pilot-3").unwrap();
        assert_eq!(s.hget(&k, "state").unwrap(), Some("Queued".to_string()));
        let all = s.hgetall(&k).unwrap();
        assert_eq!(all.len(), 2);
        assert_eq!(s.hgetall("absent").unwrap().len(), 0);
    }

    #[test]
    fn type_confusion_is_an_error() {
        let s = Store::new();
        s.set("k", "v").unwrap();
        assert_eq!(s.hget("k", "f"), Err(StoreError::WrongType("k".into())));
        assert_eq!(s.lpop("k"), Err(StoreError::WrongType("k".into())));
        s.rpush("q", "x").unwrap();
        assert_eq!(s.get("q"), Err(StoreError::WrongType("q".into())));
    }

    #[test]
    fn queues_are_fifo() {
        let s = Store::new();
        for i in 0..5 {
            s.rpush(keys::GLOBAL_QUEUE, &format!("cu-{i}")).unwrap();
        }
        assert_eq!(s.llen(keys::GLOBAL_QUEUE).unwrap(), 5);
        assert_eq!(s.lpop(keys::GLOBAL_QUEUE).unwrap(), Some("cu-0".to_string()));
        assert_eq!(s.lpop(keys::GLOBAL_QUEUE).unwrap(), Some("cu-1".to_string()));
        assert_eq!(s.lpop("empty").unwrap(), None);
    }

    #[test]
    fn interned_and_string_keys_are_interchangeable() {
        let s = Store::new();
        let k = Key::new("pd:cu:x");
        s.hset_k(&k, "state", "Running").unwrap();
        assert_eq!(s.hget("pd:cu:x", "state").unwrap(), Some("Running".to_string()));
        s.set("plain", "v").unwrap();
        assert_eq!(s.get_k(&Key::new("plain")).unwrap(), Some("v".to_string()));
        let q = keys::pilot_queue_key("p1");
        s.rpush_k(&q, "cu-1").unwrap();
        s.rpush(&keys::pilot_queue("p1"), "cu-2").unwrap();
        assert_eq!(s.llen_k(&q).unwrap(), 2);
        assert_eq!(s.lpop(&keys::pilot_queue("p1")).unwrap(), Some("cu-1".to_string()));
        assert_eq!(s.lpop_k(&q).unwrap(), Some("cu-2".to_string()));
        assert_eq!(keys::global_queue_key().as_str(), keys::GLOBAL_QUEUE);
    }

    #[test]
    fn hset_if_absent_writes_once() {
        let s = Store::new();
        assert!(s.hset_if_absent("h", "f", || "first".into()).unwrap());
        assert!(!s.hset_if_absent("h", "f", || "second".into()).unwrap());
        assert_eq!(s.hget("h", "f").unwrap(), Some("first".to_string()));
        s.set("str", "v").unwrap();
        assert!(s.hset_if_absent("str", "f", || "x".into()).is_err());
    }

    #[test]
    fn descr_cache_parses_once_and_invalidates_on_write() {
        let s = Store::new();
        let cud = crate::unit::ComputeUnitDescription {
            executable: "/bin/bwa".into(),
            cores: 2,
            ..Default::default()
        };
        s.hset(&keys::cu("c1"), "descr", &cud.to_json().to_string_compact()).unwrap();
        let d1 = s.cu_description("c1").unwrap().unwrap();
        let d2 = s.cu_description("c1").unwrap().unwrap();
        assert!(Arc::ptr_eq(&d1, &d2), "second read must hit the cache");
        assert_eq!(d1.executable, "/bin/bwa");

        // Overwrite invalidates.
        let cud2 = crate::unit::ComputeUnitDescription {
            executable: "/bin/sort".into(),
            ..Default::default()
        };
        s.hset(&keys::cu("c1"), "descr", &cud2.to_json().to_string_compact()).unwrap();
        let d3 = s.cu_description("c1").unwrap().unwrap();
        assert_eq!(d3.executable, "/bin/sort");

        // Unrelated fields leave the cache alone.
        s.hset(&keys::cu("c1"), "state", "Running").unwrap();
        let d4 = s.cu_description("c1").unwrap().unwrap();
        assert!(Arc::ptr_eq(&d3, &d4));

        // del invalidates; absent record reads as None.
        s.del(&keys::cu("c1")).unwrap();
        assert!(s.cu_description("c1").unwrap().is_none());
        assert!(s.du_description("nope").unwrap().is_none());
    }

    #[test]
    fn du_descr_cache_roundtrip() {
        let s = Store::new();
        let dud = crate::unit::DataUnitDescription {
            name: "ref".into(),
            files: vec![crate::unit::FileRef::sized("genome.fa", crate::util::Bytes::gb(8))],
            affinity: None,
        };
        s.hset(&keys::du("d1"), "descr", &dud.to_json().to_string_compact()).unwrap();
        let d1 = s.du_description("d1").unwrap().unwrap();
        let d2 = s.du_description("d1").unwrap().unwrap();
        assert!(Arc::ptr_eq(&d1, &d2));
        assert_eq!(d1.name, "ref");
        assert_eq!(d1.total_size(), crate::util::Bytes::gb(8));
        // Cache hits are store ops: they honor injected outages.
        s.set_down(true);
        assert!(s.du_description("d1").is_err());
        s.set_down(false);
        assert!(s.du_description("d1").is_ok());
    }

    #[test]
    fn pubsub_delivers_to_all_subscribers() {
        let s = Store::new();
        let r1 = s.subscribe(keys::STATE_CHANNEL);
        let r2 = s.subscribe(keys::STATE_CHANNEL);
        let n = s.publish(keys::STATE_CHANNEL, "cu-1:Running").unwrap();
        assert_eq!(n, 2);
        assert_eq!(r1.try_recv().unwrap().payload, "cu-1:Running");
        let ev = r2.try_recv().unwrap();
        assert_eq!(ev.payload, "cu-1:Running");
        assert_eq!(ev.key, keys::STATE_CHANNEL);
    }

    #[test]
    fn dropped_subscribers_are_pruned() {
        let s = Store::new();
        {
            let _r = s.subscribe("ch");
        } // receiver dropped
        let n = s.publish("ch", "x").unwrap();
        assert_eq!(n, 0);
    }

    #[test]
    fn outage_fails_ops_then_recovers() {
        let s = Store::new();
        s.set("a", "1").unwrap();
        s.set_down(true);
        assert_eq!(s.get("a"), Err(StoreError::Unavailable));
        assert_eq!(s.set("b", "2"), Err(StoreError::Unavailable));
        s.set_down(false);
        // State survived the transient outage.
        assert_eq!(s.get("a").unwrap(), Some("1".to_string()));
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let s = Store::new();
        s.set("k", "v").unwrap();
        s.hset("h", "f1", "x").unwrap();
        s.rpush("q", "a").unwrap();
        s.rpush("q", "b").unwrap();
        let snap = s.snapshot();

        let fresh = Store::new();
        fresh.restore(&snap).unwrap();
        assert_eq!(fresh.get("k").unwrap(), Some("v".to_string()));
        assert_eq!(fresh.hget("h", "f1").unwrap(), Some("x".to_string()));
        assert_eq!(fresh.lpop("q").unwrap(), Some("a".to_string()));
        assert_eq!(fresh.lpop("q").unwrap(), Some("b".to_string()));
    }

    #[test]
    fn save_load_file_roundtrip() {
        let s = Store::new();
        s.set("k", "v").unwrap();
        let path = std::env::temp_dir().join(format!("pd-store-{}.json", std::process::id()));
        s.save_to(&path).unwrap();
        let fresh = Store::new();
        fresh.load_from(&path).unwrap();
        assert_eq!(fresh.get("k").unwrap(), Some("v".to_string()));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn keyspace_prefix_scan() {
        let s = Store::new();
        s.hset(&keys::pilot("p1"), "state", "Active").unwrap();
        s.hset(&keys::pilot("p2"), "state", "New").unwrap();
        s.hset(&keys::cu("c1"), "state", "New").unwrap();
        let pilots = s.keys_with_prefix("pd:pilot:").unwrap();
        assert_eq!(pilots.len(), 2);
        // Deterministic order despite hash sharding.
        assert_eq!(pilots, vec![keys::pilot("p1"), keys::pilot("p2")]);
    }

    #[test]
    fn concurrent_queue_consumers_split_work() {
        let s = Store::new();
        for i in 0..100 {
            s.rpush("q", &format!("{i}")).unwrap();
        }
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Ok(Some(v)) = s.lpop("q") {
                    got.push(v);
                }
                got
            }));
        }
        let mut all: Vec<String> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_by_key(|v| v.parse::<u32>().unwrap());
        assert_eq!(all.len(), 100, "each item consumed exactly once");
        assert_eq!(all[0], "0");
        assert_eq!(all[99], "99");
    }

    /// Sharded-store smoke test: N threads hammer disjoint and shared
    /// keys across stripes; every op must land exactly once and the op
    /// counter must account for all of them.
    #[test]
    fn sharded_store_concurrent_smoke() {
        const THREADS: u64 = 8;
        const OPS: u64 = 400;
        let s = Store::new();
        let base = s.op_count();
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let own_q = Key::new(&format!("q:{t}"));
                let own_h = Key::new(&format!("h:{t}"));
                for i in 0..OPS {
                    // 3 ops per iteration, spread across stripes.
                    s.rpush_k(&own_q, &format!("{i}")).unwrap();
                    s.hset_k(&own_h, &format!("f{}", i % 7), "v").unwrap();
                    s.rpush(keys::GLOBAL_QUEUE, &format!("{t}:{i}")).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.op_count() - base, THREADS * OPS * 3, "every op counted exactly once");
        // Per-thread invariants.
        for t in 0..THREADS {
            assert_eq!(s.llen(&format!("q:{t}")).unwrap(), OPS as usize);
            assert_eq!(s.hgetall(&format!("h:{t}")).unwrap().len(), 7);
        }
        // Shared queue took every push from every thread.
        assert_eq!(s.llen(keys::GLOBAL_QUEUE).unwrap(), (THREADS * OPS) as usize);
        // FIFO preserved per producer on the shared queue.
        let mut last_seen: BTreeMap<String, i64> = BTreeMap::new();
        while let Some(v) = s.lpop(keys::GLOBAL_QUEUE).unwrap() {
            let (t, i) = v.split_once(':').unwrap();
            let i: i64 = i.parse().unwrap();
            let last = last_seen.entry(t.to_string()).or_insert(-1);
            assert!(i > *last, "producer {t} out of order: {i} after {last}");
            *last = i;
        }
    }

    #[test]
    fn snapshot_property_roundtrip() {
        crate::prop::check_default(
            |rng| {
                let s = Store::new();
                for i in 0..crate::prop::gen::usize_in(rng, 0, 10) {
                    match rng.below(3) {
                        0 => s.set(&format!("k{i}"), &crate::prop::gen::ascii_string(rng, 12)).unwrap(),
                        1 => s.hset(&format!("h{i}"), "f", &crate::prop::gen::ascii_string(rng, 12)).unwrap(),
                        _ => {
                            s.rpush(&format!("q{i}"), &crate::prop::gen::ascii_string(rng, 12)).unwrap();
                        }
                    }
                }
                s.snapshot()
            },
            |snap| {
                let fresh = Store::new();
                fresh.restore(snap).map_err(|e| e.to_string())?;
                if fresh.snapshot() == *snap {
                    Ok(())
                } else {
                    Err("snapshot not idempotent".into())
                }
            },
        );
    }
}
