//! Event layer of the coordination store: per-stripe pub/sub and
//! BLPOP-style blocking pops.
//!
//! BigJob's agents do not poll Redis — they block on `BLPOP` and react
//! to pub/sub notifications (paper §4.2), which is what keeps the
//! coordination cost independent of the number of idle agents. This
//! module gives the in-process store the same two primitives:
//!
//! * **Pub/sub on interned [`Key`]s.** Exact-key subscriber registries
//!   are sharded across the same [`SHARDS`] stripes as the data (a
//!   publish on one pilot's queue never contends with another's), while
//!   *pattern* subscriptions on key prefixes (e.g. the
//!   [`super::keys::QUEUE_PREFIX`] queue namespace) live in one shared
//!   registry consulted per publish — a prefix spans stripes by
//!   definition. Every [`Store::rpush`] fans out a keyspace event
//!   (key = the queue, payload = the pushed value) to both registries;
//!   explicit [`Store::publish_k`] does the same for arbitrary keys.
//!
//! * **Blocking pops.** [`Store::blpop_k`] / [`Store::blpop_any`]
//!   block the calling thread until an element arrives, built on
//!   condvar-backed waiter cells in a per-stripe registry: a popper
//!   that finds
//!   its queues empty registers a [`WaitCell`] under each queue key
//!   (then re-checks, closing the classic lost-wakeup window) and
//!   sleeps; `rpush` drains and notifies the waiters of exactly that
//!   key. Multi-queue pops implement §4.2's two-queue protocol in one
//!   call: queues are tried in priority order (agent-specific first,
//!   global second). [`Store::blpop_any_until`] is the deadline
//!   variant.
//!
//! # Outage semantics
//!
//! An injected outage ([`Store::set_down`]) wakes every blocked popper,
//! which then surfaces [`StoreError::Unavailable`] — exactly what a
//! dropped Redis connection does to a blocked `BLPOP`. Agents park on
//! [`Store::wait_available`] (woken by recovery or by their shutdown
//! flag via [`Store::wake_waiters`]) instead of sleeping in a retry
//! loop.
//!
//! # Deadline semantics under simulated time
//!
//! The discrete-event driver ([`crate::experiments::simdrive`]) is
//! single-threaded: a thread-blocking pop would deadlock it, and
//! wall-clock deadlines are meaningless at simulated-time scale. Under
//! simtime, a "blocking pop with deadline" therefore maps to the
//! non-blocking [`Store::lpop_k`] plus a *scheduled wakeup event*: the
//! sim driver subscribes to the queue namespace with
//! [`Store::subscribe_prefix`] and turns each queue event into a
//! `TryPull` sim event at the current simulated instant, while
//! `Delay`-style re-evaluation events play the role of the deadline.
//! The blocking forms in this module are for wall-clock mode (the
//! local-execution service agents) and the concurrency test suite.

use super::{stripe_of, FxMap, Key, Store, StoreError, SHARDS};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// A message delivered to a subscriber: the key it was published on
/// (so prefix subscribers can demultiplex) plus the payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub key: String,
    pub payload: String,
}

/// One waiter blocked in a pop: a signaled flag under a mutex plus the
/// condvar the blocked thread sleeps on. Registered under every queue
/// key the pop covers; a push on any of them notifies the cell.
struct WaitCell {
    signaled: Mutex<bool>,
    cv: Condvar,
}

impl WaitCell {
    fn new() -> WaitCell {
        WaitCell { signaled: Mutex::new(false), cv: Condvar::new() }
    }

    fn notify(&self) {
        let mut g = self.signaled.lock().unwrap_or_else(|e| e.into_inner());
        *g = true;
        self.cv.notify_all();
    }

    /// Sleep until notified or the deadline passes. Returns whether a
    /// signal was consumed (`false` = timed out).
    fn wait_until(&self, deadline: Option<Instant>) -> bool {
        let mut g = self.signaled.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if *g {
                *g = false;
                return true;
            }
            match deadline {
                None => g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner()),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return false;
                    }
                    let (g2, _) = self
                        .cv
                        .wait_timeout(g, d - now)
                        .unwrap_or_else(|e| e.into_inner());
                    g = g2;
                }
            }
        }
    }
}

/// Per-stripe subscriber + waiter registries (same striping as the
/// data shards, so unrelated keys never contend on one registry lock).
#[derive(Default)]
struct SubStripe {
    /// Exact-key subscribers.
    exact: FxMap<Arc<str>, Vec<Sender<Event>>>,
    /// Blocking-pop waiters per key; drained wholesale on each push
    /// (losers of the pop race re-register).
    waiters: FxMap<Arc<str>, Vec<Arc<WaitCell>>>,
}

/// The store's event hub: sharded exact-key registries, the global
/// prefix-pattern registry, and the availability condvar.
pub(super) struct EventHub {
    stripes: Vec<Mutex<SubStripe>>,
    prefixes: Mutex<Vec<(String, Sender<Event>)>>,
    /// Upper bound on live prefix subscriptions (never decremented;
    /// dead senders are pruned under the lock). Lets the push hot path
    /// skip the shared `prefixes` mutex entirely when no pattern
    /// subscriber has ever been registered — the common case in
    /// wall-clock service mode, where pushes from every agent would
    /// otherwise contend on this one store-wide lock.
    prefix_ceiling: std::sync::atomic::AtomicUsize,
    avail: Mutex<()>,
    avail_cv: Condvar,
}

impl EventHub {
    pub(super) fn new() -> EventHub {
        EventHub {
            stripes: (0..SHARDS).map(|_| Mutex::new(SubStripe::default())).collect(),
            prefixes: Mutex::new(Vec::new()),
            prefix_ceiling: std::sync::atomic::AtomicUsize::new(0),
            avail: Mutex::new(()),
            avail_cv: Condvar::new(),
        }
    }

    fn stripe(&self, idx: usize) -> MutexGuard<'_, SubStripe> {
        self.stripes[idx].lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl Store {
    // ---- pub/sub ----

    /// Subscribe to events published on exactly this key (per-stripe
    /// registry; no cross-key contention). Dropped receivers are
    /// pruned on the next publish.
    pub fn subscribe_key(&self, key: &Key) -> Receiver<Event> {
        let (tx, rx) = channel();
        self.inner
            .hub
            .stripe(key.stripe)
            .exact
            .entry(key.text.clone())
            .or_default()
            .push(tx);
        rx
    }

    /// String-keyed convenience wrapper over [`Store::subscribe_key`]
    /// (the seed's channel API; a channel is just a key).
    pub fn subscribe(&self, channel: &str) -> Receiver<Event> {
        self.subscribe_key(&Key::new(channel))
    }

    /// Pattern subscription on a key prefix — e.g.
    /// [`super::keys::QUEUE_PREFIX`] to observe every queue push in the
    /// system. Consulted on each publish regardless of stripe.
    pub fn subscribe_prefix(&self, prefix: &str) -> Receiver<Event> {
        let (tx, rx) = channel();
        self.inner
            .hub
            .prefixes
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push((prefix.to_string(), tx));
        self.inner
            .hub
            .prefix_ceiling
            .fetch_add(1, std::sync::atomic::Ordering::Release);
        rx
    }

    /// Deliver to exact-key subscribers of `key` with the stripe
    /// registry already locked (mpsc sends never block, so sending
    /// under the guard is safe — and keeps `notify_push` to a single
    /// stripe-lock acquisition per push).
    fn deliver_exact(s: &mut SubStripe, key: &str, payload: &str) -> usize {
        let mut delivered = 0;
        let mut emptied = false;
        if let Some(list) = s.exact.get_mut(key) {
            list.retain(|tx| {
                tx.send(Event { key: key.to_string(), payload: payload.to_string() }).is_ok()
            });
            delivered = list.len();
            emptied = list.is_empty();
        }
        if emptied {
            s.exact.remove(key);
        }
        delivered
    }

    /// Deliver to prefix (pattern) subscribers matching `key`.
    fn fanout_prefix(&self, key: &str, payload: &str) -> usize {
        // Lock-free fast path: no pattern subscriber was ever
        // registered (service mode) — don't touch the shared mutex.
        if self.inner.hub.prefix_ceiling.load(std::sync::atomic::Ordering::Acquire) == 0 {
            return 0;
        }
        let mut delivered = 0;
        let mut pats = self.inner.hub.prefixes.lock().unwrap_or_else(|e| e.into_inner());
        if !pats.is_empty() {
            pats.retain(|(p, tx)| {
                if key.starts_with(p.as_str()) {
                    tx.send(Event { key: key.to_string(), payload: payload.to_string() }).is_ok()
                } else {
                    true
                }
            });
            delivered += pats.iter().filter(|(p, _)| key.starts_with(p.as_str())).count();
        }
        delivered
    }

    /// Deliver an event to exact-key and matching prefix subscribers;
    /// returns how many subscribers received it.
    fn fanout(&self, stripe: usize, key: &str, payload: &str) -> usize {
        let exact = {
            let mut s = self.inner.hub.stripe(stripe);
            Self::deliver_exact(&mut s, key, payload)
        };
        exact + self.fanout_prefix(key, payload)
    }

    /// Publish `payload` on an interned key.
    pub fn publish_k(&self, key: &Key, payload: &str) -> Result<usize, StoreError> {
        self.begin()?;
        Ok(self.fanout(key.stripe, &key.text, payload))
    }

    /// String-keyed publish (the seed's channel API).
    pub fn publish(&self, channel: &str, message: &str) -> Result<usize, StoreError> {
        self.begin()?;
        Ok(self.fanout(stripe_of(channel), channel, message))
    }

    /// Internal: a value landed on `key` — wake its blocking-pop
    /// waiters (they consume data, so they go first) and fan the
    /// keyspace event out to subscribers. Called by `rpush` with the
    /// data lock already released.
    ///
    /// Every waiter on the key is woken (drained) per push: one wins
    /// the element, the rest re-check and re-park. That is an O(idle
    /// waiters) herd per *event* — deliberately traded for simplicity
    /// and loss-freedom over Redis's wake-one handoff, which cannot
    /// strand an element here either but needs per-waiter delivery
    /// state to stay correct with multi-queue pops (a single cell can
    /// be signaled for one queue and consume from another, leaving the
    /// first's element behind). Idle cost with *no* events remains
    /// zero regardless of waiter count.
    pub(super) fn notify_push(&self, stripe: usize, key: &str, payload: &str) {
        // One stripe-lock acquisition covers both the waiter drain and
        // the exact-subscriber delivery; cells are notified after the
        // guard drops (notify takes each cell's own mutex — keep the
        // lock scopes disjoint).
        let cells = {
            let mut s = self.inner.hub.stripe(stripe);
            let cells = s.waiters.remove(key);
            Self::deliver_exact(&mut s, key, payload);
            cells
        };
        if let Some(cells) = cells {
            for c in cells {
                c.notify();
            }
        }
        self.fanout_prefix(key, payload);
    }

    // ---- blocking pops ----

    fn register_waiter(&self, key: &Key, cell: &Arc<WaitCell>) {
        self.inner
            .hub
            .stripe(key.stripe)
            .waiters
            .entry(key.text.clone())
            .or_default()
            .push(cell.clone());
    }

    fn deregister_waiter(&self, queues: &[&Key], cell: &Arc<WaitCell>) {
        for k in queues {
            let mut s = self.inner.hub.stripe(k.stripe);
            let mut emptied = false;
            if let Some(v) = s.waiters.get_mut(&*k.text) {
                v.retain(|c| !Arc::ptr_eq(c, cell));
                emptied = v.is_empty();
            }
            if emptied {
                s.waiters.remove(&*k.text);
            }
        }
    }

    /// BLPOP over several queues in priority order (first non-empty
    /// wins — §4.2's agent-specific-then-global protocol in one call),
    /// blocking until an element arrives or the absolute `deadline`
    /// passes. Returns `(queue_index, value)`; `None` only on
    /// deadline. Surfaces [`StoreError::Unavailable`] immediately when
    /// the store goes down, like a dropped Redis connection.
    pub fn blpop_any_until(
        &self,
        queues: &[&Key],
        deadline: Option<Instant>,
    ) -> Result<Option<(usize, String)>, StoreError> {
        loop {
            // Fast path: no registration when data is already there.
            for (i, k) in queues.iter().enumerate() {
                if let Some(v) = self.lpop_k(k)? {
                    return Ok(Some((i, v)));
                }
            }
            let cell = Arc::new(WaitCell::new());
            for k in queues {
                self.register_waiter(k, &cell);
            }
            // Re-check after registering: a push that landed between
            // the miss above and the registration found no waiter to
            // notify — this second look closes the lost-wakeup window.
            let recheck: Result<Option<(usize, String)>, StoreError> = (|| {
                for (i, k) in queues.iter().enumerate() {
                    if let Some(v) = self.lpop_k(k)? {
                        return Ok(Some((i, v)));
                    }
                }
                Ok(None)
            })();
            match recheck {
                Ok(Some(hit)) => {
                    self.deregister_waiter(queues, &cell);
                    return Ok(Some(hit));
                }
                Ok(None) => {}
                Err(e) => {
                    self.deregister_waiter(queues, &cell);
                    return Err(e);
                }
            }
            let signaled = cell.wait_until(deadline);
            self.deregister_waiter(queues, &cell);
            if !signaled {
                // Deadline passed: one final non-blocking look keeps
                // the "value or timeout" contract precise.
                for (i, k) in queues.iter().enumerate() {
                    if let Some(v) = self.lpop_k(k)? {
                        return Ok(Some((i, v)));
                    }
                }
                return Ok(None);
            }
            // Woken: loop and race for the element; losers re-register.
        }
    }

    /// [`Store::blpop_any_until`] with a relative timeout (`None` =
    /// block indefinitely).
    pub fn blpop_any(
        &self,
        queues: &[&Key],
        timeout: Option<Duration>,
    ) -> Result<Option<(usize, String)>, StoreError> {
        self.blpop_any_until(queues, timeout.map(|t| Instant::now() + t))
    }

    /// Single-queue blocking pop (`None` timeout = block indefinitely).
    pub fn blpop_k(
        &self,
        key: &Key,
        timeout: Option<Duration>,
    ) -> Result<Option<String>, StoreError> {
        Ok(self.blpop_any(&[key], timeout)?.map(|(_, v)| v))
    }

    /// Single-queue blocking pop against an absolute deadline.
    pub fn blpop_until(
        &self,
        key: &Key,
        deadline: Option<Instant>,
    ) -> Result<Option<String>, StoreError> {
        Ok(self.blpop_any_until(&[key], deadline)?.map(|(_, v)| v))
    }

    // ---- availability ----

    /// Block until the store is reachable again or `give_up` returns
    /// true. Event-driven: woken by [`Store::set_down`]`(false)`,
    /// [`Store::restore`], or [`Store::wake_waiters`] — never a sleep
    /// loop. Agents pass their shutdown flag as `give_up`.
    pub fn wait_available(&self, give_up: impl Fn() -> bool) {
        let mut g = self.inner.hub.avail.lock().unwrap_or_else(|e| e.into_inner());
        while self.is_down() && !give_up() {
            g = self.inner.hub.avail_cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Wake every blocked waiter — blocking pops and availability
    /// waits — without touching any data. Woken parties re-check their
    /// predicates: poppers re-poll their queues (and surface
    /// `Unavailable` during an outage), availability waiters re-check
    /// the down flag and their give-up condition. Called by
    /// `set_down`, `restore`, and agent shutdown paths.
    pub fn wake_waiters(&self) {
        for idx in 0..SHARDS {
            let cells: Vec<Arc<WaitCell>> = {
                let mut s = self.inner.hub.stripe(idx);
                s.waiters.drain().flat_map(|(_, v)| v).collect()
            };
            for c in cells {
                c.notify();
            }
        }
        let _g = self.inner.hub.avail.lock().unwrap_or_else(|e| e.into_inner());
        self.inner.hub.avail_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::super::keys;
    use super::*;

    #[test]
    fn blpop_returns_existing_element_without_blocking() {
        let s = Store::new();
        let q = Key::new("pd:queue:ev1");
        s.rpush_k(&q, "a").unwrap();
        assert_eq!(s.blpop_k(&q, None).unwrap(), Some("a".to_string()));
    }

    #[test]
    fn blpop_deadline_times_out_empty() {
        let s = Store::new();
        let q = Key::new("pd:queue:ev2");
        let t0 = Instant::now();
        assert_eq!(s.blpop_k(&q, Some(Duration::from_millis(30))).unwrap(), None);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn blpop_any_respects_priority_order() {
        let s = Store::new();
        let own = Key::new(&keys::pilot_queue("pZ"));
        let global = keys::global_queue_key();
        s.rpush_k(global, "g").unwrap();
        s.rpush_k(&own, "o").unwrap();
        let first = s.blpop_any(&[&own, global], None).unwrap();
        assert_eq!(first, Some((0, "o".to_string())));
        let second = s.blpop_any(&[&own, global], None).unwrap();
        assert_eq!(second, Some((1, "g".to_string())));
    }

    #[test]
    fn push_wakes_blocked_popper() {
        let s = Store::new();
        let q = Key::new("pd:queue:ev3");
        let h = std::thread::spawn({
            let s = s.clone();
            let q = q.clone();
            move || s.blpop_k(&q, Some(Duration::from_secs(20))).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        s.rpush_k(&q, "late").unwrap();
        assert_eq!(h.join().unwrap(), Some("late".to_string()));
    }

    #[test]
    fn outage_unblocks_popper_with_unavailable() {
        let s = Store::new();
        let q = Key::new("pd:queue:ev4");
        let h = std::thread::spawn({
            let s = s.clone();
            let q = q.clone();
            move || s.blpop_k(&q, Some(Duration::from_secs(20)))
        });
        std::thread::sleep(Duration::from_millis(50));
        s.set_down(true);
        assert_eq!(h.join().unwrap(), Err(StoreError::Unavailable));
        // Recovery wakes availability waiters.
        let h2 = std::thread::spawn({
            let s = s.clone();
            move || {
                s.wait_available(|| false);
                s.is_down()
            }
        });
        std::thread::sleep(Duration::from_millis(50));
        s.set_down(false);
        assert!(!h2.join().unwrap());
    }

    #[test]
    fn requeue_does_not_wake_or_publish() {
        let s = Store::new();
        let q = Key::new("pd:queue:ev5");
        let rx = s.subscribe_prefix("pd:queue:ev5");
        s.rpush_k(&q, "x").unwrap();
        assert_eq!(rx.try_iter().count(), 1, "rpush publishes a queue event");
        let v = s.lpop_k(&q).unwrap().unwrap();
        s.requeue_k(&q, &v).unwrap();
        assert_eq!(rx.try_iter().count(), 0, "requeue is silent");
        // The value is still there for a later (non-blocking) pop.
        assert_eq!(s.lpop_k(&q).unwrap(), Some("x".to_string()));
    }

    #[test]
    fn prefix_subscription_sees_queue_namespace() {
        let s = Store::new();
        let rx = s.subscribe_prefix(keys::QUEUE_PREFIX);
        s.rpush(&keys::pilot_queue("p1"), "cu-1").unwrap();
        s.rpush(keys::GLOBAL_QUEUE, "cu-2").unwrap();
        s.set("unrelated", "v").unwrap();
        let evs: Vec<Event> = rx.try_iter().collect();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].key, keys::pilot_queue("p1"));
        assert_eq!(evs[0].payload, "cu-1");
        assert_eq!(evs[1].key, keys::GLOBAL_QUEUE);
    }

    #[test]
    fn exact_key_subscription_is_per_key() {
        let s = Store::new();
        let k1 = Key::new("pd:queue:a");
        let rx = s.subscribe_key(&k1);
        s.rpush_k(&k1, "one").unwrap();
        s.rpush("pd:queue:b", "other").unwrap();
        let evs: Vec<Event> = rx.try_iter().collect();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].payload, "one");
    }
}
