//! Event layer of the coordination store: per-stripe pub/sub and
//! BLPOP-style blocking pops with a Redis-style wake-one handoff.
//!
//! BigJob's agents do not poll Redis — they block on `BLPOP` and react
//! to pub/sub notifications (paper §4.2), which is what keeps the
//! coordination cost independent of the number of idle agents. This
//! module gives the in-process store the same two primitives:
//!
//! * **Pub/sub on interned [`Key`]s.** Exact-key subscriber registries
//!   are sharded across the same [`SHARDS`] stripes as the data (a
//!   publish on one pilot's queue never contends with another's), while
//!   *pattern* subscriptions — plain prefixes
//!   ([`Store::subscribe_prefix`]) or Redis-style globs with `*`/`?`
//!   ([`Store::subscribe_pattern`], see [`glob_match`]) — live in one
//!   shared registry consulted per publish; a pattern spans stripes by
//!   definition. Pattern subscriptions are tagged with a [`SubId`] and
//!   can be torn down with [`Store::unsubscribe`]. Every
//!   [`Store::rpush`] fans out a keyspace event (key = the queue,
//!   payload = the pushed value) to both registries; explicit
//!   [`Store::publish_k`] does the same for arbitrary keys.
//!
//! * **Blocking pops.** [`Store::blpop_k`] / [`Store::blpop_any`]
//!   block the calling thread until an element arrives, built on
//!   condvar-backed waiter cells in a per-stripe registry: a popper
//!   that finds its queues empty registers a `WaitCell` under each
//!   queue key (then re-checks, closing the classic lost-wakeup
//!   window) and sleeps. Multi-queue pops implement §4.2's two-queue
//!   protocol in one call: queues are tried in priority order
//!   (agent-specific first, global second). [`Store::blpop_any_until`]
//!   is the deadline variant.
//!
//! # Wake-one handoff
//!
//! A push on a **queue-namespace key** (under
//! [`super::keys::QUEUE_PREFIX`]) hands its wakeup to *exactly one*
//! parked waiter, like Redis serving one blocked `BLPOP` client per
//! `RPUSH` — not a thundering herd of every waiter racing for one
//! element. With multi-slot pilot agents a queue routinely has N
//! parked workers, so the herd would cost O(N) wakeups per push; the
//! handoff costs O(1). The protocol:
//!
//! * **Per-waiter delivery state.** Each `WaitCell` carries a
//!   `signaled` claim flag. A push scans the key's waiter list in
//!   registration order and *claims* the first cell whose flag is
//!   clear (`WaitCell::try_claim`); already-claimed cells are
//!   skipped, so a cell registered under several queues (a multi-queue
//!   pop) can absorb at most one pending handoff — a second push on a
//!   *different* covered queue passes over it and claims the next
//!   waiter instead of wasting its wakeup.
//!
//! * **Re-donation on exit.** A woken waiter pops its queues in
//!   priority order, which may consume an element from a different
//!   queue than the one whose push claimed it (or lose the pop race
//!   entirely and re-park). Whatever signal it absorbed is therefore
//!   passed on when the pop returns: the exit path re-checks every
//!   covered queue and, for each that is still non-empty, claims one
//!   more parked waiter. Each re-donation claims a distinct unclaimed
//!   cell, so the chain is bounded by the number of parked waiters and
//!   no element is ever stranded behind a consumed signal.
//!
//! * **Broadcast fallback.** Pushes on non-queue keys keep the
//!   pre-handoff semantics — every parked waiter on the key is drained
//!   and woken, losers re-register. Outages, recovery, and shutdown
//!   ([`Store::wake_waiters`]) always broadcast: woken parties
//!   re-check their own predicates.
//!
//! [`Store::wake_stats`] counts handoff claims, re-donations, and
//! broadcast wakeups so tests and the herd benches can assert the O(1)
//! shape directly.
//!
//! # Outage semantics
//!
//! An injected outage ([`Store::set_down`]) wakes every blocked popper,
//! which then surfaces [`StoreError::Unavailable`] — exactly what a
//! dropped Redis connection does to a blocked `BLPOP`. Agents park on
//! [`Store::wait_available`] (woken by recovery or by their shutdown
//! flag via [`Store::wake_waiters`]) instead of sleeping in a retry
//! loop.
//!
//! # Blocking pops under simulated time
//!
//! The discrete-event driver ([`crate::experiments::simdrive`]) is
//! single-threaded: a thread-blocking pop would deadlock it, and
//! wall-clock deadlines are meaningless at simulated-time scale. Under
//! simtime, a "blocking pop" therefore maps to the non-blocking
//! [`Store::lpop_k`] plus a *scheduled wakeup event*: the sim driver
//! subscribes to the queue namespace with [`Store::subscribe_prefix`]
//! and turns each queue event into a `TryPull` sim event at the
//! current simulated instant, while `Delay`-style re-evaluation events
//! play the role of the deadline. The wall-clock worker *pool* of a
//! multi-slot pilot maps the same way: each `TryPull` dispatches one
//! CU (one slot's pull) and, while free slots remain, front-schedules
//! the next `TryPull` in the chain (`SlotMode::PerSlot` in the sim
//! driver) — the deterministic, single-threaded image of N workers
//! waking one after another. The blocking forms in this module are for
//! wall-clock mode (the local-execution service agents) and the
//! concurrency test suite.

use super::{keys, stripe_of, FxMap, Key, Store, StoreError, SHARDS};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// A message delivered to a subscriber: the key it was published on
/// (so pattern subscribers can demultiplex) plus the payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub key: String,
    pub payload: String,
}

/// Redis-style glob match over key bytes: `*` matches any (possibly
/// empty) sequence, `?` matches exactly one byte, everything else
/// matches itself. Iterative with single-star backtracking — O(|key|)
/// amortized for the patterns this store sees.
pub fn glob_match(pattern: &str, text: &str) -> bool {
    let p = pattern.as_bytes();
    let t = text.as_bytes();
    let (mut pi, mut ti) = (0usize, 0usize);
    let mut star: Option<usize> = None;
    let mut star_ti = 0usize;
    while ti < t.len() {
        if pi < p.len() && (p[pi] == b'?' || p[pi] == t[ti]) {
            pi += 1;
            ti += 1;
        } else if pi < p.len() && p[pi] == b'*' {
            star = Some(pi);
            star_ti = ti;
            pi += 1;
        } else if let Some(s) = star {
            // Backtrack: let the last `*` swallow one more byte.
            pi = s + 1;
            star_ti += 1;
            ti = star_ti;
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == b'*' {
        pi += 1;
    }
    pi == p.len()
}

/// One waiter blocked in a pop: a claim flag under a mutex plus the
/// condvar the blocked thread sleeps on. Registered under every queue
/// key the pop covers; the wake-one handoff claims the cell through
/// exactly one of them per pending signal.
struct WaitCell {
    signaled: Mutex<bool>,
    cv: Condvar,
}

impl WaitCell {
    fn new() -> WaitCell {
        WaitCell { signaled: Mutex::new(false), cv: Condvar::new() }
    }

    /// Unconditional wake (broadcast paths): set the flag and notify.
    fn notify(&self) {
        let mut g = self.signaled.lock().unwrap_or_else(|e| e.into_inner());
        *g = true;
        self.cv.notify_all();
    }

    /// Wake-one handoff: claim the cell only if no signal is already
    /// pending on it. Returns whether this call took the claim.
    fn try_claim(&self) -> bool {
        let mut g = self.signaled.lock().unwrap_or_else(|e| e.into_inner());
        if *g {
            false
        } else {
            *g = true;
            self.cv.notify_all();
            true
        }
    }

    /// Sleep until notified or the deadline passes. Returns whether a
    /// signal was consumed (`false` = timed out).
    fn wait_until(&self, deadline: Option<Instant>) -> bool {
        let mut g = self.signaled.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if *g {
                *g = false;
                return true;
            }
            match deadline {
                None => g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner()),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return false;
                    }
                    let (g2, _) = self
                        .cv
                        .wait_timeout(g, d - now)
                        .unwrap_or_else(|e| e.into_inner());
                    g = g2;
                }
            }
        }
    }
}

/// Per-stripe subscriber + waiter registries (same striping as the
/// data shards, so unrelated keys never contend on one registry lock).
#[derive(Default)]
struct SubStripe {
    /// Exact-key subscribers.
    exact: FxMap<Arc<str>, Vec<Sender<Event>>>,
    /// Blocking-pop waiters per key, in registration order. Queue keys
    /// hand each push to the first unclaimed cell; non-queue keys
    /// drain the whole list per push (losers re-register).
    waiters: FxMap<Arc<str>, Vec<Arc<WaitCell>>>,
}

/// How a pattern subscription matches keys.
enum PatternKind {
    /// Literal prefix (the queue-namespace fast form).
    Prefix(String),
    /// Redis-style glob (`*`, `?`) over the whole key.
    Glob(String),
}

impl PatternKind {
    fn matches(&self, key: &str) -> bool {
        match self {
            PatternKind::Prefix(p) => key.starts_with(p.as_str()),
            PatternKind::Glob(g) => glob_match(g, key),
        }
    }
}

/// One pattern subscription in the shared registry.
struct PatternSub {
    id: u64,
    kind: PatternKind,
    tx: Sender<Event>,
}

/// Handle for tearing down a pattern subscription
/// ([`Store::unsubscribe`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubId(u64);

/// Wakeup accounting for the blocking-pop layer (see module docs).
/// Monotonic counters; read with [`Store::wake_stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WakeStats {
    /// Waiters claimed by queue-key pushes — the wake-one handoff
    /// wakes **at most one** waiter per push, so this never exceeds
    /// the number of queue pushes.
    pub push_wakeups: u64,
    /// Handoffs passed on by exiting poppers that had absorbed a
    /// signal for work they did not consume.
    pub redonations: u64,
    /// Waiters woken by pushes on non-queue keys (broadcast fallback:
    /// every parked waiter on the key, per push).
    pub broadcast_wakeups: u64,
}

/// The store's event hub: sharded exact-key registries, the global
/// pattern registry, wakeup counters, and the availability condvar.
pub(super) struct EventHub {
    stripes: Vec<Mutex<SubStripe>>,
    patterns: Mutex<Vec<PatternSub>>,
    /// Upper bound on live pattern subscriptions (never decremented;
    /// dead senders are pruned under the lock). Lets the push hot path
    /// skip the shared `patterns` mutex entirely when no pattern
    /// subscriber has ever been registered — the common case in
    /// wall-clock service mode, where pushes from every agent would
    /// otherwise contend on this one store-wide lock.
    pattern_ceiling: AtomicUsize,
    next_sub: AtomicU64,
    push_wakeups: AtomicU64,
    redonations: AtomicU64,
    broadcast_wakeups: AtomicU64,
    avail: Mutex<()>,
    avail_cv: Condvar,
}

impl EventHub {
    pub(super) fn new() -> EventHub {
        EventHub {
            stripes: (0..SHARDS).map(|_| Mutex::new(SubStripe::default())).collect(),
            patterns: Mutex::new(Vec::new()),
            pattern_ceiling: AtomicUsize::new(0),
            next_sub: AtomicU64::new(0),
            push_wakeups: AtomicU64::new(0),
            redonations: AtomicU64::new(0),
            broadcast_wakeups: AtomicU64::new(0),
            avail: Mutex::new(()),
            avail_cv: Condvar::new(),
        }
    }

    fn stripe(&self, idx: usize) -> MutexGuard<'_, SubStripe> {
        self.stripes[idx].lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl Store {
    // ---- pub/sub ----

    /// Subscribe to events published on exactly this key (per-stripe
    /// registry; no cross-key contention). Dropped receivers are
    /// pruned on the next publish.
    pub fn subscribe_key(&self, key: &Key) -> Receiver<Event> {
        let (tx, rx) = channel();
        self.inner
            .hub
            .stripe(key.stripe)
            .exact
            .entry(key.text.clone())
            .or_default()
            .push(tx);
        rx
    }

    /// String-keyed convenience wrapper over [`Store::subscribe_key`]
    /// (the seed's channel API; a channel is just a key).
    pub fn subscribe(&self, channel: &str) -> Receiver<Event> {
        self.subscribe_key(&Key::new(channel))
    }

    fn subscribe_matcher(&self, kind: PatternKind) -> (SubId, Receiver<Event>) {
        let (tx, rx) = channel();
        let id = self.inner.hub.next_sub.fetch_add(1, Ordering::Relaxed) + 1;
        self.inner
            .hub
            .patterns
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(PatternSub { id, kind, tx });
        self.inner.hub.pattern_ceiling.fetch_add(1, Ordering::Release);
        (SubId(id), rx)
    }

    /// Pattern subscription on a key prefix — e.g.
    /// [`super::keys::QUEUE_PREFIX`] to observe every queue push in the
    /// system. Consulted on each publish regardless of stripe.
    pub fn subscribe_prefix(&self, prefix: &str) -> Receiver<Event> {
        self.subscribe_prefix_tagged(prefix).1
    }

    /// [`Store::subscribe_prefix`] returning the [`SubId`] for a later
    /// [`Store::unsubscribe`].
    pub fn subscribe_prefix_tagged(&self, prefix: &str) -> (SubId, Receiver<Event>) {
        self.subscribe_matcher(PatternKind::Prefix(prefix.to_string()))
    }

    /// Redis-style glob subscription over the whole key space: `*`
    /// matches any sequence, `?` exactly one byte (see [`glob_match`]).
    /// E.g. `pd:queue:pilot:*` for every agent queue, or `pd:?u:*` for
    /// CU and DU records.
    pub fn subscribe_pattern(&self, pattern: &str) -> (SubId, Receiver<Event>) {
        self.subscribe_matcher(PatternKind::Glob(pattern.to_string()))
    }

    /// Tear down a pattern subscription: the receiver gets no events
    /// published after this returns. Returns whether the id was live.
    pub fn unsubscribe(&self, id: SubId) -> bool {
        let mut pats = self.inner.hub.patterns.lock().unwrap_or_else(|e| e.into_inner());
        let before = pats.len();
        pats.retain(|s| s.id != id.0);
        before != pats.len()
    }

    /// Deliver to exact-key subscribers of `key` with the stripe
    /// registry already locked (mpsc sends never block, so sending
    /// under the guard is safe — and keeps `notify_push` to a single
    /// stripe-lock acquisition per push).
    fn deliver_exact(s: &mut SubStripe, key: &str, payload: &str) -> usize {
        let mut delivered = 0;
        let mut emptied = false;
        if let Some(list) = s.exact.get_mut(key) {
            list.retain(|tx| {
                tx.send(Event { key: key.to_string(), payload: payload.to_string() }).is_ok()
            });
            delivered = list.len();
            emptied = list.is_empty();
        }
        if emptied {
            s.exact.remove(key);
        }
        delivered
    }

    /// Deliver to pattern (prefix/glob) subscribers matching `key`.
    fn fanout_patterns(&self, key: &str, payload: &str) -> usize {
        // Lock-free fast path: no pattern subscriber was ever
        // registered (service mode) — don't touch the shared mutex.
        if self.inner.hub.pattern_ceiling.load(Ordering::Acquire) == 0 {
            return 0;
        }
        let mut delivered = 0;
        let mut pats = self.inner.hub.patterns.lock().unwrap_or_else(|e| e.into_inner());
        pats.retain(|sub| {
            if sub.kind.matches(key) {
                let ok = sub
                    .tx
                    .send(Event { key: key.to_string(), payload: payload.to_string() })
                    .is_ok();
                if ok {
                    delivered += 1;
                }
                ok
            } else {
                true
            }
        });
        delivered
    }

    /// Deliver an event to exact-key and matching pattern subscribers;
    /// returns how many subscribers received it.
    fn fanout(&self, stripe: usize, key: &str, payload: &str) -> usize {
        let exact = {
            let mut s = self.inner.hub.stripe(stripe);
            Self::deliver_exact(&mut s, key, payload)
        };
        exact + self.fanout_patterns(key, payload)
    }

    /// Publish `payload` on an interned key.
    pub fn publish_k(&self, key: &Key, payload: &str) -> Result<usize, StoreError> {
        self.begin()?;
        Ok(self.fanout(key.stripe, &key.text, payload))
    }

    /// String-keyed publish (the seed's channel API).
    pub fn publish(&self, channel: &str, message: &str) -> Result<usize, StoreError> {
        self.begin()?;
        Ok(self.fanout(stripe_of(channel), channel, message))
    }

    /// Internal: a value landed on `key` — wake blocking-pop waiters
    /// and fan the keyspace event out to subscribers. Called by
    /// `rpush` with the data lock already released.
    ///
    /// Queue-namespace keys get the **wake-one handoff** (module
    /// docs): the push claims the first parked waiter whose cell holds
    /// no pending signal — at most one wakeup per push, O(1) under a
    /// herd of N parked multi-slot workers. Other keys keep the
    /// broadcast semantics: every waiter is drained and woken, one
    /// wins the element, the rest re-check and re-park. Idle cost with
    /// *no* events remains zero in both shapes.
    pub(super) fn notify_push(&self, stripe: usize, key: &str, payload: &str) {
        if key.starts_with(keys::QUEUE_PREFIX) {
            // One stripe-lock acquisition covers the claim scan and the
            // exact-subscriber delivery. `try_claim` notifies under the
            // cell's own mutex nested inside the stripe guard — safe:
            // no path acquires a stripe lock while holding a cell lock.
            let claimed = {
                let mut s = self.inner.hub.stripe(stripe);
                let claimed = Self::claim_first_unclaimed(&s, key);
                Self::deliver_exact(&mut s, key, payload);
                claimed
            };
            if claimed {
                self.inner.hub.push_wakeups.fetch_add(1, Ordering::Relaxed);
            }
        } else {
            // Broadcast fallback: drain and wake every waiter; cells
            // are notified after the guard drops.
            let cells = {
                let mut s = self.inner.hub.stripe(stripe);
                let cells = s.waiters.remove(key);
                Self::deliver_exact(&mut s, key, payload);
                cells
            };
            if let Some(cells) = cells {
                self.inner
                    .hub
                    .broadcast_wakeups
                    .fetch_add(cells.len() as u64, Ordering::Relaxed);
                for c in cells {
                    c.notify();
                }
            }
        }
        self.fanout_patterns(key, payload);
    }

    /// Wakeup accounting snapshot (tests, herd benches).
    pub fn wake_stats(&self) -> WakeStats {
        WakeStats {
            push_wakeups: self.inner.hub.push_wakeups.load(Ordering::Relaxed),
            redonations: self.inner.hub.redonations.load(Ordering::Relaxed),
            broadcast_wakeups: self.inner.hub.broadcast_wakeups.load(Ordering::Relaxed),
        }
    }

    // ---- blocking pops ----

    fn register_waiter(&self, key: &Key, cell: &Arc<WaitCell>) {
        self.inner
            .hub
            .stripe(key.stripe)
            .waiters
            .entry(key.text.clone())
            .or_default()
            .push(cell.clone());
    }

    fn deregister_waiter(&self, queues: &[&Key], cell: &Arc<WaitCell>) {
        for k in queues {
            let mut s = self.inner.hub.stripe(k.stripe);
            let mut emptied = false;
            if let Some(v) = s.waiters.get_mut(&*k.text) {
                v.retain(|c| !Arc::ptr_eq(c, cell));
                emptied = v.is_empty();
            }
            if emptied {
                s.waiters.remove(&*k.text);
            }
        }
    }

    /// The single home of the claim policy: scan `key`'s waiter list
    /// in registration order and claim the first cell with no pending
    /// signal. Both handoff sites (push-side `notify_push` and the
    /// exit-side re-donation) go through here, so the loss-freedom
    /// argument — re-donation replays exactly what a push would have
    /// done — holds by construction. Caller holds the stripe guard.
    fn claim_first_unclaimed(s: &SubStripe, key: &str) -> bool {
        if let Some(cells) = s.waiters.get(key) {
            for c in cells {
                if c.try_claim() {
                    return true;
                }
            }
        }
        false
    }

    /// Claim one parked, unclaimed waiter on `key`. Returns whether a
    /// claim was handed out.
    fn handoff_one(&self, stripe: usize, key: &str) -> bool {
        let s = self.inner.hub.stripe(stripe);
        Self::claim_first_unclaimed(&s, key)
    }

    /// Exit protocol of the wake-one handoff: this popper may have
    /// absorbed a signal for work it did not consume (its cell was
    /// claimed by a push on queue B while it popped queue A, or it
    /// timed out after a claim landed). For every covered queue that
    /// still holds work, pass one wakeup on. No-op during an outage
    /// (`llen` errors are skipped; `set_down` broadcasts anyway).
    fn redonate_absorbed(&self, queues: &[&Key]) {
        for k in queues {
            if !k.text.starts_with(keys::QUEUE_PREFIX) {
                continue; // non-queue pushes broadcast; nothing absorbed
            }
            if matches!(self.llen_k(k), Ok(n) if n > 0) && self.handoff_one(k.stripe, &k.text) {
                self.inner.hub.redonations.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// BLPOP over several queues in priority order (first non-empty
    /// wins — §4.2's agent-specific-then-global protocol in one call),
    /// blocking until an element arrives or the absolute `deadline`
    /// passes. Returns `(queue_index, value)`; `None` only on
    /// deadline. Surfaces [`StoreError::Unavailable`] immediately when
    /// the store goes down, like a dropped Redis connection.
    pub fn blpop_any_until(
        &self,
        queues: &[&Key],
        deadline: Option<Instant>,
    ) -> Result<Option<(usize, String)>, StoreError> {
        // Fast path: no registration when data is already there.
        for (i, k) in queues.iter().enumerate() {
            if let Some(v) = self.lpop_k(k)? {
                return Ok(Some((i, v)));
            }
        }
        let result = self.blpop_parked(queues, deadline);
        // Wake-one exit protocol: pass on any absorbed signal before
        // surfacing our own result (see module docs).
        self.redonate_absorbed(queues);
        result
    }

    /// Slow path: park until an element, the deadline, or an outage.
    fn blpop_parked(
        &self,
        queues: &[&Key],
        deadline: Option<Instant>,
    ) -> Result<Option<(usize, String)>, StoreError> {
        loop {
            let cell = Arc::new(WaitCell::new());
            for k in queues {
                self.register_waiter(k, &cell);
            }
            // Re-check after registering: a push that landed between
            // the last miss and the registration found no waiter to
            // claim — this second look closes the lost-wakeup window.
            let recheck: Result<Option<(usize, String)>, StoreError> = (|| {
                for (i, k) in queues.iter().enumerate() {
                    if let Some(v) = self.lpop_k(k)? {
                        return Ok(Some((i, v)));
                    }
                }
                Ok(None)
            })();
            match recheck {
                Ok(Some(hit)) => {
                    self.deregister_waiter(queues, &cell);
                    return Ok(Some(hit));
                }
                Ok(None) => {}
                Err(e) => {
                    self.deregister_waiter(queues, &cell);
                    return Err(e);
                }
            }
            let signaled = cell.wait_until(deadline);
            self.deregister_waiter(queues, &cell);
            if !signaled {
                // Deadline passed: one final non-blocking look keeps
                // the "value or timeout" contract precise.
                for (i, k) in queues.iter().enumerate() {
                    if let Some(v) = self.lpop_k(k)? {
                        return Ok(Some((i, v)));
                    }
                }
                return Ok(None);
            }
            // Claimed: race for the element; a loser re-parks (the
            // next round re-registers and re-checks every queue, so
            // nothing the loser could have seen is missed).
            for (i, k) in queues.iter().enumerate() {
                if let Some(v) = self.lpop_k(k)? {
                    return Ok(Some((i, v)));
                }
            }
        }
    }

    /// [`Store::blpop_any_until`] with a relative timeout (`None` =
    /// block indefinitely).
    pub fn blpop_any(
        &self,
        queues: &[&Key],
        timeout: Option<Duration>,
    ) -> Result<Option<(usize, String)>, StoreError> {
        self.blpop_any_until(queues, timeout.map(|t| Instant::now() + t))
    }

    /// Single-queue blocking pop (`None` timeout = block indefinitely).
    pub fn blpop_k(
        &self,
        key: &Key,
        timeout: Option<Duration>,
    ) -> Result<Option<String>, StoreError> {
        Ok(self.blpop_any(&[key], timeout)?.map(|(_, v)| v))
    }

    /// Single-queue blocking pop against an absolute deadline.
    pub fn blpop_until(
        &self,
        key: &Key,
        deadline: Option<Instant>,
    ) -> Result<Option<String>, StoreError> {
        Ok(self.blpop_any_until(&[key], deadline)?.map(|(_, v)| v))
    }

    // ---- availability ----

    /// Block until the store is reachable again or `give_up` returns
    /// true. Event-driven: woken by [`Store::set_down`]`(false)`,
    /// [`Store::restore`], or [`Store::wake_waiters`] — never a sleep
    /// loop. Agents pass their shutdown flag as `give_up`.
    pub fn wait_available(&self, give_up: impl Fn() -> bool) {
        let mut g = self.inner.hub.avail.lock().unwrap_or_else(|e| e.into_inner());
        while self.is_down() && !give_up() {
            g = self.inner.hub.avail_cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Wake every blocked waiter — blocking pops and availability
    /// waits — without touching any data. Always a broadcast (never
    /// the wake-one handoff): woken parties re-check their own
    /// predicates — poppers re-poll their queues (and surface
    /// `Unavailable` during an outage), availability waiters re-check
    /// the down flag and their give-up condition. Called by
    /// `set_down`, `restore`, and agent shutdown paths.
    pub fn wake_waiters(&self) {
        for idx in 0..SHARDS {
            let cells: Vec<Arc<WaitCell>> = {
                let mut s = self.inner.hub.stripe(idx);
                s.waiters.drain().flat_map(|(_, v)| v).collect()
            };
            for c in cells {
                c.notify();
            }
        }
        let _g = self.inner.hub.avail.lock().unwrap_or_else(|e| e.into_inner());
        self.inner.hub.avail_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::super::keys;
    use super::*;

    #[test]
    fn blpop_returns_existing_element_without_blocking() {
        let s = Store::new();
        let q = Key::new("pd:queue:ev1");
        s.rpush_k(&q, "a").unwrap();
        assert_eq!(s.blpop_k(&q, None).unwrap(), Some("a".to_string()));
    }

    #[test]
    fn blpop_deadline_times_out_empty() {
        let s = Store::new();
        let q = Key::new("pd:queue:ev2");
        let t0 = Instant::now();
        assert_eq!(s.blpop_k(&q, Some(Duration::from_millis(30))).unwrap(), None);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn blpop_any_respects_priority_order() {
        let s = Store::new();
        let own = Key::new(&keys::pilot_queue("pZ"));
        let global = keys::global_queue_key();
        s.rpush_k(global, "g").unwrap();
        s.rpush_k(&own, "o").unwrap();
        let first = s.blpop_any(&[&own, global], None).unwrap();
        assert_eq!(first, Some((0, "o".to_string())));
        let second = s.blpop_any(&[&own, global], None).unwrap();
        assert_eq!(second, Some((1, "g".to_string())));
    }

    #[test]
    fn push_wakes_blocked_popper() {
        let s = Store::new();
        let q = Key::new("pd:queue:ev3");
        let h = std::thread::spawn({
            let s = s.clone();
            let q = q.clone();
            move || s.blpop_k(&q, Some(Duration::from_secs(20))).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        s.rpush_k(&q, "late").unwrap();
        assert_eq!(h.join().unwrap(), Some("late".to_string()));
    }

    #[test]
    fn queue_push_wakes_at_most_one_parked_waiter() {
        let s = Store::new();
        let q = Key::new("pd:queue:ev-herd");
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = s.clone();
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                s.blpop_k(&q, Some(Duration::from_secs(20))).unwrap()
            }));
        }
        std::thread::sleep(Duration::from_millis(100)); // park the herd
        let before = s.wake_stats();
        s.rpush_k(&q, "one").unwrap();
        // Exactly one element: exactly one waiter can return with it.
        // The claim is handed out synchronously inside the push.
        let after = s.wake_stats();
        assert!(
            after.push_wakeups - before.push_wakeups <= 1,
            "wake-one handoff woke {} waiters for one push",
            after.push_wakeups - before.push_wakeups
        );
        // Release the rest and confirm exactly-once delivery overall.
        for i in 0..3 {
            s.rpush_k(&q, &format!("more-{i}")).unwrap();
        }
        let got: Vec<Option<String>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(got.iter().all(|v| v.is_some()));
        assert_eq!(s.llen_k(&q).unwrap(), 0);
        let end = s.wake_stats();
        assert!(end.push_wakeups - before.push_wakeups <= 4, "more wakeups than pushes");
    }

    #[test]
    fn non_queue_push_broadcasts_to_all_waiters() {
        let s = Store::new();
        let q = Key::new("bench:ev-broadcast");
        let mut handles = Vec::new();
        for _ in 0..3 {
            let s = s.clone();
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                s.blpop_k(&q, Some(Duration::from_secs(20))).unwrap()
            }));
        }
        std::thread::sleep(Duration::from_millis(100));
        let before = s.wake_stats();
        s.rpush_k(&q, "x").unwrap();
        let after = s.wake_stats();
        assert!(
            after.broadcast_wakeups - before.broadcast_wakeups >= 2,
            "non-queue keys must keep the broadcast wake ({} woken)",
            after.broadcast_wakeups - before.broadcast_wakeups
        );
        // One winner; release the two losers that re-parked.
        s.rpush_k(&q, "y").unwrap();
        s.rpush_k(&q, "z").unwrap();
        for h in handles {
            assert!(h.join().unwrap().is_some());
        }
    }

    #[test]
    fn outage_unblocks_popper_with_unavailable() {
        let s = Store::new();
        let q = Key::new("pd:queue:ev4");
        let h = std::thread::spawn({
            let s = s.clone();
            let q = q.clone();
            move || s.blpop_k(&q, Some(Duration::from_secs(20)))
        });
        std::thread::sleep(Duration::from_millis(50));
        s.set_down(true);
        assert_eq!(h.join().unwrap(), Err(StoreError::Unavailable));
        // Recovery wakes availability waiters.
        let h2 = std::thread::spawn({
            let s = s.clone();
            move || {
                s.wait_available(|| false);
                s.is_down()
            }
        });
        std::thread::sleep(Duration::from_millis(50));
        s.set_down(false);
        assert!(!h2.join().unwrap());
    }

    #[test]
    fn requeue_does_not_wake_or_publish() {
        let s = Store::new();
        let q = Key::new("pd:queue:ev5");
        let rx = s.subscribe_prefix("pd:queue:ev5");
        s.rpush_k(&q, "x").unwrap();
        assert_eq!(rx.try_iter().count(), 1, "rpush publishes a queue event");
        let v = s.lpop_k(&q).unwrap().unwrap();
        s.requeue_k(&q, &v).unwrap();
        assert_eq!(rx.try_iter().count(), 0, "requeue is silent");
        // The value is still there for a later (non-blocking) pop.
        assert_eq!(s.lpop_k(&q).unwrap(), Some("x".to_string()));
    }

    #[test]
    fn prefix_subscription_sees_queue_namespace() {
        let s = Store::new();
        let rx = s.subscribe_prefix(keys::QUEUE_PREFIX);
        s.rpush(&keys::pilot_queue("p1"), "cu-1").unwrap();
        s.rpush(keys::GLOBAL_QUEUE, "cu-2").unwrap();
        s.set("unrelated", "v").unwrap();
        let evs: Vec<Event> = rx.try_iter().collect();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].key, keys::pilot_queue("p1"));
        assert_eq!(evs[0].payload, "cu-1");
        assert_eq!(evs[1].key, keys::GLOBAL_QUEUE);
    }

    #[test]
    fn exact_key_subscription_is_per_key() {
        let s = Store::new();
        let k1 = Key::new("pd:queue:a");
        let rx = s.subscribe_key(&k1);
        s.rpush_k(&k1, "one").unwrap();
        s.rpush("pd:queue:b", "other").unwrap();
        let evs: Vec<Event> = rx.try_iter().collect();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].payload, "one");
    }

    #[test]
    fn glob_match_cases() {
        assert!(glob_match("*", ""));
        assert!(glob_match("*", "anything"));
        assert!(glob_match("pd:queue:*", "pd:queue:global"));
        assert!(glob_match("pd:queue:pilot:*", "pd:queue:pilot:pilot-000001"));
        assert!(!glob_match("pd:queue:pilot:*", "pd:queue:global"));
        assert!(glob_match("pd:?u:42", "pd:cu:42"));
        assert!(glob_match("pd:?u:42", "pd:du:42"));
        assert!(!glob_match("pd:?u:42", "pd:cpu:42"));
        assert!(glob_match("*:global", "pd:queue:global"));
        assert!(glob_match("pd:*:pilot:*", "pd:queue:pilot:p1"));
        assert!(!glob_match("pd:*:pilot", "pd:queue:pilot:p1"));
        assert!(glob_match("exact", "exact"));
        assert!(!glob_match("exact", "exact!"));
        assert!(!glob_match("exact!", "exact"));
        assert!(glob_match("a*b*c", "a-xx-b-yy-c"));
        assert!(!glob_match("a*b*c", "a-xx-c-yy-b"));
        assert!(glob_match("??", "ab"));
        assert!(!glob_match("??", "a"));
        assert!(!glob_match("", "a"));
        assert!(glob_match("", ""));
    }

    #[test]
    fn glob_subscription_filters_keys() {
        let s = Store::new();
        let (_id, rx) = s.subscribe_pattern("pd:queue:pilot:*");
        s.rpush(&keys::pilot_queue("pA"), "cu-1").unwrap();
        s.rpush(keys::GLOBAL_QUEUE, "cu-2").unwrap();
        s.publish("pd:queue:pilot:pB", "cu-3").unwrap();
        let evs: Vec<Event> = rx.try_iter().collect();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].key, keys::pilot_queue("pA"));
        assert_eq!(evs[1].payload, "cu-3");
    }

    #[test]
    fn unsubscribed_receiver_gets_no_further_events() {
        let s = Store::new();
        let (id, rx) = s.subscribe_pattern("pd:queue:*");
        let (id2, rx2) = s.subscribe_prefix_tagged(keys::QUEUE_PREFIX);
        s.rpush(keys::GLOBAL_QUEUE, "before").unwrap();
        assert!(s.unsubscribe(id));
        assert!(!s.unsubscribe(id), "second unsubscribe of the same id is a no-op");
        s.rpush(keys::GLOBAL_QUEUE, "after").unwrap();
        let evs: Vec<Event> = rx.try_iter().collect();
        assert_eq!(evs.len(), 1, "only the pre-unsubscribe event: {evs:?}");
        assert_eq!(evs[0].payload, "before");
        // The other subscription is untouched.
        assert_eq!(rx2.try_iter().count(), 2);
        assert!(s.unsubscribe(id2));
    }
}
