//! Deterministic pseudo-random numbers and distributions.
//!
//! Every stochastic element of the DCI simulation (batch-queue waits,
//! transfer failures, read sampling) draws from a seeded [`Rng`], so any
//! experiment is exactly reproducible from its seed. Implementation:
//! SplitMix64 for seeding, xoshiro256++ for the stream — both public
//! domain algorithms (Blackman & Vigna).

/// xoshiro256++ generator seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (e.g. one per simulated site) so
    /// adding draws to one site does not perturb another.
    pub fn fork(&mut self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(self.next_u64() ^ h)
    }

    /// Derive an independent stream from the *current* state without
    /// advancing this generator — unlike [`Rng::fork`], which consumes
    /// a draw from the parent. The open-loop workload engine keys one
    /// stream per tenant off the base seed this way, so adding or
    /// removing a tenant can never perturb the draw sequences of the
    /// others.
    pub fn stream(&self, label: &str) -> Rng {
        self.clone().fork(label)
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n) (n > 0). Uses rejection to avoid
    /// modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with the given mean (inverse-CDF).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // avoid ln(0)
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        mean + std * (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal parameterized by the mean/std of the *underlying*
    /// normal — heavy-tailed, the standard batch-queue-wait model.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(2);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.exp(10.0)).sum::<f64>() / n as f64;
        assert!((m - 10.0).abs() < 0.5, "mean={m}");
    }

    #[test]
    fn normal_moments_close() {
        let mut r = Rng::new(4);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal(5.0, 2.0)).collect();
        let mean = crate::util::mean(&xs);
        let sd = crate::util::stddev(&xs);
        assert!((mean - 5.0).abs() < 0.1, "mean={mean}");
        assert!((sd - 2.0).abs() < 0.1, "sd={sd}");
    }

    #[test]
    fn shuffle_preserves_elements() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(6);
        let mut a = r.fork("lonestar");
        let mut b = r.fork("stampede");
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn stream_is_label_stable_and_leaves_parent_untouched() {
        let base = Rng::new(9);
        let mut a1 = base.stream("tenant-a");
        let mut b = base.stream("tenant-b");
        // Deriving other streams in between must not change a's.
        let mut a2 = base.stream("tenant-a");
        for _ in 0..64 {
            assert_eq!(a1.next_u64(), a2.next_u64());
        }
        assert_ne!(base.stream("tenant-a").next_u64(), b.next_u64());
        // The parent state is untouched: its next draw equals a fresh
        // generator's with the same seed.
        let mut p = base.clone();
        assert_eq!(p.next_u64(), Rng::new(9).next_u64());
    }
}
