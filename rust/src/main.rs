//! pilot-data CLI — the leader entrypoint.
//!
//! Subcommands:
//!   exp <id|all> [--seed N] [--results DIR]   regenerate a paper table/figure
//!   align [--artifacts DIR] [--reads N]       run the local alignment demo
//!   capabilities                              print the adaptor registry
//!
//! Examples:
//!   pilot-data exp fig9 --seed 42
//!   pilot-data exp all
//!   pilot-data align --reads 256

use pilot_data::experiments;
use pilot_data::util::cli::Args;
use std::path::PathBuf;

fn usage() -> ! {
    eprintln!(
        "usage: pilot-data <command>\n\
         \n\
         commands:\n\
           exp <id|all> [--seed N] [--results DIR]   regenerate table1 / fig7..fig13 / modes /\n\
                                                      backends / openloop / resilience / scale /\n\
                                                      sweep\n\
                                                      (sweep: parallel mode x sites x quota grid\n\
                                                      + annealing tuner, with an opt-in backend\n\
                                                      axis; workers\n\
                                                      from PD_SWEEP_THREADS or available cores;\n\
                                                      backends: storage classes x delay\n\
                                                      scheduling on the 2-site workload)\n\
           align [--artifacts DIR] [--reads N] [--pilots N]  local-mode alignment demo\n\
           capabilities                               print storage adaptor registry\n"
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["verbose"])?;
    match args.positional.first().map(String::as_str) {
        Some("exp") => cmd_exp(&args),
        Some("align") => cmd_align(&args),
        Some("capabilities") => {
            for t in experiments::table1::run()? {
                println!("{}", t.render());
            }
            Ok(())
        }
        _ => usage(),
    }
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    let Some(id) = args.positional.get(1).map(String::as_str) else {
        eprintln!("exp: missing experiment id");
        usage()
    };
    let seed: u64 = args.opt_parse_or("seed", 42)?;
    let results = PathBuf::from(args.opt_or("results", "results"));
    let ids: Vec<&str> = if id == "all" { experiments::ALL.to_vec() } else { vec![id] };
    for id in ids {
        eprintln!("== running {id} (seed {seed}) ==");
        let t0 = std::time::Instant::now();
        let tables = experiments::run(id, seed)?;
        experiments::report(id, &tables, &results)?;
        eprintln!("   ({id} took {:.2}s wall)", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

/// Local-mode end-to-end alignment: real pilots (threads), real files,
/// real PJRT compute. A compact version of examples/genome_pipeline.rs.
fn cmd_align(args: &Args) -> anyhow::Result<()> {
    use pilot_data::rng::Rng;
    use pilot_data::runtime::{payload, AlignExecutor, RuntimeServer};
    use pilot_data::service::PilotSystem;
    use pilot_data::workload;
    use std::sync::Arc;

    let artifacts = args.opt_or("artifacts", "artifacts");
    let n_reads: usize = args.opt_parse_or("reads", 256)?;
    let n_pilots: u32 = args.opt_parse_or("pilots", 2)?;

    let server = RuntimeServer::spawn(&artifacts)?;
    let info = server.handle().info("model.hlo.txt")?;
    let workdir = std::env::temp_dir().join(format!("pd-align-{}", std::process::id()));
    let sys = PilotSystem::new(&workdir, Arc::new(AlignExecutor::new(&server, "model.hlo.txt")));

    // Synthetic genome + reads; windows tile the genome with overlap
    // Lw - L so every read is fully contained in some window, and
    // reads start on the seed kernel's 4-base shift lattice.
    let mut rng = Rng::new(args.opt_parse_or("seed", 7)?);
    let stride = info.lw - info.l;
    let genome_len = (info.w - 1) * stride + info.lw;
    let genome = workload::synth_genome(&mut rng, genome_len);
    let windows = workload::extract_windows(&genome, info.lw, stride);
    let windows = &windows[..info.w];
    let (reads, positions) =
        workload::sample_reads_lattice(&mut rng, &genome, n_reads, info.l, 0.02, 4);

    let pds = sys.data_service();
    let cds = sys.compute_data_service();
    let pcs = sys.compute_service();
    let pd = pds.create_pilot_data(pilot_data::pd_desc(&workdir, "pd0", "local/site-a"))?;
    for i in 0..n_pilots {
        pcs.create_pilot(pilot_data::pilot_desc(&format!("local/p{i}")))?;
    }

    let windows_payload =
        payload::encode(info.w as u32, info.lw as u32, &workload::encode_f32(windows));
    let t0 = std::time::Instant::now();
    let chunk = (n_reads / n_pilots.max(1) as usize).max(1);
    let mut outs = Vec::new();
    for (i, reads_chunk) in reads.chunks(chunk).enumerate() {
        let reads_payload = payload::encode(
            reads_chunk.len() as u32,
            info.l as u32,
            &workload::encode_f32(reads_chunk),
        );
        let input = cds.put_data_unit(
            &format!("chunk{i}"),
            &[("reads.pd1", &reads_payload), ("windows.pd1", &windows_payload)],
            &pd,
        )?;
        let output = cds.submit_data_unit(
            pilot_data::unit::DataUnitDescription {
                name: format!("out{i}"),
                files: vec![],
                affinity: None,
            },
            &pd,
        )?;
        outs.push(output.clone());
        cds.submit_compute_unit(pilot_data::unit::ComputeUnitDescription {
            executable: "pjrt:align".into(),
            cores: 1,
            input_data: vec![input],
            output_data: vec![output],
            ..Default::default()
        })?;
    }
    sys.wait_all(std::time::Duration::from_secs(600))?;
    let wall = t0.elapsed().as_secs_f64();

    // Gather and score.
    let mut best_windows = Vec::new();
    for out in &outs {
        let csv = String::from_utf8(cds.fetch(out, "scores.csv")?)?;
        for line in csv.lines().skip(1) {
            let cols: Vec<&str> = line.split(',').collect();
            best_windows.push(cols[1].parse::<f32>()?);
        }
    }
    let hit = workload::window_hit_rate(&positions, &best_windows, info.lw, stride, info.l);
    println!(
        "aligned {n_reads} reads across {n_pilots} pilots in {wall:.2}s \
         ({:.0} reads/s), window hit rate {:.1}%",
        n_reads as f64 / wall,
        hit * 100.0
    );
    sys.shutdown();
    let _ = std::fs::remove_dir_all(workdir);
    Ok(())
}
