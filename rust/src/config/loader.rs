//! JSON-loadable testbed definitions — the config system a downstream
//! user edits to model *their* infrastructure instead of the paper's.
//!
//! Schema (all bandwidths in MiB/s, times in seconds):
//!
//! ```json
//! {
//!   "default_uplink_mib": 100,
//!   "uplinks":  { "xsede/tacc/lonestar": 200, ... },
//!   "machines": [
//!     { "name": "lonestar", "label": "xsede/tacc/lonestar",
//!       "cores": 22656, "queue_base": 60, "queue_mean": 420,
//!       "queue_sigma": 0.9, "fs_mib": 2000, "speed": 1.0,
//!       "max_pilot_cores": 0 }
//!   ],
//!   "endpoints": [
//!     { "name": "lonestar-scratch",
//!       "url": "ssh://lonestar-scratch/scratch/pd",
//!       "label": "xsede/tacc/lonestar" }
//!   ],
//!   "groups": { "osgGridFtpGroup": ["irods-a", "irods-b"] },
//!   "gateway": "xsede/iu/gw68"
//! }
//! ```
//!
//! `max_pilot_cores: 0` means unlimited.

use super::Testbed;
use crate::batch::{BatchState, Machine, QueueModel};
use crate::json::Json;
use crate::net::{Bandwidth, Network};
use crate::storage::{simstore::SimStore, Endpoint};
use crate::topology::{Label, Topology};

/// Build a [`Testbed`] from a JSON document.
pub fn testbed_from_json(j: &Json) -> anyhow::Result<Testbed> {
    let mut net = Network::new();
    net.set_default_uplink(Bandwidth::mbps(j.f64_field_or("default_uplink_mib", 100.0)));
    if let Some(Json::Obj(uplinks)) = j.get("uplinks") {
        for (label, bw) in uplinks {
            let mib = bw
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("uplink '{label}' must be a number"))?;
            net.set_uplink(label, Bandwidth::mbps(mib));
        }
    }

    let mut machines = Vec::new();
    for m in j.get("machines").and_then(Json::as_arr).unwrap_or(&[]) {
        let name = m.str_field("name")?;
        let label = m.str_field("label")?;
        let cores = m.u64_field_or("cores", 64) as u32;
        let queue = QueueModel::with_mean(
            m.f64_field_or("queue_base", 30.0),
            m.f64_field_or("queue_mean", 600.0),
            m.f64_field_or("queue_sigma", 1.0),
        );
        let mut machine = Machine::new(name, label, cores)
            .with_queue(queue)
            .with_fs_bandwidth(Bandwidth::mbps(m.f64_field_or("fs_mib", 2000.0)))
            .with_speed_factor(m.f64_field_or("speed", 1.0));
        let max_pilot = m.u64_field_or("max_pilot_cores", 0) as u32;
        if max_pilot > 0 {
            machine = machine.with_max_pilot_cores(max_pilot);
        }
        machines.push(machine);
    }
    anyhow::ensure!(!machines.is_empty(), "testbed needs at least one machine");
    let batch = BatchState::new(machines);

    let mut store = SimStore::new();
    for e in j.get("endpoints").and_then(Json::as_arr).unwrap_or(&[]) {
        let name = e.str_field("name")?;
        let endpoint = Endpoint::new(e.str_field("url")?, e.str_field("label")?)?;
        store.add_pd(name, endpoint);
    }
    if let Some(Json::Obj(groups)) = j.get("groups") {
        for (group, members) in groups {
            let members: Vec<String> = members
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect();
            let refs: Vec<&str> = members.iter().map(String::as_str).collect();
            store.define_group(group, &refs)?;
        }
    }

    let gateway = Label::new(j.get("gateway").and_then(Json::as_str).unwrap_or(""));
    Ok(Testbed { topo: Topology::new(), net, batch, store, gateway })
}

/// Load a testbed from a JSON file.
pub fn testbed_from_file(path: &std::path::Path) -> anyhow::Result<Testbed> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    testbed_from_json(&crate::json::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Bytes;

    fn sample() -> &'static str {
        r#"{
            "default_uplink_mib": 50,
            "uplinks": { "siteA/m1": 200, "siteB": 25 },
            "machines": [
                { "name": "m1", "label": "siteA/m1", "cores": 128,
                  "queue_mean": 100, "fs_mib": 1000, "speed": 1.2 },
                { "name": "m2", "label": "siteB/m2", "cores": 16,
                  "max_pilot_cores": 4 }
            ],
            "endpoints": [
                { "name": "pd-a", "url": "ssh://pd-a/data", "label": "siteA/m1" },
                { "name": "pd-b", "url": "srm://pd-b/pool", "label": "siteB/m2" }
            ],
            "groups": { "all": ["pd-a", "pd-b"] },
            "gateway": "siteA/m1"
        }"#
    }

    #[test]
    fn loads_complete_testbed() {
        let tb = testbed_from_json(&crate::json::parse(sample()).unwrap()).unwrap();
        let m1 = tb.batch.machine("m1").unwrap();
        assert_eq!(m1.cores, 128);
        assert!((m1.speed_factor - 1.2).abs() < 1e-9);
        assert!((m1.queue.mean() - 100.0).abs() < 1.0);
        let m2 = tb.batch.machine("m2").unwrap();
        assert_eq!(m2.max_pilot_cores, 4);
        assert!(tb.store.pd("pd-a").is_ok());
        assert_eq!(tb.store.group_members("all").unwrap().len(), 2);
        assert_eq!(tb.gateway, Label::new("siteA/m1"));
        // Uplink override took effect: siteB is the 25 MiB/s bottleneck.
        let bw = tb.net.effective_bandwidth(&Label::new("siteA/m1"), &Label::new("siteB/m2"));
        assert!((bw.0 - Bandwidth::mbps(25.0).0).abs() < 1.0);
    }

    #[test]
    fn loaded_testbed_runs_a_workload() {
        use crate::experiments::simdrive::SimSystem;
        use crate::workload::bwa_ensemble;
        let tb = testbed_from_json(&crate::json::parse(sample()).unwrap()).unwrap();
        let mut sys = SimSystem::new(tb, 5);
        let ens = bwa_ensemble(2, Bytes::mb(512), Bytes::gb(1));
        let ref_du = sys.upload_du(&ens.reference, "pd-a").unwrap();
        sys.run().unwrap();
        sys.submit_pilot("m1", 8, "pd-a").unwrap();
        for c in &ens.read_chunks {
            let chunk = sys.upload_du(c, "pd-a").unwrap();
            let mut cud = ens.cu_template.clone();
            cud.input_data = vec![ref_du.clone(), chunk];
            sys.submit_cu(cud).unwrap();
        }
        sys.run().unwrap();
        assert!(sys.state.workload_finished());
    }

    #[test]
    fn rejects_invalid_documents() {
        assert!(testbed_from_json(&crate::json::parse("{}").unwrap()).is_err()); // no machines
        let bad = r#"{ "machines": [ { "label": "x/y" } ] }"#; // missing name
        assert!(testbed_from_json(&crate::json::parse(bad).unwrap()).is_err());
        let bad_ep = r#"{ "machines": [ {"name":"m","label":"x/m"} ],
                          "endpoints": [ {"name":"p","url":"bogus://x","label":"x/m"} ] }"#;
        assert!(testbed_from_json(&crate::json::parse(bad_ep).unwrap()).is_err());
    }

    #[test]
    fn missing_file_is_a_clean_error() {
        assert!(testbed_from_file(std::path::Path::new("/nonexistent/tb.json")).is_err());
    }
}
