//! Calibrated testbed configuration — the simulated stand-in for the
//! paper's production DCI (XSEDE + OSG + AWS).
//!
//! Machine and network parameters are set from the quantities the paper
//! itself reports: Lonestar→Stampede moves 9 GB in ≈450 s (Fig. 11/12
//! discussion) → ≈20 MiB/s effective inter-machine rate at TACC under
//! load; Stampede's queue wait averaged 8100 s in Fig. 11 scenario 3 vs
//! ≈400 s in scenario 2 (experiments override per scenario); OSG pilot
//! queue waits exceed XSEDE's (Fig. 9); the OSG iRODS server sits at
//! Fermilab; S3 ingest is WAN-limited (Fig. 7). Everything else is
//! order-of-magnitude realistic for 2013-era infrastructure.

pub mod loader;

use crate::batch::{BatchState, Machine, QueueModel};
use crate::net::{Bandwidth, Network};
use crate::storage::{simstore::SimStore, BackendKind, Endpoint, ProtocolParams};
use crate::topology::{Label, Topology};

/// The nine OSG sites used in the experiments ("we restrict OSG
/// resources to a set of 9 machines, which are supported by the OSG
/// iRODS installation … distributed across the eastern and central US
/// including resources at TACC, Purdue and Cornell").
pub const OSG_SITES: [&str; 9] = [
    "purdue", "cornell", "tacc-osg", "fnal", "unl", "uchicago", "ucsd-t2", "iu-grid", "uwm",
];

/// Per-site OSG uplink bandwidths (MiB/s) — deliberately heterogeneous:
/// "different sites have very different performance characteristics"
/// (Fig. 8 inset).
pub const OSG_UPLINK_MIB: [f64; 9] = [110.0, 60.0, 95.0, 150.0, 45.0, 80.0, 55.0, 70.0, 40.0];

/// A fully assembled simulated testbed.
pub struct Testbed {
    pub topo: Topology,
    pub net: Network,
    pub batch: BatchState,
    pub store: SimStore,
    /// The submission/gateway machine (GW68 at Indiana University).
    pub gateway: Label,
}

/// Labels of the paper's XSEDE machines.
pub fn lonestar() -> Label {
    Label::new("xsede/tacc/lonestar")
}
pub fn stampede() -> Label {
    Label::new("xsede/tacc/stampede")
}
pub fn trestles() -> Label {
    Label::new("xsede/sdsc/trestles")
}
pub fn gw68() -> Label {
    Label::new("xsede/iu/gw68")
}
pub fn osg_site(site: &str) -> Label {
    Label::new(&format!("osg/{site}"))
}

/// Build the calibrated paper testbed.
pub fn paper_testbed() -> Testbed {
    let topo = Topology::new();

    // ---- network ----
    let mut net = Network::new();
    net.set_default_uplink(Bandwidth::mbps(100.0));
    // Backbone trunks.
    net.set_uplink("xsede", Bandwidth::mbps(1200.0));
    net.set_uplink("osg", Bandwidth::mbps(600.0));
    net.set_uplink("ec2", Bandwidth::mbps(12.0)); // WAN to AWS: the Fig. 7 S3 ceiling
    net.set_uplink("ec2/us-east", Bandwidth::mbps(12.0));
    // TACC campus + machines. A single unloaded Lonestar->Stampede SSH
    // flow moves 9 GB in ~100 s (matching the ~130 s replica creation
    // of Fig. 11 sc. 3); under ~10 concurrent staging flows the fair
    // share drops to ~20 MiB/s -> the ~450 s/task of Fig. 11 sc. 2.
    net.set_uplink("xsede/tacc", Bandwidth::mbps(800.0));
    net.set_uplink("xsede/tacc/lonestar", Bandwidth::mbps(200.0));
    net.set_uplink("xsede/tacc/stampede", Bandwidth::mbps(200.0));
    net.set_uplink("xsede/sdsc", Bandwidth::mbps(400.0));
    net.set_uplink("xsede/sdsc/trestles", Bandwidth::mbps(100.0));
    net.set_uplink("xsede/iu", Bandwidth::mbps(400.0));
    net.set_uplink("xsede/iu/gw68", Bandwidth::mbps(120.0));
    // OSG sites with heterogeneous uplinks; Fermilab hosts the central
    // iRODS server.
    for (site, mib) in OSG_SITES.iter().zip(OSG_UPLINK_MIB) {
        net.set_uplink(&format!("osg/{site}"), Bandwidth::mbps(mib));
    }

    // ---- machines / batch queues ----
    // XSEDE queue waits: minutes-scale mean; heavy tail. OSG pilots
    // (via GlideinWMS): longer and more variable.
    let machines = vec![
        Machine::new("lonestar", "xsede/tacc/lonestar", 22_656)
            .with_queue(QueueModel::with_mean(60.0, 420.0, 0.9))
            .with_fs_bandwidth(Bandwidth::mbps(2_000.0)) // Lustre effective scan aggregate under production load
            .with_speed_factor(1.0),
        Machine::new("stampede", "xsede/tacc/stampede", 102_400)
            .with_queue(QueueModel::with_mean(60.0, 400.0, 0.9))
            .with_fs_bandwidth(Bandwidth::mbps(3_000.0))
            .with_speed_factor(0.8), // newer Sandy Bridge nodes
        Machine::new("trestles", "xsede/sdsc/trestles", 10_368)
            .with_queue(QueueModel::with_mean(120.0, 2500.0, 1.4)) // "high fluctuation"
            .with_fs_bandwidth(Bandwidth::mbps(1_200.0))
            .with_speed_factor(1.25),
        Machine::new("gw68", "xsede/iu/gw68", 8)
            .with_queue(QueueModel::with_mean(0.0, 1.0, 0.1))
            .with_fs_bandwidth(Bandwidth::mbps(400.0)),
    ];
    let mut machines = machines;
    for site in OSG_SITES {
        machines.push(
            Machine::new(&format!("osg-{site}"), &format!("osg/{site}"), 64)
                .with_queue(QueueModel::with_mean(120.0, 900.0, 1.2))
                .with_fs_bandwidth(Bandwidth::mbps(900.0))
                .with_max_pilot_cores(8) // HTC: pilots marshal ≤ one node
                .with_speed_factor(1.4),
        );
    }
    let batch = BatchState::new(machines);

    // ---- storage endpoints ----
    let mut store = SimStore::new();
    store.add_pd(
        "gw68-staging",
        Endpoint::new("ssh://gw68-staging/home/staging", "xsede/iu/gw68").unwrap(),
    );
    store.add_pd(
        "lonestar-scratch",
        Endpoint::new("ssh://lonestar-scratch/scratch/pd", "xsede/tacc/lonestar").unwrap(),
    );
    store.add_pd(
        "lonestar-go",
        Endpoint::new("go://lonestar-go/scratch/pd", "xsede/tacc/lonestar").unwrap(),
    );
    store.add_pd(
        "stampede-scratch",
        Endpoint::new("ssh://stampede-scratch/scratch/pd", "xsede/tacc/stampede").unwrap(),
    );
    store.add_pd(
        "trestles-scratch",
        Endpoint::new("ssh://trestles-scratch/scratch/pd", "xsede/sdsc/trestles").unwrap(),
    );
    store.add_pd("s3-east", Endpoint::new("s3://s3-east/pd-bucket", "ec2/us-east").unwrap());
    // OSG: SRM pool + per-site iRODS resources federated by the
    // Fermilab server.
    store.add_pd("osg-srm", Endpoint::new("srm://osg-srm/pool/pd", "osg/fnal").unwrap());
    for site in OSG_SITES {
        store.add_pd(
            &format!("irods-{site}"),
            Endpoint::new(&format!("irods://irods-{site}/osg/{site}"), &format!("osg/{site}"))
                .unwrap(),
        );
        store.add_pd(
            &format!("srm-{site}"),
            Endpoint::new(&format!("srm://srm-{site}/pool/{site}"), &format!("osg/{site}"))
                .unwrap(),
        );
    }
    let irods_members: Vec<String> = OSG_SITES.iter().map(|s| format!("irods-{s}")).collect();
    let member_refs: Vec<&str> = irods_members.iter().map(String::as_str).collect();
    store.define_group("osgGridFtpGroup", &member_refs).unwrap();

    Testbed { topo, net, batch, store, gateway: gw68() }
}

/// Reference BWA task cost model (per 256 MiB read chunk against the
/// 8 GiB reference index, 2 cores): ~37 min pure compute on the
/// reference machine. Chosen so the Fig. 11 per-task runtime (1 GiB
/// chunk -> ~2.5 h) makes Stampede's 8100 s queue wait land mid-run,
/// as the paper's scenario 3 requires.
pub fn bwa_cpu_secs_per_chunk() -> f64 {
    2200.0
}

/// Protocol parameter lookup shorthand.
pub fn proto(kind: BackendKind) -> ProtocolParams {
    ProtocolParams::defaults(kind)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Bytes;

    #[test]
    fn testbed_has_all_machines_and_endpoints() {
        let tb = paper_testbed();
        for m in ["lonestar", "stampede", "trestles", "gw68"] {
            assert!(tb.batch.machine(m).is_ok(), "missing {m}");
        }
        for site in OSG_SITES {
            assert!(tb.batch.machine(&format!("osg-{site}")).is_ok());
            assert!(tb.store.pd(&format!("irods-{site}")).is_ok());
        }
        assert_eq!(tb.store.group_members("osgGridFtpGroup").unwrap().len(), 9);
    }

    #[test]
    fn tacc_cross_machine_rate_matches_paper_calibration() {
        // One SSH flow moves 9 GB Lonestar -> Stampede in ~450 s
        // (paper Fig. 11/12: "moving this data ... required on
        // average 450 sec per task") — the scp per-flow cap binds.
        let tb = paper_testbed();
        let ssh = proto(BackendKind::Ssh);
        let t = crate::storage::simstore::transfer_cost(
            &tb.net,
            &lonestar(),
            &stampede(),
            None,
            &ssh,
            Bytes::gb(9),
            1,
        )
        .wire_s;
        assert!((350.0..600.0).contains(&t), "t={t}");
    }

    #[test]
    fn s3_is_wan_limited() {
        let tb = paper_testbed();
        let bw = tb.net.effective_bandwidth(&gw68(), &Label::new("ec2/us-east"));
        assert!(bw.0 <= Bandwidth::mbps(30.0).0 + 1.0);
    }

    #[test]
    fn osg_queues_longer_than_xsede() {
        let tb = paper_testbed();
        let ls = tb.batch.machine("lonestar").unwrap().queue.mean();
        let osg = tb.batch.machine("osg-purdue").unwrap().queue.mean();
        assert!(osg > 1.5 * ls, "osg={osg} xsede={ls}");
    }

    #[test]
    fn osg_pilots_capped_to_single_node() {
        let tb = paper_testbed();
        assert_eq!(tb.batch.machine("osg-purdue").unwrap().max_pilot_cores, 8);
        assert_eq!(tb.batch.machine("lonestar").unwrap().max_pilot_cores, u32::MAX);
    }

    #[test]
    fn site_uplinks_are_heterogeneous() {
        let tb = paper_testbed();
        let rates: Vec<f64> = OSG_SITES
            .iter()
            .map(|s| tb.net.effective_bandwidth(&osg_site("fnal"), &osg_site(s)).0)
            .collect();
        let min = rates.iter().cloned().fold(f64::MAX, f64::min);
        let max = rates.iter().cloned().fold(0.0, f64::max);
        assert!(max / min > 2.0, "expected >2x spread, rates={rates:?}");
    }
}
