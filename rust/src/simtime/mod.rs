//! Discrete-event simulation engine.
//!
//! The paper's experiments run on production DCI where the dominant time
//! scales are batch-queue waits (minutes–hours) and WAN transfers
//! (minutes). We reproduce those experiments inside a deterministic
//! discrete-event simulation: [`Sim`] owns a priority queue of timed
//! events; the world advances by popping the earliest event and handing
//! it to the caller's handler, which may schedule further events.
//!
//! Ties are broken FIFO (by insertion sequence) so runs are fully
//! deterministic. A separate **front lane** ([`Sim::schedule_front`])
//! fires before every normally scheduled event at the same instant —
//! used by the sim driver's per-slot agent chains, where one pilot's
//! next slot must pull before any other same-time event interleaves
//! (the DES equivalent of a worker handing off to the next worker of
//! the same pool).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in seconds since experiment start.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct SimTime(pub f64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0.0);
    pub fn secs(self) -> f64 {
        self.0
    }
    pub fn after(self, delay: f64) -> SimTime {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        SimTime(self.0 + delay)
    }
}

impl std::fmt::Display for SimTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "t={}", crate::util::fmt_secs(self.0))
    }
}

struct Scheduled<E> {
    time: f64,
    /// 0 = front lane (fires before lane-1 events at the same time),
    /// 1 = normal. Within a lane, ties stay FIFO by `seq`.
    lane: u8,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.lane == other.lane && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, then
        // front lane first, then FIFO on the sequence number.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.lane.cmp(&self.lane))
            .then(other.seq.cmp(&self.seq))
    }
}

/// The event engine. `E` is the caller's event type.
pub struct Sim<E> {
    now: f64,
    seq: u64,
    queue: BinaryHeap<Scheduled<E>>,
    processed: u64,
}

impl<E> Default for Sim<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Sim<E> {
    pub fn new() -> Sim<E> {
        Sim { now: 0.0, seq: 0, queue: BinaryHeap::new(), processed: 0 }
    }

    /// Current simulated time (seconds).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn time(&self) -> SimTime {
        SimTime(self.now)
    }

    /// Number of events processed so far (debugging / budget guards).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule `event` to fire `delay` seconds from now.
    pub fn schedule(&mut self, delay: f64, event: E) {
        assert!(delay >= 0.0 && delay.is_finite(), "bad delay {delay}");
        self.seq += 1;
        self.queue.push(Scheduled { time: self.now + delay, lane: 1, seq: self.seq, event });
    }

    /// Schedule at an absolute time (must not be in the past).
    pub fn schedule_at(&mut self, time: f64, event: E) {
        assert!(time >= self.now, "schedule_at past time {time} < now {}", self.now);
        self.seq += 1;
        self.queue.push(Scheduled { time, lane: 1, seq: self.seq, event });
    }

    /// Schedule `event` at the current instant, ahead of every event
    /// already queued for this instant. Continuation lane for handlers
    /// that must run again before any other same-time event interleaves
    /// (e.g. the per-slot agent pull chain); front-lane events among
    /// themselves stay FIFO.
    pub fn schedule_front(&mut self, event: E) {
        self.seq += 1;
        self.queue.push(Scheduled { time: self.now, lane: 0, seq: self.seq, event });
    }

    /// Pop the next event, advancing the clock. Returns `None` when the
    /// queue is empty.
    pub fn next_event(&mut self) -> Option<(SimTime, E)> {
        let s = self.queue.pop()?;
        debug_assert!(s.time >= self.now, "time went backwards");
        self.now = s.time;
        self.processed += 1;
        Some((SimTime(s.time), s.event))
    }

    /// Drive the simulation until the queue drains or `handler` returns
    /// `false` (stop requested). The handler receives `(self, time,
    /// event)` and may schedule more events.
    pub fn run(&mut self, mut handler: impl FnMut(&mut Sim<E>, SimTime, E) -> bool) {
        while let Some(s) = self.queue.pop() {
            self.now = s.time;
            self.processed += 1;
            if !handler(self, SimTime(s.time), s.event) {
                break;
            }
        }
    }

    /// Like [`Sim::run`] but with a hard event budget — guards against
    /// accidental infinite self-rescheduling in tests.
    pub fn run_bounded(
        &mut self,
        max_events: u64,
        mut handler: impl FnMut(&mut Sim<E>, SimTime, E) -> bool,
    ) -> anyhow::Result<()> {
        let start = self.processed;
        while let Some(s) = self.queue.pop() {
            self.now = s.time;
            self.processed += 1;
            if self.processed - start > max_events {
                anyhow::bail!("event budget {max_events} exceeded at t={}", self.now);
            }
            if !handler(self, SimTime(s.time), s.event) {
                break;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut sim: Sim<u32> = Sim::new();
        sim.schedule(5.0, 2);
        sim.schedule(1.0, 1);
        sim.schedule(9.0, 3);
        let mut seen = Vec::new();
        sim.run(|_, t, e| {
            seen.push((t.secs(), e));
            true
        });
        assert_eq!(seen, vec![(1.0, 1), (5.0, 2), (9.0, 3)]);
    }

    #[test]
    fn ties_are_fifo() {
        let mut sim: Sim<u32> = Sim::new();
        for i in 0..10 {
            sim.schedule(1.0, i);
        }
        let mut seen = Vec::new();
        sim.run(|_, _, e| {
            seen.push(e);
            true
        });
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn front_lane_preempts_same_time_events() {
        let mut sim: Sim<&'static str> = Sim::new();
        sim.schedule(1.0, "a");
        sim.schedule(1.0, "b");
        sim.schedule(2.0, "later");
        let mut seen = Vec::new();
        sim.run(|sim, _, e| {
            seen.push(e);
            if e == "a" {
                // Chain: both front events must run before "b", in
                // FIFO order among themselves — and never before an
                // earlier-time event would have.
                sim.schedule_front("front-1");
                sim.schedule_front("front-2");
            }
            true
        });
        assert_eq!(seen, vec!["a", "front-1", "front-2", "b", "later"]);
    }

    #[test]
    fn front_lane_does_not_rewind_time() {
        let mut sim: Sim<u32> = Sim::new();
        sim.schedule(5.0, 1);
        let mut times = Vec::new();
        sim.run(|sim, t, e| {
            times.push((t.secs(), e));
            if e == 1 {
                sim.schedule_front(2);
            }
            true
        });
        assert_eq!(times, vec![(5.0, 1), (5.0, 2)]);
    }

    #[test]
    fn handler_can_schedule_more() {
        let mut sim: Sim<&'static str> = Sim::new();
        sim.schedule(1.0, "start");
        let mut log = Vec::new();
        sim.run(|sim, t, e| {
            log.push((t.secs(), e));
            if e == "start" {
                sim.schedule(2.0, "follow-up");
            }
            true
        });
        assert_eq!(log, vec![(1.0, "start"), (3.0, "follow-up")]);
    }

    #[test]
    fn stop_early() {
        let mut sim: Sim<u32> = Sim::new();
        for i in 0..5 {
            sim.schedule(i as f64, i);
        }
        let mut n = 0;
        sim.run(|_, _, _| {
            n += 1;
            n < 3
        });
        assert_eq!(n, 3);
        assert_eq!(sim.pending(), 2);
    }

    #[test]
    fn budget_guard_trips() {
        let mut sim: Sim<u8> = Sim::new();
        sim.schedule(0.0, 0);
        let res = sim.run_bounded(100, |sim, _, _| {
            sim.schedule(1.0, 0); // infinite self-reschedule
            true
        });
        assert!(res.is_err());
    }

    #[test]
    fn clock_monotonic_property() {
        crate::prop::check_default(
            |rng| {
                (0..crate::prop::gen::usize_in(rng, 1, 50))
                    .map(|_| rng.range_f64(0.0, 100.0))
                    .collect::<Vec<f64>>()
            },
            |delays| {
                let mut sim: Sim<()> = Sim::new();
                for d in delays {
                    sim.schedule(*d, ());
                }
                let mut last = -1.0;
                let mut ok = true;
                sim.run(|_, t, _| {
                    ok &= t.secs() >= last;
                    last = t.secs();
                    true
                });
                if ok {
                    Ok(())
                } else {
                    Err("time went backwards".into())
                }
            },
        );
    }
}
