//! Discrete-event simulation engine: a calendar-queue **event wheel**.
//!
//! The paper's experiments run on production DCI where the dominant time
//! scales are batch-queue waits (minutes–hours) and WAN transfers
//! (minutes). We reproduce those experiments inside a deterministic
//! discrete-event simulation: [`Sim`] owns a timed event queue; the
//! world advances by popping the earliest event and handing it to the
//! caller's handler, which may schedule further events.
//!
//! # Ordering contract
//!
//! Events fire in `(time, lane, seq)` order. Ties on time are broken
//! FIFO (by insertion sequence) so runs are fully deterministic. A
//! separate **front lane** ([`Sim::schedule_front`]) fires before every
//! normally scheduled event at the same instant — used by the sim
//! driver's per-slot agent chains, where one pilot's next slot must
//! pull before any other same-time event interleaves (the DES
//! equivalent of a worker handing off to the next worker of the same
//! pool). Times are compared with [`f64::total_cmp`] (a *total* order —
//! a NaN can never silently corrupt heap order), non-finite times are
//! rejected at the scheduling boundary, and every accepted time is
//! normalized through `+ 0.0` so `-0.0` and `+0.0` are one instant.
//!
//! # The wheel
//!
//! The default backend is a calendar queue tuned for the driver's
//! event mix, where the vast majority of events are either *at the
//! current instant* (pull chains, wakeups, completions cascading at one
//! timestamp) or *in the near future* (transfer/compute completions):
//!
//! - **Now lanes** — two FIFO deques hold events whose timestamp is
//!   bit-equal to the current clock: one for the front lane, one for
//!   normal lane-1 events. While either is non-empty the clock cannot
//!   advance (their head is the global minimum), so push and pop are
//!   plain O(1) deque operations — no comparisons at all on the
//!   same-instant fast path that dominates large fleets.
//! - **Near-future buckets** — `BUCKETS` (256) slots spanning
//!   `[origin, origin + BUCKETS × width)`; an event at time `t` lands
//!   in bucket `⌊(t − origin) / width⌋`. Each bucket is a small
//!   min-ordered heap on `(time, seq)`, so the first non-empty bucket
//!   (tracked by a monotone cursor that is rewound if a push lands
//!   behind it) always holds the earliest timed event.
//! - **Overflow tier** — events beyond the bucket window go to a
//!   single min-heap. When the buckets drain, the wheel **lazily
//!   rebuckets**: `origin` snaps to the overflow minimum, `width`
//!   stretches to `(max − min) / (BUCKETS − 1)` (floored at a minimum
//!   width) so the whole overflow population fits the new window, and
//!   the tier drains into the buckets in one pass. Rebucketing is
//!   amortized O(1) per event — each event moves overflow→bucket at
//!   most once per rebucket epoch.
//! - **Slab event cells** — payloads live in a slab (`Vec<Option<E>>` +
//!   free list) and the ordering structures move only 24-byte
//!   `(time, seq, slot)` cells, so large payload enums are written once
//!   on schedule and read once on fire, never shuffled through heap
//!   sift operations.
//!
//! Worst case (adversarial time distributions collapsing into one
//! bucket) degrades to the classic binary-heap O(log n) — never worse
//! than the seed implementation.
//!
//! The original single `BinaryHeap` backend is retained as a reference
//! implementation ([`QueueBackend::Heap`], via [`Sim::with_backend`]):
//! the randomized property suites (here and in `crate::prop`) drive
//! identical schedules through both backends and require **bit-identical
//! pop sequences**, including lane and seq tie-breaks.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// Simulated time in seconds since experiment start.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct SimTime(pub f64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0.0);
    pub fn secs(self) -> f64 {
        self.0
    }
    pub fn after(self, delay: f64) -> SimTime {
        // A real assert (not debug_assert): a negative or NaN delay in a
        // release build would silently schedule into the past.
        assert!(delay >= 0.0, "negative delay {delay}");
        SimTime(self.0 + delay)
    }
}

impl std::fmt::Display for SimTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "t={}", crate::util::fmt_secs(self.0))
    }
}

/// Which queue implementation backs a [`Sim`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueBackend {
    /// Calendar-queue event wheel (default).
    Wheel,
    /// The original `BinaryHeap` — kept as the property-test reference.
    Heap,
}

/// Cheap structural counters kept by the wheel backend — pure
/// increments on paths the wheel already takes, so they can never
/// perturb event ordering (the heap-vs-wheel bit-identity property
/// suites keep holding). Read through [`Sim::queue_stats`]; the
/// [`QueueBackend::Heap`] reference reports all-zeros.
///
/// These attribute events/sec differences across workload tiers
/// (`experiments::scale`, `BENCH_scale.json`): a falling
/// [`now_hit_rate`](QueueStats::now_hit_rate) means fewer O(1)
/// same-instant pushes, and growing `rebuckets`/`rebucketed_cells`
/// mean more overflow traffic through the amortized rebucket path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueStats {
    /// Lazy rebucket passes (overflow tier drained into the window).
    pub rebuckets: u64,
    /// Cells moved overflow→bucket across all rebucket passes.
    pub rebucketed_cells: u64,
    /// Pushes that landed behind the cursor and rewound it.
    pub cursor_rewinds: u64,
    /// Pushes routed to a now-lane deque (O(1), no comparisons).
    pub now_hits: u64,
    /// Pushes routed through the bucket/overflow tier.
    pub timed_pushes: u64,
    /// Pushes that went straight to the overflow tier.
    pub overflow_pushes: u64,
    /// Peak live payload cells in the slab arena (queue high-water mark).
    pub slab_peak: u32,
}

impl QueueStats {
    /// Fraction of pushes that took the O(1) now-lane fast path.
    pub fn now_hit_rate(&self) -> f64 {
        let total = self.now_hits + self.timed_pushes;
        if total == 0 {
            0.0
        } else {
            self.now_hits as f64 / total as f64
        }
    }
}

struct Scheduled<E> {
    time: f64,
    /// 0 = front lane (fires before lane-1 events at the same time),
    /// 1 = normal. Within a lane, ties stay FIFO by `seq`.
    lane: u8,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time.to_bits() == other.time.to_bits()
            && self.lane == other.lane
            && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, then
        // front lane first, then FIFO on the sequence number. total_cmp
        // keeps the order total even if a NaN ever slipped past the
        // scheduling asserts.
        other
            .time
            .total_cmp(&self.time)
            .then(other.lane.cmp(&self.lane))
            .then(other.seq.cmp(&self.seq))
    }
}

/// Number of near-future buckets in the wheel window.
const BUCKETS: usize = 256;
/// Floor for the bucket width — guards divide-by-zero when the whole
/// overflow population shares one timestamp.
const MIN_WIDTH: f64 = 1e-9;

/// A slab-backed event handle: ordering state only, payload lives in
/// the slab at `slot`.
#[derive(Clone, Copy)]
struct Cell {
    time: f64,
    seq: u64,
    slot: u32,
}

impl PartialEq for Cell {
    fn eq(&self, other: &Self) -> bool {
        self.time.to_bits() == other.time.to_bits() && self.seq == other.seq
    }
}
impl Eq for Cell {}
impl PartialOrd for Cell {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Cell {
    fn cmp(&self, other: &Self) -> Ordering {
        // Inverted: BinaryHeap<Cell> is a min-queue on (time, seq).
        other.time.total_cmp(&self.time).then(other.seq.cmp(&self.seq))
    }
}

/// Calendar-queue wheel (see the module docs for the layout).
struct Wheel<E> {
    /// Event payload arena; `free` recycles vacant slots.
    slab: Vec<Option<E>>,
    free: Vec<u32>,
    /// Lane-0 events at the current instant (always the global minimum).
    now_front: VecDeque<Cell>,
    /// Lane-1 events whose time is bit-equal to the current clock.
    now_lane: VecDeque<Cell>,
    buckets: Vec<BinaryHeap<Cell>>,
    /// First possibly non-empty bucket; rewound when a push lands
    /// behind it, advanced lazily on peek.
    cursor: usize,
    origin: f64,
    width: f64,
    overflow: BinaryHeap<Cell>,
    len: usize,
    stats: QueueStats,
}

impl<E> Wheel<E> {
    fn new() -> Wheel<E> {
        Wheel {
            slab: Vec::new(),
            free: Vec::new(),
            now_front: VecDeque::new(),
            now_lane: VecDeque::new(),
            buckets: (0..BUCKETS).map(|_| BinaryHeap::new()).collect(),
            cursor: 0,
            origin: 0.0,
            width: 1.0,
            overflow: BinaryHeap::new(),
            len: 0,
            stats: QueueStats::default(),
        }
    }

    fn alloc(&mut self, event: E) -> u32 {
        let slot = if let Some(slot) = self.free.pop() {
            self.slab[slot as usize] = Some(event);
            slot
        } else {
            self.slab.push(Some(event));
            (self.slab.len() - 1) as u32
        };
        let live = (self.slab.len() - self.free.len()) as u32;
        self.stats.slab_peak = self.stats.slab_peak.max(live);
        slot
    }

    fn take(&mut self, slot: u32) -> E {
        let ev = self.slab[slot as usize].take().expect("slab slot already vacated");
        self.free.push(slot);
        ev
    }

    fn push(&mut self, now: f64, time: f64, lane: u8, seq: u64, event: E) {
        let slot = self.alloc(event);
        let cell = Cell { time, seq, slot };
        self.len += 1;
        if lane == 0 {
            // Front-lane events are only ever created at `now`; while any
            // are pending they are the global minimum, so a FIFO deque
            // reproduces (time, lane, seq) order exactly.
            self.stats.now_hits += 1;
            self.now_front.push_back(cell);
        } else if time.to_bits() == now.to_bits() {
            // Same-instant lane-1 events: the clock cannot advance while
            // this deque is non-empty, so FIFO order == seq order.
            self.stats.now_hits += 1;
            self.now_lane.push_back(cell);
        } else {
            self.stats.timed_pushes += 1;
            self.push_timed(cell, true);
        }
    }

    fn push_timed(&mut self, cell: Cell, fresh: bool) {
        let rel = (cell.time - self.origin) / self.width;
        if rel < BUCKETS as f64 {
            let idx = if rel <= 0.0 { 0 } else { (rel as usize).min(BUCKETS - 1) };
            if idx < self.cursor {
                self.stats.cursor_rewinds += 1;
                self.cursor = idx;
            }
            self.buckets[idx].push(cell);
        } else {
            // Rebucket re-insertions (`fresh == false`) always fit the
            // freshly snapped window, so this only counts caller pushes.
            if fresh {
                self.stats.overflow_pushes += 1;
            }
            self.overflow.push(cell);
        }
    }

    /// Advance the cursor to the first non-empty bucket, lazily
    /// rebucketing the overflow tier when the window is exhausted.
    /// Post-condition: `cursor < BUCKETS` iff any timed event remains.
    fn settle(&mut self) {
        loop {
            while self.cursor < BUCKETS && self.buckets[self.cursor].is_empty() {
                self.cursor += 1;
            }
            if self.cursor < BUCKETS || self.overflow.is_empty() {
                return;
            }
            // Rebucket: snap the window to the overflow population and
            // drain it. width is chosen so every drained cell fits the
            // new window (max lands in the last bucket).
            let cells = std::mem::take(&mut self.overflow).into_vec();
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for c in &cells {
                lo = lo.min(c.time);
                hi = hi.max(c.time);
            }
            self.origin = lo;
            self.width = ((hi - lo) / (BUCKETS as f64 - 1.0)).max(MIN_WIDTH);
            self.cursor = 0;
            self.stats.rebuckets += 1;
            self.stats.rebucketed_cells += cells.len() as u64;
            for c in cells {
                self.push_timed(c, false);
            }
        }
    }

    /// `(time, seq)` of the earliest timed (non-now-lane) event.
    fn peek_timed(&mut self) -> Option<(f64, u64)> {
        self.settle();
        if self.cursor < BUCKETS {
            // Bucket invariant: the first non-empty bucket holds the
            // timed minimum, and every overflow cell lies beyond the
            // bucket window.
            let c = self.buckets[self.cursor].peek().expect("settle left an empty cursor bucket");
            Some((c.time, c.seq))
        } else {
            debug_assert!(self.overflow.is_empty());
            None
        }
    }

    fn pop(&mut self) -> Option<(f64, u8, u64, E)> {
        if let Some(c) = self.now_front.pop_front() {
            self.len -= 1;
            let ev = self.take(c.slot);
            return Some((c.time, 0, c.seq, ev));
        }
        let nn = self.now_lane.front().map(|c| (c.time, c.seq));
        let timed = self.peek_timed();
        let pick_now_lane = match (nn, timed) {
            (None, None) => return None,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (Some((t1, s1)), Some((t2, s2))) => match t1.total_cmp(&t2) {
                Ordering::Less => true,
                Ordering::Greater => false,
                Ordering::Equal => s1 < s2,
            },
        };
        let c = if pick_now_lane {
            self.now_lane.pop_front().expect("now-lane head vanished")
        } else {
            self.settle();
            self.buckets[self.cursor].pop().expect("cursor bucket drained under peek")
        };
        self.len -= 1;
        let ev = self.take(c.slot);
        Some((c.time, 1, c.seq, ev))
    }
}

enum Queue<E> {
    Wheel(Wheel<E>),
    Heap(BinaryHeap<Scheduled<E>>),
}

/// The event engine. `E` is the caller's event type.
pub struct Sim<E> {
    now: f64,
    seq: u64,
    queue: Queue<E>,
    processed: u64,
}

impl<E> Default for Sim<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Sim<E> {
    /// A wheel-backed engine (the default).
    pub fn new() -> Sim<E> {
        Sim::with_backend(QueueBackend::Wheel)
    }

    /// Choose the queue backend explicitly — [`QueueBackend::Heap`] is
    /// the retained reference for the bit-identity property suites.
    pub fn with_backend(backend: QueueBackend) -> Sim<E> {
        let queue = match backend {
            QueueBackend::Wheel => Queue::Wheel(Wheel::new()),
            QueueBackend::Heap => Queue::Heap(BinaryHeap::new()),
        };
        Sim { now: 0.0, seq: 0, queue, processed: 0 }
    }

    pub fn backend(&self) -> QueueBackend {
        match self.queue {
            Queue::Wheel(_) => QueueBackend::Wheel,
            Queue::Heap(_) => QueueBackend::Heap,
        }
    }

    /// Current simulated time (seconds).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn time(&self) -> SimTime {
        SimTime(self.now)
    }

    /// Number of events processed so far (debugging / budget guards).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn pending(&self) -> usize {
        match &self.queue {
            Queue::Wheel(w) => w.len,
            Queue::Heap(h) => h.len(),
        }
    }

    /// Structural counters from the wheel backend ([`QueueStats`]).
    /// The heap reference backend keeps no counters and reports the
    /// all-zero default.
    pub fn queue_stats(&self) -> QueueStats {
        match &self.queue {
            Queue::Wheel(w) => w.stats,
            Queue::Heap(_) => QueueStats::default(),
        }
    }

    /// Shared scheduling boundary: normalizes `-0.0`, rejects
    /// non-finite or past times, assigns the FIFO sequence number.
    fn push(&mut self, time: f64, lane: u8, event: E) {
        // total_cmp orders -0.0 < +0.0 while the wheel's now-lane
        // routing uses bit equality; `+ 0.0` maps -0.0 to +0.0 so both
        // backends agree that they are one instant.
        let time = time + 0.0;
        assert!(
            time.is_finite() && time >= self.now,
            "bad event time {time} (now {})",
            self.now
        );
        self.seq += 1;
        match &mut self.queue {
            Queue::Wheel(w) => w.push(self.now, time, lane, self.seq, event),
            Queue::Heap(h) => h.push(Scheduled { time, lane, seq: self.seq, event }),
        }
    }

    /// Schedule `event` to fire `delay` seconds from now.
    pub fn schedule(&mut self, delay: f64, event: E) {
        assert!(delay >= 0.0 && delay.is_finite(), "bad delay {delay}");
        self.push(self.now + delay, 1, event);
    }

    /// Schedule at an absolute time (must be finite and not in the past).
    pub fn schedule_at(&mut self, time: f64, event: E) {
        assert!(
            time.is_finite() && time >= self.now,
            "schedule_at bad time {time} (now {})",
            self.now
        );
        self.push(time, 1, event);
    }

    /// Schedule `event` at the current instant, ahead of every event
    /// already queued for this instant. Continuation lane for handlers
    /// that must run again before any other same-time event interleaves
    /// (e.g. the per-slot agent pull chain); front-lane events among
    /// themselves stay FIFO.
    pub fn schedule_front(&mut self, event: E) {
        self.push(self.now, 0, event);
    }

    /// Pop the next event, advancing the clock. Returns `None` when the
    /// queue is empty.
    pub fn next_event(&mut self) -> Option<(SimTime, E)> {
        let (time, _lane, _seq, event) = match &mut self.queue {
            Queue::Wheel(w) => w.pop()?,
            Queue::Heap(h) => {
                let s = h.pop()?;
                (s.time, s.lane, s.seq, s.event)
            }
        };
        debug_assert!(time >= self.now, "time went backwards");
        self.now = time;
        self.processed += 1;
        Some((SimTime(time), event))
    }

    /// Drive the simulation until the queue drains or `handler` returns
    /// `false` (stop requested). The handler receives `(self, time,
    /// event)` and may schedule more events.
    pub fn run(&mut self, mut handler: impl FnMut(&mut Sim<E>, SimTime, E) -> bool) {
        while let Some((t, e)) = self.next_event() {
            if !handler(self, t, e) {
                break;
            }
        }
    }

    /// Like [`Sim::run`] but with a hard event budget — guards against
    /// accidental infinite self-rescheduling. Processes at most
    /// `max_events` events; errors only if the queue still holds work
    /// when the budget is spent.
    pub fn run_bounded(
        &mut self,
        max_events: u64,
        mut handler: impl FnMut(&mut Sim<E>, SimTime, E) -> bool,
    ) -> anyhow::Result<()> {
        let mut used = 0u64;
        while used < max_events {
            let Some((t, e)) = self.next_event() else {
                return Ok(());
            };
            used += 1;
            if !handler(self, t, e) {
                return Ok(());
            }
        }
        if self.pending() > 0 {
            anyhow::bail!("event budget {max_events} exceeded at t={}", self.now);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BACKENDS: [QueueBackend; 2] = [QueueBackend::Wheel, QueueBackend::Heap];

    #[test]
    fn events_fire_in_time_order() {
        for backend in BACKENDS {
            let mut sim: Sim<u32> = Sim::with_backend(backend);
            sim.schedule(5.0, 2);
            sim.schedule(1.0, 1);
            sim.schedule(9.0, 3);
            let mut seen = Vec::new();
            sim.run(|_, t, e| {
                seen.push((t.secs(), e));
                true
            });
            assert_eq!(seen, vec![(1.0, 1), (5.0, 2), (9.0, 3)], "{backend:?}");
        }
    }

    #[test]
    fn ties_are_fifo() {
        for backend in BACKENDS {
            let mut sim: Sim<u32> = Sim::with_backend(backend);
            for i in 0..10 {
                sim.schedule(1.0, i);
            }
            let mut seen = Vec::new();
            sim.run(|_, _, e| {
                seen.push(e);
                true
            });
            assert_eq!(seen, (0..10).collect::<Vec<_>>(), "{backend:?}");
        }
    }

    #[test]
    fn front_lane_preempts_same_time_events() {
        for backend in BACKENDS {
            let mut sim: Sim<&'static str> = Sim::with_backend(backend);
            sim.schedule(1.0, "a");
            sim.schedule(1.0, "b");
            sim.schedule(2.0, "later");
            let mut seen = Vec::new();
            sim.run(|sim, _, e| {
                seen.push(e);
                if e == "a" {
                    // Chain: both front events must run before "b", in
                    // FIFO order among themselves — and never before an
                    // earlier-time event would have.
                    sim.schedule_front("front-1");
                    sim.schedule_front("front-2");
                }
                true
            });
            assert_eq!(seen, vec!["a", "front-1", "front-2", "b", "later"], "{backend:?}");
        }
    }

    #[test]
    fn front_lane_does_not_rewind_time() {
        let mut sim: Sim<u32> = Sim::new();
        sim.schedule(5.0, 1);
        let mut times = Vec::new();
        sim.run(|sim, t, e| {
            times.push((t.secs(), e));
            if e == 1 {
                sim.schedule_front(2);
            }
            true
        });
        assert_eq!(times, vec![(5.0, 1), (5.0, 2)]);
    }

    #[test]
    fn handler_can_schedule_more() {
        let mut sim: Sim<&'static str> = Sim::new();
        sim.schedule(1.0, "start");
        let mut log = Vec::new();
        sim.run(|sim, t, e| {
            log.push((t.secs(), e));
            if e == "start" {
                sim.schedule(2.0, "follow-up");
            }
            true
        });
        assert_eq!(log, vec![(1.0, "start"), (3.0, "follow-up")]);
    }

    #[test]
    fn stop_early() {
        let mut sim: Sim<u32> = Sim::new();
        for i in 0..5 {
            sim.schedule(i as f64, i);
        }
        let mut n = 0;
        sim.run(|_, _, _| {
            n += 1;
            n < 3
        });
        assert_eq!(n, 3);
        assert_eq!(sim.pending(), 2);
    }

    #[test]
    fn budget_guard_trips() {
        let mut sim: Sim<u8> = Sim::new();
        sim.schedule(0.0, 0);
        let res = sim.run_bounded(100, |sim, _, _| {
            sim.schedule(1.0, 0); // infinite self-reschedule
            true
        });
        assert!(res.is_err());
    }

    #[test]
    fn run_bounded_processes_exactly_the_budget() {
        for backend in BACKENDS {
            // Exactly max_events pending: the full budget is usable.
            let mut sim: Sim<u32> = Sim::with_backend(backend);
            for i in 0..100 {
                sim.schedule(i as f64, i);
            }
            let mut handled = 0u64;
            let res = sim.run_bounded(100, |_, _, _| {
                handled += 1;
                true
            });
            assert!(res.is_ok(), "{backend:?}");
            assert_eq!(handled, 100, "{backend:?}");
            assert_eq!(sim.pending(), 0, "{backend:?}");

            // One more than the budget: stop after max_events, with the
            // extra event still pending (the seed processed 101 here).
            let mut sim: Sim<u32> = Sim::with_backend(backend);
            for i in 0..101 {
                sim.schedule(i as f64, i);
            }
            let mut handled = 0u64;
            let res = sim.run_bounded(100, |_, _, _| {
                handled += 1;
                true
            });
            assert!(res.is_err(), "{backend:?}");
            assert_eq!(handled, 100, "{backend:?}");
            assert_eq!(sim.pending(), 1, "{backend:?}");
            assert_eq!(sim.processed(), 100, "{backend:?}");
        }
    }

    #[test]
    #[should_panic(expected = "schedule_at bad time")]
    fn schedule_at_rejects_infinite_time() {
        let mut sim: Sim<u8> = Sim::new();
        sim.schedule_at(f64::INFINITY, 0);
    }

    #[test]
    #[should_panic(expected = "schedule_at bad time")]
    fn schedule_at_rejects_nan_time() {
        let mut sim: Sim<u8> = Sim::new();
        sim.schedule_at(f64::NAN, 0);
    }

    #[test]
    #[should_panic(expected = "negative delay")]
    fn simtime_after_rejects_negative_delay_in_release_too() {
        // `after` used debug_assert!; it must hold in release builds.
        let _ = SimTime(1.0).after(-0.5);
    }

    #[test]
    fn negative_zero_is_the_current_instant() {
        for backend in BACKENDS {
            let mut sim: Sim<u32> = Sim::with_backend(backend);
            sim.schedule_at(-0.0, 1); // normalized to +0.0
            sim.schedule(0.0, 2);
            sim.schedule_front(3);
            let mut seen = Vec::new();
            sim.run(|_, t, e| {
                seen.push((t.secs().to_bits(), e));
                true
            });
            assert_eq!(
                seen,
                vec![(0.0f64.to_bits(), 3), (0.0f64.to_bits(), 1), (0.0f64.to_bits(), 2)],
                "{backend:?}"
            );
        }
    }

    #[test]
    fn far_future_spread_exercises_overflow_and_rebucketing() {
        // A spread from sub-second to 1e9 s forces overflow pushes and
        // at least one lazy rebucket; order must stay exact.
        for backend in BACKENDS {
            let mut sim: Sim<usize> = Sim::with_backend(backend);
            let mut times: Vec<f64> = Vec::new();
            let mut x = 0.001f64;
            while x < 1.0e9 {
                times.push(x);
                times.push(x); // ties at every scale
                x *= 3.7;
            }
            for (i, t) in times.iter().enumerate() {
                sim.schedule_at(*t, i);
            }
            let mut seen = Vec::new();
            sim.run(|_, t, e| {
                seen.push((t.secs(), e));
                true
            });
            let mut expect: Vec<(f64, usize)> =
                times.iter().copied().zip(0..times.len()).collect();
            expect.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            assert_eq!(seen, expect, "{backend:?}");
            assert_eq!(sim.pending(), 0, "{backend:?}");
        }
    }

    #[test]
    fn queue_stats_count_wheel_activity() {
        // Far-future spread: overflow pushes and at least one rebucket.
        let mut sim: Sim<usize> = Sim::new();
        let mut x = 0.001f64;
        let mut n = 0usize;
        while x < 1.0e9 {
            sim.schedule_at(x, n);
            x *= 3.7;
            n += 1;
        }
        sim.run(|_, _, _| true);
        let s = sim.queue_stats();
        assert!(s.overflow_pushes > 0, "spread must hit the overflow tier: {s:?}");
        assert!(s.rebuckets > 0, "draining must rebucket: {s:?}");
        assert!(s.rebucketed_cells >= s.rebuckets, "{s:?}");
        assert_eq!(s.timed_pushes, n as u64, "{s:?}");
        assert!(s.slab_peak >= 1 && s.slab_peak <= n as u32, "{s:?}");

        // Same-instant chains take the now-lane fast path; a push that
        // lands behind the cursor rewinds it.
        let mut sim: Sim<u32> = Sim::new();
        sim.schedule(100.0, 0);
        sim.run(|sim, _, e| {
            if e == 0 {
                sim.schedule_front(1); // now-lane (front)
                sim.schedule(0.0, 2); // now-lane (bit-equal time)
                sim.schedule(50.0, 3); // bucket ahead of the clock
            }
            if e == 2 {
                // Fires at t=100 after peeking advanced the cursor to
                // the t=150 bucket; this short-delay push lands in the
                // t=110 bucket, behind the cursor — a rewind.
                sim.schedule(10.0, 4);
            }
            true
        });
        let s = sim.queue_stats();
        assert_eq!(s.now_hits, 2, "{s:?}");
        assert!(s.now_hit_rate() > 0.0 && s.now_hit_rate() < 1.0, "{s:?}");
        assert!(s.cursor_rewinds >= 1, "{s:?}");

        // The heap reference keeps no counters.
        let mut sim: Sim<u32> = Sim::with_backend(QueueBackend::Heap);
        sim.schedule(1.0, 1);
        sim.run(|_, _, _| true);
        assert_eq!(sim.queue_stats(), QueueStats::default());
    }

    #[test]
    fn clock_monotonic_property() {
        crate::prop::check_default(
            |rng| {
                (0..crate::prop::gen::usize_in(rng, 1, 50))
                    .map(|_| rng.range_f64(0.0, 100.0))
                    .collect::<Vec<f64>>()
            },
            |delays| {
                let mut sim: Sim<()> = Sim::new();
                for d in delays {
                    sim.schedule(*d, ());
                }
                let mut last = -1.0;
                let mut ok = true;
                sim.run(|_, t, _| {
                    ok &= t.secs() >= last;
                    last = t.secs();
                    true
                });
                if ok {
                    Ok(())
                } else {
                    Err("time went backwards".into())
                }
            },
        );
    }

    /// Randomized program interpreted on both backends: schedules with
    /// tie-heavy delays, absolute times, zero delays, and front-lane
    /// pushes from inside handlers. The full pop sequences (time bits +
    /// event id) must be bit-identical — this is the heap-vs-wheel
    /// oracle the engine swap rests on.
    #[test]
    fn wheel_pop_sequence_is_bit_identical_to_heap_reference() {
        // Tie-heavy grid: duplicates at several magnitudes plus far
        // futures that force the overflow tier.
        const DELAYS: [f64; 8] = [0.0, 0.0, 0.25, 1.0, 1.0, 3.5, 1.0e4, 1.0e7];

        fn interpret(
            backend: QueueBackend,
            initial: &[usize],
            reactions: &[(u8, usize)],
        ) -> Vec<(u64, u32)> {
            let mut sim: Sim<u32> = Sim::with_backend(backend);
            for (i, d) in initial.iter().enumerate() {
                sim.schedule(DELAYS[*d % DELAYS.len()], i as u32);
            }
            let mut next_id = initial.len() as u32;
            let mut ri = 0usize;
            let mut out = Vec::new();
            sim.run(|sim, t, e| {
                out.push((t.secs().to_bits(), e));
                if ri < reactions.len() {
                    let (kind, d) = reactions[ri];
                    ri += 1;
                    let delay = DELAYS[d % DELAYS.len()];
                    match kind % 4 {
                        0 => sim.schedule(delay, next_id),
                        1 => sim.schedule(0.0, next_id),
                        2 => sim.schedule_at(sim.now() + delay, next_id),
                        _ => sim.schedule_front(next_id),
                    }
                    next_id += 1;
                }
                true
            });
            assert_eq!(sim.pending(), 0);
            out
        }

        crate::prop::check(
            crate::prop::Config { cases: 96, seed: 0x11EE1 },
            |rng| {
                let initial: Vec<usize> = (0..crate::prop::gen::usize_in(rng, 1, 60))
                    .map(|_| rng.below(1 << 16) as usize)
                    .collect();
                let reactions: Vec<(u8, usize)> = (0..crate::prop::gen::usize_in(rng, 0, 80))
                    .map(|_| (rng.below(256) as u8, rng.below(1 << 16) as usize))
                    .collect();
                (initial, reactions)
            },
            |(initial, reactions)| {
                let wheel = interpret(QueueBackend::Wheel, initial, reactions);
                let heap = interpret(QueueBackend::Heap, initial, reactions);
                if wheel == heap {
                    Ok(())
                } else {
                    let i = wheel.iter().zip(&heap).position(|(a, b)| a != b);
                    Err(format!(
                        "pop sequences diverge (lens {} vs {}, first mismatch at {i:?})",
                        wheel.len(),
                        heap.len()
                    ))
                }
            },
        );
    }
}
