//! Failure injection.
//!
//! The paper reports that failures are routine at scale: "the frequency
//! of failures was very high … while the osgGridFtpGroup group consisted
//! of 9 nodes, the average number of resources that actually received a
//! replica was ∼7.5" (Fig. 8), and Fig. 11/13 runs saw wall-time limits
//! and transfer errors. This module centralizes the knobs for injecting
//! those faults deterministically.
//!
//! # Fault model
//!
//! The system distinguishes four failure kinds, each recoverable:
//!
//! * **Transfer faults** — every transfer attempt fails independently
//!   with a rate composed from the destination protocol's
//!   `ProtocolParams::failure_rate` and the per-link rates on the
//!   crossed network path (`Network::path_failure_rate`). In the DES a
//!   failed attempt runs for a partial-transfer fraction of its wire
//!   time, releases its network flow, then retries after
//!   [`RetryPolicy::backoff_for`] *in simulated time* — up to
//!   [`RetryPolicy::max_attempts`]; exhaustion surfaces as a failed
//!   staging event. ([`attempt_transfer`] is the older aggregate form
//!   of the same model, collapsing the attempt sequence into one
//!   statistical outcome; it is retained as the property-test oracle
//!   for fault-free bit-identity.)
//! * **Pilot failures** — a pilot dies mid-CU (`Ev::PilotFailed`) or
//!   hits its wall-time (`Ev::PilotExpired`). In-flight CUs take the
//!   `StagingInput→Queued` / `Running→Queued` retry edges and are
//!   re-dispatched by the scheduler; per-CU re-dispatch counters bound
//!   the retries. In the wall-clock service the same liveness is
//!   lease-based: agents refresh `pd:pilot:hb:<id>` heartbeats and the
//!   manager reclaims the queue of any agent whose lease expired.
//! * **Storage outages** — `Ev::PdDown` evicts every replica on the PD
//!   and publishes each loss on `pd:data:lost:<du>`; the active
//!   execution mode repairs lost replicas from survivors. `Ev::PdUp`
//!   re-registers the PD empty, publishes `pd:data:avail:<pd>`, and
//!   lets the mode re-balance onto the recovered storage.
//! * **Coordination outages** — [`ScopedOutage`] / [`OutagePlan`] take
//!   the coordination store itself down; agents park in
//!   `wait_available` and resume when it returns.
//!
//! [`ChaosPlan`] composes the first three into a seeded random
//! failure/recovery timeline that can be injected into any
//! `SimSystem` (`apply_chaos`), which is how the resilience experiment
//! and the chaos property suite drive the whole lifecycle at once.

use crate::coordination::Store;
use crate::rng::Rng;

/// Retry policy for transfers ("Globus Online e.g. automatically
/// restarts failed transfers").
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    pub max_attempts: u32,
    /// Base backoff in seconds, doubled per attempt.
    pub backoff_s: f64,
    /// Ceiling on any single backoff. Uncapped doubling overflows
    /// `powi` to `inf` at high attempt counts and schedules retries
    /// astronomically far into simulated time well before that.
    pub max_backoff_s: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, backoff_s: 5.0, max_backoff_s: 300.0 }
    }
}

impl RetryPolicy {
    pub fn none() -> RetryPolicy {
        RetryPolicy { max_attempts: 1, backoff_s: 0.0, max_backoff_s: 300.0 }
    }

    /// Exponential backoff, capped at [`RetryPolicy::max_backoff_s`].
    /// The exponent is clamped below 1024 so `powi` stays finite (and
    /// `attempt as i32` cannot wrap); the cap keeps the result bounded
    /// long before that.
    pub fn backoff_for(&self, attempt: u32) -> f64 {
        (self.backoff_s * 2f64.powi(attempt.min(1023) as i32)).min(self.max_backoff_s)
    }
}

/// Outcome of a transfer attempt sequence under a failure rate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttemptOutcome {
    pub succeeded: bool,
    pub attempts: u32,
    /// Extra seconds spent on failed attempts + backoff.
    pub wasted_s: f64,
}

/// Roll a sequence of attempts: each fails independently with
/// `failure_rate`; a failed attempt wastes a fraction of the nominal
/// transfer time (we model failures as detected mid-flight, on average
/// halfway) plus backoff.
pub fn attempt_transfer(
    rng: &mut Rng,
    failure_rate: f64,
    nominal_s: f64,
    policy: RetryPolicy,
) -> AttemptOutcome {
    let mut wasted = 0.0;
    for attempt in 0..policy.max_attempts {
        if !rng.chance(failure_rate) {
            return AttemptOutcome { succeeded: true, attempts: attempt + 1, wasted_s: wasted };
        }
        wasted += nominal_s * rng.range_f64(0.1, 0.9) + policy.backoff_for(attempt);
    }
    AttemptOutcome { succeeded: false, attempts: policy.max_attempts, wasted_s: wasted }
}

/// RAII coordination-store outage: the store goes down on
/// construction and comes back up when the guard drops, so a test (or
/// chaos hook) cannot leak a permanently dead store past an early
/// return or panic. While the guard lives, blocked poppers surface
/// [`crate::coordination::StoreError::Unavailable`] and agents park in
/// `wait_available`; the drop wakes them all. The guard is
/// re-entrant: it restores the *prior* down state, so a nested or
/// overlapping guard (or one created while an outage was already
/// injected by hand) does not end an outage it did not start.
pub struct ScopedOutage {
    store: Store,
    was_down: bool,
}

impl ScopedOutage {
    pub fn inject(store: &Store) -> ScopedOutage {
        let was_down = store.is_down();
        store.set_down(true);
        ScopedOutage { store: store.clone(), was_down }
    }
}

impl Drop for ScopedOutage {
    fn drop(&mut self) {
        self.store.set_down(self.was_down);
    }
}

/// Scheduled coordination-store outages (start, duration) in sim time.
#[derive(Debug, Clone, Default)]
pub struct OutagePlan {
    pub windows: Vec<(f64, f64)>,
}

impl OutagePlan {
    pub fn is_down_at(&self, t: f64) -> bool {
        self.windows.iter().any(|(s, d)| t >= *s && t < s + d)
    }
}

/// A seeded random failure/recovery timeline over a simulation run:
/// pilot kills, PD down→up cycles, and per-link fault rates. Inject
/// into a driver with `SimSystem::apply_chaos` before (or between)
/// `run()` calls; every timestamp is absolute sim time.
///
/// The plan is plain data on purpose — tests that must guarantee
/// survivors (one live pilot, one replica of every input) simply pass
/// only the expendable pilots/PDs to [`ChaosPlan::seeded`].
#[derive(Debug, Clone, Default)]
pub struct ChaosPlan {
    /// (pilot id, kill time): hard mid-CU death, not wall-time expiry.
    pub pilot_kills: Vec<(String, f64)>,
    /// (pd name, outage start).
    pub pd_down: Vec<(String, f64)>,
    /// (pd name, recovery time) — paired with an entry in `pd_down`.
    pub pd_up: Vec<(String, f64)>,
    /// (link label, per-attempt failure rate), applied for the whole
    /// run.
    pub link_faults: Vec<(String, f64)>,
}

impl ChaosPlan {
    /// Generate a plan. `intensity` in `[0, 1]` scales both the
    /// probability that each candidate pilot/PD/link is hit and the
    /// injected link fault rates; kill and outage times land inside
    /// `(0.05, 0.75) * horizon_s` so recoveries fit the run.
    pub fn seeded(
        seed: u64,
        intensity: f64,
        pilots: &[String],
        pds: &[String],
        links: &[String],
        horizon_s: f64,
    ) -> ChaosPlan {
        let mut rng = Rng::new(seed ^ 0xC4A0_5BAD);
        let intensity = intensity.clamp(0.0, 1.0);
        let mut plan = ChaosPlan::default();
        for p in pilots {
            if rng.chance(0.7 * intensity) {
                plan.pilot_kills.push((p.clone(), horizon_s * rng.range_f64(0.05, 0.75)));
            }
        }
        for pd in pds {
            if rng.chance(0.6 * intensity) {
                let down = horizon_s * rng.range_f64(0.05, 0.6);
                let up = down + horizon_s * rng.range_f64(0.05, 0.3);
                plan.pd_down.push((pd.clone(), down));
                plan.pd_up.push((pd.clone(), up));
            }
        }
        for link in links {
            if rng.chance(0.8 * intensity) {
                plan.link_faults.push((link.clone(), 0.25 * intensity * rng.range_f64(0.2, 1.0)));
            }
        }
        plan
    }

    /// Total number of injected fault events (diagnostics/reporting).
    pub fn len(&self) -> usize {
        self.pilot_kills.len() + self.pd_down.len() + self.link_faults.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_always_succeeds_first_try() {
        let mut rng = Rng::new(1);
        let o = attempt_transfer(&mut rng, 0.0, 100.0, RetryPolicy::default());
        assert_eq!(o, AttemptOutcome { succeeded: true, attempts: 1, wasted_s: 0.0 });
    }

    #[test]
    fn certain_failure_exhausts_attempts() {
        let mut rng = Rng::new(2);
        let o = attempt_transfer(&mut rng, 1.0, 100.0, RetryPolicy::default());
        assert!(!o.succeeded);
        assert_eq!(o.attempts, 3);
        assert!(o.wasted_s > 0.0);
    }

    #[test]
    fn failure_rate_matches_fig8_partial_replication() {
        // With per-attempt failure 0.17 and no retries, a 9-node group
        // should succeed on ≈7.5 nodes on average.
        let mut rng = Rng::new(3);
        let trials = 20_000;
        let mut successes = 0u32;
        for _ in 0..trials {
            if attempt_transfer(&mut rng, 0.17, 60.0, RetryPolicy::none()).succeeded {
                successes += 1;
            }
        }
        let per_group = 9.0 * successes as f64 / trials as f64;
        assert!((per_group - 7.5).abs() < 0.2, "per_group={per_group}");
    }

    #[test]
    fn backoff_doubles() {
        let p = RetryPolicy { max_attempts: 4, backoff_s: 2.0, max_backoff_s: 300.0 };
        assert_eq!(p.backoff_for(0), 2.0);
        assert_eq!(p.backoff_for(2), 8.0);
    }

    #[test]
    fn backoff_is_capped_at_large_attempt_counts() {
        let p = RetryPolicy::default();
        // Small attempts keep the historical doubling.
        assert_eq!(p.backoff_for(0), 5.0);
        assert_eq!(p.backoff_for(2), 20.0);
        // Past the cap, the ceiling holds — and stays finite even where
        // the uncapped powi would overflow to inf (attempt ≥ 1024) or
        // where `attempt as i32` would have wrapped negative.
        assert_eq!(p.backoff_for(10), p.max_backoff_s);
        assert_eq!(p.backoff_for(2_000), p.max_backoff_s);
        assert_eq!(p.backoff_for(u32::MAX), p.max_backoff_s);
        assert!(p.backoff_for(u32::MAX).is_finite());
        // A zero base never produces a NaN through 0 × inf.
        let z = RetryPolicy { max_attempts: 9, backoff_s: 0.0, max_backoff_s: 300.0 };
        assert_eq!(z.backoff_for(5_000), 0.0);
    }

    #[test]
    fn scoped_outage_restores_on_drop_even_on_unwind() {
        let s = Store::new();
        s.set("k", "v").unwrap();
        {
            let _o = ScopedOutage::inject(&s);
            assert!(s.get("k").is_err(), "ops must fail during the outage");
        }
        assert_eq!(s.get("k").unwrap(), Some("v".to_string()), "drop must restore");
        // Restored through an unwind too.
        let s2 = s.clone();
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _o = ScopedOutage::inject(&s2);
            panic!("boom");
        }));
        assert!(s.get("k").is_ok(), "outage leaked past a panic");
    }

    #[test]
    fn chaos_plan_is_seed_deterministic_and_scales_with_intensity() {
        let pilots: Vec<String> = (0..6).map(|i| format!("pilot-{i}")).collect();
        let pds: Vec<String> = (0..4).map(|i| format!("pd-{i}")).collect();
        let links = vec!["xsede".to_string(), "osg".to_string()];
        let mk = |seed, i| ChaosPlan::seeded(seed, i, &pilots, &pds, &links, 10_000.0);
        // Same seed, same plan — different seed, (almost surely) not.
        let a = mk(7, 0.8);
        let b = mk(7, 0.8);
        assert_eq!(a.pilot_kills, b.pilot_kills);
        assert_eq!(a.pd_down, b.pd_down);
        assert_eq!(a.pd_up, b.pd_up);
        assert_eq!(a.link_faults, b.link_faults);
        // Zero intensity is a no-op plan.
        let z = mk(7, 0.0);
        assert!(z.is_empty());
        // Recoveries follow their outages, inside the horizon.
        for ((pd_d, down), (pd_u, up)) in a.pd_down.iter().zip(&a.pd_up) {
            assert_eq!(pd_d, pd_u);
            assert!(*down < *up && *up < 10_000.0);
        }
        for (_, t) in &a.pilot_kills {
            assert!(*t > 0.0 && *t < 7_500.0);
        }
        // Higher intensity injects at least as much on average: check a
        // small seed ensemble rather than one draw.
        let (mut lo, mut hi) = (0usize, 0usize);
        for s in 0..32 {
            lo += mk(s, 0.2).len();
            hi += mk(s, 1.0).len();
        }
        assert!(hi > lo, "intensity 1.0 injected {hi} <= intensity 0.2's {lo}");
    }

    #[test]
    fn outage_windows() {
        let plan = OutagePlan { windows: vec![(10.0, 5.0), (100.0, 1.0)] };
        assert!(!plan.is_down_at(9.9));
        assert!(plan.is_down_at(10.0));
        assert!(plan.is_down_at(14.9));
        assert!(!plan.is_down_at(15.0));
        assert!(plan.is_down_at(100.5));
    }
}
