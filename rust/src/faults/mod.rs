//! Failure injection.
//!
//! The paper reports that failures are routine at scale: "the frequency
//! of failures was very high … while the osgGridFtpGroup group consisted
//! of 9 nodes, the average number of resources that actually received a
//! replica was ∼7.5" (Fig. 8), and Fig. 11/13 runs saw wall-time limits
//! and transfer errors. This module centralizes the knobs for injecting
//! those faults deterministically.

use crate::coordination::Store;
use crate::rng::Rng;

/// Retry policy for transfers ("Globus Online e.g. automatically
/// restarts failed transfers").
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    pub max_attempts: u32,
    /// Base backoff in seconds, doubled per attempt.
    pub backoff_s: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, backoff_s: 5.0 }
    }
}

impl RetryPolicy {
    pub fn none() -> RetryPolicy {
        RetryPolicy { max_attempts: 1, backoff_s: 0.0 }
    }

    pub fn backoff_for(&self, attempt: u32) -> f64 {
        self.backoff_s * 2f64.powi(attempt as i32)
    }
}

/// Outcome of a transfer attempt sequence under a failure rate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttemptOutcome {
    pub succeeded: bool,
    pub attempts: u32,
    /// Extra seconds spent on failed attempts + backoff.
    pub wasted_s: f64,
}

/// Roll a sequence of attempts: each fails independently with
/// `failure_rate`; a failed attempt wastes a fraction of the nominal
/// transfer time (we model failures as detected mid-flight, on average
/// halfway) plus backoff.
pub fn attempt_transfer(
    rng: &mut Rng,
    failure_rate: f64,
    nominal_s: f64,
    policy: RetryPolicy,
) -> AttemptOutcome {
    let mut wasted = 0.0;
    for attempt in 0..policy.max_attempts {
        if !rng.chance(failure_rate) {
            return AttemptOutcome { succeeded: true, attempts: attempt + 1, wasted_s: wasted };
        }
        wasted += nominal_s * rng.range_f64(0.1, 0.9) + policy.backoff_for(attempt);
    }
    AttemptOutcome { succeeded: false, attempts: policy.max_attempts, wasted_s: wasted }
}

/// RAII coordination-store outage: the store goes down on
/// construction and comes back up when the guard drops, so a test (or
/// chaos hook) cannot leak a permanently dead store past an early
/// return or panic. While the guard lives, blocked poppers surface
/// [`crate::coordination::StoreError::Unavailable`] and agents park in
/// `wait_available`; the drop wakes them all. The guard is
/// re-entrant: it restores the *prior* down state, so a nested or
/// overlapping guard (or one created while an outage was already
/// injected by hand) does not end an outage it did not start.
pub struct ScopedOutage {
    store: Store,
    was_down: bool,
}

impl ScopedOutage {
    pub fn inject(store: &Store) -> ScopedOutage {
        let was_down = store.is_down();
        store.set_down(true);
        ScopedOutage { store: store.clone(), was_down }
    }
}

impl Drop for ScopedOutage {
    fn drop(&mut self) {
        self.store.set_down(self.was_down);
    }
}

/// Scheduled coordination-store outages (start, duration) in sim time.
#[derive(Debug, Clone, Default)]
pub struct OutagePlan {
    pub windows: Vec<(f64, f64)>,
}

impl OutagePlan {
    pub fn is_down_at(&self, t: f64) -> bool {
        self.windows.iter().any(|(s, d)| t >= *s && t < s + d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_always_succeeds_first_try() {
        let mut rng = Rng::new(1);
        let o = attempt_transfer(&mut rng, 0.0, 100.0, RetryPolicy::default());
        assert_eq!(o, AttemptOutcome { succeeded: true, attempts: 1, wasted_s: 0.0 });
    }

    #[test]
    fn certain_failure_exhausts_attempts() {
        let mut rng = Rng::new(2);
        let o = attempt_transfer(&mut rng, 1.0, 100.0, RetryPolicy::default());
        assert!(!o.succeeded);
        assert_eq!(o.attempts, 3);
        assert!(o.wasted_s > 0.0);
    }

    #[test]
    fn failure_rate_matches_fig8_partial_replication() {
        // With per-attempt failure 0.17 and no retries, a 9-node group
        // should succeed on ≈7.5 nodes on average.
        let mut rng = Rng::new(3);
        let trials = 20_000;
        let mut successes = 0u32;
        for _ in 0..trials {
            if attempt_transfer(&mut rng, 0.17, 60.0, RetryPolicy::none()).succeeded {
                successes += 1;
            }
        }
        let per_group = 9.0 * successes as f64 / trials as f64;
        assert!((per_group - 7.5).abs() < 0.2, "per_group={per_group}");
    }

    #[test]
    fn backoff_doubles() {
        let p = RetryPolicy { max_attempts: 4, backoff_s: 2.0 };
        assert_eq!(p.backoff_for(0), 2.0);
        assert_eq!(p.backoff_for(2), 8.0);
    }

    #[test]
    fn scoped_outage_restores_on_drop_even_on_unwind() {
        let s = Store::new();
        s.set("k", "v").unwrap();
        {
            let _o = ScopedOutage::inject(&s);
            assert!(s.get("k").is_err(), "ops must fail during the outage");
        }
        assert_eq!(s.get("k").unwrap(), Some("v".to_string()), "drop must restore");
        // Restored through an unwind too.
        let s2 = s.clone();
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _o = ScopedOutage::inject(&s2);
            panic!("boom");
        }));
        assert!(s.get("k").is_ok(), "outage leaked past a panic");
    }

    #[test]
    fn outage_windows() {
        let plan = OutagePlan { windows: vec![(10.0, 5.0), (100.0, 1.0)] };
        assert!(!plan.is_down_at(9.9));
        assert!(plan.is_down_at(10.0));
        assert!(plan.is_down_at(14.9));
        assert!(!plan.is_down_at(15.0));
        assert!(plan.is_down_at(100.5));
    }
}
