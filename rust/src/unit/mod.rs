//! Data-Units and Compute-Units — the primary abstractions for
//! expressing and managing application workloads (paper §4.3.2).
//!
//! A **Data-Unit (DU)** is an immutable container for a logical group of
//! "affine" files, completely decoupled from its physical location;
//! replicas of a DU can reside in different Pilot-Data. A **Compute-Unit
//! (CU)** encapsulates an application task — an executable with
//! parameters — with `input_data` / `output_data` dependencies on DUs.
//! Both are described by JSON description objects (CUD / DUD).

use crate::json::Json;
use crate::topology::Label;
use crate::util::Bytes;

/// One logical file inside a Data-Unit. In sim mode only `size`
/// matters; in local mode `src` points at real content to ingest.
#[derive(Debug, Clone, PartialEq)]
pub struct FileRef {
    /// Application-level relative path inside the DU namespace.
    pub name: String,
    pub size: Bytes,
    /// Optional real source path (local execution mode).
    pub src: Option<String>,
}

impl FileRef {
    pub fn sized(name: &str, size: Bytes) -> FileRef {
        FileRef { name: name.to_string(), size, src: None }
    }

    pub fn local(name: &str, src: &str, size: Bytes) -> FileRef {
        FileRef { name: name.to_string(), size, src: Some(src.to_string()) }
    }
}

/// Data-Unit-Description: the JSON document submitted to the
/// Compute-Data Service (paper: "A DUD contains all references to the
/// input files that should be used to initially populate the DU").
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DataUnitDescription {
    pub name: String,
    pub files: Vec<FileRef>,
    /// Affinity label constraining/hinting placement.
    pub affinity: Option<Label>,
}

impl DataUnitDescription {
    pub fn total_size(&self) -> Bytes {
        self.files.iter().map(|f| f.size).sum()
    }

    pub fn to_json(&self) -> Json {
        let files: Vec<Json> = self
            .files
            .iter()
            .map(|f| {
                let mut j = Json::obj().set("name", f.name.as_str()).set("size", f.size.0);
                if let Some(src) = &f.src {
                    j = j.set("src", src.as_str());
                }
                j
            })
            .collect();
        let mut j = Json::obj().set("name", self.name.as_str()).set("files", Json::Arr(files));
        if let Some(a) = &self.affinity {
            j = j.set("affinity", a.0.as_str());
        }
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<DataUnitDescription> {
        let files = j
            .get("files")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(|f| {
                Ok(FileRef {
                    name: f.str_field("name")?.to_string(),
                    size: Bytes::b(f.u64_field_or("size", 0)),
                    src: f.get("src").and_then(Json::as_str).map(str::to_string),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(DataUnitDescription {
            name: j.str_field("name").unwrap_or("").to_string(),
            files,
            affinity: j.get("affinity").and_then(Json::as_str).map(Label::new),
        })
    }
}

/// Data-Unit lifecycle (BigJob state model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DuState {
    /// Described, not yet materialized anywhere.
    New,
    /// Files are being transferred into a Pilot-Data.
    Pending,
    /// At least one complete replica exists.
    Running,
    /// All requested placements/replications finished.
    Done,
    Failed,
}

impl DuState {
    /// Legal transitions of the DU state machine.
    pub fn can_transition(self, to: DuState) -> bool {
        use DuState::*;
        matches!(
            (self, to),
            (New, Pending)
                | (Pending, Running)
                | (Pending, Failed)
                | (Running, Done)
                | (Running, Pending) // additional replication started
                | (Running, Failed)
                | (Done, Pending) // re-replication of a finished DU
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            DuState::New => "New",
            DuState::Pending => "Pending",
            DuState::Running => "Running",
            DuState::Done => "Done",
            DuState::Failed => "Failed",
        }
    }
}

/// A Data-Unit instance: immutable description + mutable state. The
/// DU's id doubles as its location-independent logical URL
/// (paper: "The Data-Unit URL serves as a single level namespace
/// independent of the actual physical location").
///
/// The total file size is summed **once at construction** and cached —
/// [`DataUnit::size`] sits inside the scheduler's per-(CU, pilot)
/// scoring loop, where re-summing the file list per call was pure
/// overhead. The description is therefore only reachable through
/// [`DataUnit::description`] / [`DataUnit::description_mut`]; the
/// mutable path returns a guard that re-sums the cache on drop, so the
/// cached value can never go stale.
#[derive(Debug, Clone)]
pub struct DataUnit {
    pub id: String,
    description: DataUnitDescription,
    pub state: DuState,
    /// Cached `description.total_size()`.
    cached_size: Bytes,
}

impl DataUnit {
    pub fn new(description: DataUnitDescription) -> DataUnit {
        let cached_size = description.total_size();
        DataUnit { id: crate::util::next_id("du"), description, state: DuState::New, cached_size }
    }

    pub fn description(&self) -> &DataUnitDescription {
        &self.description
    }

    /// Mutable access to the description. The guard recomputes the
    /// cached size when dropped.
    pub fn description_mut(&mut self) -> DuDescrMut<'_> {
        DuDescrMut { du: self }
    }

    pub fn logical_url(&self) -> String {
        format!("du://{}", self.id)
    }

    pub fn size(&self) -> Bytes {
        self.cached_size
    }

    pub fn file_count(&self) -> u32 {
        self.description.files.len() as u32
    }

    pub fn transition(&mut self, to: DuState) -> anyhow::Result<()> {
        if self.state == to {
            return Ok(());
        }
        if !self.state.can_transition(to) {
            anyhow::bail!("DU {}: illegal transition {:?} -> {to:?}", self.id, self.state);
        }
        self.state = to;
        Ok(())
    }
}

/// Write guard over a [`DataUnit`]'s description: derefs to
/// [`DataUnitDescription`] and re-sums the cached size on drop (see
/// [`DataUnit::description_mut`]).
pub struct DuDescrMut<'a> {
    du: &'a mut DataUnit,
}

impl std::ops::Deref for DuDescrMut<'_> {
    type Target = DataUnitDescription;
    fn deref(&self) -> &DataUnitDescription {
        &self.du.description
    }
}

impl std::ops::DerefMut for DuDescrMut<'_> {
    fn deref_mut(&mut self) -> &mut DataUnitDescription {
        &mut self.du.description
    }
}

impl Drop for DuDescrMut<'_> {
    fn drop(&mut self) {
        self.du.cached_size = self.du.description.total_size();
    }
}

/// Compute-Unit lifecycle. `Unschedulable` is entered when affinity
/// constraints can never be met (no matching pilot) so the workload
/// manager can surface the error instead of spinning.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CuState {
    New,
    /// Placed in a queue (global or pilot-specific).
    Queued,
    /// Input DUs are being staged to the execution sandbox.
    StagingInput,
    Running,
    /// Output is being written back to output DUs.
    StagingOutput,
    Done,
    Failed,
    Unschedulable,
}

impl CuState {
    pub fn can_transition(self, to: CuState) -> bool {
        use CuState::*;
        matches!(
            (self, to),
            (New, Queued)
                | (New, Unschedulable)
                | (Queued, StagingInput)
                | (Queued, Queued) // re-queue (delayed scheduling / agent death)
                | (Queued, Unschedulable)
                | (StagingInput, Running)
                | (StagingInput, Failed)
                | (StagingInput, Queued) // staging failed, retry elsewhere
                | (Running, StagingOutput)
                | (Running, Failed)
                | (Running, Queued) // pilot died mid-run, re-queue
                | (StagingOutput, Done)
                | (StagingOutput, Failed)
        )
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, CuState::Done | CuState::Failed | CuState::Unschedulable)
    }

    pub fn name(self) -> &'static str {
        match self {
            CuState::New => "New",
            CuState::Queued => "Queued",
            CuState::StagingInput => "StagingInput",
            CuState::Running => "Running",
            CuState::StagingOutput => "StagingOutput",
            CuState::Done => "Done",
            CuState::Failed => "Failed",
            CuState::Unschedulable => "Unschedulable",
        }
    }
}

/// Compute-Unit-Description (CUD). `cpu_secs_hint`/`io_bytes_hint`
/// carry the workload's cost-model inputs for sim mode (CPU-seconds at
/// reference speed, bytes scanned from shared FS); local mode ignores
/// them and runs the real executable/kernel.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ComputeUnitDescription {
    pub executable: String,
    pub arguments: Vec<String>,
    pub cores: u32,
    pub input_data: Vec<String>,
    pub output_data: Vec<String>,
    /// Constrain execution to a subtree of the topology.
    pub affinity: Option<Label>,
    /// Sim-mode cost model: pure CPU seconds on the reference machine.
    pub cpu_secs_hint: f64,
    /// Sim-mode cost model: bytes scanned from the shared filesystem
    /// during execution (drives the Fig. 11 I/O-saturation effect).
    pub io_bytes_hint: Bytes,
}

impl ComputeUnitDescription {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("executable", self.executable.as_str())
            .set("arguments", self.arguments.clone())
            .set("cores", self.cores as u64)
            .set("input_data", self.input_data.clone())
            .set("output_data", self.output_data.clone())
            .set("cpu_secs_hint", self.cpu_secs_hint)
            .set("io_bytes_hint", self.io_bytes_hint.0);
        if let Some(a) = &self.affinity {
            j = j.set("affinity", a.0.as_str());
        }
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ComputeUnitDescription> {
        let strings = |key: &str| -> Vec<String> {
            j.get(key)
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect()
        };
        Ok(ComputeUnitDescription {
            executable: j.str_field("executable")?.to_string(),
            arguments: strings("arguments"),
            cores: j.u64_field_or("cores", 1) as u32,
            input_data: strings("input_data"),
            output_data: strings("output_data"),
            affinity: j.get("affinity").and_then(Json::as_str).map(Label::new),
            cpu_secs_hint: j.f64_field_or("cpu_secs_hint", 0.0),
            io_bytes_hint: Bytes::b(j.u64_field_or("io_bytes_hint", 0)),
        })
    }
}

/// A Compute-Unit instance with execution bookkeeping (the per-task
/// timings behind Figs. 10, 12, 13).
#[derive(Debug, Clone)]
pub struct ComputeUnit {
    pub id: String,
    pub description: ComputeUnitDescription,
    pub state: CuState,
    /// Pilot the CU was bound to, once scheduled.
    pub pilot: Option<String>,
    /// Timestamps (sim seconds or unix seconds) per phase.
    pub t_submitted: f64,
    pub t_started_staging: f64,
    pub t_started_run: f64,
    pub t_finished: f64,
    /// Seconds spent downloading input (Fig. 10 "Download").
    pub staging_s: f64,
    pub error: Option<String>,
}

impl ComputeUnit {
    pub fn new(description: ComputeUnitDescription) -> ComputeUnit {
        ComputeUnit {
            id: crate::util::next_id("cu"),
            description,
            state: CuState::New,
            pilot: None,
            t_submitted: 0.0,
            t_started_staging: 0.0,
            t_started_run: 0.0,
            t_finished: 0.0,
            staging_s: 0.0,
            error: None,
        }
    }

    pub fn transition(&mut self, to: CuState) -> anyhow::Result<()> {
        if self.state == to && to != CuState::Queued {
            return Ok(());
        }
        if !self.state.can_transition(to) {
            anyhow::bail!("CU {}: illegal transition {:?} -> {to:?}", self.id, self.state);
        }
        self.state = to;
        Ok(())
    }

    /// Pilot-internal queueing time T_Q_task (paper §6.1).
    pub fn queue_wait_s(&self) -> f64 {
        (self.t_started_staging - self.t_submitted).max(0.0)
    }

    /// Wall time from run start to completion (Fig. 10 "Runtime").
    pub fn run_s(&self) -> f64 {
        (self.t_finished - self.t_started_run).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dud() -> DataUnitDescription {
        DataUnitDescription {
            name: "bwa-input".into(),
            files: vec![
                FileRef::sized("ref/genome.fa", Bytes::gb(8)),
                FileRef::sized("reads/chunk0.fq", Bytes::mb(256)),
            ],
            affinity: Some(Label::new("xsede/tacc/lonestar")),
        }
    }

    #[test]
    fn dud_json_roundtrip() {
        let d = dud();
        let j = d.to_json();
        let back = DataUnitDescription::from_json(&j).unwrap();
        assert_eq!(back, d);
        assert_eq!(back.total_size(), Bytes::gb(8) + Bytes::mb(256));
    }

    #[test]
    fn cud_json_roundtrip() {
        let c = ComputeUnitDescription {
            executable: "/bin/bwa".into(),
            arguments: vec!["aln".into(), "-t".into(), "2".into()],
            cores: 2,
            input_data: vec!["du-1".into(), "du-2".into()],
            output_data: vec!["du-3".into()],
            affinity: Some(Label::new("osg")),
            cpu_secs_hint: 1200.0,
            io_bytes_hint: Bytes::gb(9),
        };
        let back = ComputeUnitDescription::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn cud_from_json_requires_executable() {
        assert!(ComputeUnitDescription::from_json(&Json::obj()).is_err());
    }

    #[test]
    fn du_state_machine_accepts_legal_path() {
        let mut du = DataUnit::new(dud());
        assert_eq!(du.state, DuState::New);
        du.transition(DuState::Pending).unwrap();
        du.transition(DuState::Running).unwrap();
        du.transition(DuState::Pending).unwrap(); // replication
        du.transition(DuState::Running).unwrap();
        du.transition(DuState::Done).unwrap();
    }

    #[test]
    fn du_state_machine_rejects_illegal() {
        let mut du = DataUnit::new(dud());
        assert!(du.transition(DuState::Done).is_err());
        du.transition(DuState::Pending).unwrap();
        assert!(du.transition(DuState::New).is_err());
    }

    #[test]
    fn cu_state_machine_full_lifecycle() {
        let mut cu = ComputeUnit::new(ComputeUnitDescription {
            executable: "x".into(),
            ..Default::default()
        });
        for s in [
            CuState::Queued,
            CuState::StagingInput,
            CuState::Running,
            CuState::StagingOutput,
            CuState::Done,
        ] {
            cu.transition(s).unwrap();
        }
        assert!(cu.state.is_terminal());
        assert!(cu.transition(CuState::Running).is_err());
    }

    #[test]
    fn cu_requeue_on_failure_paths() {
        let mut cu = ComputeUnit::new(Default::default());
        cu.transition(CuState::Queued).unwrap();
        cu.transition(CuState::StagingInput).unwrap();
        cu.transition(CuState::Queued).unwrap(); // staging failed -> retry
        cu.transition(CuState::StagingInput).unwrap();
        cu.transition(CuState::Running).unwrap();
        cu.transition(CuState::Queued).unwrap(); // pilot died -> retry
    }

    #[test]
    fn cu_timing_accessors() {
        let mut cu = ComputeUnit::new(Default::default());
        cu.t_submitted = 10.0;
        cu.t_started_staging = 25.0;
        cu.t_started_run = 40.0;
        cu.t_finished = 100.0;
        assert_eq!(cu.queue_wait_s(), 15.0);
        assert_eq!(cu.run_s(), 60.0);
    }

    #[test]
    fn du_size_is_cached_and_mutation_invalidates_it() {
        let mut du = DataUnit::new(dud());
        let s0 = du.size();
        assert_eq!(s0, Bytes::gb(8) + Bytes::mb(256));
        // Reads leave the cache alone.
        assert_eq!(du.description().files.len(), 2);
        assert_eq!(du.size(), s0);
        // Mutation through the guard re-sums on drop.
        du.description_mut().files.push(FileRef::sized("extra.bin", Bytes::gb(1)));
        assert_eq!(
            du.size(),
            s0 + Bytes::gb(1),
            "mutating the description must invalidate the cached size"
        );
        {
            let mut g = du.description_mut();
            g.files.clear();
            g.name = "emptied".into();
        }
        assert_eq!(du.size(), Bytes(0));
        assert_eq!(du.file_count(), 0);
        assert_eq!(du.description().name, "emptied");
    }

    #[test]
    fn du_logical_url_is_location_independent() {
        let du = DataUnit::new(dud());
        assert!(du.logical_url().starts_with("du://du-"));
    }

    #[test]
    fn state_machine_no_terminal_escape() {
        use CuState::*;
        let all = [New, Queued, StagingInput, Running, StagingOutput, Done, Failed, Unschedulable];
        for from in all {
            for to in all {
                if from.is_terminal() {
                    assert!(!from.can_transition(to), "{from:?} -> {to:?} must be illegal");
                }
            }
        }
    }
}
