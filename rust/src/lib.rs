//! # Pilot-Data: An Abstraction for Distributed Data
//!
//! A full reimplementation of the Pilot-Data system (Luckow, Santcroos,
//! Zebrowski, Jha — 2013): a unified abstraction for distributed **data**
//! management in conjunction with Pilot-Jobs, including
//!
//! * the Pilot-API (`service`): [`service::PilotComputeService`],
//!   [`service::PilotDataService`], [`service::ComputeDataService`];
//! * Pilot-Computes and Pilot-Data (`pilot`) with pull-based agents
//!   coordinated through a from-scratch Redis-equivalent (`coordination`);
//! * Data-Units / Compute-Units (`unit`) and the affinity-aware
//!   scheduler of §5 (`scheduler`) over a hierarchical resource topology
//!   (`topology`);
//! * storage adaptors for the paper's backends — SSH, SRM/GridFTP, iRODS,
//!   Globus Online, S3, local filesystem (`storage`);
//! * a deterministic discrete-event simulation of production DCI
//!   (machines, batch queues, shared networks: `simtime`, `batch`, `net`)
//!   substituting for XSEDE/OSG;
//! * an alignment runtime (`runtime`) executing the JAX/Pallas
//!   pipeline's reference semantics (`python/compile`) as native
//!   kernels, so Compute-Units run *real* compute in local mode —
//!   python never on the task path;
//! * experiment drivers regenerating every figure and table of the
//!   paper's evaluation (`experiments`).
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod util;
pub mod json;
pub mod rng;
pub mod prop;
pub mod simtime;
pub mod topology;
pub mod net;
pub mod batch;
pub mod storage;
pub mod coordination;
pub mod faults;
pub mod unit;
pub mod pilot;
pub mod scheduler;
pub mod service;
pub mod runtime;
pub mod workload;
pub mod metrics;
pub mod config;
pub mod experiments;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Convenience constructor: a `file://` Pilot-Data-Description rooted
/// under `dir/name` with the given affinity label (local mode).
pub fn pd_desc(
    dir: &std::path::Path,
    name: &str,
    affinity: &str,
) -> pilot::PilotDataDescription {
    pilot::PilotDataDescription {
        service_url: format!("file://localhost{}/{name}", dir.display()),
        size: util::Bytes::gb(1),
        affinity: Some(topology::Label::new(affinity)),
    }
}

/// Convenience constructor: a local (`fork://`) Pilot-Compute-Description.
pub fn pilot_desc(affinity: &str) -> pilot::PilotComputeDescription {
    pilot::PilotComputeDescription {
        service_url: "fork://localhost".into(),
        cores: 2,
        walltime_s: 3600.0,
        affinity: Some(topology::Label::new(affinity)),
    }
}
