//! # Pilot-Data: An Abstraction for Distributed Data
//!
//! A full reimplementation of the Pilot-Data system (Luckow, Santcroos,
//! Zebrowski, Jha — 2013): a unified abstraction for distributed **data**
//! management in conjunction with Pilot-Jobs.
//!
//! # Layer diagram
//!
//! The crate is organized as a stack; each layer consumes only the
//! layers below it:
//!
//! ```text
//!   experiments/          paper figures + the mode-comparison driver
//!        │                (drivers over simulated time: `simdrive`)
//!   datamgmt/             execution-mode engine: pluggable staging /
//!        │                replication policies over the substrate
//!   pilot/ service/ scheduler/
//!        │                Pilot-Manager state + Pilot-API facades +
//!        │                the §5 affinity scheduler
//!   topology/ net/ storage/ batch/
//!        │                interned data plane: resource topology,
//!        │                shared-network flow model, quota-checked
//!        │                replica store, batch queues
//!   coordination/         sharded Redis-equivalent: keyspace events,
//!        │                blocking pops, wake-one handoff
//!   simtime/ rng/ util/ json/
//!                         deterministic DES core + support
//! ```
//!
//! In detail:
//!
//! * the Pilot-API (`service`): [`service::PilotComputeService`],
//!   [`service::PilotDataService`], [`service::ComputeDataService`];
//! * Pilot-Computes and Pilot-Data (`pilot`) with pull-based agents
//!   coordinated through a from-scratch Redis-equivalent (`coordination`)
//!   whose event layer (pub/sub, blocking pops) drives both wall-clock
//!   agents and the sim driver's wakeups;
//! * Data-Units / Compute-Units (`unit`) and the affinity-aware
//!   scheduler of §5 (`scheduler`) over a hierarchical resource topology
//!   (`topology`, interned to integer node ids);
//! * the **execution-mode engine** (`datamgmt`): pluggable
//!   staging/replication policies — on-demand, pre-stage,
//!   auto-replicate — over a storage-capacity model with per-PD quotas
//!   and LRU eviction (`storage::simstore`);
//! * storage adaptors for the paper's backends — SSH, SRM/GridFTP, iRODS,
//!   Globus Online, S3, local filesystem (`storage`);
//! * a deterministic discrete-event simulation of production DCI
//!   (machines, batch queues, shared networks: `simtime`, `batch`, `net`)
//!   substituting for XSEDE/OSG;
//! * an alignment runtime (`runtime`) executing the JAX/Pallas
//!   pipeline's reference semantics (`python/compile`) as native
//!   kernels, so Compute-Units run *real* compute in local mode —
//!   python never on the task path;
//! * experiment drivers regenerating every figure and table of the
//!   paper's evaluation, plus the execution-mode comparison
//!   (`experiments`).
//!
//! See `README.md` for the paper-to-module map and how to run each
//! experiment, and `ROADMAP.md` for the architecture notes.
//!
//! # Quickstart: submit a workload against the simulated testbed
//!
//! The same manager/scheduler/store stack that runs wall-clock agents
//! replays hour-scale runs in milliseconds under simulated time:
//!
//! ```
//! use pilot_data::config::paper_testbed;
//! use pilot_data::experiments::simdrive::SimSystem;
//! use pilot_data::unit::{ComputeUnitDescription, DataUnitDescription, FileRef};
//! use pilot_data::util::Bytes;
//!
//! let mut sys = SimSystem::new(paper_testbed(), 42);
//! // Upload a Data-Unit to Lonestar's scratch Pilot-Data...
//! let du = sys
//!     .upload_du(
//!         &DataUnitDescription {
//!             name: "reads".into(),
//!             files: vec![FileRef::sized("chunk0", Bytes::mb(256))],
//!             affinity: None,
//!         },
//!         "lonestar-scratch",
//!     )
//!     .unwrap();
//! sys.run().unwrap(); // land the upload
//! // ...start a pilot there and submit a Compute-Unit over the DU.
//! sys.submit_pilot("lonestar", 4, "lonestar-scratch").unwrap();
//! sys.submit_cu(ComputeUnitDescription {
//!     executable: "/bin/bwa".into(),
//!     cores: 2,
//!     input_data: vec![du],
//!     ..Default::default()
//! })
//! .unwrap();
//! sys.run().unwrap();
//! assert!(sys.state.workload_finished());
//! assert!(sys.makespan() > 0.0);
//! ```
//!
//! To swap the data-management policy, see [`datamgmt`] — the same
//! submit sequence under `PreStage` or `AutoReplicate` changes *when*
//! the bytes move, not the application code.

pub mod util;
pub mod json;
pub mod rng;
pub mod prop;
pub mod simtime;
pub mod topology;
pub mod net;
pub mod batch;
pub mod storage;
pub mod coordination;
pub mod faults;
pub mod unit;
pub mod pilot;
pub mod datamgmt;
pub mod scheduler;
pub mod service;
pub mod runtime;
pub mod workload;
pub mod metrics;
pub mod config;
pub mod experiments;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Convenience constructor: a `file://` Pilot-Data-Description rooted
/// under `dir/name` with the given affinity label (local mode).
pub fn pd_desc(
    dir: &std::path::Path,
    name: &str,
    affinity: &str,
) -> pilot::PilotDataDescription {
    pilot::PilotDataDescription {
        service_url: format!("file://localhost{}/{name}", dir.display()),
        size: util::Bytes::gb(1),
        affinity: Some(topology::Label::new(affinity)),
    }
}

/// Convenience constructor: a local (`fork://`) Pilot-Compute-Description.
pub fn pilot_desc(affinity: &str) -> pilot::PilotComputeDescription {
    pilot::PilotComputeDescription {
        service_url: "fork://localhost".into(),
        cores: 2,
        walltime_s: 3600.0,
        affinity: Some(topology::Label::new(affinity)),
    }
}
