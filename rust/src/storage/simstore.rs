//! Simulated storage state: which Data-Unit replicas reside on which
//! Pilot-Data endpoints, plus the transfer cost model combining the
//! protocol parameters with the shared network.
//!
//! Transfers that involve a protocol without third-party support are
//! routed through the submission machine (the paper stages via GW68,
//! the XSEDE gateway at Indiana University), doubling the path: this is
//! exactly why naive data management in Fig. 9 scenarios 1–2 is slow.
//!
//! # Capacity model
//!
//! A Pilot-Data is a *finite* storage allocation (paper §4.3.1: "a
//! certain physical storage resource"), so every [`SimPd`] can carry a
//! byte **quota**. [`SimStore::try_place`] is the quota-checked
//! placement path: it accounts used bytes per PD and, when a new
//! replica does not fit, evicts replicas in **LRU order** — skipping
//! [`SimStore::pin`]ned replicas and any replica that is the *last*
//! copy of its Data-Unit — until the newcomer fits or no legal victim
//! remains ([`PlaceOutcome::NoCapacity`]). PDs without a quota behave
//! exactly like the seed's unbounded store (nothing is ever evicted),
//! which is what keeps the `OnDemand` execution mode bit-identical to
//! the pre-capacity behavior. [`SimStore::evict`] stays the *forced*
//! removal path (PD outages, tests): it bypasses the pin/last-replica
//! safety rules by design.

use super::{BackendProfile, Endpoint, ProtocolParams};
use crate::net::{Bandwidth, FlowHandle, Network};
use crate::topology::{Label, NodeId};
use crate::util::Bytes;
use std::collections::{BTreeMap, BTreeSet};

/// Cost breakdown of one transfer (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferCost {
    pub setup_s: f64,
    pub wire_s: f64,
    pub register_s: f64,
}

impl TransferCost {
    pub fn total(&self) -> f64 {
        self.setup_s + self.wire_s + self.register_s
    }
}

/// One wire leg at a sampled fair-share bandwidth: effective rate =
/// min(network share × protocol efficiency, the protocol's
/// single-flow ceiling), floored away from zero. The single home of
/// the formula for all live cost paths (`transfer_cost_reference`
/// keeps its own frozen copy by design — it is the oracle).
fn leg_secs(params: &ProtocolParams, size: Bytes, bw: Bandwidth) -> f64 {
    let eff = params.efficiency.max(1e-6);
    let net_rate = bw.bytes_per_sec() * eff;
    size.as_f64() / net_rate.min(params.per_flow_cap).max(1e-6)
}

/// Compute the cost of moving `size` bytes in `files` files from
/// `src` to `dst` with protocol `params`, at current network
/// congestion. `via` is the submission host used when the protocol
/// cannot do third-party transfers and neither endpoint is the
/// submission host itself.
///
/// Label-keyed compat shim; hot paths use [`transfer_cost_id`] or the
/// combined [`transfer_cost_flow`].
pub fn transfer_cost(
    net: &Network,
    src: &Label,
    dst: &Label,
    via: Option<&Label>,
    params: &ProtocolParams,
    size: Bytes,
    files: u32,
) -> TransferCost {
    let setup_s = params.setup_s + params.per_file_s * files as f64;
    let leg = |a: &Label, b: &Label| leg_secs(params, size, net.effective_bandwidth(a, b));
    let wire_s = match via {
        Some(gw) if !params.third_party && src != gw && dst != gw && src != dst => {
            // Two legs through the gateway.
            leg(src, gw) + leg(gw, dst)
        }
        _ => leg(src, dst),
    };
    TransferCost { setup_s, wire_s, register_s: params.register_s }
}

/// [`transfer_cost`] over interned node ids: allocation-free post-memo
/// (`&mut` because first-seen paths are memoized into the network's
/// path table).
pub fn transfer_cost_id(
    net: &mut Network,
    src: NodeId,
    dst: NodeId,
    via: Option<NodeId>,
    params: &ProtocolParams,
    size: Bytes,
    files: u32,
) -> TransferCost {
    let setup_s = params.setup_s + params.per_file_s * files as f64;
    let leg = |net: &mut Network, a: NodeId, b: NodeId| {
        leg_secs(params, size, net.effective_bandwidth_id(a, b))
    };
    let wire_s = match via {
        Some(gw) if !params.third_party && src != gw && dst != gw && src != dst => {
            leg(net, src, gw) + leg(net, gw, dst)
        }
        _ => leg(net, src, dst),
    };
    TransferCost { setup_s, wire_s, register_s: params.register_s }
}

/// Price the transfer *and* register its src→dst flow in one path
/// walk ([`Network::begin_flow_priced_id`]) — the transfer-start fast
/// path. Numbers are identical to [`transfer_cost_id`] followed by
/// `begin_flow_id`: the bandwidth is sampled before the flow's own
/// increment lands. Gateway-routed transfers still price two legs (the
/// seed shape) but register only the direct src→dst flow, exactly as
/// the drivers always did.
pub fn transfer_cost_flow(
    net: &mut Network,
    src: NodeId,
    dst: NodeId,
    via: Option<NodeId>,
    params: &ProtocolParams,
    size: Bytes,
    files: u32,
) -> (TransferCost, FlowHandle) {
    let setup_s = params.setup_s + params.per_file_s * files as f64;
    let routed =
        matches!(via, Some(gw) if !params.third_party && src != gw && dst != gw && src != dst);
    let (wire_s, flow) = if routed {
        let gw = via.unwrap();
        let w = leg_secs(params, size, net.effective_bandwidth_id(src, gw))
            + leg_secs(params, size, net.effective_bandwidth_id(gw, dst));
        (w, net.begin_flow_id(src, dst))
    } else {
        let (flow, bw) = net.begin_flow_priced_id(src, dst);
        (leg_secs(params, size, bw), flow)
    };
    (TransferCost { setup_s, wire_s, register_s: params.register_s }, flow)
}

/// [`transfer_cost`] against the retained seed engine
/// ([`crate::net::reference::StringNetwork`]) — property-test oracle
/// and the `perf_micro` string baseline.
pub fn transfer_cost_reference(
    net: &crate::net::reference::StringNetwork,
    src: &Label,
    dst: &Label,
    via: Option<&Label>,
    params: &ProtocolParams,
    size: Bytes,
    files: u32,
) -> TransferCost {
    let setup_s = params.setup_s + params.per_file_s * files as f64;
    let eff = params.efficiency.max(1e-6);
    let leg = |a: &Label, b: &Label| {
        let net_rate = net.effective_bandwidth(a, b).bytes_per_sec() * eff;
        size.as_f64() / net_rate.min(params.per_flow_cap).max(1e-6)
    };
    let wire_s = match via {
        Some(gw) if !params.third_party && src != gw && dst != gw && src != dst => {
            leg(src, gw) + leg(gw, dst)
        }
        _ => leg(src, dst),
    };
    TransferCost { setup_s, wire_s, register_s: params.register_s }
}

/// Exchange rate folding monetary cost into replica-ranking seconds:
/// one dollar of egress is treated as this many seconds of transfer
/// pain when [`SimStore::closest_replica`] ranks priced sources. Only
/// a ranking weight — wall-clock costs never include it.
pub const DOLLAR_WEIGHT_S: f64 = 60.0;

/// Compose the src/dst device profiles into a priced path cost: fixed
/// latency adds to the setup term once per attempt, and each device's
/// bandwidth ceiling floors the wire time at `size / cap`
/// (min()-composition with the uplink walk — the slower of network
/// path and device governs).
fn profile_adjust(
    mut cost: TransferCost,
    src: &BackendProfile,
    dst: &BackendProfile,
    size: Bytes,
) -> TransferCost {
    for p in [src, dst] {
        cost.setup_s += p.fixed_latency_s;
        if let Some(cap) = p.bandwidth_cap {
            cost.wire_s = cost.wire_s.max(size.as_f64() / cap.max(1e-6));
        }
    }
    cost
}

/// A named Pilot-Data location in the simulation with its endpoint.
#[derive(Debug, Clone)]
pub struct SimPd {
    pub name: String,
    pub endpoint: Endpoint,
    /// Storage quota in bytes; `None` = unbounded (the seed behavior).
    pub quota: Option<Bytes>,
    /// Physical device profile behind the endpoint. The default is the
    /// uniform no-op ([`BackendProfile::is_uniform`]); a store where
    /// every PD keeps it prices transfers on the exact pre-profile
    /// path.
    pub profile: BackendProfile,
}

/// Outcome of a quota-checked placement ([`SimStore::try_place`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlaceOutcome {
    /// The replica was placed; `evicted` lists the `(du, pd)` replicas
    /// removed under capacity pressure to make room, in eviction
    /// (LRU) order.
    Placed { evicted: Vec<(String, String)> },
    /// The replica does not fit: the PD is down, the DU is larger than
    /// the quota, or every resident byte is pinned / a last replica.
    /// Nothing was evicted and nothing was placed.
    NoCapacity,
}

/// Registry of endpoints, DU replica placement, per-PD capacity
/// accounting, and iRODS-style server-side replication groups.
#[derive(Debug, Default)]
pub struct SimStore {
    pds: BTreeMap<String, SimPd>,
    /// du id -> set of pd names holding a full replica.
    replicas: BTreeMap<String, BTreeSet<String>>,
    /// du id -> (size, file count).
    du_meta: BTreeMap<String, (Bytes, u32)>,
    /// replication group name -> member pd names (iRODS resource groups).
    groups: BTreeMap<String, Vec<String>>,
    /// pd name -> bytes occupied by resident replicas.
    used: BTreeMap<String, u64>,
    /// pd name -> resident du ids in recency order (front = coldest):
    /// the eviction order under capacity pressure.
    lru: BTreeMap<String, Vec<String>>,
    /// (du, pd) replicas exempt from capacity eviction.
    pinned: BTreeSet<(String, String)>,
    /// PDs currently unavailable (storage outage): they serve no
    /// transfers and accept no placements until restored.
    down: BTreeSet<String>,
    /// Count of PDs with a quota set — lets [`SimStore::any_quota`]
    /// answer in O(1) so quota-less testbeds skip per-placement
    /// capacity scans entirely.
    quota_count: usize,
    /// Count of PDs with a non-uniform [`BackendProfile`] — lets
    /// [`SimStore::heterogeneous`] answer in O(1) so homogeneous
    /// testbeds take the exact pre-profile pricing and ranking paths
    /// (the bit-identity oracles depend on this).
    profile_count: usize,
}

impl SimStore {
    pub fn new() -> SimStore {
        SimStore::default()
    }

    pub fn add_pd(&mut self, name: &str, endpoint: Endpoint) {
        let old = self.pds.insert(
            name.to_string(),
            SimPd {
                name: name.to_string(),
                endpoint,
                quota: None,
                profile: BackendProfile::default(),
            },
        );
        // Re-registering replaces the entry quota-less and with the
        // uniform profile; keep the O(1) counters honest.
        if let Some(p) = old {
            if p.quota.is_some() {
                self.quota_count -= 1;
            }
            if !p.profile.is_uniform() {
                self.profile_count -= 1;
            }
        }
    }

    /// Attach a device profile to a PD. Setting a non-uniform profile
    /// flips the store heterogeneous ([`SimStore::heterogeneous`]);
    /// setting the uniform default back flips it homogeneous again
    /// once no priced PD remains.
    pub fn set_profile(&mut self, pd: &str, profile: BackendProfile) -> anyhow::Result<()> {
        let slot = &mut self
            .pds
            .get_mut(pd)
            .ok_or_else(|| anyhow::anyhow!("unknown pilot-data '{pd}'"))?
            .profile;
        match (slot.is_uniform(), profile.is_uniform()) {
            (true, false) => self.profile_count += 1,
            (false, true) => self.profile_count -= 1,
            _ => {}
        }
        *slot = profile;
        Ok(())
    }

    /// `true` if any PD carries a non-uniform [`BackendProfile`]
    /// (O(1)). All profile-aware pricing and ranking is gated on this,
    /// so homogeneous testbeds run bit-identically to the pre-profile
    /// code.
    pub fn heterogeneous(&self) -> bool {
        self.profile_count > 0
    }

    /// Dollars charged for moving `bytes` from `src_pd` to `dst_pd`
    /// (both devices' per-GB rates apply; 0.0 on homogeneous stores or
    /// unknown PDs).
    pub fn transfer_dollars(&self, src_pd: &str, dst_pd: &str, bytes: u64) -> f64 {
        if !self.heterogeneous() {
            return 0.0;
        }
        let rate = |pd: &str| self.pds.get(pd).map(|p| p.profile.dollars_for(bytes)).unwrap_or(0.0);
        rate(src_pd) + rate(dst_pd)
    }

    /// Set (or clear) a PD's storage quota. Shrinking below the
    /// current occupancy does not evict anything retroactively; the
    /// next [`SimStore::try_place`] faces the pressure.
    pub fn set_quota(&mut self, pd: &str, quota: Option<Bytes>) -> anyhow::Result<()> {
        let slot = &mut self
            .pds
            .get_mut(pd)
            .ok_or_else(|| anyhow::anyhow!("unknown pilot-data '{pd}'"))?
            .quota;
        match (slot.is_some(), quota.is_some()) {
            (false, true) => self.quota_count += 1,
            (true, false) => self.quota_count -= 1,
            _ => {}
        }
        *slot = quota;
        Ok(())
    }

    /// `true` if any PD has a quota set (O(1); down PDs still count —
    /// callers that care filter themselves, and a store whose every
    /// quota'd PD is down yields the same decisions either way).
    pub fn any_quota(&self) -> bool {
        self.quota_count > 0
    }

    /// Override the per-attempt transfer failure rate of `pd`'s
    /// protocol (clamped to `[0, 1]`). Fault experiments scale rates up
    /// and bit-identity properties zero them; the default comes from
    /// the endpoint's protocol ([`crate::storage::ProtocolParams`]).
    pub fn set_failure_rate(&mut self, pd: &str, rate: f64) -> anyhow::Result<()> {
        self.pds
            .get_mut(pd)
            .ok_or_else(|| anyhow::anyhow!("unknown pilot-data '{pd}'"))?
            .endpoint
            .params
            .failure_rate = rate.clamp(0.0, 1.0);
        Ok(())
    }

    /// Bytes occupied by resident replicas on `pd`.
    pub fn used(&self, pd: &str) -> Bytes {
        Bytes(self.used.get(pd).copied().unwrap_or(0))
    }

    /// Remaining quota headroom (`None` for unbounded PDs).
    pub fn free_space(&self, pd: &str) -> Option<Bytes> {
        let q = self.pds.get(pd)?.quota?;
        Some(q.saturating_sub(self.used(pd)))
    }

    /// Exempt a resident replica from capacity eviction.
    pub fn pin(&mut self, du: &str, pd: &str) -> anyhow::Result<()> {
        anyhow::ensure!(self.has_replica(du, pd), "no replica of '{du}' on '{pd}' to pin");
        self.pinned.insert((du.to_string(), pd.to_string()));
        Ok(())
    }

    pub fn unpin(&mut self, du: &str, pd: &str) {
        self.pinned.remove(&(du.to_string(), pd.to_string()));
    }

    pub fn is_pinned(&self, du: &str, pd: &str) -> bool {
        self.pinned.contains(&(du.to_string(), pd.to_string()))
    }

    /// Mark a replica as recently used (moved to the warm end of the
    /// PD's LRU order). Called by the drivers when a replica serves as
    /// a transfer source, so eviction preferentially removes cold data.
    pub fn touch(&mut self, du: &str, pd: &str) {
        if let Some(order) = self.lru.get_mut(pd) {
            if let Some(i) = order.iter().position(|d| d == du) {
                let d = order.remove(i);
                order.push(d);
            }
        }
    }

    /// Take a PD out of (or back into) service. A down PD serves no
    /// transfers and rejects placements; its resident replicas are the
    /// caller's to force-[`SimStore::evict`] (the sim driver does so on
    /// its `PdDown` event).
    pub fn set_pd_down(&mut self, pd: &str, down: bool) {
        if down {
            self.down.insert(pd.to_string());
        } else {
            self.down.remove(pd);
        }
    }

    pub fn pd_is_down(&self, pd: &str) -> bool {
        self.down.contains(pd)
    }

    /// Du ids with a resident replica on `pd` (LRU order).
    pub fn dus_on(&self, pd: &str) -> Vec<String> {
        self.lru.get(pd).cloned().unwrap_or_default()
    }

    /// Total replica count across all DUs (mode-comparison metric).
    pub fn total_replicas(&self) -> usize {
        self.replicas.values().map(BTreeSet::len).sum()
    }

    /// Replica count of one DU.
    pub fn replica_count(&self, du: &str) -> usize {
        self.replicas.get(du).map(BTreeSet::len).unwrap_or(0)
    }

    pub fn pd(&self, name: &str) -> anyhow::Result<&SimPd> {
        self.pds
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown pilot-data '{name}'"))
    }

    pub fn pds(&self) -> impl Iterator<Item = &SimPd> {
        self.pds.values()
    }

    pub fn define_group(&mut self, group: &str, members: &[&str]) -> anyhow::Result<()> {
        for m in members {
            self.pd(m)?;
        }
        self.groups
            .insert(group.to_string(), members.iter().map(|s| s.to_string()).collect());
        Ok(())
    }

    pub fn group_members(&self, group: &str) -> anyhow::Result<&[String]> {
        self.groups
            .get(group)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow::anyhow!("unknown replication group '{group}'"))
    }

    /// Record DU metadata on first placement.
    pub fn register_du(&mut self, du: &str, size: Bytes, files: u32) {
        self.du_meta.insert(du.to_string(), (size, files));
    }

    pub fn du_meta(&self, du: &str) -> anyhow::Result<(Bytes, u32)> {
        self.du_meta
            .get(du)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unknown data-unit '{du}'"))
    }

    /// Mark `pd` as holding a full replica of `du`, evicting under
    /// capacity pressure if the PD has a quota. Errors when the
    /// replica cannot legally fit ([`PlaceOutcome::NoCapacity`]) —
    /// impossible on quota-less PDs, so seed-era callers are
    /// unaffected. Callers that must react to eviction or rejection
    /// (the sim driver) use [`SimStore::try_place`] instead.
    pub fn place(&mut self, du: &str, pd: &str) -> anyhow::Result<()> {
        match self.try_place(du, pd)? {
            PlaceOutcome::Placed { .. } => Ok(()),
            PlaceOutcome::NoCapacity => {
                anyhow::bail!("no capacity for '{du}' on '{pd}'")
            }
        }
    }

    /// Quota-checked placement (see the module docs' capacity model).
    /// Idempotent: re-placing a resident replica just touches its LRU
    /// slot. Eviction victims are chosen in LRU order, skipping pinned
    /// replicas and last replicas; feasibility is decided *before* the
    /// first eviction, so a rejected placement evicts nothing.
    pub fn try_place(&mut self, du: &str, pd: &str) -> anyhow::Result<PlaceOutcome> {
        let quota = self
            .pds
            .get(pd)
            .ok_or_else(|| anyhow::anyhow!("unknown pilot-data '{pd}'"))?
            .quota;
        let (size, _) = self
            .du_meta
            .get(du)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("register_du('{du}') before place"))?;
        if self.down.contains(pd) {
            return Ok(PlaceOutcome::NoCapacity);
        }
        if self.has_replica(du, pd) {
            self.touch(du, pd);
            return Ok(PlaceOutcome::Placed { evicted: Vec::new() });
        }
        let mut evicted = Vec::new();
        if let Some(q) = quota {
            let used = self.used(pd);
            let need = size.as_u64();
            if used.as_u64() + need > q.as_u64() {
                // Feasibility first: can legal evictions ever make room?
                let evictable: u64 = self
                    .dus_on(pd)
                    .iter()
                    .filter(|d| self.evictable(d.as_str(), pd))
                    .map(|d| self.du_meta[d.as_str()].0.as_u64())
                    .sum();
                if used.as_u64().saturating_sub(evictable) + need > q.as_u64() {
                    return Ok(PlaceOutcome::NoCapacity);
                }
                while self.used(pd).as_u64() + need > q.as_u64() {
                    // Coldest legal victim. The feasibility check above
                    // guarantees one exists until the newcomer fits.
                    let victim = self
                        .dus_on(pd)
                        .into_iter()
                        .find(|d| self.evictable(d.as_str(), pd))
                        .expect("feasibility checked before evicting");
                    self.evict(&victim, pd);
                    evicted.push((victim, pd.to_string()));
                }
            }
        }
        self.replicas.entry(du.to_string()).or_default().insert(pd.to_string());
        *self.used.entry(pd.to_string()).or_insert(0) += size.as_u64();
        self.lru.entry(pd.to_string()).or_default().push(du.to_string());
        Ok(PlaceOutcome::Placed { evicted })
    }

    /// May this replica be removed under capacity pressure? Pinned
    /// replicas and the last replica of a DU are protected.
    fn evictable(&self, du: &str, pd: &str) -> bool {
        !self.is_pinned(du, pd) && self.replica_count(du) > 1
    }

    /// Could `size` bytes be placed on `pd` right now, evicting if
    /// legal? (Policy-side capacity probe; does not mutate.)
    pub fn can_fit(&self, pd: &str, size: Bytes) -> bool {
        if self.down.contains(pd) {
            return false;
        }
        let Some(p) = self.pds.get(pd) else { return false };
        let Some(q) = p.quota else { return true };
        let evictable: u64 = self
            .dus_on(pd)
            .iter()
            .filter(|d| self.evictable(d, pd))
            .map(|d| self.du_meta[d.as_str()].0.as_u64())
            .sum();
        self.used(pd).as_u64().saturating_sub(evictable) + size.as_u64() <= q.as_u64()
    }

    /// Forced replica removal (storage outage, tests): bypasses the
    /// pin/last-replica protections of capacity eviction and keeps the
    /// byte accounting consistent.
    pub fn evict(&mut self, du: &str, pd: &str) {
        let was_present = self
            .replicas
            .get_mut(du)
            .map(|set| set.remove(pd))
            .unwrap_or(false);
        if was_present {
            let size = self.du_meta.get(du).map(|(s, _)| s.as_u64()).unwrap_or(0);
            if let Some(u) = self.used.get_mut(pd) {
                *u = u.saturating_sub(size);
            }
            if let Some(order) = self.lru.get_mut(pd) {
                order.retain(|d| d != du);
            }
            self.pinned.remove(&(du.to_string(), pd.to_string()));
        }
    }

    pub fn replicas(&self, du: &str) -> Vec<&SimPd> {
        self.replicas
            .get(du)
            .map(|set| set.iter().filter_map(|n| self.pds.get(n)).collect())
            .unwrap_or_default()
    }

    pub fn has_replica(&self, du: &str, pd: &str) -> bool {
        self.replicas.get(du).map(|s| s.contains(pd)).unwrap_or(false)
    }

    /// The replica of `du` closest (max affinity) to `target`, if any —
    /// this is the paper's "optimized replication mechanism, which
    /// utilizes the replica closest to the target site".
    ///
    /// On a [`SimStore::heterogeneous`] store the ranking is
    /// price-aware: affinity still dominates (it is the transfer-cost
    /// proxy — closer means a cheaper path walk), but equal-affinity
    /// sources break ties toward the device with the lower penalty
    /// (`fixed_latency_s` + device wire time + [`DOLLAR_WEIGHT_S`] ×
    /// egress dollars), so a free node-local copy beats an equally
    /// close object-store copy. Homogeneous stores take the seed
    /// ranking verbatim.
    pub fn closest_replica(
        &self,
        topo: &crate::topology::Topology,
        du: &str,
        target: &Label,
    ) -> Option<&SimPd> {
        if self.heterogeneous() {
            let size = self.du_meta.get(du).map(|(s, _)| *s).unwrap_or(Bytes(0));
            let penalty = |p: &SimPd| {
                let prof = &p.profile;
                let mut s = prof.fixed_latency_s + DOLLAR_WEIGHT_S * prof.dollars_for(size.as_u64());
                if let Some(cap) = prof.bandwidth_cap {
                    s += size.as_f64() / cap.max(1e-6);
                }
                s
            };
            return self.replicas(du).into_iter().min_by(|a, b| {
                let ka = (-topo.affinity_interned(target, &a.endpoint.label), penalty(a));
                let kb = (-topo.affinity_interned(target, &b.endpoint.label), penalty(b));
                ka.partial_cmp(&kb).unwrap_or(std::cmp::Ordering::Equal)
            });
        }
        self.replicas(du)
            .into_iter()
            .max_by(|a, b| {
                topo.affinity_interned(target, &a.endpoint.label)
                    .partial_cmp(&topo.affinity_interned(target, &b.endpoint.label))
                    .unwrap()
            })
    }

    /// Cost of staging `du` from `src_pd` into `dst_pd` right now.
    pub fn staging_cost(
        &self,
        net: &Network,
        du: &str,
        src_pd: &str,
        dst_pd: &str,
        via: Option<&Label>,
    ) -> anyhow::Result<TransferCost> {
        let (size, files) = self.du_meta(du)?;
        let src = self.pd(src_pd)?;
        let dst = self.pd(dst_pd)?;
        // The destination's protocol governs the transfer mechanics.
        let cost = transfer_cost(
            net,
            &src.endpoint.label,
            &dst.endpoint.label,
            via,
            &dst.endpoint.params,
            size,
            files,
        );
        if self.heterogeneous() {
            return Ok(profile_adjust(cost, &src.profile, &dst.profile, size));
        }
        Ok(cost)
    }

    /// [`SimStore::staging_cost`] that also registers the src→dst wire
    /// flow, in one path walk (see [`transfer_cost_flow`]) — the
    /// sim driver's transfer-start fast path. Endpoint labels intern
    /// into the network's arena (O(1) after first sight).
    pub fn staging_cost_flow(
        &self,
        net: &mut Network,
        du: &str,
        src_pd: &str,
        dst_pd: &str,
        via: Option<&Label>,
    ) -> anyhow::Result<(TransferCost, FlowHandle)> {
        let (size, files) = self.du_meta(du)?;
        let src = self.pd(src_pd)?;
        let dst = self.pd(dst_pd)?;
        let s = net.node(&src.endpoint.label);
        let d = net.node(&dst.endpoint.label);
        let v = via.map(|l| net.node(l));
        let (cost, flow) = transfer_cost_flow(net, s, d, v, &dst.endpoint.params, size, files);
        if self.heterogeneous() {
            return Ok((profile_adjust(cost, &src.profile, &dst.profile, size), flow));
        }
        Ok((cost, flow))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Bandwidth;
    use crate::storage::BackendKind;
    use crate::topology::Topology;

    fn store_with(names: &[(&str, &str, &str)]) -> SimStore {
        let mut s = SimStore::new();
        for (name, url, label) in names {
            s.add_pd(name, Endpoint::new(url, label).unwrap());
        }
        s
    }

    #[test]
    fn place_and_lookup_replicas() {
        let mut s = store_with(&[
            ("pd-ls", "ssh://lonestar/scratch", "xsede/tacc/lonestar"),
            ("pd-osg", "irods://fermilab/coll", "osg/fermilab"),
        ]);
        s.register_du("du-1", Bytes::gb(2), 8);
        s.place("du-1", "pd-ls").unwrap();
        s.place("du-1", "pd-osg").unwrap();
        assert_eq!(s.replicas("du-1").len(), 2);
        assert!(s.has_replica("du-1", "pd-ls"));
        s.evict("du-1", "pd-ls");
        assert!(!s.has_replica("du-1", "pd-ls"));
        assert!(s.place("du-unregistered", "pd-ls").is_err());
        assert!(s.place("du-1", "pd-nope").is_err());
    }

    #[test]
    fn closest_replica_uses_affinity() {
        let mut s = store_with(&[
            ("pd-ls", "ssh://lonestar/scratch", "xsede/tacc/lonestar"),
            ("pd-eu", "srm://surfsara/pool", "egi/surfsara"),
        ]);
        s.register_du("du-1", Bytes::gb(1), 1);
        s.place("du-1", "pd-ls").unwrap();
        s.place("du-1", "pd-eu").unwrap();
        let topo = Topology::new();
        let near = s
            .closest_replica(&topo, "du-1", &Label::new("xsede/tacc/stampede"))
            .unwrap();
        assert_eq!(near.name, "pd-ls");
    }

    #[test]
    fn third_party_vs_gateway_routing() {
        let mut net = Network::new();
        net.set_default_uplink(Bandwidth::mbps(100.0));
        let src = Label::new("osg/purdue");
        let dst = Label::new("xsede/tacc/lonestar");
        let gw = Label::new("xsede/iu/gw68");
        let srm = ProtocolParams::defaults(BackendKind::Srm);
        let ssh = ProtocolParams::defaults(BackendKind::Ssh);
        let direct = transfer_cost(&net, &src, &dst, Some(&gw), &srm, Bytes::gb(1), 1);
        let routed = transfer_cost(&net, &src, &dst, Some(&gw), &ssh, Bytes::gb(1), 1);
        // SSH (no third-party) pays two WAN legs; SRM one.
        assert!(routed.wire_s > 1.8 * direct.wire_s * (srm.efficiency / ssh.efficiency));
    }

    #[test]
    fn gateway_not_used_when_endpoint_is_gateway() {
        let net = Network::new();
        let gw = Label::new("xsede/iu/gw68");
        let dst = Label::new("osg/purdue");
        let ssh = ProtocolParams::defaults(BackendKind::Ssh);
        let c1 = transfer_cost(&net, &gw, &dst, Some(&gw), &ssh, Bytes::gb(1), 1);
        let c2 = transfer_cost(&net, &gw, &dst, None, &ssh, Bytes::gb(1), 1);
        assert_eq!(c1, c2);
    }

    #[test]
    fn staging_cost_uses_destination_protocol() {
        let mut s = store_with(&[
            ("pd-gw", "ssh://gw68/staging", "xsede/iu/gw68"),
            ("pd-srm", "srm://osg-pool/x", "osg/fermilab"),
        ]);
        s.register_du("du-1", Bytes::gb(4), 16);
        s.place("du-1", "pd-gw").unwrap();
        let net = Network::new();
        let c = s.staging_cost(&net, "du-1", "pd-gw", "pd-srm", None).unwrap();
        let srm = ProtocolParams::defaults(BackendKind::Srm);
        assert_eq!(c.setup_s, srm.setup_s + 16.0 * srm.per_file_s);
        assert!(c.wire_s > 0.0);
    }

    /// Satellite regression (single-walk transfer start): on random
    /// topologies and random transfer sequences, the combined
    /// [`transfer_cost_flow`] must produce bitwise-identical costs and
    /// the same live-flow state as the legacy two-step
    /// (`transfer_cost` then `begin_flow`) — including gateway-routed,
    /// loopback, and already-congested cases. This is what guarantees
    /// fig7/fig8 traces are unchanged by the refactor.
    #[test]
    fn combined_priced_staging_equals_two_step_property() {
        use crate::net::Bandwidth;
        crate::prop::check_default(
            |rng| {
                let mk = |rng: &mut crate::rng::Rng| {
                    let depth = crate::prop::gen::usize_in(rng, 1, 4);
                    let parts: Vec<String> =
                        (0..depth).map(|d| format!("h{}", rng.below(3 + d as u64))).collect();
                    parts.join("/")
                };
                let labels: Vec<String> =
                    (0..crate::prop::gen::usize_in(rng, 2, 6)).map(|_| mk(rng)).collect();
                let uplinks: Vec<(String, f64)> = (0..crate::prop::gen::usize_in(rng, 0, 5))
                    .map(|_| (mk(rng), rng.range_f64(1.0, 500.0)))
                    .collect();
                let n = labels.len();
                let transfers: Vec<(usize, usize, usize, bool, u64, u32, bool)> =
                    (0..crate::prop::gen::usize_in(rng, 1, 16))
                        .map(|_| {
                            (
                                rng.below(n as u64) as usize,       // src
                                rng.below(n as u64) as usize,       // dst
                                rng.below(n as u64) as usize,       // gateway
                                rng.chance(0.5),                    // route via gateway?
                                1 + rng.below(8),                   // GiB
                                1 + rng.below(16) as u32,           // files
                                rng.chance(0.3),                    // end an open flow first
                            )
                        })
                        .collect();
                (labels, uplinks, transfers)
            },
            |(labels, uplinks, transfers)| {
                let labels: Vec<Label> = labels.iter().map(|s| Label::new(s)).collect();
                // Two independently-evolving networks: A runs the legacy
                // two-step, B the combined walk.
                let setup = || {
                    let mut net = Network::new();
                    for (label, mb) in uplinks {
                        net.set_uplink(label, Bandwidth::mbps(*mb));
                    }
                    net
                };
                let mut net_a = setup();
                let mut net_b = setup();
                let kinds = BackendKind::all_simulated();
                let mut open_a = Vec::new();
                let mut open_b = Vec::new();
                for (k, (s, d, g, via, gb, files, end_first)) in transfers.iter().enumerate() {
                    if *end_first {
                        if let (Some(ha), Some(hb)) = (open_a.pop(), open_b.pop()) {
                            net_a.end_flow(&ha);
                            net_b.end_flow(&hb);
                        }
                    }
                    let params = ProtocolParams::defaults(kinds[k % kinds.len()]);
                    let (src, dst, gw) = (&labels[*s], &labels[*d], &labels[*g]);
                    let via = if *via { Some(gw) } else { None };
                    let size = Bytes::gb(*gb);
                    // Legacy: price, then register (seed order).
                    let cost_a = transfer_cost(&net_a, src, dst, via, &params, size, *files);
                    open_a.push(net_a.begin_flow(src, dst));
                    // Combined: one walk.
                    let (si, di) = (net_b.node(src), net_b.node(dst));
                    let vi = via.map(|l| net_b.node(l));
                    let (cost_b, hb) =
                        transfer_cost_flow(&mut net_b, si, di, vi, &params, size, *files);
                    open_b.push(hb);
                    if cost_a != cost_b {
                        return Err(format!(
                            "transfer {k} {src}->{dst} via {via:?}: {cost_a:?} != {cost_b:?}"
                        ));
                    }
                    // Live congestion agrees after every transfer.
                    if net_a.congestion(src, dst) != net_b.congestion_id(si, di) {
                        return Err(format!("congestion after transfer {k} diverges"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Id-keyed [`transfer_cost_id`] equals both the label shim and the
    /// retained seed engine, bitwise, on the calibrated testbed pairs.
    #[test]
    fn transfer_cost_id_matches_string_and_reference() {
        use crate::net::reference::StringNetwork;
        use crate::net::Bandwidth;
        let mut net = Network::new();
        let mut sref = StringNetwork::new();
        for (label, mb) in [("xsede", 1200.0), ("xsede/tacc", 800.0), ("osg", 600.0)] {
            net.set_uplink(label, Bandwidth::mbps(mb));
            sref.set_uplink(label, Bandwidth::mbps(mb));
        }
        let src = Label::new("xsede/tacc/lonestar");
        let dst = Label::new("osg/purdue");
        let gw = Label::new("xsede/iu/gw68");
        let (si, di, gi) = (net.node(&src), net.node(&dst), net.node(&gw));
        for kind in BackendKind::all_simulated() {
            let p = ProtocolParams::defaults(kind);
            for via in [None, Some(&gw)] {
                let vi = via.map(|_| gi);
                let a = transfer_cost(&net, &src, &dst, via, &p, Bytes::gb(2), 8);
                let b = transfer_cost_id(&mut net, si, di, vi, &p, Bytes::gb(2), 8);
                let c = transfer_cost_reference(&sref, &src, &dst, via, &p, Bytes::gb(2), 8);
                assert_eq!(a, b, "{kind:?} via={via:?}");
                assert_eq!(a, c, "{kind:?} via={via:?} (reference)");
            }
        }
    }

    #[test]
    fn staging_cost_flow_prices_and_registers_once() {
        let mut s = store_with(&[
            ("pd-gw", "ssh://gw68/staging", "xsede/iu/gw68"),
            ("pd-srm", "srm://osg-pool/x", "osg/fermilab"),
        ]);
        s.register_du("du-1", Bytes::gb(4), 16);
        s.place("du-1", "pd-gw").unwrap();
        let mut net = Network::new();
        let plain = s.staging_cost(&net, "du-1", "pd-gw", "pd-srm", None).unwrap();
        let (cost, flow) =
            s.staging_cost_flow(&mut net, "du-1", "pd-gw", "pd-srm", None).unwrap();
        assert_eq!(plain, cost, "combined walk must price like the two-step");
        let (a, b) = (
            net.node(&Label::new("xsede/iu/gw68")),
            net.node(&Label::new("osg/fermilab")),
        );
        assert_eq!(net.congestion_id(a, b), 1, "flow must be registered");
        net.end_flow(&flow);
        assert_eq!(net.congestion_id(a, b), 0);
        assert!(s.staging_cost_flow(&mut net, "du-nope", "pd-gw", "pd-srm", None).is_err());
    }

    #[test]
    fn any_quota_counter_tracks_set_clear_and_readd() {
        let mut s = store_with(&[
            ("pd-a", "ssh://a/scratch", "xsede/tacc/lonestar"),
            ("pd-b", "ssh://b/scratch", "xsede/tacc/stampede"),
        ]);
        assert!(!s.any_quota());
        s.set_quota("pd-a", Some(Bytes::gb(5))).unwrap();
        assert!(s.any_quota());
        s.set_quota("pd-a", Some(Bytes::gb(7))).unwrap(); // Some→Some: no double count
        s.set_quota("pd-b", Some(Bytes::gb(1))).unwrap();
        s.set_quota("pd-a", None).unwrap();
        assert!(s.any_quota(), "pd-b still bounded");
        // Re-registering a quota'd PD replaces it quota-less.
        s.add_pd("pd-b", Endpoint::new("ssh://b/scratch", "xsede/tacc/stampede").unwrap());
        assert!(!s.any_quota());
        s.set_quota("pd-a", None).unwrap(); // None→None: stays balanced
        assert!(!s.any_quota());
    }

    #[test]
    fn quota_evicts_in_lru_order() {
        let mut s = store_with(&[
            ("pd-a", "ssh://a/scratch", "xsede/tacc/lonestar"),
            ("pd-b", "ssh://b/scratch", "xsede/tacc/stampede"),
        ]);
        s.set_quota("pd-a", Some(Bytes::gb(5))).unwrap();
        for (du, gb) in [("du-1", 2), ("du-2", 2), ("du-3", 2)] {
            s.register_du(du, Bytes::gb(gb), 1);
            // Second replicas on pd-b so du-1/du-2 are legal victims.
            s.place(du, "pd-b").unwrap();
        }
        s.place("du-1", "pd-a").unwrap();
        s.place("du-2", "pd-a").unwrap();
        assert_eq!(s.used("pd-a"), Bytes::gb(4));
        // Touch du-1: du-2 becomes the coldest and must be the victim.
        s.touch("du-1", "pd-a");
        match s.try_place("du-3", "pd-a").unwrap() {
            PlaceOutcome::Placed { evicted } => {
                assert_eq!(evicted, vec![("du-2".to_string(), "pd-a".to_string())]);
            }
            PlaceOutcome::NoCapacity => panic!("eviction should have made room"),
        }
        assert!(s.has_replica("du-1", "pd-a"));
        assert!(!s.has_replica("du-2", "pd-a"));
        assert!(s.has_replica("du-3", "pd-a"));
        assert!(s.used("pd-a").as_u64() <= Bytes::gb(5).as_u64());
        assert_eq!(s.free_space("pd-a"), Some(Bytes::gb(1)));
    }

    #[test]
    fn pinned_and_last_replicas_survive_pressure() {
        let mut s = store_with(&[
            ("pd-a", "ssh://a/scratch", "xsede/tacc/lonestar"),
            ("pd-b", "ssh://b/scratch", "xsede/tacc/stampede"),
        ]);
        s.set_quota("pd-a", Some(Bytes::gb(4))).unwrap();
        s.register_du("du-last", Bytes::gb(2), 1); // only replica lives on pd-a
        s.register_du("du-pin", Bytes::gb(2), 1);
        s.register_du("du-new", Bytes::gb(2), 1);
        s.place("du-last", "pd-a").unwrap();
        s.place("du-pin", "pd-b").unwrap();
        s.place("du-pin", "pd-a").unwrap();
        s.pin("du-pin", "pd-a").unwrap();
        s.place("du-new", "pd-b").unwrap();
        // Both residents are protected: last replica + pinned.
        assert_eq!(s.try_place("du-new", "pd-a").unwrap(), PlaceOutcome::NoCapacity);
        assert!(s.has_replica("du-last", "pd-a"), "last replica must survive");
        assert!(s.has_replica("du-pin", "pd-a"), "pinned replica must survive");
        assert_eq!(s.used("pd-a"), Bytes::gb(4), "rejected placement must not evict");
        // Unpinning makes du-pin a legal victim (it has a pd-b copy).
        s.unpin("du-pin", "pd-a");
        assert!(matches!(
            s.try_place("du-new", "pd-a").unwrap(),
            PlaceOutcome::Placed { .. }
        ));
        assert!(!s.has_replica("du-pin", "pd-a"));
        // A DU larger than the whole quota can never fit.
        s.register_du("du-huge", Bytes::gb(16), 1);
        s.place("du-huge", "pd-b").unwrap();
        assert_eq!(s.try_place("du-huge", "pd-a").unwrap(), PlaceOutcome::NoCapacity);
    }

    #[test]
    fn down_pd_rejects_placements_and_recovers() {
        let mut s = store_with(&[("pd-a", "ssh://a/x", "osg/a"), ("pd-b", "ssh://b/x", "osg/b")]);
        s.register_du("du-1", Bytes::gb(1), 1);
        s.set_pd_down("pd-a", true);
        assert!(s.pd_is_down("pd-a"));
        assert!(!s.can_fit("pd-a", Bytes::b(1)));
        assert_eq!(s.try_place("du-1", "pd-a").unwrap(), PlaceOutcome::NoCapacity);
        s.set_pd_down("pd-a", false);
        assert!(matches!(s.try_place("du-1", "pd-a").unwrap(), PlaceOutcome::Placed { .. }));
    }

    /// ISSUE 5 satellite: capacity/eviction invariants under randomized
    /// workloads — after every operation, `used(pd)` equals the sum of
    /// resident replica sizes and never exceeds the quota; capacity
    /// eviction never removes a pinned replica and never removes the
    /// last replica of a DU (forced `evict` is excluded by
    /// construction: the property only drives `try_place`).
    #[test]
    fn capacity_invariants_property() {
        crate::prop::check_default(
            |rng| {
                let n_pds = crate::prop::gen::usize_in(rng, 1, 4);
                // Third element: device profile (0 = uniform, 1 =
                // object-store, 2 = node-local) — the invariants must
                // hold on heterogeneous stores too (ISSUE 10: cost-
                // ranked placement never evicts a pinned/last replica).
                let pds: Vec<(String, Option<u64>, u8)> = (0..n_pds)
                    .map(|i| {
                        (
                            format!("pd-{i}"),
                            if rng.chance(0.7) { Some(2 + rng.below(8)) } else { None },
                            rng.below(3) as u8,
                        )
                    })
                    .collect();
                let n_dus = crate::prop::gen::usize_in(rng, 1, 6);
                let dus: Vec<(String, u64)> =
                    (0..n_dus).map(|i| (format!("du-{i}"), 1 + rng.below(4))).collect();
                let n_ops = crate::prop::gen::usize_in(rng, 1, 40);
                // op: (kind, du index, pd index) — kind 0..=2:
                // try_place / touch / pin-toggle.
                let ops: Vec<(u8, usize, usize)> = (0..n_ops)
                    .map(|_| {
                        (
                            rng.below(3) as u8,
                            rng.below(n_dus as u64) as usize,
                            rng.below(n_pds as u64) as usize,
                        )
                    })
                    .collect();
                (pds, dus, ops)
            },
            |(pds, dus, ops)| {
                let mut s = SimStore::new();
                for (name, quota, prof) in pds {
                    s.add_pd(name, Endpoint::new(&format!("ssh://{name}/x"), "osg/a").unwrap());
                    s.set_quota(name, (*quota).map(Bytes::gb)).unwrap();
                    let profile = match prof {
                        1 => crate::storage::BackendProfile::object_store(),
                        2 => crate::storage::BackendProfile::node_local(),
                        _ => crate::storage::BackendProfile::default(),
                    };
                    s.set_profile(name, profile).unwrap();
                }
                for (du, gb) in dus {
                    s.register_du(du, Bytes::gb(*gb), 1);
                }
                let check = |s: &SimStore, when: &str| -> Result<(), String> {
                    for (pd, quota, _) in pds {
                        let resident: u64 = dus
                            .iter()
                            .filter(|(du, _)| s.has_replica(du, pd))
                            .map(|(_, gb)| Bytes::gb(*gb).as_u64())
                            .sum();
                        if s.used(pd).as_u64() != resident {
                            return Err(format!(
                                "{when}: used({pd})={} != resident {resident}",
                                s.used(pd).as_u64()
                            ));
                        }
                        if let Some(q) = quota {
                            if resident > Bytes::gb(*q).as_u64() {
                                return Err(format!("{when}: {pd} over quota"));
                            }
                        }
                    }
                    Ok(())
                };
                for (i, (kind, di, pi)) in ops.iter().enumerate() {
                    let du = &dus[*di].0;
                    let pd = &pds[*pi].0;
                    match kind {
                        0 => {
                            let mut pinned_before: Vec<(String, String)> = Vec::new();
                            for (d, _) in dus.iter() {
                                for (p, _, _) in pds.iter() {
                                    if s.is_pinned(d, p) {
                                        pinned_before.push((d.clone(), p.clone()));
                                    }
                                }
                            }
                            let last_before: Vec<String> = dus
                                .iter()
                                .filter(|(d, _)| s.replica_count(d.as_str()) == 1)
                                .map(|(d, _)| d.clone())
                                .collect();
                            match s.try_place(du, pd).map_err(|e| e.to_string())? {
                                PlaceOutcome::Placed { evicted } => {
                                    for (ed, ep) in &evicted {
                                        if pinned_before.contains(&(ed.clone(), ep.clone())) {
                                            return Err(format!(
                                                "op {i}: pinned ({ed},{ep}) evicted"
                                            ));
                                        }
                                    }
                                    for d in &last_before {
                                        if s.replica_count(d) == 0 {
                                            return Err(format!(
                                                "op {i}: last replica of {d} evicted"
                                            ));
                                        }
                                    }
                                }
                                PlaceOutcome::NoCapacity => {}
                            }
                            // Placement never drops any DU to zero
                            // replicas, placed or not.
                            for (d, _) in dus.iter() {
                                if last_before.contains(d) && s.replica_count(d) == 0 {
                                    return Err(format!("op {i}: {d} lost its only replica"));
                                }
                            }
                        }
                        1 => s.touch(du, pd),
                        _ => {
                            if s.is_pinned(du, pd) {
                                s.unpin(du, pd);
                            } else {
                                let _ = s.pin(du, pd);
                            }
                        }
                    }
                    check(&s, &format!("after op {i}"))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn profile_counter_tracks_set_clear_and_readd() {
        use crate::storage::BackendProfile;
        let mut s = store_with(&[
            ("pd-a", "ssh://a/scratch", "xsede/tacc/lonestar"),
            ("pd-b", "ssh://b/scratch", "xsede/tacc/stampede"),
        ]);
        assert!(!s.heterogeneous());
        s.set_profile("pd-a", BackendProfile::object_store()).unwrap();
        assert!(s.heterogeneous());
        s.set_profile("pd-a", BackendProfile::node_local()).unwrap(); // non-uniform→non-uniform
        s.set_profile("pd-b", BackendProfile::object_store()).unwrap();
        s.set_profile("pd-a", BackendProfile::default()).unwrap();
        assert!(s.heterogeneous(), "pd-b still priced");
        // Re-registering a priced PD resets it to the uniform default.
        s.add_pd("pd-b", Endpoint::new("ssh://b/scratch", "xsede/tacc/stampede").unwrap());
        assert!(!s.heterogeneous());
        s.set_profile("pd-a", BackendProfile::parallel_fs()).unwrap(); // uniform→uniform
        assert!(!s.heterogeneous());
        assert!(s.set_profile("pd-nope", BackendProfile::node_local()).is_err());
    }

    #[test]
    fn uniform_profiles_price_identically_to_the_seed_path() {
        use crate::storage::BackendProfile;
        let mut s = store_with(&[
            ("pd-gw", "ssh://gw68/staging", "xsede/iu/gw68"),
            ("pd-srm", "srm://osg-pool/x", "osg/fermilab"),
        ]);
        s.register_du("du-1", Bytes::gb(4), 16);
        s.place("du-1", "pd-gw").unwrap();
        let net = Network::new();
        let before = s.staging_cost(&net, "du-1", "pd-gw", "pd-srm", None).unwrap();
        // Explicitly setting the uniform default on every PD keeps the
        // store homogeneous: costs stay bitwise identical.
        s.set_profile("pd-gw", BackendProfile::parallel_fs()).unwrap();
        s.set_profile("pd-srm", BackendProfile::default()).unwrap();
        assert!(!s.heterogeneous());
        let after = s.staging_cost(&net, "du-1", "pd-gw", "pd-srm", None).unwrap();
        assert_eq!(before, after);
        assert_eq!(s.transfer_dollars("pd-gw", "pd-srm", Bytes::gb(4).as_u64()), 0.0);
    }

    #[test]
    fn heterogeneous_profiles_add_latency_cap_and_dollars() {
        use crate::storage::BackendProfile;
        let mut s = store_with(&[
            ("pd-gw", "ssh://gw68/staging", "xsede/iu/gw68"),
            ("pd-srm", "srm://osg-pool/x", "osg/fermilab"),
        ]);
        s.register_du("du-1", Bytes::gb(4), 16);
        s.place("du-1", "pd-gw").unwrap();
        let net = Network::new();
        let base = s.staging_cost(&net, "du-1", "pd-gw", "pd-srm", None).unwrap();
        s.set_profile("pd-gw", BackendProfile::object_store()).unwrap();
        let priced = s.staging_cost(&net, "du-1", "pd-gw", "pd-srm", None).unwrap();
        // Latency lands in setup once per attempt…
        let os = BackendProfile::object_store();
        assert!((priced.setup_s - base.setup_s - os.fixed_latency_s).abs() < 1e-12);
        // …and the device cap floors the wire time (min() with the
        // uplink walk: the slower of path and device governs).
        let device_floor = Bytes::gb(4).as_f64() / os.bandwidth_cap.unwrap();
        assert!((priced.wire_s - base.wire_s.max(device_floor)).abs() < 1e-9);
        // The combined flow path prices identically.
        let mut net2 = Network::new();
        let (flow_cost, _h) =
            s.staging_cost_flow(&mut net2, "du-1", "pd-gw", "pd-srm", None).unwrap();
        assert_eq!(priced, flow_cost);
        // Dollars: only the object-store side charges.
        let d = s.transfer_dollars("pd-gw", "pd-srm", Bytes::gb(4).as_u64());
        assert!((d - os.cost_per_gb * 4.0).abs() < 1e-12);
    }

    #[test]
    fn priced_closest_replica_prefers_cheap_equally_close_sources() {
        use crate::storage::BackendProfile;
        // Two replicas at the same affinity distance from the target;
        // the object-store copy is billed, the node-local one free.
        let mut s = store_with(&[
            ("pd-s3", "s3://bucket/x", "aws/us-east"),
            ("pd-nl", "ssh://node/x", "osg/purdue"),
        ]);
        s.register_du("du-1", Bytes::gb(2), 1);
        s.place("du-1", "pd-s3").unwrap();
        s.place("du-1", "pd-nl").unwrap();
        s.set_profile("pd-s3", BackendProfile::object_store()).unwrap();
        s.set_profile("pd-nl", BackendProfile::node_local()).unwrap();
        let topo = Topology::new();
        // Target at a third site: both replicas are equally distant
        // (disjoint label trees), so the price penalty decides.
        let near = s
            .closest_replica(&topo, "du-1", &Label::new("xsede/tacc/lonestar"))
            .unwrap();
        assert_eq!(near.name, "pd-nl", "free node-local copy must win the tie");
        // Affinity still dominates price: move the target next to the
        // expensive copy and it wins anyway.
        let near = s.closest_replica(&topo, "du-1", &Label::new("aws/us-east")).unwrap();
        assert_eq!(near.name, "pd-s3");
    }

    #[test]
    fn groups_validate_members() {
        let mut s = store_with(&[
            ("a", "irods://a/c", "osg/a"),
            ("b", "irods://b/c", "osg/b"),
        ]);
        assert!(s.define_group("osgGridFtpGroup", &["a", "b"]).is_ok());
        assert!(s.define_group("bad", &["a", "missing"]).is_err());
        assert_eq!(s.group_members("osgGridFtpGroup").unwrap().len(), 2);
        assert!(s.group_members("nope").is_err());
    }
}
