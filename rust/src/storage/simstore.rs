//! Simulated storage state: which Data-Unit replicas reside on which
//! Pilot-Data endpoints, plus the transfer cost model combining the
//! protocol parameters with the shared network.
//!
//! Transfers that involve a protocol without third-party support are
//! routed through the submission machine (the paper stages via GW68,
//! the XSEDE gateway at Indiana University), doubling the path: this is
//! exactly why naive data management in Fig. 9 scenarios 1–2 is slow.

use super::{Endpoint, ProtocolParams};
use crate::net::{Bandwidth, FlowHandle, Network};
use crate::topology::{Label, NodeId};
use crate::util::Bytes;
use std::collections::{BTreeMap, BTreeSet};

/// Cost breakdown of one transfer (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferCost {
    pub setup_s: f64,
    pub wire_s: f64,
    pub register_s: f64,
}

impl TransferCost {
    pub fn total(&self) -> f64 {
        self.setup_s + self.wire_s + self.register_s
    }
}

/// One wire leg at a sampled fair-share bandwidth: effective rate =
/// min(network share × protocol efficiency, the protocol's
/// single-flow ceiling), floored away from zero. The single home of
/// the formula for all live cost paths (`transfer_cost_reference`
/// keeps its own frozen copy by design — it is the oracle).
fn leg_secs(params: &ProtocolParams, size: Bytes, bw: Bandwidth) -> f64 {
    let eff = params.efficiency.max(1e-6);
    let net_rate = bw.bytes_per_sec() * eff;
    size.as_f64() / net_rate.min(params.per_flow_cap).max(1e-6)
}

/// Compute the cost of moving `size` bytes in `files` files from
/// `src` to `dst` with protocol `params`, at current network
/// congestion. `via` is the submission host used when the protocol
/// cannot do third-party transfers and neither endpoint is the
/// submission host itself.
///
/// Label-keyed compat shim; hot paths use [`transfer_cost_id`] or the
/// combined [`transfer_cost_flow`].
pub fn transfer_cost(
    net: &Network,
    src: &Label,
    dst: &Label,
    via: Option<&Label>,
    params: &ProtocolParams,
    size: Bytes,
    files: u32,
) -> TransferCost {
    let setup_s = params.setup_s + params.per_file_s * files as f64;
    let leg = |a: &Label, b: &Label| leg_secs(params, size, net.effective_bandwidth(a, b));
    let wire_s = match via {
        Some(gw) if !params.third_party && src != gw && dst != gw && src != dst => {
            // Two legs through the gateway.
            leg(src, gw) + leg(gw, dst)
        }
        _ => leg(src, dst),
    };
    TransferCost { setup_s, wire_s, register_s: params.register_s }
}

/// [`transfer_cost`] over interned node ids: allocation-free post-memo
/// (`&mut` because first-seen paths are memoized into the network's
/// path table).
pub fn transfer_cost_id(
    net: &mut Network,
    src: NodeId,
    dst: NodeId,
    via: Option<NodeId>,
    params: &ProtocolParams,
    size: Bytes,
    files: u32,
) -> TransferCost {
    let setup_s = params.setup_s + params.per_file_s * files as f64;
    let leg = |net: &mut Network, a: NodeId, b: NodeId| {
        leg_secs(params, size, net.effective_bandwidth_id(a, b))
    };
    let wire_s = match via {
        Some(gw) if !params.third_party && src != gw && dst != gw && src != dst => {
            leg(net, src, gw) + leg(net, gw, dst)
        }
        _ => leg(net, src, dst),
    };
    TransferCost { setup_s, wire_s, register_s: params.register_s }
}

/// Price the transfer *and* register its src→dst flow in one path
/// walk ([`Network::begin_flow_priced_id`]) — the transfer-start fast
/// path. Numbers are identical to [`transfer_cost_id`] followed by
/// `begin_flow_id`: the bandwidth is sampled before the flow's own
/// increment lands. Gateway-routed transfers still price two legs (the
/// seed shape) but register only the direct src→dst flow, exactly as
/// the drivers always did.
pub fn transfer_cost_flow(
    net: &mut Network,
    src: NodeId,
    dst: NodeId,
    via: Option<NodeId>,
    params: &ProtocolParams,
    size: Bytes,
    files: u32,
) -> (TransferCost, FlowHandle) {
    let setup_s = params.setup_s + params.per_file_s * files as f64;
    let routed =
        matches!(via, Some(gw) if !params.third_party && src != gw && dst != gw && src != dst);
    let (wire_s, flow) = if routed {
        let gw = via.unwrap();
        let w = leg_secs(params, size, net.effective_bandwidth_id(src, gw))
            + leg_secs(params, size, net.effective_bandwidth_id(gw, dst));
        (w, net.begin_flow_id(src, dst))
    } else {
        let (flow, bw) = net.begin_flow_priced_id(src, dst);
        (leg_secs(params, size, bw), flow)
    };
    (TransferCost { setup_s, wire_s, register_s: params.register_s }, flow)
}

/// [`transfer_cost`] against the retained seed engine
/// ([`crate::net::reference::StringNetwork`]) — property-test oracle
/// and the `perf_micro` string baseline.
pub fn transfer_cost_reference(
    net: &crate::net::reference::StringNetwork,
    src: &Label,
    dst: &Label,
    via: Option<&Label>,
    params: &ProtocolParams,
    size: Bytes,
    files: u32,
) -> TransferCost {
    let setup_s = params.setup_s + params.per_file_s * files as f64;
    let eff = params.efficiency.max(1e-6);
    let leg = |a: &Label, b: &Label| {
        let net_rate = net.effective_bandwidth(a, b).bytes_per_sec() * eff;
        size.as_f64() / net_rate.min(params.per_flow_cap).max(1e-6)
    };
    let wire_s = match via {
        Some(gw) if !params.third_party && src != gw && dst != gw && src != dst => {
            leg(src, gw) + leg(gw, dst)
        }
        _ => leg(src, dst),
    };
    TransferCost { setup_s, wire_s, register_s: params.register_s }
}

/// A named Pilot-Data location in the simulation with its endpoint.
#[derive(Debug, Clone)]
pub struct SimPd {
    pub name: String,
    pub endpoint: Endpoint,
}

/// Registry of endpoints, DU replica placement, and iRODS-style
/// server-side replication groups.
#[derive(Debug, Default)]
pub struct SimStore {
    pds: BTreeMap<String, SimPd>,
    /// du id -> set of pd names holding a full replica.
    replicas: BTreeMap<String, BTreeSet<String>>,
    /// du id -> (size, file count).
    du_meta: BTreeMap<String, (Bytes, u32)>,
    /// replication group name -> member pd names (iRODS resource groups).
    groups: BTreeMap<String, Vec<String>>,
}

impl SimStore {
    pub fn new() -> SimStore {
        SimStore::default()
    }

    pub fn add_pd(&mut self, name: &str, endpoint: Endpoint) {
        self.pds.insert(name.to_string(), SimPd { name: name.to_string(), endpoint });
    }

    pub fn pd(&self, name: &str) -> anyhow::Result<&SimPd> {
        self.pds
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown pilot-data '{name}'"))
    }

    pub fn pds(&self) -> impl Iterator<Item = &SimPd> {
        self.pds.values()
    }

    pub fn define_group(&mut self, group: &str, members: &[&str]) -> anyhow::Result<()> {
        for m in members {
            self.pd(m)?;
        }
        self.groups
            .insert(group.to_string(), members.iter().map(|s| s.to_string()).collect());
        Ok(())
    }

    pub fn group_members(&self, group: &str) -> anyhow::Result<&[String]> {
        self.groups
            .get(group)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow::anyhow!("unknown replication group '{group}'"))
    }

    /// Record DU metadata on first placement.
    pub fn register_du(&mut self, du: &str, size: Bytes, files: u32) {
        self.du_meta.insert(du.to_string(), (size, files));
    }

    pub fn du_meta(&self, du: &str) -> anyhow::Result<(Bytes, u32)> {
        self.du_meta
            .get(du)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unknown data-unit '{du}'"))
    }

    /// Mark `pd` as holding a full replica of `du`.
    pub fn place(&mut self, du: &str, pd: &str) -> anyhow::Result<()> {
        self.pd(pd)?;
        if !self.du_meta.contains_key(du) {
            anyhow::bail!("register_du('{du}') before place");
        }
        self.replicas.entry(du.to_string()).or_default().insert(pd.to_string());
        Ok(())
    }

    pub fn evict(&mut self, du: &str, pd: &str) {
        if let Some(set) = self.replicas.get_mut(du) {
            set.remove(pd);
        }
    }

    pub fn replicas(&self, du: &str) -> Vec<&SimPd> {
        self.replicas
            .get(du)
            .map(|set| set.iter().filter_map(|n| self.pds.get(n)).collect())
            .unwrap_or_default()
    }

    pub fn has_replica(&self, du: &str, pd: &str) -> bool {
        self.replicas.get(du).map(|s| s.contains(pd)).unwrap_or(false)
    }

    /// The replica of `du` closest (max affinity) to `target`, if any —
    /// this is the paper's "optimized replication mechanism, which
    /// utilizes the replica closest to the target site".
    pub fn closest_replica(
        &self,
        topo: &crate::topology::Topology,
        du: &str,
        target: &Label,
    ) -> Option<&SimPd> {
        self.replicas(du)
            .into_iter()
            .max_by(|a, b| {
                topo.affinity_interned(target, &a.endpoint.label)
                    .partial_cmp(&topo.affinity_interned(target, &b.endpoint.label))
                    .unwrap()
            })
    }

    /// Cost of staging `du` from `src_pd` into `dst_pd` right now.
    pub fn staging_cost(
        &self,
        net: &Network,
        du: &str,
        src_pd: &str,
        dst_pd: &str,
        via: Option<&Label>,
    ) -> anyhow::Result<TransferCost> {
        let (size, files) = self.du_meta(du)?;
        let src = self.pd(src_pd)?;
        let dst = self.pd(dst_pd)?;
        // The destination's protocol governs the transfer mechanics.
        Ok(transfer_cost(
            net,
            &src.endpoint.label,
            &dst.endpoint.label,
            via,
            &dst.endpoint.params,
            size,
            files,
        ))
    }

    /// [`SimStore::staging_cost`] that also registers the src→dst wire
    /// flow, in one path walk (see [`transfer_cost_flow`]) — the
    /// sim driver's transfer-start fast path. Endpoint labels intern
    /// into the network's arena (O(1) after first sight).
    pub fn staging_cost_flow(
        &self,
        net: &mut Network,
        du: &str,
        src_pd: &str,
        dst_pd: &str,
        via: Option<&Label>,
    ) -> anyhow::Result<(TransferCost, FlowHandle)> {
        let (size, files) = self.du_meta(du)?;
        let src = self.pd(src_pd)?;
        let dst = self.pd(dst_pd)?;
        let s = net.node(&src.endpoint.label);
        let d = net.node(&dst.endpoint.label);
        let v = via.map(|l| net.node(l));
        Ok(transfer_cost_flow(net, s, d, v, &dst.endpoint.params, size, files))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Bandwidth;
    use crate::storage::BackendKind;
    use crate::topology::Topology;

    fn store_with(names: &[(&str, &str, &str)]) -> SimStore {
        let mut s = SimStore::new();
        for (name, url, label) in names {
            s.add_pd(name, Endpoint::new(url, label).unwrap());
        }
        s
    }

    #[test]
    fn place_and_lookup_replicas() {
        let mut s = store_with(&[
            ("pd-ls", "ssh://lonestar/scratch", "xsede/tacc/lonestar"),
            ("pd-osg", "irods://fermilab/coll", "osg/fermilab"),
        ]);
        s.register_du("du-1", Bytes::gb(2), 8);
        s.place("du-1", "pd-ls").unwrap();
        s.place("du-1", "pd-osg").unwrap();
        assert_eq!(s.replicas("du-1").len(), 2);
        assert!(s.has_replica("du-1", "pd-ls"));
        s.evict("du-1", "pd-ls");
        assert!(!s.has_replica("du-1", "pd-ls"));
        assert!(s.place("du-unregistered", "pd-ls").is_err());
        assert!(s.place("du-1", "pd-nope").is_err());
    }

    #[test]
    fn closest_replica_uses_affinity() {
        let mut s = store_with(&[
            ("pd-ls", "ssh://lonestar/scratch", "xsede/tacc/lonestar"),
            ("pd-eu", "srm://surfsara/pool", "egi/surfsara"),
        ]);
        s.register_du("du-1", Bytes::gb(1), 1);
        s.place("du-1", "pd-ls").unwrap();
        s.place("du-1", "pd-eu").unwrap();
        let topo = Topology::new();
        let near = s
            .closest_replica(&topo, "du-1", &Label::new("xsede/tacc/stampede"))
            .unwrap();
        assert_eq!(near.name, "pd-ls");
    }

    #[test]
    fn third_party_vs_gateway_routing() {
        let mut net = Network::new();
        net.set_default_uplink(Bandwidth::mbps(100.0));
        let src = Label::new("osg/purdue");
        let dst = Label::new("xsede/tacc/lonestar");
        let gw = Label::new("xsede/iu/gw68");
        let srm = ProtocolParams::defaults(BackendKind::Srm);
        let ssh = ProtocolParams::defaults(BackendKind::Ssh);
        let direct = transfer_cost(&net, &src, &dst, Some(&gw), &srm, Bytes::gb(1), 1);
        let routed = transfer_cost(&net, &src, &dst, Some(&gw), &ssh, Bytes::gb(1), 1);
        // SSH (no third-party) pays two WAN legs; SRM one.
        assert!(routed.wire_s > 1.8 * direct.wire_s * (srm.efficiency / ssh.efficiency));
    }

    #[test]
    fn gateway_not_used_when_endpoint_is_gateway() {
        let net = Network::new();
        let gw = Label::new("xsede/iu/gw68");
        let dst = Label::new("osg/purdue");
        let ssh = ProtocolParams::defaults(BackendKind::Ssh);
        let c1 = transfer_cost(&net, &gw, &dst, Some(&gw), &ssh, Bytes::gb(1), 1);
        let c2 = transfer_cost(&net, &gw, &dst, None, &ssh, Bytes::gb(1), 1);
        assert_eq!(c1, c2);
    }

    #[test]
    fn staging_cost_uses_destination_protocol() {
        let mut s = store_with(&[
            ("pd-gw", "ssh://gw68/staging", "xsede/iu/gw68"),
            ("pd-srm", "srm://osg-pool/x", "osg/fermilab"),
        ]);
        s.register_du("du-1", Bytes::gb(4), 16);
        s.place("du-1", "pd-gw").unwrap();
        let net = Network::new();
        let c = s.staging_cost(&net, "du-1", "pd-gw", "pd-srm", None).unwrap();
        let srm = ProtocolParams::defaults(BackendKind::Srm);
        assert_eq!(c.setup_s, srm.setup_s + 16.0 * srm.per_file_s);
        assert!(c.wire_s > 0.0);
    }

    /// Satellite regression (single-walk transfer start): on random
    /// topologies and random transfer sequences, the combined
    /// [`transfer_cost_flow`] must produce bitwise-identical costs and
    /// the same live-flow state as the legacy two-step
    /// (`transfer_cost` then `begin_flow`) — including gateway-routed,
    /// loopback, and already-congested cases. This is what guarantees
    /// fig7/fig8 traces are unchanged by the refactor.
    #[test]
    fn combined_priced_staging_equals_two_step_property() {
        use crate::net::Bandwidth;
        crate::prop::check_default(
            |rng| {
                let mk = |rng: &mut crate::rng::Rng| {
                    let depth = crate::prop::gen::usize_in(rng, 1, 4);
                    let parts: Vec<String> =
                        (0..depth).map(|d| format!("h{}", rng.below(3 + d as u64))).collect();
                    parts.join("/")
                };
                let labels: Vec<String> =
                    (0..crate::prop::gen::usize_in(rng, 2, 6)).map(|_| mk(rng)).collect();
                let uplinks: Vec<(String, f64)> = (0..crate::prop::gen::usize_in(rng, 0, 5))
                    .map(|_| (mk(rng), rng.range_f64(1.0, 500.0)))
                    .collect();
                let n = labels.len();
                let transfers: Vec<(usize, usize, usize, bool, u64, u32, bool)> =
                    (0..crate::prop::gen::usize_in(rng, 1, 16))
                        .map(|_| {
                            (
                                rng.below(n as u64) as usize,       // src
                                rng.below(n as u64) as usize,       // dst
                                rng.below(n as u64) as usize,       // gateway
                                rng.chance(0.5),                    // route via gateway?
                                1 + rng.below(8),                   // GiB
                                1 + rng.below(16) as u32,           // files
                                rng.chance(0.3),                    // end an open flow first
                            )
                        })
                        .collect();
                (labels, uplinks, transfers)
            },
            |(labels, uplinks, transfers)| {
                let labels: Vec<Label> = labels.iter().map(|s| Label::new(s)).collect();
                // Two independently-evolving networks: A runs the legacy
                // two-step, B the combined walk.
                let setup = || {
                    let mut net = Network::new();
                    for (label, mb) in uplinks {
                        net.set_uplink(label, Bandwidth::mbps(*mb));
                    }
                    net
                };
                let mut net_a = setup();
                let mut net_b = setup();
                let kinds = BackendKind::all_simulated();
                let mut open_a = Vec::new();
                let mut open_b = Vec::new();
                for (k, (s, d, g, via, gb, files, end_first)) in transfers.iter().enumerate() {
                    if *end_first {
                        if let (Some(ha), Some(hb)) = (open_a.pop(), open_b.pop()) {
                            net_a.end_flow(&ha);
                            net_b.end_flow(&hb);
                        }
                    }
                    let params = ProtocolParams::defaults(kinds[k % kinds.len()]);
                    let (src, dst, gw) = (&labels[*s], &labels[*d], &labels[*g]);
                    let via = if *via { Some(gw) } else { None };
                    let size = Bytes::gb(*gb);
                    // Legacy: price, then register (seed order).
                    let cost_a = transfer_cost(&net_a, src, dst, via, &params, size, *files);
                    open_a.push(net_a.begin_flow(src, dst));
                    // Combined: one walk.
                    let (si, di) = (net_b.node(src), net_b.node(dst));
                    let vi = via.map(|l| net_b.node(l));
                    let (cost_b, hb) =
                        transfer_cost_flow(&mut net_b, si, di, vi, &params, size, *files);
                    open_b.push(hb);
                    if cost_a != cost_b {
                        return Err(format!(
                            "transfer {k} {src}->{dst} via {via:?}: {cost_a:?} != {cost_b:?}"
                        ));
                    }
                    // Live congestion agrees after every transfer.
                    if net_a.congestion(src, dst) != net_b.congestion_id(si, di) {
                        return Err(format!("congestion after transfer {k} diverges"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Id-keyed [`transfer_cost_id`] equals both the label shim and the
    /// retained seed engine, bitwise, on the calibrated testbed pairs.
    #[test]
    fn transfer_cost_id_matches_string_and_reference() {
        use crate::net::reference::StringNetwork;
        use crate::net::Bandwidth;
        let mut net = Network::new();
        let mut sref = StringNetwork::new();
        for (label, mb) in [("xsede", 1200.0), ("xsede/tacc", 800.0), ("osg", 600.0)] {
            net.set_uplink(label, Bandwidth::mbps(mb));
            sref.set_uplink(label, Bandwidth::mbps(mb));
        }
        let src = Label::new("xsede/tacc/lonestar");
        let dst = Label::new("osg/purdue");
        let gw = Label::new("xsede/iu/gw68");
        let (si, di, gi) = (net.node(&src), net.node(&dst), net.node(&gw));
        for kind in BackendKind::all_simulated() {
            let p = ProtocolParams::defaults(kind);
            for via in [None, Some(&gw)] {
                let vi = via.map(|_| gi);
                let a = transfer_cost(&net, &src, &dst, via, &p, Bytes::gb(2), 8);
                let b = transfer_cost_id(&mut net, si, di, vi, &p, Bytes::gb(2), 8);
                let c = transfer_cost_reference(&sref, &src, &dst, via, &p, Bytes::gb(2), 8);
                assert_eq!(a, b, "{kind:?} via={via:?}");
                assert_eq!(a, c, "{kind:?} via={via:?} (reference)");
            }
        }
    }

    #[test]
    fn staging_cost_flow_prices_and_registers_once() {
        let mut s = store_with(&[
            ("pd-gw", "ssh://gw68/staging", "xsede/iu/gw68"),
            ("pd-srm", "srm://osg-pool/x", "osg/fermilab"),
        ]);
        s.register_du("du-1", Bytes::gb(4), 16);
        s.place("du-1", "pd-gw").unwrap();
        let mut net = Network::new();
        let plain = s.staging_cost(&net, "du-1", "pd-gw", "pd-srm", None).unwrap();
        let (cost, flow) =
            s.staging_cost_flow(&mut net, "du-1", "pd-gw", "pd-srm", None).unwrap();
        assert_eq!(plain, cost, "combined walk must price like the two-step");
        let (a, b) = (
            net.node(&Label::new("xsede/iu/gw68")),
            net.node(&Label::new("osg/fermilab")),
        );
        assert_eq!(net.congestion_id(a, b), 1, "flow must be registered");
        net.end_flow(&flow);
        assert_eq!(net.congestion_id(a, b), 0);
        assert!(s.staging_cost_flow(&mut net, "du-nope", "pd-gw", "pd-srm", None).is_err());
    }

    #[test]
    fn groups_validate_members() {
        let mut s = store_with(&[
            ("a", "irods://a/c", "osg/a"),
            ("b", "irods://b/c", "osg/b"),
        ]);
        assert!(s.define_group("osgGridFtpGroup", &["a", "b"]).is_ok());
        assert!(s.define_group("bad", &["a", "missing"]).is_err());
        assert_eq!(s.group_members("osgGridFtpGroup").unwrap().len(), 2);
        assert!(s.group_members("nope").is_err());
    }
}
