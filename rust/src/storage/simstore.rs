//! Simulated storage state: which Data-Unit replicas reside on which
//! Pilot-Data endpoints, plus the transfer cost model combining the
//! protocol parameters with the shared network.
//!
//! Transfers that involve a protocol without third-party support are
//! routed through the submission machine (the paper stages via GW68,
//! the XSEDE gateway at Indiana University), doubling the path: this is
//! exactly why naive data management in Fig. 9 scenarios 1–2 is slow.

use super::{Endpoint, ProtocolParams};
use crate::net::Network;
use crate::topology::Label;
use crate::util::Bytes;
use std::collections::{BTreeMap, BTreeSet};

/// Cost breakdown of one transfer (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferCost {
    pub setup_s: f64,
    pub wire_s: f64,
    pub register_s: f64,
}

impl TransferCost {
    pub fn total(&self) -> f64 {
        self.setup_s + self.wire_s + self.register_s
    }
}

/// Compute the cost of moving `size` bytes in `files` files from
/// `src` to `dst` with protocol `params`, at current network
/// congestion. `via` is the submission host used when the protocol
/// cannot do third-party transfers and neither endpoint is the
/// submission host itself.
pub fn transfer_cost(
    net: &Network,
    src: &Label,
    dst: &Label,
    via: Option<&Label>,
    params: &ProtocolParams,
    size: Bytes,
    files: u32,
) -> TransferCost {
    let setup_s = params.setup_s + params.per_file_s * files as f64;
    let eff = params.efficiency.max(1e-6);
    // One leg: effective rate = min(fair network share x protocol
    // efficiency, the protocol's single-flow ceiling).
    let leg = |a: &Label, b: &Label| {
        let net_rate = net.effective_bandwidth(a, b).bytes_per_sec() * eff;
        size.as_f64() / net_rate.min(params.per_flow_cap).max(1e-6)
    };
    let wire_s = match via {
        Some(gw) if !params.third_party && src != gw && dst != gw && src != dst => {
            // Two legs through the gateway.
            leg(src, gw) + leg(gw, dst)
        }
        _ => leg(src, dst),
    };
    TransferCost { setup_s, wire_s, register_s: params.register_s }
}

/// A named Pilot-Data location in the simulation with its endpoint.
#[derive(Debug, Clone)]
pub struct SimPd {
    pub name: String,
    pub endpoint: Endpoint,
}

/// Registry of endpoints, DU replica placement, and iRODS-style
/// server-side replication groups.
#[derive(Debug, Default)]
pub struct SimStore {
    pds: BTreeMap<String, SimPd>,
    /// du id -> set of pd names holding a full replica.
    replicas: BTreeMap<String, BTreeSet<String>>,
    /// du id -> (size, file count).
    du_meta: BTreeMap<String, (Bytes, u32)>,
    /// replication group name -> member pd names (iRODS resource groups).
    groups: BTreeMap<String, Vec<String>>,
}

impl SimStore {
    pub fn new() -> SimStore {
        SimStore::default()
    }

    pub fn add_pd(&mut self, name: &str, endpoint: Endpoint) {
        self.pds.insert(name.to_string(), SimPd { name: name.to_string(), endpoint });
    }

    pub fn pd(&self, name: &str) -> anyhow::Result<&SimPd> {
        self.pds
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown pilot-data '{name}'"))
    }

    pub fn pds(&self) -> impl Iterator<Item = &SimPd> {
        self.pds.values()
    }

    pub fn define_group(&mut self, group: &str, members: &[&str]) -> anyhow::Result<()> {
        for m in members {
            self.pd(m)?;
        }
        self.groups
            .insert(group.to_string(), members.iter().map(|s| s.to_string()).collect());
        Ok(())
    }

    pub fn group_members(&self, group: &str) -> anyhow::Result<&[String]> {
        self.groups
            .get(group)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow::anyhow!("unknown replication group '{group}'"))
    }

    /// Record DU metadata on first placement.
    pub fn register_du(&mut self, du: &str, size: Bytes, files: u32) {
        self.du_meta.insert(du.to_string(), (size, files));
    }

    pub fn du_meta(&self, du: &str) -> anyhow::Result<(Bytes, u32)> {
        self.du_meta
            .get(du)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unknown data-unit '{du}'"))
    }

    /// Mark `pd` as holding a full replica of `du`.
    pub fn place(&mut self, du: &str, pd: &str) -> anyhow::Result<()> {
        self.pd(pd)?;
        if !self.du_meta.contains_key(du) {
            anyhow::bail!("register_du('{du}') before place");
        }
        self.replicas.entry(du.to_string()).or_default().insert(pd.to_string());
        Ok(())
    }

    pub fn evict(&mut self, du: &str, pd: &str) {
        if let Some(set) = self.replicas.get_mut(du) {
            set.remove(pd);
        }
    }

    pub fn replicas(&self, du: &str) -> Vec<&SimPd> {
        self.replicas
            .get(du)
            .map(|set| set.iter().filter_map(|n| self.pds.get(n)).collect())
            .unwrap_or_default()
    }

    pub fn has_replica(&self, du: &str, pd: &str) -> bool {
        self.replicas.get(du).map(|s| s.contains(pd)).unwrap_or(false)
    }

    /// The replica of `du` closest (max affinity) to `target`, if any —
    /// this is the paper's "optimized replication mechanism, which
    /// utilizes the replica closest to the target site".
    pub fn closest_replica(
        &self,
        topo: &crate::topology::Topology,
        du: &str,
        target: &Label,
    ) -> Option<&SimPd> {
        self.replicas(du)
            .into_iter()
            .max_by(|a, b| {
                topo.affinity(target, &a.endpoint.label)
                    .partial_cmp(&topo.affinity(target, &b.endpoint.label))
                    .unwrap()
            })
    }

    /// Cost of staging `du` from `src_pd` into `dst_pd` right now.
    pub fn staging_cost(
        &self,
        net: &Network,
        du: &str,
        src_pd: &str,
        dst_pd: &str,
        via: Option<&Label>,
    ) -> anyhow::Result<TransferCost> {
        let (size, files) = self.du_meta(du)?;
        let src = self.pd(src_pd)?;
        let dst = self.pd(dst_pd)?;
        // The destination's protocol governs the transfer mechanics.
        Ok(transfer_cost(
            net,
            &src.endpoint.label,
            &dst.endpoint.label,
            via,
            &dst.endpoint.params,
            size,
            files,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Bandwidth;
    use crate::storage::BackendKind;
    use crate::topology::Topology;

    fn store_with(names: &[(&str, &str, &str)]) -> SimStore {
        let mut s = SimStore::new();
        for (name, url, label) in names {
            s.add_pd(name, Endpoint::new(url, label).unwrap());
        }
        s
    }

    #[test]
    fn place_and_lookup_replicas() {
        let mut s = store_with(&[
            ("pd-ls", "ssh://lonestar/scratch", "xsede/tacc/lonestar"),
            ("pd-osg", "irods://fermilab/coll", "osg/fermilab"),
        ]);
        s.register_du("du-1", Bytes::gb(2), 8);
        s.place("du-1", "pd-ls").unwrap();
        s.place("du-1", "pd-osg").unwrap();
        assert_eq!(s.replicas("du-1").len(), 2);
        assert!(s.has_replica("du-1", "pd-ls"));
        s.evict("du-1", "pd-ls");
        assert!(!s.has_replica("du-1", "pd-ls"));
        assert!(s.place("du-unregistered", "pd-ls").is_err());
        assert!(s.place("du-1", "pd-nope").is_err());
    }

    #[test]
    fn closest_replica_uses_affinity() {
        let mut s = store_with(&[
            ("pd-ls", "ssh://lonestar/scratch", "xsede/tacc/lonestar"),
            ("pd-eu", "srm://surfsara/pool", "egi/surfsara"),
        ]);
        s.register_du("du-1", Bytes::gb(1), 1);
        s.place("du-1", "pd-ls").unwrap();
        s.place("du-1", "pd-eu").unwrap();
        let topo = Topology::new();
        let near = s
            .closest_replica(&topo, "du-1", &Label::new("xsede/tacc/stampede"))
            .unwrap();
        assert_eq!(near.name, "pd-ls");
    }

    #[test]
    fn third_party_vs_gateway_routing() {
        let mut net = Network::new();
        net.set_default_uplink(Bandwidth::mbps(100.0));
        let src = Label::new("osg/purdue");
        let dst = Label::new("xsede/tacc/lonestar");
        let gw = Label::new("xsede/iu/gw68");
        let srm = ProtocolParams::defaults(BackendKind::Srm);
        let ssh = ProtocolParams::defaults(BackendKind::Ssh);
        let direct = transfer_cost(&net, &src, &dst, Some(&gw), &srm, Bytes::gb(1), 1);
        let routed = transfer_cost(&net, &src, &dst, Some(&gw), &ssh, Bytes::gb(1), 1);
        // SSH (no third-party) pays two WAN legs; SRM one.
        assert!(routed.wire_s > 1.8 * direct.wire_s * (srm.efficiency / ssh.efficiency));
    }

    #[test]
    fn gateway_not_used_when_endpoint_is_gateway() {
        let net = Network::new();
        let gw = Label::new("xsede/iu/gw68");
        let dst = Label::new("osg/purdue");
        let ssh = ProtocolParams::defaults(BackendKind::Ssh);
        let c1 = transfer_cost(&net, &gw, &dst, Some(&gw), &ssh, Bytes::gb(1), 1);
        let c2 = transfer_cost(&net, &gw, &dst, None, &ssh, Bytes::gb(1), 1);
        assert_eq!(c1, c2);
    }

    #[test]
    fn staging_cost_uses_destination_protocol() {
        let mut s = store_with(&[
            ("pd-gw", "ssh://gw68/staging", "xsede/iu/gw68"),
            ("pd-srm", "srm://osg-pool/x", "osg/fermilab"),
        ]);
        s.register_du("du-1", Bytes::gb(4), 16);
        s.place("du-1", "pd-gw").unwrap();
        let net = Network::new();
        let c = s.staging_cost(&net, "du-1", "pd-gw", "pd-srm", None).unwrap();
        let srm = ProtocolParams::defaults(BackendKind::Srm);
        assert_eq!(c.setup_s, srm.setup_s + 16.0 * srm.per_file_s);
        assert!(c.wire_s > 0.0);
    }

    #[test]
    fn groups_validate_members() {
        let mut s = store_with(&[
            ("a", "irods://a/c", "osg/a"),
            ("b", "irods://b/c", "osg/b"),
        ]);
        assert!(s.define_group("osgGridFtpGroup", &["a", "b"]).is_ok());
        assert!(s.define_group("bad", &["a", "missing"]).is_err());
        assert_eq!(s.group_members("osgGridFtpGroup").unwrap().len(), 2);
        assert!(s.group_members("nope").is_err());
    }
}
