//! Storage backends and adaptors.
//!
//! A Pilot-Data backend is defined by (i) the storage resource and
//! (ii) the access protocol to this storage (paper §4.2). The URL
//! scheme of the Pilot-Data-Description selects the adaptor, exactly as
//! in BigJob: `ssh://`, `srm://`, `irods://`, `go://` (Globus Online),
//! `s3://`, and `file://` for the real local-filesystem backend used in
//! local execution mode.
//!
//! Each simulated protocol carries a calibrated cost model
//! ([`ProtocolParams`]): connection/setup overhead, per-file overhead,
//! transfer efficiency relative to the raw network path, registration
//! time, and a failure probability. These parameters are what produce
//! the Fig. 7/8 orderings (SRM/GridFTP fastest, SSH cheap to start,
//! Globus Online amortizing its service overhead at volume, S3 limited
//! by the WAN uplink, iRODS ≈ SSH plus management overhead).

pub mod localfs;
pub mod simstore;

use crate::topology::Label;

/// The storage backend families of Table 1 / §2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum BackendKind {
    /// Plain directory reached over SSH/SCP.
    Ssh,
    /// SRM-managed pool accessed via GridFTP (dCache/StoRM-class).
    Srm,
    /// iRODS federated collections (server-side replication groups).
    Irods,
    /// Globus Online managed GridFTP transfers.
    GlobusOnline,
    /// Cloud object store (Amazon S3-class).
    S3,
    /// Real local filesystem (local execution mode).
    LocalFs,
}

impl BackendKind {
    pub fn scheme(self) -> &'static str {
        match self {
            BackendKind::Ssh => "ssh",
            BackendKind::Srm => "srm",
            BackendKind::Irods => "irods",
            BackendKind::GlobusOnline => "go",
            BackendKind::S3 => "s3",
            BackendKind::LocalFs => "file",
        }
    }

    pub fn from_scheme(s: &str) -> anyhow::Result<BackendKind> {
        Ok(match s {
            "ssh" => BackendKind::Ssh,
            "srm" | "gsiftp" | "gridftp" => BackendKind::Srm,
            "irods" => BackendKind::Irods,
            "go" | "globusonline" => BackendKind::GlobusOnline,
            "s3" => BackendKind::S3,
            "file" => BackendKind::LocalFs,
            other => anyhow::bail!("unknown storage scheme '{other}'"),
        })
    }

    pub fn all_simulated() -> [BackendKind; 5] {
        [
            BackendKind::Ssh,
            BackendKind::Srm,
            BackendKind::Irods,
            BackendKind::GlobusOnline,
            BackendKind::S3,
        ]
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendKind::Ssh => "SSH",
            BackendKind::Srm => "SRM/GridFTP",
            BackendKind::Irods => "iRODS",
            BackendKind::GlobusOnline => "Globus Online",
            BackendKind::S3 => "S3",
            BackendKind::LocalFs => "LocalFS",
        })
    }
}

/// Backend URL: `scheme://resource/path`, where `resource` maps to an
/// affinity label in the topology.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PdUrl {
    pub kind: BackendKind,
    pub resource: String,
    pub path: String,
}

impl PdUrl {
    pub fn parse(url: &str) -> anyhow::Result<PdUrl> {
        let (scheme, rest) = url
            .split_once("://")
            .ok_or_else(|| anyhow::anyhow!("missing scheme in '{url}'"))?;
        let kind = BackendKind::from_scheme(scheme)?;
        let (resource, path) = match rest.split_once('/') {
            Some((r, p)) => (r.to_string(), format!("/{p}")),
            None => (rest.to_string(), "/".to_string()),
        };
        if resource.is_empty() {
            anyhow::bail!("missing resource in '{url}'");
        }
        Ok(PdUrl { kind, resource, path })
    }
}

impl std::fmt::Display for PdUrl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}://{}{}", self.kind.scheme(), self.resource, self.path)
    }
}

/// Calibrated per-protocol cost model.
#[derive(Debug, Clone)]
pub struct ProtocolParams {
    /// One-time connection / request setup (seconds). Globus Online's
    /// service round-trips dominate here.
    pub setup_s: f64,
    /// Per-file transfer initiation overhead (seconds).
    pub per_file_s: f64,
    /// Achieved fraction of the raw path capacity. Parallel-stream
    /// protocols (GridFTP) approach 1.0; single-TCP tools get far less.
    pub efficiency: f64,
    /// Per-flow bandwidth ceiling (bytes/s): what one stream of this
    /// protocol can move regardless of path capacity (a single scp
    /// stream tops out near 20 MiB/s; GridFTP parallel streams go much
    /// higher). This is what made the paper's Lonestar->Stampede moves
    /// take ~450 s per 9 GB task.
    pub per_flow_cap: f64,
    /// Time to register data into the namespace after transfer.
    pub register_s: f64,
    /// Probability that a single transfer attempt fails (Fig. 8 observed
    /// a high failure frequency on OSG).
    pub failure_rate: f64,
    /// Server-side replication support (iRODS resource groups).
    pub server_side_replication: bool,
    /// Third-party (site-to-site) transfer support without routing
    /// through the submission machine.
    pub third_party: bool,
}

impl ProtocolParams {
    /// Defaults calibrated so the Fig. 7 ordering holds (see the
    /// README's experiment notes for the substitution rationale).
    pub fn defaults(kind: BackendKind) -> ProtocolParams {
        match kind {
            BackendKind::Ssh => ProtocolParams {
                per_flow_cap: 1048576.0 * 20.0,
                setup_s: 1.5,
                per_file_s: 0.3,
                efficiency: 0.45,
                register_s: 0.2,
                failure_rate: 0.01,
                server_side_replication: false,
                third_party: false,
            },
            BackendKind::Srm => ProtocolParams {
                per_flow_cap: 1048576.0 * 150.0,
                setup_s: 3.0,
                per_file_s: 0.4,
                efficiency: 0.95, // GridFTP parallel streams near link capacity
                register_s: 1.0,
                failure_rate: 0.08, // "the frequency of failures was very high" on OSG
                server_side_replication: false,
                third_party: true,
            },
            BackendKind::Irods => ProtocolParams {
                per_flow_cap: 1048576.0 * 18.0,
                setup_s: 3.5,
                per_file_s: 0.8,
                efficiency: 0.40,
                register_s: 1.5,
                failure_rate: 0.12, // Fig. 8: ~7.5 of 9 group members succeed
                server_side_replication: true,
                third_party: true,
            },
            BackendKind::GlobusOnline => ProtocolParams {
                per_flow_cap: 1048576.0 * 100.0,
                setup_s: 28.0, // service-based request creation
                per_file_s: 0.2,
                efficiency: 0.85, // GridFTP underneath, plus management layer
                register_s: 2.0,
                failure_rate: 0.01, // GO auto-restarts failed transfers
                server_side_replication: false,
                third_party: true,
            },
            BackendKind::S3 => ProtocolParams {
                per_flow_cap: 1048576.0 * 30.0,
                setup_s: 1.0,
                per_file_s: 0.5,
                efficiency: 0.90, // bottleneck is the WAN uplink, not the protocol
                register_s: 0.3,
                failure_rate: 0.01,
                server_side_replication: true, // intra-region replication
                third_party: false,
            },
            BackendKind::LocalFs => ProtocolParams {
                per_flow_cap: 1048576.0 * 100000.0,
                setup_s: 0.0,
                per_file_s: 0.0,
                efficiency: 1.0,
                register_s: 0.0,
                failure_rate: 0.0,
                server_side_replication: false,
                third_party: false,
            },
        }
    }
}

/// One row of the Table 1 capability matrix.
#[derive(Debug, Clone)]
pub struct Capability {
    pub kind: BackendKind,
    pub scheme: &'static str,
    pub replication: bool,
    pub third_party: bool,
    pub namespace: &'static str,
    pub infrastructures: &'static [&'static str],
}

/// The adaptor registry: which backends exist, their capabilities, and
/// which production infrastructure deploys them (regenerates Table 1).
pub fn capability_matrix() -> Vec<Capability> {
    use BackendKind::*;
    fn cap(
        kind: BackendKind,
        namespace: &'static str,
        infrastructures: &'static [&'static str],
    ) -> Capability {
        let p = ProtocolParams::defaults(kind);
        Capability {
            kind,
            scheme: kind.scheme(),
            replication: p.server_side_replication,
            third_party: p.third_party,
            namespace,
            infrastructures,
        }
    }
    vec![
        cap(Ssh, "posix path", &["XSEDE", "OSG", "EGI"]),
        cap(Srm, "logical namespace", &["OSG", "EGI", "Atlas/OSG"]),
        cap(Irods, "collections + metadata", &["XSEDE", "OSG"]),
        cap(GlobusOnline, "endpoint + path", &["XSEDE"]),
        cap(S3, "1-level bucket", &["AWS", "OpenStack/Eucalyptus"]),
        cap(LocalFs, "posix path", &["local"]),
    ]
}

/// A storage endpoint bound to a topology location: the (resource,
/// protocol) pair that defines a Pilot-Data backend.
#[derive(Debug, Clone)]
pub struct Endpoint {
    pub url: PdUrl,
    pub label: Label,
    pub params: ProtocolParams,
}

impl Endpoint {
    pub fn new(url: &str, label: &str) -> anyhow::Result<Endpoint> {
        let url = PdUrl::parse(url)?;
        let params = ProtocolParams::defaults(url.kind);
        Ok(Endpoint { url, label: Label::new(label), params })
    }

    pub fn with_params(mut self, params: ProtocolParams) -> Endpoint {
        self.params = params;
        self
    }
}

/// Physical backend families for heterogeneous-testbed pricing (the
/// parallel-FS / object-store / node-local split evaluated in the
/// pilot-abstraction follow-up papers). Orthogonal to [`BackendKind`]:
/// the kind names the *protocol*, the class names the *device* behind
/// it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum BackendClass {
    /// Shared parallel filesystem (Lustre/GPFS-class): no extra
    /// latency, high shared bandwidth, free within the allocation.
    #[default]
    ParallelFs,
    /// Cloud object store (S3-class): per-request latency, WAN-bounded
    /// bandwidth, billed per GB moved.
    ObjectStore,
    /// Node-local disk/SSD: near-zero latency and free, but only fast
    /// when the compute lands on the same node — the case delay
    /// scheduling exists to exploit.
    NodeLocal,
}

impl std::fmt::Display for BackendClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendClass::ParallelFs => "parallel-fs",
            BackendClass::ObjectStore => "object-store",
            BackendClass::NodeLocal => "node-local",
        })
    }
}

/// Per-PD device profile composed into transfer pricing on
/// heterogeneous testbeds.
///
/// The profile adjusts a priced transfer *into or out of* the PD it is
/// attached to: `fixed_latency_s` adds to the setup term once per
/// attempt, `bandwidth_cap` floors the wire time at `size / cap`
/// (min()-composed with the uplink walk — the slower of path and
/// device governs), and `cost_per_gb` accrues into
/// `SimSystem::dollars_spent` for every byte moved.
///
/// [`BackendProfile::default`] is the uniform no-op profile (zero
/// latency, no cap, zero cost): a testbed where every PD keeps the
/// default prices transfers **bit-identically** to the
/// pre-profile code path, which is what the scheduler oracle
/// properties pin.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BackendProfile {
    pub class: BackendClass,
    /// Fixed per-attempt latency added to transfer setup (seconds).
    pub fixed_latency_s: f64,
    /// Device bandwidth ceiling (bytes/s); `None` = unbounded (the
    /// network path alone governs).
    pub bandwidth_cap: Option<f64>,
    /// Monetary cost per GiB moved in or out of this PD.
    pub cost_per_gb: f64,
}

impl Default for BackendProfile {
    fn default() -> BackendProfile {
        BackendProfile {
            class: BackendClass::ParallelFs,
            fixed_latency_s: 0.0,
            bandwidth_cap: None,
            cost_per_gb: 0.0,
        }
    }
}

impl BackendProfile {
    /// Shared parallel filesystem: the uniform default (free, uncapped).
    pub fn parallel_fs() -> BackendProfile {
        BackendProfile::default()
    }

    /// Cloud object store: ~90 ms request latency, 60 MiB/s device
    /// ceiling, $0.09/GB egress-class pricing.
    pub fn object_store() -> BackendProfile {
        BackendProfile {
            class: BackendClass::ObjectStore,
            fixed_latency_s: 0.09,
            bandwidth_cap: Some(1048576.0 * 60.0),
            cost_per_gb: 0.09,
        }
    }

    /// Node-local disk: free and effectively latency-less, with a
    /// single-spindle 200 MiB/s ceiling.
    pub fn node_local() -> BackendProfile {
        BackendProfile {
            class: BackendClass::NodeLocal,
            fixed_latency_s: 0.0,
            bandwidth_cap: Some(1048576.0 * 200.0),
            cost_per_gb: 0.0,
        }
    }

    /// True when this profile changes nothing relative to the uniform
    /// default — used to keep homogeneous testbeds on the exact
    /// pre-profile pricing path.
    pub fn is_uniform(&self) -> bool {
        self.fixed_latency_s == 0.0 && self.bandwidth_cap.is_none() && self.cost_per_gb == 0.0
    }

    /// Dollars charged for moving `bytes` in or out of this PD.
    pub fn dollars_for(&self, bytes: u64) -> f64 {
        self.cost_per_gb * bytes as f64 / (1024.0 * 1024.0 * 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn url_parse_roundtrip() {
        let u = PdUrl::parse("irods://osg-fermilab/osgGridFtpGroup/pd-1").unwrap();
        assert_eq!(u.kind, BackendKind::Irods);
        assert_eq!(u.resource, "osg-fermilab");
        assert_eq!(u.path, "/osgGridFtpGroup/pd-1");
        assert_eq!(u.to_string(), "irods://osg-fermilab/osgGridFtpGroup/pd-1");
    }

    #[test]
    fn url_without_path_gets_root() {
        let u = PdUrl::parse("s3://my-bucket").unwrap();
        assert_eq!(u.path, "/");
        assert_eq!(u.kind, BackendKind::S3);
    }

    #[test]
    fn url_errors() {
        assert!(PdUrl::parse("no-scheme").is_err());
        assert!(PdUrl::parse("bogus://x/y").is_err());
        assert!(PdUrl::parse("ssh:///path-only").is_err());
    }

    #[test]
    fn scheme_aliases() {
        assert_eq!(BackendKind::from_scheme("gsiftp").unwrap(), BackendKind::Srm);
        assert_eq!(BackendKind::from_scheme("globusonline").unwrap(), BackendKind::GlobusOnline);
    }

    #[test]
    fn fig7_ordering_is_baked_into_params() {
        // Large transfers: effective protocol speed ordering must be
        // SRM > GO > SSH > iRODS (S3 is limited by topology, not params).
        let eff = |k| ProtocolParams::defaults(k).efficiency;
        assert!(eff(BackendKind::Srm) > eff(BackendKind::GlobusOnline));
        assert!(eff(BackendKind::GlobusOnline) > eff(BackendKind::Ssh));
        assert!(eff(BackendKind::Ssh) > eff(BackendKind::Irods));
        // Small transfers: SSH setup must undercut GO's service overhead.
        let setup = |k| ProtocolParams::defaults(k).setup_s;
        assert!(setup(BackendKind::Ssh) < setup(BackendKind::GlobusOnline) / 10.0);
    }

    #[test]
    fn capability_matrix_covers_all_backends() {
        let m = capability_matrix();
        assert_eq!(m.len(), 6);
        let irods = m.iter().find(|c| c.kind == BackendKind::Irods).unwrap();
        assert!(irods.replication);
        let ssh = m.iter().find(|c| c.kind == BackendKind::Ssh).unwrap();
        assert!(!ssh.third_party);
    }

    #[test]
    fn default_profile_is_the_uniform_noop() {
        let p = BackendProfile::default();
        assert!(p.is_uniform());
        assert_eq!(p.class, BackendClass::ParallelFs);
        assert_eq!(p.dollars_for(1 << 30), 0.0);
        assert!(BackendProfile::parallel_fs().is_uniform());
    }

    #[test]
    fn preset_profiles_are_heterogeneous_and_priced() {
        let os = BackendProfile::object_store();
        assert!(!os.is_uniform());
        assert_eq!(os.class, BackendClass::ObjectStore);
        assert!((os.dollars_for(2 << 30) - 0.18).abs() < 1e-12);
        let nl = BackendProfile::node_local();
        assert!(!nl.is_uniform());
        assert_eq!(nl.dollars_for(u64::MAX / 2), 0.0);
        assert!(nl.bandwidth_cap.unwrap() > os.bandwidth_cap.unwrap());
    }

    #[test]
    fn endpoint_binds_label() {
        let e = Endpoint::new("ssh://lonestar/scratch/pd", "xsede/tacc/lonestar").unwrap();
        assert_eq!(e.label, Label::new("xsede/tacc/lonestar"));
        assert_eq!(e.url.kind, BackendKind::Ssh);
    }
}
