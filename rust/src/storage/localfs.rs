//! Real local-filesystem adaptor (`file://` scheme) — the backend used
//! in *local execution mode*, where Pilot-Data directories are real
//! directories, Data-Unit files are real files, and Compute-Units run
//! real alignment compute through the PJRT runtime.
//!
//! Layout mirrors BigJob's sandboxes: each Pilot-Data gets a root
//! directory; each Data-Unit a subdirectory (`<root>/<du-id>/…`);
//! Compute-Unit sandboxes link or copy DU files in.

use crate::util::Bytes;
use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// A real directory acting as a Pilot-Data store.
#[derive(Debug, Clone)]
pub struct LocalFs {
    root: PathBuf,
}

impl LocalFs {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: impl Into<PathBuf>) -> anyhow::Result<LocalFs> {
        let root = root.into();
        fs::create_dir_all(&root)?;
        Ok(LocalFs { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn resolve(&self, du: &str, name: &str) -> anyhow::Result<PathBuf> {
        // Two-level namespace: DU id, then an application-level relative
        // path inside the DU (paper §4 capability 2/3). Reject escapes.
        if du.contains("..") || name.contains("..") || name.starts_with('/') {
            anyhow::bail!("path escape rejected: {du}/{name}");
        }
        Ok(self.root.join(du).join(name))
    }

    /// Store file content under `du/name`.
    pub fn put(&self, du: &str, name: &str, content: &[u8]) -> anyhow::Result<()> {
        let path = self.resolve(du, name)?;
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(&path)?;
        f.write_all(content)?;
        Ok(())
    }

    /// Copy a file from the real filesystem into the store.
    pub fn put_file(&self, du: &str, name: &str, src: &Path) -> anyhow::Result<()> {
        let path = self.resolve(du, name)?;
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::copy(src, &path)?;
        Ok(())
    }

    pub fn get(&self, du: &str, name: &str) -> anyhow::Result<Vec<u8>> {
        let path = self.resolve(du, name)?;
        let mut buf = Vec::new();
        fs::File::open(&path)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?
            .read_to_end(&mut buf)?;
        Ok(buf)
    }

    /// Absolute path of a stored file (for linking into CU sandboxes —
    /// "the data can be directly accessed via a logical filesystem
    /// link").
    pub fn path_of(&self, du: &str, name: &str) -> anyhow::Result<PathBuf> {
        self.resolve(du, name)
    }

    /// List `(name, size)` of files within a DU, sorted by name.
    pub fn list(&self, du: &str) -> anyhow::Result<Vec<(String, Bytes)>> {
        let dir = self.root.join(du);
        let mut out = Vec::new();
        if !dir.exists() {
            return Ok(out);
        }
        fn walk(base: &Path, dir: &Path, out: &mut Vec<(String, Bytes)>) -> anyhow::Result<()> {
            for entry in fs::read_dir(dir)? {
                let entry = entry?;
                let p = entry.path();
                if p.is_dir() {
                    walk(base, &p, out)?;
                } else {
                    let rel = p.strip_prefix(base)?.to_string_lossy().to_string();
                    out.push((rel, Bytes::b(entry.metadata()?.len())));
                }
            }
            Ok(())
        }
        walk(&dir, &dir, &mut out)?;
        out.sort();
        Ok(out)
    }

    /// Remove a whole DU (transient intermediate data teardown).
    pub fn remove_du(&self, du: &str) -> anyhow::Result<()> {
        let dir = self.root.join(du);
        if dir.exists() {
            fs::remove_dir_all(dir)?;
        }
        Ok(())
    }

    /// Total bytes stored in a DU.
    pub fn du_size(&self, du: &str) -> anyhow::Result<Bytes> {
        Ok(self.list(du)?.into_iter().map(|(_, s)| s).sum())
    }

    /// Link (or copy if linking fails) a DU's files into `sandbox`,
    /// implementing the CU input-staging contract of §4.3.2.
    pub fn stage_into_sandbox(&self, du: &str, sandbox: &Path) -> anyhow::Result<usize> {
        fs::create_dir_all(sandbox)?;
        let mut n = 0;
        for (name, _) in self.list(du)? {
            let src = self.path_of(du, &name)?;
            let dst = sandbox.join(&name);
            if let Some(parent) = dst.parent() {
                fs::create_dir_all(parent)?;
            }
            if dst.exists() {
                fs::remove_file(&dst)?;
            }
            // Hard link is the "logical filesystem link" fast path.
            if fs::hard_link(&src, &dst).is_err() {
                fs::copy(&src, &dst)?;
            }
            n += 1;
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("pd-localfs-test-{name}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn put_get_roundtrip() {
        let fs_ = LocalFs::open(tmp("rt")).unwrap();
        fs_.put("du-1", "reads/chunk0.fq", b"ACGT").unwrap();
        assert_eq!(fs_.get("du-1", "reads/chunk0.fq").unwrap(), b"ACGT");
    }

    #[test]
    fn list_reports_sizes_and_nested_paths() {
        let fs_ = LocalFs::open(tmp("list")).unwrap();
        fs_.put("du-2", "a.txt", b"12345").unwrap();
        fs_.put("du-2", "sub/b.txt", b"1").unwrap();
        let l = fs_.list("du-2").unwrap();
        assert_eq!(l.len(), 2);
        assert_eq!(l[0], ("a.txt".to_string(), Bytes::b(5)));
        assert_eq!(l[1], ("sub/b.txt".to_string(), Bytes::b(1)));
        assert_eq!(fs_.du_size("du-2").unwrap(), Bytes::b(6));
        assert!(fs_.list("du-nope").unwrap().is_empty());
    }

    #[test]
    fn rejects_path_escapes() {
        let fs_ = LocalFs::open(tmp("esc")).unwrap();
        assert!(fs_.put("du-3", "../evil", b"x").is_err());
        assert!(fs_.put("../du", "f", b"x").is_err());
        assert!(fs_.put("du-3", "/abs", b"x").is_err());
    }

    #[test]
    fn sandbox_staging_links_all_files() {
        let fs_ = LocalFs::open(tmp("stage")).unwrap();
        fs_.put("du-4", "x", b"1").unwrap();
        fs_.put("du-4", "y", b"22").unwrap();
        let sandbox = tmp("stage-sb");
        let n = fs_.stage_into_sandbox("du-4", &sandbox).unwrap();
        assert_eq!(n, 2);
        assert_eq!(fs::read(sandbox.join("x")).unwrap(), b"1");
        assert_eq!(fs::read(sandbox.join("y")).unwrap(), b"22");
        // Re-staging is idempotent.
        assert_eq!(fs_.stage_into_sandbox("du-4", &sandbox).unwrap(), 2);
    }

    #[test]
    fn remove_du_cleans_up() {
        let fs_ = LocalFs::open(tmp("rm")).unwrap();
        fs_.put("du-5", "f", b"x").unwrap();
        fs_.remove_du("du-5").unwrap();
        assert!(fs_.list("du-5").unwrap().is_empty());
        fs_.remove_du("du-5").unwrap(); // idempotent
    }
}
