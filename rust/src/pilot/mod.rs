//! Pilot-Computes, Pilot-Data, and the Pilot-Manager state.
//!
//! A **Pilot-Compute** marshals a set of resource slots acquired from a
//! local resource manager; a **Pilot-Data** represents a physical
//! storage resource used as a logical container for dynamic data
//! placement (paper §4.3.1). The **Pilot-Manager** is the central
//! coordinator orchestrating a set of decentral **Pilot-Agents**
//! (Fig. 1); all shared state lives in the coordination store so that
//! managers and applications can disconnect and re-connect.

use crate::coordination::{keys, Store};
use crate::storage::PdUrl;
use crate::topology::Label;
use crate::unit::{ComputeUnit, CuState, DataUnit};
use crate::util::Bytes;
use std::collections::BTreeMap;
use std::sync::OnceLock;

/// Pilot lifecycle (both compute and data pilots).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PilotState {
    New,
    /// Submitted to the resource manager, waiting in the batch queue.
    Queued,
    /// Agent is up and pulling work / storage is provisioned.
    Active,
    Done,
    Failed,
    Canceled,
}

impl PilotState {
    pub fn can_transition(self, to: PilotState) -> bool {
        use PilotState::*;
        matches!(
            (self, to),
            (New, Queued)
                | (New, Failed)
                | (Queued, Active)
                | (Queued, Failed)
                | (Queued, Canceled)
                | (Active, Done)
                | (Active, Failed)
                | (Active, Canceled)
        )
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, PilotState::Done | PilotState::Failed | PilotState::Canceled)
    }
}

/// Pilot-Compute-Description: resource-manager URL, slot count,
/// walltime, and the user-assigned affinity label that maps the pilot
/// into the logical resource topology (§5).
#[derive(Debug, Clone, Default)]
pub struct PilotComputeDescription {
    /// Resource manager endpoint, e.g. `batch://lonestar` in sim mode
    /// or `fork://localhost` in local mode.
    pub service_url: String,
    pub cores: u32,
    pub walltime_s: f64,
    pub affinity: Option<Label>,
}

impl PilotComputeDescription {
    pub fn machine(&self) -> anyhow::Result<String> {
        let (_, rest) = self
            .service_url
            .split_once("://")
            .ok_or_else(|| anyhow::anyhow!("bad service url '{}'", self.service_url))?;
        Ok(rest.split('/').next().unwrap_or(rest).to_string())
    }
}

/// A Pilot-Compute instance.
#[derive(Debug, Clone)]
pub struct PilotCompute {
    pub id: String,
    pub description: PilotComputeDescription,
    pub state: PilotState,
    /// Slots currently occupied by running CUs.
    pub busy_slots: u32,
    /// Time the pilot became Active (for walltime accounting).
    pub t_active: f64,
}

impl PilotCompute {
    pub fn new(description: PilotComputeDescription) -> PilotCompute {
        PilotCompute {
            id: crate::util::next_id("pilot"),
            description,
            state: PilotState::New,
            busy_slots: 0,
            t_active: 0.0,
        }
    }

    pub fn affinity(&self) -> Label {
        self.affinity_ref().clone()
    }

    /// Borrowed affinity label — the scheduler scores every eligible
    /// pilot per placement, so this avoids a `String` clone per pilot
    /// per decision.
    pub fn affinity_ref(&self) -> &Label {
        static EMPTY: OnceLock<Label> = OnceLock::new();
        self.description
            .affinity
            .as_ref()
            .unwrap_or_else(|| EMPTY.get_or_init(|| Label::new("")))
    }

    pub fn free_slots(&self) -> u32 {
        self.description.cores.saturating_sub(self.busy_slots)
    }

    pub fn has_free_slot(&self, cores: u32) -> bool {
        self.state == PilotState::Active && self.free_slots() >= cores.max(1)
    }

    pub fn transition(&mut self, to: PilotState) -> anyhow::Result<()> {
        if self.state == to {
            return Ok(());
        }
        if !self.state.can_transition(to) {
            anyhow::bail!("pilot {}: illegal transition {:?} -> {to:?}", self.id, self.state);
        }
        self.state = to;
        Ok(())
    }
}

/// Pilot-Data-Description: backend URL (scheme selects the adaptor),
/// capacity, and affinity label.
#[derive(Debug, Clone, Default)]
pub struct PilotDataDescription {
    pub service_url: String,
    pub size: Bytes,
    pub affinity: Option<Label>,
}

/// A Pilot-Data instance: a storage allocation acting as a logical
/// container for Data-Unit replicas.
#[derive(Debug, Clone)]
pub struct PilotData {
    pub id: String,
    pub description: PilotDataDescription,
    pub state: PilotState,
    pub url: PdUrl,
}

impl PilotData {
    pub fn new(description: PilotDataDescription) -> anyhow::Result<PilotData> {
        let url = PdUrl::parse(&description.service_url)?;
        Ok(PilotData {
            id: crate::util::next_id("pd"),
            description,
            state: PilotState::New,
            url,
        })
    }

    pub fn affinity(&self) -> Label {
        self.description.affinity.clone().unwrap_or_else(|| Label::new(""))
    }

    pub fn transition(&mut self, to: PilotState) -> anyhow::Result<()> {
        if self.state == to {
            return Ok(());
        }
        if !self.state.can_transition(to) {
            anyhow::bail!("pd {}: illegal transition {:?} -> {to:?}", self.id, self.state);
        }
        self.state = to;
        Ok(())
    }
}

/// The Pilot-Manager's in-memory view of the world. Mirrors the
/// coordination store; [`ManagerState::checkpoint`] writes the durable
/// copy and [`ManagerState::reconnect`] rebuilds entity state from it.
///
/// Besides the entity maps, the state maintains three **incremental
/// indexes** consumed by the scheduler, so a `SchedContext` assembles
/// in O(1) instead of being rebuilt in O(pilots + DUs·replicas) per
/// placement decision:
///
/// * `du_locations` — DU id → affinity labels holding a replica,
///   appended by [`ManagerState::note_replica`] when a transfer lands;
/// * `queue_depth` — pilot id → CUs waiting in its agent queue, bumped
///   by [`ManagerState::note_queue_push`] / `note_queue_pop` at the
///   same call sites that rpush/lpop the coordination store;
/// * `pilots_by_label` — affinity label → pilot ids, for targeted
///   agent wakeups (only pilots that gained data-local work).
#[derive(Default)]
pub struct ManagerState {
    pub pilots: BTreeMap<String, PilotCompute>,
    pub pilot_datas: BTreeMap<String, PilotData>,
    pub cus: BTreeMap<String, ComputeUnit>,
    pub dus: BTreeMap<String, DataUnit>,
    /// DU id -> labels of Pilot-Data currently holding a full replica.
    du_locations: BTreeMap<String, Vec<Label>>,
    /// Pilot id -> CUs waiting in its agent-specific queue.
    queue_depth: BTreeMap<String, usize>,
    /// Affinity label -> pilots registered at that label.
    pilots_by_label: BTreeMap<String, Vec<String>>,
}

impl ManagerState {
    pub fn new() -> ManagerState {
        ManagerState::default()
    }

    pub fn add_pilot(&mut self, p: PilotCompute) -> String {
        let id = p.id.clone();
        self.pilots_by_label.entry(p.affinity_ref().0.clone()).or_default().push(id.clone());
        self.pilots.insert(id.clone(), p);
        id
    }

    /// Record that `du` now has a replica at `label` (idempotent).
    pub fn note_replica(&mut self, du: &str, label: &Label) {
        let entry = self.du_locations.entry(du.to_string()).or_default();
        if !entry.contains(label) {
            entry.push(label.clone());
        }
    }

    /// Remove `label` from `du`'s replica-location index — the inverse
    /// of [`ManagerState::note_replica`], called when the *last*
    /// replica at that label is evicted or lost to a storage outage,
    /// so `data_score` stops crediting data that is no longer there.
    pub fn drop_replica(&mut self, du: &str, label: &Label) {
        if let Some(locs) = self.du_locations.get_mut(du) {
            locs.retain(|l| l != label);
            if locs.is_empty() {
                self.du_locations.remove(du);
            }
        }
    }

    /// One CU was pushed onto `pilot`'s agent queue.
    pub fn note_queue_push(&mut self, pilot: &str) {
        *self.queue_depth.entry(pilot.to_string()).or_insert(0) += 1;
    }

    /// One CU was popped off `pilot`'s agent queue.
    pub fn note_queue_pop(&mut self, pilot: &str) {
        if let Some(d) = self.queue_depth.get_mut(pilot) {
            *d = d.saturating_sub(1);
        }
    }

    /// Forget `pilot`'s queue depth (its queue was drained wholesale,
    /// e.g. on walltime expiry).
    pub fn reset_queue_depth(&mut self, pilot: &str) {
        self.queue_depth.remove(pilot);
    }

    /// Live DU-replica-location index (see [`crate::scheduler::SchedContext`]).
    pub fn du_locations(&self) -> &BTreeMap<String, Vec<Label>> {
        &self.du_locations
    }

    /// Live per-pilot queue-depth counters.
    pub fn queue_depths(&self) -> &BTreeMap<String, usize> {
        &self.queue_depth
    }

    /// Pilots registered at exactly this affinity label.
    pub fn pilots_at_label(&self, label: &Label) -> &[String] {
        self.pilots_by_label.get(&label.0).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Pilots whose affinity label lies within the subtree rooted at
    /// `constraint` (`Label::within` semantics) — label-subtree
    /// candidate pruning for the scheduler's constraint filter and for
    /// DU-arrival wakeups. A `BTreeMap` range scan over the label
    /// index touches only the constrained subtree instead of walking
    /// the whole fleet. Ids are **borrowed** from the index (this sits
    /// on the per-placement hot path — no per-candidate clones) and
    /// come back sorted, so callers iterate in the same order a
    /// `pilots.values()` scan would.
    pub fn pilots_within(&self, constraint: &Label) -> Vec<&str> {
        let root = constraint.0.as_str();
        let mut ids: Vec<&str> = self
            .pilots_by_label
            .range::<str, _>(root..)
            .take_while(|(l, _)| l.starts_with(root))
            // String prefix is necessary but not sufficient: `osg2`
            // starts with `osg` yet is not within it. Labels are
            // normalized (no stray slashes), so "equal or next byte is
            // '/'" is exactly component-wise containment.
            .filter(|(l, _)| root.is_empty() || l.len() == root.len() || l.as_bytes()[root.len()] == b'/')
            .flat_map(|(_, ids)| ids.iter().map(String::as_str))
            .collect();
        ids.sort_unstable();
        ids
    }

    pub fn add_pd(&mut self, pd: PilotData) -> String {
        let id = pd.id.clone();
        self.pilot_datas.insert(id.clone(), pd);
        id
    }

    pub fn add_cu(&mut self, cu: ComputeUnit) -> String {
        let id = cu.id.clone();
        self.cus.insert(id.clone(), cu);
        id
    }

    pub fn add_du(&mut self, du: DataUnit) -> String {
        let id = du.id.clone();
        self.dus.insert(id.clone(), du);
        id
    }

    pub fn active_pilots(&self) -> impl Iterator<Item = &PilotCompute> {
        self.pilots.values().filter(|p| p.state == PilotState::Active)
    }

    /// All CUs in a terminal state?
    pub fn workload_finished(&self) -> bool {
        self.cus.values().all(|c| c.state.is_terminal())
    }

    pub fn count_cu_state(&self, state: CuState) -> usize {
        self.cus.values().filter(|c| c.state == state).count()
    }

    /// Write pilot/CU/DU state to the coordination store (the paper's
    /// "complete state of BigJob is maintained in Redis"). Immutable
    /// `descr` records are written with HSETNX semantics so repeated
    /// checkpoints do not re-serialize every description.
    pub fn checkpoint(&self, store: &Store) -> anyhow::Result<()> {
        for p in self.pilots.values() {
            let k = keys::pilot(&p.id);
            store.hset(&k, "state", &format!("{:?}", p.state))?;
            store.hset(&k, "cores", &p.description.cores.to_string())?;
            store.hset(&k, "affinity", &p.affinity_ref().0)?;
            store.hset(&k, "busy", &p.busy_slots.to_string())?;
        }
        for c in self.cus.values() {
            let k = keys::cu(&c.id);
            store.hset(&k, "state", c.state.name())?;
            store.hset(&k, "pilot", c.pilot.as_deref().unwrap_or(""))?;
            store.hset_if_absent(&k, "descr", || c.description.to_json().to_string_compact())?;
        }
        for d in self.dus.values() {
            let k = keys::du(&d.id);
            store.hset(&k, "state", d.state.name())?;
            store.hset_if_absent(&k, "descr", || d.description().to_json().to_string_compact())?;
            // Replica labels (the du_locations index) as a JSON array,
            // overwritten on every checkpoint — this is what lets a
            // reconnected manager score data affinity immediately
            // instead of warming up from zero.
            let locs = self.du_locations.get(&d.id).map(Vec::as_slice).unwrap_or(&[]);
            let arr = crate::json::Json::Arr(
                locs.iter().map(|l| crate::json::Json::Str(l.0.clone())).collect(),
            );
            store.hset(&k, "replicas", &arr.to_string_compact())?;
        }
        Ok(())
    }

    /// Rebuild pilot records, CU descriptions, and states from the
    /// store after a manager restart ("re-connect to a Pilot and
    /// Compute-Unit via a unique URL"). Descriptions come through the
    /// store's typed record cache, so each JSON document is parsed at
    /// most once. Pilot `busy` counts are the multi-slot agents'
    /// store-mirrored slot state, so a reconnected manager's scheduler
    /// filters free slots against real occupancy instead of assuming
    /// an idle fleet.
    pub fn reconnect(store: &Store) -> anyhow::Result<ManagerState> {
        let mut st = ManagerState::new();
        for key in store.keys_with_prefix("pd:pilot:")? {
            let h = store.hgetall(&key)?;
            let id = key.trim_start_matches("pd:pilot:").to_string();
            let cores = h.get("cores").and_then(|s| s.parse().ok()).unwrap_or(1);
            let affinity = h.get("affinity").map(|s| Label::new(s));
            let mut p = PilotCompute::new(PilotComputeDescription {
                // The resource-manager URL is not checkpointed; a
                // reconnected manager coordinates through the store
                // only, so a synthetic scheme is sufficient.
                service_url: format!("reconnect://{id}"),
                cores,
                walltime_s: f64::INFINITY,
                affinity,
            });
            p.id = id.clone();
            p.state = match h.get("state").map(String::as_str) {
                Some("Queued") => PilotState::Queued,
                Some("Active") => PilotState::Active,
                Some("Done") => PilotState::Done,
                Some("Failed") => PilotState::Failed,
                Some("Canceled") => PilotState::Canceled,
                _ => PilotState::New,
            };
            p.busy_slots = h.get("busy").and_then(|s| s.parse().ok()).unwrap_or(0);
            st.add_pilot(p);
        }
        for key in store.keys_with_prefix("pd:cu:")? {
            let h = store.hgetall(&key)?;
            let id = key.trim_start_matches("pd:cu:").to_string();
            let description = store
                .cu_description(&id)?
                .ok_or_else(|| anyhow::anyhow!("cu {id} missing descr"))?;
            let mut cu = ComputeUnit::new((*description).clone());
            cu.id = id.clone();
            cu.state = match h.get("state").map(String::as_str) {
                Some("Queued") => CuState::Queued,
                Some("StagingInput") => CuState::StagingInput,
                Some("Running") => CuState::Running,
                Some("StagingOutput") => CuState::StagingOutput,
                Some("Done") => CuState::Done,
                Some("Failed") => CuState::Failed,
                Some("Unschedulable") => CuState::Unschedulable,
                _ => CuState::New,
            };
            cu.pilot = h.get("pilot").filter(|s| !s.is_empty()).cloned();
            st.cus.insert(cu.id.clone(), cu);
        }
        for key in store.keys_with_prefix("pd:du:")? {
            let id = key.trim_start_matches("pd:du:").to_string();
            if let Some(description) = store.du_description(&id)? {
                let mut du = DataUnit::new((*description).clone());
                du.id = id.clone();
                st.dus.insert(id.clone(), du);
            }
            // Restore the replica-location index from the checkpointed
            // label array, so data-affinity scoring is warm immediately
            // after a manager restart (same placement decisions as
            // before the restart — property-tested).
            if let Some(raw) = store.hget(&key, "replicas")? {
                if let Ok(parsed) = crate::json::parse(&raw) {
                    if let Some(arr) = parsed.as_arr() {
                        for label in arr {
                            if let Some(s) = label.as_str() {
                                st.note_replica(&id, &Label::new(s));
                            }
                        }
                    }
                }
            }
        }
        // Rebuild the live queue-depth counters from the store's agent
        // queues so a reconnected manager schedules against real
        // backlog, not empty indexes.
        for key in store.keys_with_prefix(keys::PILOT_QUEUE_PREFIX)? {
            let pilot = key.trim_start_matches(keys::PILOT_QUEUE_PREFIX).to_string();
            let depth = store.llen(&key)?;
            if depth > 0 {
                st.queue_depth.insert(pilot, depth);
            }
        }
        Ok(st)
    }
}

/// Pure agent-side pull policy: which queue to poll, in order. Each
/// Pilot-Agent "generally pulls from two queues: its agent-specific
/// queue and a global queue" (§4.2). This is the single home of that
/// protocol — the sim driver and the local-mode agent loop both call
/// it. The `bool` says whether the CU came off the agent-specific
/// queue, so callers can decrement their queue-depth counter in
/// lockstep.
pub fn agent_pull_tracked(
    store: &Store,
    own_queue: &crate::coordination::Key,
) -> Result<Option<(String, bool)>, crate::coordination::StoreError> {
    if let Some(cu) = store.lpop_k(own_queue)? {
        return Ok(Some((cu, true)));
    }
    Ok(store.lpop_k(keys::global_queue_key())?.map(|cu| (cu, false)))
}

/// String-key convenience wrapper around [`agent_pull_tracked`].
pub fn agent_pull(store: &Store, pilot_id: &str) -> Result<Option<String>, crate::coordination::StoreError> {
    Ok(agent_pull_tracked(store, &keys::pilot_queue_key(pilot_id))?.map(|(cu, _)| cu))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::unit::ComputeUnitDescription;

    fn pcd(machine: &str, cores: u32, affinity: &str) -> PilotComputeDescription {
        PilotComputeDescription {
            service_url: format!("batch://{machine}"),
            cores,
            walltime_s: 3600.0,
            affinity: Some(Label::new(affinity)),
        }
    }

    #[test]
    fn pilot_lifecycle() {
        let mut p = PilotCompute::new(pcd("lonestar", 24, "xsede/tacc/lonestar"));
        assert_eq!(p.state, PilotState::New);
        p.transition(PilotState::Queued).unwrap();
        p.transition(PilotState::Active).unwrap();
        assert!(p.has_free_slot(1));
        p.transition(PilotState::Done).unwrap();
        assert!(p.transition(PilotState::Active).is_err());
    }

    #[test]
    fn machine_extracted_from_service_url() {
        assert_eq!(pcd("stampede", 1, "x").machine().unwrap(), "stampede");
        let bad = PilotComputeDescription { service_url: "nope".into(), ..Default::default() };
        assert!(bad.machine().is_err());
    }

    #[test]
    fn slot_accounting() {
        let mut p = PilotCompute::new(pcd("lonestar", 4, "x"));
        p.state = PilotState::Active;
        assert_eq!(p.free_slots(), 4);
        p.busy_slots = 3;
        assert!(p.has_free_slot(1));
        assert!(!p.has_free_slot(2));
        p.busy_slots = 4;
        assert!(!p.has_free_slot(1));
    }

    #[test]
    fn inactive_pilot_has_no_slots() {
        let mut p = PilotCompute::new(pcd("lonestar", 4, "x"));
        assert!(!p.has_free_slot(1)); // New
        p.state = PilotState::Queued;
        assert!(!p.has_free_slot(1));
    }

    #[test]
    fn pilot_data_from_url() {
        let pd = PilotData::new(PilotDataDescription {
            service_url: "irods://fermilab/osgGridFtpGroup".into(),
            size: Bytes::gb(100),
            affinity: Some(Label::new("osg/fermilab")),
        })
        .unwrap();
        assert_eq!(pd.url.kind, crate::storage::BackendKind::Irods);
        assert!(PilotData::new(PilotDataDescription {
            service_url: "???".into(),
            ..Default::default()
        })
        .is_err());
    }

    #[test]
    fn agent_prefers_own_queue_then_global() {
        let store = Store::new();
        store.rpush(keys::GLOBAL_QUEUE, "cu-g").unwrap();
        store.rpush(&keys::pilot_queue("p1"), "cu-own").unwrap();
        assert_eq!(agent_pull(&store, "p1").unwrap(), Some("cu-own".into()));
        assert_eq!(agent_pull(&store, "p1").unwrap(), Some("cu-g".into()));
        assert_eq!(agent_pull(&store, "p1").unwrap(), None);
    }

    #[test]
    fn checkpoint_reconnect_roundtrip() {
        let mut st = ManagerState::new();
        let cu = ComputeUnit::new(ComputeUnitDescription {
            executable: "/bin/bwa".into(),
            cores: 2,
            input_data: vec!["du-9".into()],
            ..Default::default()
        });
        let cu_id = cu.id.clone();
        st.add_cu(cu);
        st.cus.get_mut(&cu_id).unwrap().transition(CuState::Queued).unwrap();
        let du = DataUnit::new(crate::unit::DataUnitDescription {
            name: "d".into(),
            files: vec![crate::unit::FileRef::sized("f", Bytes::mb(1))],
            affinity: None,
        });
        st.add_du(du);
        st.add_pilot(PilotCompute::new(pcd("lonestar", 8, "xsede")));

        let store = Store::new();
        st.checkpoint(&store).unwrap();

        let back = ManagerState::reconnect(&store).unwrap();
        assert_eq!(back.cus.len(), 1);
        let cu2 = &back.cus[&cu_id];
        assert_eq!(cu2.state, CuState::Queued);
        assert_eq!(cu2.description.executable, "/bin/bwa");
        assert_eq!(back.dus.len(), 1);
    }

    #[test]
    fn reconnect_rebuilds_pilots_with_busy_slots() {
        let mut st = ManagerState::new();
        let pid = st.add_pilot(PilotCompute::new(pcd("lonestar", 16, "xsede/tacc/lonestar")));
        {
            let p = st.pilots.get_mut(&pid).unwrap();
            p.transition(PilotState::Queued).unwrap();
            p.transition(PilotState::Active).unwrap();
            // A multi-slot agent mid-run: 3 slots occupied.
            p.busy_slots = 3;
        }
        let store = Store::new();
        st.checkpoint(&store).unwrap();

        let back = ManagerState::reconnect(&store).unwrap();
        let p = &back.pilots[&pid];
        assert_eq!(p.state, PilotState::Active);
        assert_eq!(p.description.cores, 16);
        assert_eq!(p.busy_slots, 3);
        assert_eq!(p.free_slots(), 13);
        assert_eq!(p.affinity_ref().0, "xsede/tacc/lonestar");
        // The label index is rebuilt too (scheduler constraint pruning
        // works immediately after reconnect).
        assert_eq!(back.pilots_at_label(&Label::new("xsede/tacc/lonestar")), &[pid]);
    }

    #[test]
    fn queue_depth_counters_are_incremental() {
        let mut st = ManagerState::new();
        let p = st.add_pilot(PilotCompute::new(pcd("lonestar", 8, "xsede")));
        assert_eq!(st.queue_depths().get(&p), None);
        st.note_queue_push(&p);
        st.note_queue_push(&p);
        assert_eq!(st.queue_depths()[&p], 2);
        st.note_queue_pop(&p);
        assert_eq!(st.queue_depths()[&p], 1);
        // Popping below zero saturates instead of wrapping.
        st.note_queue_pop(&p);
        st.note_queue_pop(&p);
        assert_eq!(st.queue_depths()[&p], 0);
        st.note_queue_push(&p);
        st.reset_queue_depth(&p);
        assert_eq!(st.queue_depths().get(&p), None);
    }

    #[test]
    fn replica_index_dedups_labels() {
        let mut st = ManagerState::new();
        let l1 = Label::new("xsede/tacc/lonestar");
        let l2 = Label::new("osg/fnal");
        st.note_replica("du-1", &l1);
        st.note_replica("du-1", &l1); // duplicate
        st.note_replica("du-1", &l2);
        assert_eq!(st.du_locations()["du-1"], vec![l1.clone(), l2]);
        assert!(st.du_locations().get("du-2").is_none());
    }

    #[test]
    fn drop_replica_inverts_note_replica() {
        let mut st = ManagerState::new();
        let l1 = Label::new("xsede/tacc/lonestar");
        let l2 = Label::new("osg/fnal");
        st.note_replica("du-1", &l1);
        st.note_replica("du-1", &l2);
        st.drop_replica("du-1", &l1);
        assert_eq!(st.du_locations()["du-1"], vec![l2.clone()]);
        // Dropping the last label removes the whole entry, and
        // dropping from an unknown DU is a no-op.
        st.drop_replica("du-1", &l2);
        assert!(st.du_locations().get("du-1").is_none());
        st.drop_replica("du-unknown", &l1);
    }

    /// Satellite (ROADMAP): DU replica labels are checkpointed into the
    /// store mirror, so `reconnect` restores `du_locations` and the
    /// scheduler's data-affinity scoring does not warm up from zero
    /// after a manager restart. Property: on randomized fleets,
    /// replica sets, and CU mixes, scores and placements are identical
    /// pre/post restart.
    #[test]
    fn reconnect_restores_data_affinity_scores_property() {
        use crate::scheduler::{AffinityScheduler, SchedContext, Scheduler};
        use crate::topology::Topology;
        use crate::unit::{ComputeUnitDescription, DataUnitDescription, FileRef};

        crate::prop::check_default(
            |rng| {
                let sites = ["osg/a", "osg/b", "xsede/tacc/ls", "xsede/tacc/st", "ec2/east"];
                let n_pilots = crate::prop::gen::usize_in(rng, 1, 5);
                let pilots: Vec<(u32, String, bool, u32)> = (0..n_pilots)
                    .map(|_| {
                        (
                            1 + rng.below(16) as u32,
                            rng.choose(&sites).to_string(),
                            rng.chance(0.8),
                            rng.below(4) as u32,
                        )
                    })
                    .collect();
                let n_dus = crate::prop::gen::usize_in(rng, 1, 5);
                let dus: Vec<(u64, Vec<String>)> = (0..n_dus)
                    .map(|_| {
                        (
                            1 + rng.below(64),
                            (0..rng.below(4)).map(|_| rng.choose(&sites).to_string()).collect(),
                        )
                    })
                    .collect();
                let n_cus = crate::prop::gen::usize_in(rng, 1, 6);
                let cus: Vec<(u32, Option<String>, Vec<usize>)> = (0..n_cus)
                    .map(|_| {
                        (
                            1 + rng.below(4) as u32,
                            if rng.chance(0.3) {
                                Some(rng.choose(&sites).to_string())
                            } else {
                                None
                            },
                            (0..1 + rng.below(3)).map(|_| rng.below(n_dus as u64) as usize).collect(),
                        )
                    })
                    .collect();
                (pilots, dus, cus)
            },
            |(pilots, dus, cus)| {
                let mut st = ManagerState::new();
                for (cores, site, active, busy) in pilots {
                    let mut p = PilotCompute::new(PilotComputeDescription {
                        service_url: "batch://m".into(),
                        cores: *cores,
                        walltime_s: 1e6,
                        affinity: Some(Label::new(site)),
                    });
                    p.state = if *active { PilotState::Active } else { PilotState::Queued };
                    p.busy_slots = (*busy).min(*cores);
                    st.add_pilot(p);
                }
                let mut du_ids = Vec::new();
                for (gb, labels) in dus {
                    let id = st.add_du(DataUnit::new(DataUnitDescription {
                        name: "d".into(),
                        files: vec![FileRef::sized("f", Bytes::gb(*gb))],
                        affinity: None,
                    }));
                    for l in labels {
                        st.note_replica(&id, &Label::new(l));
                    }
                    du_ids.push(id);
                }
                let store = Store::new();
                st.checkpoint(&store).map_err(|e| e.to_string())?;
                let back = ManagerState::reconnect(&store).map_err(|e| e.to_string())?;
                if back.du_locations() != st.du_locations() {
                    return Err(format!(
                        "du_locations not restored:\n pre:  {:?}\n post: {:?}",
                        st.du_locations(),
                        back.du_locations()
                    ));
                }
                let topo = Topology::new();
                let sched_a = AffinityScheduler::new(None);
                let sched_b = AffinityScheduler::new(None);
                for (cores, aff, inputs) in cus {
                    let cu = ComputeUnit::new(ComputeUnitDescription {
                        executable: "x".into(),
                        cores: *cores,
                        input_data: inputs.iter().map(|i| du_ids[*i].clone()).collect(),
                        affinity: aff.as_deref().map(Label::new),
                        ..Default::default()
                    });
                    let ctx_pre = SchedContext::from_state(&topo, &st);
                    let ctx_post = SchedContext::from_state(&topo, &back);
                    for p in st.pilots.values() {
                        let pre = ctx_pre.data_score(&cu, p.affinity_ref());
                        let post = ctx_post.data_score(&cu, p.affinity_ref());
                        if pre.to_bits() != post.to_bits() {
                            return Err(format!(
                                "data_score({}, {}) pre {pre} != post {post}",
                                cu.id, p.id
                            ));
                        }
                    }
                    let a = sched_a.place(&cu, &ctx_pre);
                    let b = sched_b.place(&cu, &ctx_post);
                    if a != b {
                        return Err(format!("placement pre {a:?} != post {b:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pilots_by_label_index_tracks_additions() {
        let mut st = ManagerState::new();
        let a = st.add_pilot(PilotCompute::new(pcd("lonestar", 8, "xsede/tacc/lonestar")));
        let b = st.add_pilot(PilotCompute::new(pcd("lonestar2", 8, "xsede/tacc/lonestar")));
        let c = st.add_pilot(PilotCompute::new(pcd("fnal", 8, "osg/fnal")));
        let tacc = Label::new("xsede/tacc/lonestar");
        assert_eq!(st.pilots_at_label(&tacc), &[a, b]);
        assert_eq!(st.pilots_at_label(&Label::new("osg/fnal")), &[c]);
        assert!(st.pilots_at_label(&Label::new("nowhere")).is_empty());
    }

    #[test]
    fn pilots_within_prunes_by_label_subtree() {
        let mut st = ManagerState::new();
        let a = st.add_pilot(PilotCompute::new(pcd("ls", 8, "xsede/tacc/lonestar")));
        let b = st.add_pilot(PilotCompute::new(pcd("st", 8, "xsede/tacc/stampede")));
        let c = st.add_pilot(PilotCompute::new(pcd("fnal", 8, "osg/fnal")));
        // Adversarial sibling: shares the string prefix but not the
        // component prefix.
        let d = st.add_pilot(PilotCompute::new(pcd("tc2", 8, "xsede/tacc2")));
        let got = st.pilots_within(&Label::new("xsede/tacc"));
        let mut want = vec![a.as_str(), b.as_str()];
        want.sort_unstable();
        assert_eq!(got, want);
        assert_eq!(st.pilots_within(&Label::new("xsede/tacc/lonestar")), vec![a.as_str()]);
        assert_eq!(st.pilots_within(&Label::new("osg")), vec![c.as_str()]);
        assert!(st.pilots_within(&Label::new("nowhere")).is_empty());
        // Empty constraint = whole fleet, in id order.
        let mut all = vec![a.as_str(), b.as_str(), c.as_str(), d.as_str()];
        all.sort_unstable();
        assert_eq!(st.pilots_within(&Label::new("")), all);
        // Matches the brute-force definition on every pilot.
        for constraint in ["", "xsede", "xsede/tacc", "xsede/tacc2", "osg/fnal"] {
            let constraint = Label::new(constraint);
            let mut brute: Vec<&str> = st
                .pilots
                .values()
                .filter(|p| p.affinity_ref().within(&constraint))
                .map(|p| p.id.as_str())
                .collect();
            brute.sort_unstable();
            assert_eq!(st.pilots_within(&constraint), brute, "constraint {constraint}");
        }
    }

    #[test]
    fn workload_finished_logic() {
        let mut st = ManagerState::new();
        assert!(st.workload_finished()); // vacuous
        let cu = ComputeUnit::new(Default::default());
        let id = st.add_cu(cu);
        assert!(!st.workload_finished());
        let c = st.cus.get_mut(&id).unwrap();
        c.state = CuState::Done;
        assert!(st.workload_finished());
        assert_eq!(st.count_cu_state(CuState::Done), 1);
    }
}
