//! Batch-queue simulation: machines, queue-wait models, slot accounting.
//!
//! Pilot startup on production DCI is dominated by the local resource
//! manager's queue wait T_Q (paper §6.1). We model each machine with a
//! heavy-tailed (log-normal) wait distribution whose parameters are
//! calibrated per machine class from the values the paper reports
//! (e.g. Stampede's mean T_Q ≈ 8100 s in Fig. 11 scenario 3, OSG pilots
//! waiting longer than XSEDE ones in Fig. 9), plus core/slot accounting
//! and walltime limits.

use crate::net::Bandwidth;
use crate::rng::Rng;
use crate::topology::Label;
use std::collections::BTreeMap;

/// Queue wait-time model for a machine: `T_Q = base + LogNormal(mu,
/// sigma)` seconds, truncated at `cap`.
#[derive(Debug, Clone)]
pub struct QueueModel {
    pub base: f64,
    pub mu: f64,
    pub sigma: f64,
    pub cap: f64,
}

impl QueueModel {
    /// A queue with the given mean wait and mild heavy tail. We pick
    /// sigma, then solve mu so that the log-normal mean `exp(mu +
    /// sigma²/2)` matches `mean_wait - base`.
    pub fn with_mean(base: f64, mean_wait: f64, sigma: f64) -> QueueModel {
        let excess = (mean_wait - base).max(1.0);
        let mu = excess.ln() - sigma * sigma / 2.0;
        QueueModel { base, mu, sigma, cap: mean_wait * 10.0 }
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        (self.base + rng.lognormal(self.mu, self.sigma)).min(self.cap)
    }

    /// Analytic mean of the model (for reporting / assertions).
    pub fn mean(&self) -> f64 {
        self.base + (self.mu + self.sigma * self.sigma / 2.0).exp()
    }
}

/// A compute resource: cores, shared-filesystem aggregate bandwidth
/// (the Lustre/GPFS I/O ceiling that Fig. 11/12 shows saturating), and a
/// queue model.
#[derive(Debug, Clone)]
pub struct Machine {
    pub name: String,
    pub label: Label,
    pub cores: u32,
    pub queue: QueueModel,
    /// Aggregate shared-FS bandwidth; concurrent I/O-heavy tasks share it.
    pub fs_bandwidth: Bandwidth,
    /// Maximum walltime for a pilot job (seconds).
    pub walltime_limit: f64,
    /// Max cores a single pilot may marshal (OSG pilots are 1 core/node).
    pub max_pilot_cores: u32,
    /// Relative CPU speed (1.0 = reference machine; >1 = slower).
    pub speed_factor: f64,
}

impl Machine {
    pub fn new(name: &str, label: &str, cores: u32) -> Machine {
        Machine {
            name: name.to_string(),
            label: Label::new(label),
            cores,
            queue: QueueModel::with_mean(30.0, 600.0, 1.0),
            fs_bandwidth: Bandwidth::mbps(2000.0),
            walltime_limit: 48.0 * 3600.0,
            max_pilot_cores: u32::MAX,
            speed_factor: 1.0,
        }
    }

    pub fn with_speed_factor(mut self, f: f64) -> Machine {
        self.speed_factor = f;
        self
    }

    pub fn with_queue(mut self, q: QueueModel) -> Machine {
        self.queue = q;
        self
    }

    pub fn with_fs_bandwidth(mut self, bw: Bandwidth) -> Machine {
        self.fs_bandwidth = bw;
        self
    }

    pub fn with_max_pilot_cores(mut self, n: u32) -> Machine {
        self.max_pilot_cores = n;
        self
    }
}

/// Slot accounting across a set of machines. Tracks cores handed to
/// active pilots and the number of I/O-active tasks per machine (for the
/// shared-FS contention model).
#[derive(Debug, Default)]
pub struct BatchState {
    machines: BTreeMap<String, Machine>,
    used_cores: BTreeMap<String, u32>,
    io_active: BTreeMap<String, u32>,
}

impl BatchState {
    pub fn new(machines: Vec<Machine>) -> BatchState {
        let mut m = BTreeMap::new();
        for mach in machines {
            m.insert(mach.name.clone(), mach);
        }
        BatchState { machines: m, used_cores: BTreeMap::new(), io_active: BTreeMap::new() }
    }

    pub fn machine(&self, name: &str) -> anyhow::Result<&Machine> {
        self.machines
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown machine '{name}'"))
    }

    pub fn machines(&self) -> impl Iterator<Item = &Machine> {
        self.machines.values()
    }

    /// Override a machine's queue model (experiments replay specific
    /// observed waits, e.g. Stampede's 8100 s mean in Fig. 11 sc. 3).
    pub fn set_queue(&mut self, name: &str, q: QueueModel) -> anyhow::Result<()> {
        self.machines
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("unknown machine '{name}'"))?
            .queue = q;
        Ok(())
    }

    /// Override a machine's relative CPU speed.
    pub fn set_speed_factor(&mut self, name: &str, f: f64) -> anyhow::Result<()> {
        self.machines
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("unknown machine '{name}'"))?
            .speed_factor = f;
        Ok(())
    }

    /// Override a machine's shared-FS bandwidth.
    pub fn set_fs_bandwidth(&mut self, name: &str, bw: Bandwidth) -> anyhow::Result<()> {
        self.machines
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("unknown machine '{name}'"))?
            .fs_bandwidth = bw;
        Ok(())
    }

    /// Sample the queue wait for a pilot requesting `cores` on `name`
    /// and reserve the cores (they are released with
    /// [`BatchState::release`]). Errors if the request exceeds machine
    /// capacity or the per-pilot limit.
    pub fn submit(&mut self, name: &str, cores: u32, rng: &mut Rng) -> anyhow::Result<f64> {
        let m = self.machine(name)?;
        if cores > m.max_pilot_cores {
            anyhow::bail!(
                "pilot of {cores} cores exceeds per-pilot limit {} on {name}",
                m.max_pilot_cores
            );
        }
        if cores > m.cores {
            anyhow::bail!("pilot of {cores} cores exceeds machine capacity {} on {name}", m.cores);
        }
        let wait = m.queue.sample(rng);
        // Heavier requests relative to the machine wait longer: scale
        // the sampled wait by (1 + fraction requested).
        let frac = cores as f64 / m.cores as f64;
        let wait = wait * (1.0 + frac);
        *self.used_cores.entry(name.to_string()).or_insert(0) += cores;
        Ok(wait)
    }

    pub fn release(&mut self, name: &str, cores: u32) {
        if let Some(u) = self.used_cores.get_mut(name) {
            *u = u.saturating_sub(cores);
        }
    }

    pub fn used(&self, name: &str) -> u32 {
        *self.used_cores.get(name).unwrap_or(&0)
    }

    /// Mark a task on `name` as performing heavy I/O (entering its
    /// staging or scan phase); returns current I/O-active count
    /// including this one.
    pub fn io_begin(&mut self, name: &str) -> u32 {
        let n = self.io_active.entry(name.to_string()).or_insert(0);
        *n += 1;
        *n
    }

    pub fn io_end(&mut self, name: &str) {
        if let Some(n) = self.io_active.get_mut(name) {
            *n = n.saturating_sub(1);
        }
    }

    pub fn io_active(&self, name: &str) -> u32 {
        *self.io_active.get(name).unwrap_or(&0)
    }

    /// Per-task share of the machine's shared-FS bandwidth given current
    /// I/O activity — the Fig. 11 "Lustre saturates at 1024 concurrent
    /// readers" effect.
    pub fn fs_share(&self, name: &str) -> Bandwidth {
        let m = &self.machines[name];
        let sharers = (self.io_active(name).max(1)) as f64;
        Bandwidth(m.fs_bandwidth.0 / sharers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_model_mean_calibration() {
        let q = QueueModel::with_mean(30.0, 600.0, 1.0);
        assert!((q.mean() - 600.0).abs() < 1.0);
        let mut rng = Rng::new(1);
        let n = 30_000;
        let m: f64 = (0..n).map(|_| q.sample(&mut rng)).sum::<f64>() / n as f64;
        // Sampled mean within 10% (cap truncation biases slightly low).
        assert!((m - 600.0).abs() < 60.0, "sampled mean {m}");
    }

    #[test]
    fn samples_nonnegative_and_capped() {
        let q = QueueModel::with_mean(10.0, 100.0, 2.0);
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            let s = q.sample(&mut rng);
            assert!(s >= 10.0 && s <= 1000.0, "s={s}");
        }
    }

    #[test]
    fn submit_reserves_and_release_frees() {
        let mut bs = BatchState::new(vec![Machine::new("lonestar", "xsede/tacc/lonestar", 2048)]);
        let mut rng = Rng::new(3);
        let w = bs.submit("lonestar", 1024, &mut rng).unwrap();
        assert!(w > 0.0);
        assert_eq!(bs.used("lonestar"), 1024);
        bs.release("lonestar", 1024);
        assert_eq!(bs.used("lonestar"), 0);
    }

    #[test]
    fn oversized_requests_rejected() {
        let mut bs = BatchState::new(vec![
            Machine::new("osg-node", "osg/purdue", 8).with_max_pilot_cores(1),
        ]);
        let mut rng = Rng::new(4);
        assert!(bs.submit("osg-node", 4, &mut rng).is_err()); // per-pilot limit
        assert!(bs.submit("osg-node", 1, &mut rng).is_ok());
        assert!(bs.submit("nowhere", 1, &mut rng).is_err());
    }

    #[test]
    fn fs_share_divides_by_io_activity() {
        let mut bs = BatchState::new(vec![Machine::new("m", "x/m", 64)
            .with_fs_bandwidth(Bandwidth::mbps(1000.0))]);
        let full = bs.fs_share("m").0;
        bs.io_begin("m");
        bs.io_begin("m");
        assert!((bs.fs_share("m").0 - full / 2.0).abs() < 1.0);
        bs.io_end("m");
        bs.io_end("m");
        assert_eq!(bs.io_active("m"), 0);
        assert!((bs.fs_share("m").0 - full).abs() < 1.0);
    }

    #[test]
    fn io_accounting_property_never_negative() {
        crate::prop::check_default(
            |rng| {
                (0..crate::prop::gen::usize_in(rng, 1, 60))
                    .map(|_| rng.chance(0.5))
                    .collect::<Vec<bool>>()
            },
            |ops| {
                let mut bs =
                    BatchState::new(vec![Machine::new("m", "x/m", 8)]);
                let mut live = 0i64;
                for begin in ops {
                    if *begin {
                        bs.io_begin("m");
                        live += 1;
                    } else {
                        bs.io_end("m");
                        live = (live - 1).max(0);
                    }
                }
                if bs.io_active("m") as i64 == live {
                    Ok(())
                } else {
                    Err(format!("io_active={} expected {live}", bs.io_active("m")))
                }
            },
        );
    }
}
