//! Resource topology and the affinity model (paper §5, Fig. 6) — now
//! built around an **interned node arena**.
//!
//! Data centers and machines are organized in a logical topology tree;
//! the further the distance between two resources, the smaller their
//! affinity. Resources are named by slash-separated *affinity labels*
//! exactly as in the Pilot-Description (e.g. `us-east/tacc/lonestar`),
//! and the tree is built implicitly from the labels in use. Edges may
//! carry weights to reflect dynamic connectivity differences (the
//! paper's proposed enhancement).
//!
//! # Interned model (perf)
//!
//! Every label interns to a [`NodeId`] (`u32`) in a [`NodeArena`]: one
//! hash of the full path string on the way in, then a record of
//! `(parent, depth, weight-above)` per node. Once interned,
//! LCA/`distance`/`within` are pure integer walks over `Vec`-indexed
//! parent chains — no string splitting, no slicing, zero heap
//! allocations:
//!
//! * [`Topology::node`] — intern a label (O(components) first time,
//!   O(1) full-string hash after);
//! * [`Topology::distance_id`] / [`Topology::affinity_id`] — integer
//!   LCA climb plus precomputed per-edge weights;
//! * [`Topology::distance_interned`] / [`Topology::affinity_interned`]
//!   — label-keyed front door to the same id walk (one arena lock, two
//!   hash lookups); this is what the scheduler's `data_score` hot loop
//!   calls.
//!
//! The id walk is engineered to be **bit-identical** to the retained
//! string implementation ([`Topology::distance`]): the defaults-only
//! fast path uses the same multiplication, and weighted sums accumulate
//! per side in increasing depth order, mirroring the string walk's
//! float-addition order exactly (property-tested in this module).
//! The string API is kept as the compat shim and the property-test
//! reference; the arena lives behind a `Mutex` so interning works
//! through `&Topology` (the scheduler only ever sees a shared
//! reference). [`NodeArena`] is reused by [`crate::net`], which keys
//! its uplink capacities and flow counters by the same id scheme.

use crate::coordination::FxMap;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// An affinity label: a path in the logical topology tree.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Label(pub String);

impl Label {
    pub fn new(s: &str) -> Label {
        Label(s.trim_matches('/').to_string())
    }

    pub fn components(&self) -> Vec<&str> {
        if self.0.is_empty() {
            vec![]
        } else {
            self.0.split('/').collect()
        }
    }

    /// Number of components, without allocating.
    pub fn depth(&self) -> usize {
        if self.0.is_empty() {
            0
        } else {
            self.0.split('/').count()
        }
    }

    /// Depth of the deepest shared ancestor with `other`.
    /// Allocation-free: this sits inside the scheduler's per-pilot
    /// scoring loop.
    pub fn common_prefix_len(&self, other: &Label) -> usize {
        if self.0.is_empty() || other.0.is_empty() {
            return 0;
        }
        self.0
            .split('/')
            .zip(other.0.split('/'))
            .take_while(|(a, b)| a == b)
            .count()
    }

    /// True if `self` lies in the subtree rooted at `prefix` — used for
    /// affinity *constraints* ("run only under `xsede/tacc`").
    pub fn within(&self, prefix: &Label) -> bool {
        let pc = prefix.depth();
        pc <= self.depth() && self.common_prefix_len(prefix) == pc
    }
}

impl std::fmt::Display for Label {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl From<&str> for Label {
    fn from(s: &str) -> Label {
        Label::new(s)
    }
}

/// Interned identity of one topology-tree node. Valid only for the
/// arena (Topology/Network) that minted it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub u32);

impl NodeId {
    /// The tree root (the empty label).
    pub const ROOT: NodeId = NodeId(0);

    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Append-only arena of topology-tree nodes: full-path interning plus
/// per-node `(parent, depth)` so ancestor walks are integer chases over
/// dense `Vec`s. Node 0 is always the root (empty label); interning a
/// label also interns its whole prefix chain, so every node's parent
/// exists by construction.
#[derive(Debug, Clone)]
pub struct NodeArena {
    /// Full normalized path -> node index.
    map: FxMap<String, u32>,
    parent: Vec<u32>,
    depth: Vec<u32>,
    /// Full path per node (compat shims and diagnostics only).
    paths: Vec<String>,
}

impl Default for NodeArena {
    fn default() -> Self {
        NodeArena::new()
    }
}

impl NodeArena {
    pub fn new() -> NodeArena {
        let mut map = FxMap::default();
        map.insert(String::new(), 0);
        NodeArena { map, parent: vec![0], depth: vec![0], paths: vec![String::new()] }
    }

    /// Number of nodes (≥ 1: the root always exists).
    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        false // the root always exists
    }

    /// O(1): one hash of the full path. `None` if never interned.
    pub fn lookup(&self, label: &Label) -> Option<NodeId> {
        self.lookup_str(label.0.as_str())
    }

    /// [`NodeArena::lookup`] by raw path slice — lets compat shims
    /// probe label *prefixes* without allocating substrings.
    pub fn lookup_str(&self, path: &str) -> Option<NodeId> {
        self.map.get(path).map(|&i| NodeId(i))
    }

    /// Intern `label` (and its whole prefix chain), returning the node
    /// of the deepest component. O(1) full-string hash when already
    /// interned.
    pub fn intern(&mut self, label: &Label) -> NodeId {
        if let Some(&i) = self.map.get(label.0.as_str()) {
            return NodeId(i);
        }
        let s = label.0.as_str();
        let mut node = 0u32;
        let mut depth = 0u32;
        let ends = s.match_indices('/').map(|(i, _)| i).chain(std::iter::once(s.len()));
        for end in ends {
            depth += 1;
            let prefix = &s[..end];
            node = match self.map.get(prefix) {
                Some(&i) => i,
                None => {
                    let id = self.parent.len() as u32;
                    self.parent.push(node);
                    self.depth.push(depth);
                    self.paths.push(prefix.to_string());
                    self.map.insert(prefix.to_string(), id);
                    id
                }
            };
        }
        NodeId(node)
    }

    pub fn parent(&self, n: NodeId) -> NodeId {
        NodeId(self.parent[n.index()])
    }

    pub fn depth(&self, n: NodeId) -> u32 {
        self.depth[n.index()]
    }

    /// Full label path of a node ("" for the root).
    pub fn path_str(&self, n: NodeId) -> &str {
        &self.paths[n.index()]
    }

    /// Lowest common ancestor: lift the deeper side, then climb both.
    pub fn lca(&self, a: NodeId, b: NodeId) -> NodeId {
        let (mut x, mut y) = (a.0 as usize, b.0 as usize);
        while self.depth[x] > self.depth[y] {
            x = self.parent[x] as usize;
        }
        while self.depth[y] > self.depth[x] {
            y = self.parent[y] as usize;
        }
        while x != y {
            x = self.parent[x] as usize;
            y = self.parent[y] as usize;
        }
        NodeId(x as u32)
    }

    /// The node on `n`'s parent chain at exactly `depth` (≤ `n`'s own).
    pub fn ancestor_at(&self, n: NodeId, depth: u32) -> NodeId {
        let mut x = n.0 as usize;
        while self.depth[x] > depth {
            x = self.parent[x] as usize;
        }
        NodeId(x as u32)
    }

    /// Integer image of [`Label::within`]: is `n` in `root`'s subtree?
    pub fn within(&self, n: NodeId, root: NodeId) -> bool {
        self.depth(n) >= self.depth(root) && self.ancestor_at(n, self.depth(root)) == root
    }
}

/// Arena plus per-node edge weights (weight of the uplink edge *above*
/// each node), kept in lockstep with `Topology::edge_weights`.
#[derive(Debug, Clone)]
struct Interned {
    arena: NodeArena,
    weight_above: Vec<f64>,
}

/// The topology tree with per-edge weights. An edge is identified by the
/// label of its *child* endpoint; unlisted edges weigh
/// `default_edge_weight`.
#[derive(Debug)]
pub struct Topology {
    default_edge_weight: f64,
    /// String-keyed override view: the compat API and the property-test
    /// reference. The interned `weight_above` mirrors it exactly.
    edge_weights: BTreeMap<String, f64>,
    /// Node arena behind a mutex so interning works through
    /// `&Topology` — the scheduler only ever holds a shared reference.
    interned: Mutex<Interned>,
}

impl Default for Topology {
    fn default() -> Self {
        Topology {
            default_edge_weight: 1.0,
            edge_weights: BTreeMap::new(),
            interned: Mutex::new(Interned {
                arena: NodeArena::new(),
                weight_above: vec![0.0],
            }),
        }
    }
}

impl Clone for Topology {
    fn clone(&self) -> Topology {
        Topology {
            default_edge_weight: self.default_edge_weight,
            edge_weights: self.edge_weights.clone(),
            interned: Mutex::new(self.interned.lock().unwrap().clone()),
        }
    }
}

impl Topology {
    pub fn new() -> Topology {
        Topology::default()
    }

    /// Fill `weight_above` for nodes interned since the last sync. New
    /// nodes can only carry an override if it was set by
    /// `set_edge_weight` (which interns eagerly), so the lookup is a
    /// correctness belt, not a hot path.
    fn sync_weights(&self, inner: &mut Interned) {
        while inner.weight_above.len() < inner.arena.len() {
            let path = inner.arena.path_str(NodeId(inner.weight_above.len() as u32));
            let w = *self.edge_weights.get(path).unwrap_or(&self.default_edge_weight);
            inner.weight_above.push(w);
        }
    }

    /// Override the weight of the edge above the node named by `label`.
    pub fn set_edge_weight(&mut self, label: &str, weight: f64) {
        assert!(weight >= 0.0);
        let label = Label::new(label);
        self.edge_weights.insert(label.0.clone(), weight);
        let mut inner = self.interned.lock().unwrap();
        let id = inner.arena.intern(&label);
        self.sync_weights(&mut inner);
        inner.weight_above[id.index()] = weight;
    }

    /// Intern `label` into the node arena (O(1) once interned).
    pub fn node(&self, label: &Label) -> NodeId {
        let mut inner = self.interned.lock().unwrap();
        let id = inner.arena.intern(label);
        self.sync_weights(&mut inner);
        id
    }

    /// Weighted hops above `n` down to (exclusive) `from_depth`,
    /// mirroring the string `suffix_weight` branch-for-branch — same
    /// multiplication on the defaults-only fast path, same
    /// increasing-depth addition order otherwise — so id distances are
    /// bit-identical to string distances.
    fn suffix_weight_id(&self, inner: &Interned, n: NodeId, from_depth: u32) -> f64 {
        let nd = inner.arena.depth(n);
        if self.edge_weights.is_empty() {
            return (nd - from_depth) as f64 * self.default_edge_weight;
        }
        let mut w = 0.0;
        for d in (from_depth + 1)..=nd {
            let node = inner.arena.ancestor_at(n, d);
            w += inner.weight_above[node.index()];
        }
        w
    }

    fn distance_id_inner(&self, inner: &Interned, a: NodeId, b: NodeId) -> f64 {
        let common = inner.arena.depth(inner.arena.lca(a, b));
        self.suffix_weight_id(inner, a, common) + self.suffix_weight_id(inner, b, common)
    }

    /// Tree distance between two interned nodes: an integer LCA climb
    /// plus precomputed per-edge weights. Zero heap allocations.
    pub fn distance_id(&self, a: NodeId, b: NodeId) -> f64 {
        let inner = self.interned.lock().unwrap();
        self.distance_id_inner(&inner, a, b)
    }

    /// Affinity in (0, 1] over interned nodes.
    pub fn affinity_id(&self, a: NodeId, b: NodeId) -> f64 {
        1.0 / (1.0 + self.distance_id(a, b))
    }

    /// [`Topology::distance`] through the arena: one lock, two
    /// full-string hash lookups, then the integer walk. This is the
    /// scheduler's `data_score` hot path.
    pub fn distance_interned(&self, a: &Label, b: &Label) -> f64 {
        let mut inner = self.interned.lock().unwrap();
        let ai = inner.arena.intern(a);
        let bi = inner.arena.intern(b);
        self.sync_weights(&mut inner);
        self.distance_id_inner(&inner, ai, bi)
    }

    /// [`Topology::affinity`] through the arena (see
    /// [`Topology::distance_interned`]).
    pub fn affinity_interned(&self, a: &Label, b: &Label) -> f64 {
        1.0 / (1.0 + self.distance_interned(a, b))
    }

    /// Total weight of the edges above `label`'s nodes deeper than
    /// `from_depth`. Edge keys are label *prefixes*, so lookups slice
    /// the original string instead of joining components. Retained as
    /// the string reference implementation the interned walk is
    /// property-tested against.
    fn suffix_weight(&self, label: &Label, from_depth: usize) -> f64 {
        let s = label.0.as_str();
        if s.is_empty() {
            return 0.0;
        }
        if self.edge_weights.is_empty() {
            // Fast path: every edge weighs the default.
            return (label.depth() - from_depth) as f64 * self.default_edge_weight;
        }
        let mut w = 0.0;
        let mut depth = 0usize;
        let ends = s.match_indices('/').map(|(i, _)| i).chain(std::iter::once(s.len()));
        for end in ends {
            depth += 1;
            if depth > from_depth {
                w += *self.edge_weights.get(&s[..end]).unwrap_or(&self.default_edge_weight);
            }
        }
        w
    }

    /// Tree distance between two labels: the weighted number of hops up
    /// from each label to their lowest common ancestor. String compat
    /// shim and property-test reference; hot paths use
    /// [`Topology::distance_interned`] / [`Topology::distance_id`].
    pub fn distance(&self, a: &Label, b: &Label) -> f64 {
        let common = a.common_prefix_len(b);
        self.suffix_weight(a, common) + self.suffix_weight(b, common)
    }

    /// Affinity in (0, 1]: 1 for identical labels, decreasing with
    /// distance. The paper: "the smaller the distance between two
    /// resources, the larger the affinity".
    pub fn affinity(&self, a: &Label, b: &Label) -> f64 {
        1.0 / (1.0 + self.distance(a, b))
    }

    /// Of `candidates`, those with maximal affinity to `target`.
    pub fn closest<'a>(&self, target: &Label, candidates: &'a [Label]) -> Vec<&'a Label> {
        if candidates.is_empty() {
            return vec![];
        }
        let best = candidates
            .iter()
            .map(|c| self.affinity_interned(target, c))
            .fold(f64::MIN, f64::max);
        candidates
            .iter()
            .filter(|c| (self.affinity_interned(target, c) - best).abs() < 1e-12)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l(s: &str) -> Label {
        Label::new(s)
    }

    #[test]
    fn label_components_and_prefix() {
        let a = l("us-east/tacc/lonestar");
        assert_eq!(a.components(), vec!["us-east", "tacc", "lonestar"]);
        assert_eq!(a.common_prefix_len(&l("us-east/tacc/stampede")), 2);
        assert_eq!(a.common_prefix_len(&l("eu/surfsara")), 0);
        assert!(a.within(&l("us-east/tacc")));
        assert!(a.within(&a));
        assert!(!a.within(&l("us-east/purdue")));
        assert!(l("").within(&l("")));
    }

    #[test]
    fn distance_is_symmetric_zero_on_self() {
        let t = Topology::new();
        let a = l("us-east/tacc/lonestar");
        let b = l("us-east/purdue/condor");
        assert_eq!(t.distance(&a, &a), 0.0);
        assert_eq!(t.distance(&a, &b), t.distance(&b, &a));
        // lonestar->tacc->us-east (2 edges) + us-east->purdue->condor (2)
        assert_eq!(t.distance(&a, &b), 4.0);
        // Same site, different machine: 1 up + 1 down.
        assert_eq!(t.distance(&a, &l("us-east/tacc/stampede")), 2.0);
    }

    #[test]
    fn affinity_ordering_matches_paper_model() {
        let t = Topology::new();
        let lonestar = l("us-east/tacc/lonestar");
        let same = t.affinity(&lonestar, &lonestar);
        let same_site = t.affinity(&lonestar, &l("us-east/tacc/stampede"));
        let same_region = t.affinity(&lonestar, &l("us-east/purdue/condor"));
        let far = t.affinity(&lonestar, &l("eu/surfsara/grid"));
        assert!(same > same_site && same_site > same_region && same_region > far);
        assert_eq!(same, 1.0);
    }

    #[test]
    fn weighted_edges_change_distance() {
        let mut t = Topology::new();
        // Make the WAN hop to EU expensive.
        t.set_edge_weight("eu", 10.0);
        let a = l("us-east/tacc/lonestar");
        let eu = l("eu/surfsara");
        // 3 edges up from lonestar (weight 1 each) + down: "eu" (10) + "eu/surfsara" (1).
        assert_eq!(t.distance(&a, &eu), 3.0 + 10.0 + 1.0);
        // Interned walk sees the same weights.
        assert_eq!(t.distance_interned(&a, &eu), 14.0);
        assert_eq!(t.distance_id(t.node(&a), t.node(&eu)), 14.0);
    }

    #[test]
    fn closest_picks_max_affinity() {
        let t = Topology::new();
        let target = l("osg/purdue");
        let cands = vec![l("osg/purdue"), l("osg/cornell"), l("xsede/tacc/lonestar")];
        let best = t.closest(&target, &cands);
        assert_eq!(best, vec![&cands[0]]);
        // Ties: two equally-far candidates are both returned.
        let cands2 = vec![l("osg/cornell"), l("osg/tacc")];
        assert_eq!(t.closest(&target, &cands2).len(), 2);
    }

    #[test]
    fn arena_interns_prefix_chains_once() {
        let mut arena = NodeArena::new();
        let a = arena.intern(&l("osg/purdue/c1"));
        assert_eq!(arena.depth(a), 3);
        assert_eq!(arena.path_str(a), "osg/purdue/c1");
        // Parent chain exists and is shared with siblings.
        let purdue = arena.parent(a);
        assert_eq!(arena.path_str(purdue), "osg/purdue");
        let b = arena.intern(&l("osg/purdue/c2"));
        assert_eq!(arena.parent(b), purdue);
        // Re-interning is identity; lookup agrees.
        assert_eq!(arena.intern(&l("osg/purdue/c1")), a);
        assert_eq!(arena.lookup(&l("osg/purdue")), Some(purdue));
        assert_eq!(arena.lookup(&l("osg/nowhere")), None);
        // Root is node 0.
        assert_eq!(arena.intern(&l("")), NodeId::ROOT);
        assert_eq!(arena.depth(NodeId::ROOT), 0);
    }

    #[test]
    fn arena_lca_and_within_match_label_math() {
        let mut arena = NodeArena::new();
        let ls = arena.intern(&l("xsede/tacc/lonestar"));
        let st = arena.intern(&l("xsede/tacc/stampede"));
        let osg = arena.intern(&l("osg/purdue"));
        let tacc = arena.lookup(&l("xsede/tacc")).unwrap();
        assert_eq!(arena.lca(ls, st), tacc);
        assert_eq!(arena.lca(ls, ls), ls);
        assert_eq!(arena.lca(ls, osg), NodeId::ROOT);
        assert!(arena.within(ls, tacc));
        assert!(arena.within(ls, ls));
        assert!(!arena.within(tacc, ls));
        assert!(!arena.within(osg, tacc));
        assert!(arena.within(osg, NodeId::ROOT));
        // Adversarial sibling: "xsede/tacc2" shares the string prefix
        // but not the component prefix.
        let tc2 = arena.intern(&l("xsede/tacc2"));
        assert!(!arena.within(tc2, tacc));
        assert_eq!(arena.ancestor_at(ls, 1), arena.lookup(&l("xsede")).unwrap());
    }

    #[test]
    fn triangle_inequality_property() {
        crate::prop::check_default(
            |rng| {
                let mk = |rng: &mut crate::rng::Rng| {
                    let depth = crate::prop::gen::usize_in(rng, 1, 4);
                    let parts: Vec<String> =
                        (0..depth).map(|d| format!("n{}", rng.below(3 + d as u64))).collect();
                    Label::new(&parts.join("/"))
                };
                (mk(rng), mk(rng), mk(rng))
            },
            |(a, b, c)| {
                let t = Topology::new();
                let ab = t.distance(a, b);
                let bc = t.distance(b, c);
                let ac = t.distance(a, c);
                if ac <= ab + bc + 1e-9 {
                    Ok(())
                } else {
                    Err(format!("triangle violated: d({a},{c})={ac} > {ab}+{bc}"))
                }
            },
        );
    }

    /// Tentpole acceptance: the interned id walk must be bit-identical
    /// to the string reference on randomized topologies — same labels,
    /// random edge-weight overrides (including the defaults-only fast
    /// path), every pair compared via `f64::to_bits`.
    #[test]
    fn interned_distance_matches_string_reference_property() {
        crate::prop::check_default(
            |rng| {
                let mk = |rng: &mut crate::rng::Rng| {
                    let depth = crate::prop::gen::usize_in(rng, 0, 5);
                    let parts: Vec<String> =
                        (0..depth).map(|d| format!("n{}", rng.below(3 + d as u64))).collect();
                    Label::new(&parts.join("/"))
                };
                let labels: Vec<Label> = (0..crate::prop::gen::usize_in(rng, 2, 8))
                    .map(|_| mk(rng))
                    .collect();
                let n_weights = if rng.chance(0.3) {
                    0 // defaults-only fast path
                } else {
                    crate::prop::gen::usize_in(rng, 1, 5)
                };
                let weights: Vec<(Label, f64)> = (0..n_weights)
                    .map(|_| (mk(rng), rng.range_f64(0.1, 9.0)))
                    .collect();
                (labels, weights)
            },
            |(labels, weights)| {
                let mut t = Topology::new();
                for (label, w) in weights {
                    if !label.0.is_empty() {
                        t.set_edge_weight(&label.0, *w);
                    }
                }
                for a in labels {
                    for b in labels {
                        let string = t.distance(a, b);
                        let interned = t.distance_interned(a, b);
                        let by_id = t.distance_id(t.node(a), t.node(b));
                        if string.to_bits() != interned.to_bits() {
                            return Err(format!(
                                "d({a},{b}): string {string} != interned {interned}"
                            ));
                        }
                        if string.to_bits() != by_id.to_bits() {
                            return Err(format!("d({a},{b}): string {string} != id {by_id}"));
                        }
                        if t.affinity(a, b).to_bits() != t.affinity_interned(a, b).to_bits() {
                            return Err(format!("affinity({a},{b}) diverges"));
                        }
                        // within() and the arena's subtree test agree.
                        let arena_within = {
                            let inner = t.interned.lock().unwrap();
                            let (ai, bi) = (inner.arena.lookup(a).unwrap(), inner.arena.lookup(b).unwrap());
                            inner.arena.within(ai, bi)
                        };
                        if arena_within != a.within(b) {
                            return Err(format!("within({a},{b}) diverges"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
