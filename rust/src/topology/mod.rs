//! Resource topology and the affinity model (paper §5, Fig. 6).
//!
//! Data centers and machines are organized in a logical topology tree;
//! the further the distance between two resources, the smaller their
//! affinity. Resources are named by slash-separated *affinity labels*
//! exactly as in the Pilot-Description (e.g.
//! `us-east/tacc/lonestar`), and the tree is built implicitly from the
//! labels in use. Edges may carry weights to reflect dynamic
//! connectivity differences (the paper's proposed enhancement).

use std::collections::BTreeMap;

/// An affinity label: a path in the logical topology tree.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Label(pub String);

impl Label {
    pub fn new(s: &str) -> Label {
        Label(s.trim_matches('/').to_string())
    }

    pub fn components(&self) -> Vec<&str> {
        if self.0.is_empty() {
            vec![]
        } else {
            self.0.split('/').collect()
        }
    }

    /// Number of components, without allocating.
    pub fn depth(&self) -> usize {
        if self.0.is_empty() {
            0
        } else {
            self.0.split('/').count()
        }
    }

    /// Depth of the deepest shared ancestor with `other`.
    /// Allocation-free: this sits inside the scheduler's per-pilot
    /// scoring loop.
    pub fn common_prefix_len(&self, other: &Label) -> usize {
        if self.0.is_empty() || other.0.is_empty() {
            return 0;
        }
        self.0
            .split('/')
            .zip(other.0.split('/'))
            .take_while(|(a, b)| a == b)
            .count()
    }

    /// True if `self` lies in the subtree rooted at `prefix` — used for
    /// affinity *constraints* ("run only under `xsede/tacc`").
    pub fn within(&self, prefix: &Label) -> bool {
        let pc = prefix.depth();
        pc <= self.depth() && self.common_prefix_len(prefix) == pc
    }
}

impl std::fmt::Display for Label {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl From<&str> for Label {
    fn from(s: &str) -> Label {
        Label::new(s)
    }
}

/// The topology tree with per-edge weights. An edge is identified by the
/// label of its *child* endpoint; unlisted edges weigh
/// `default_edge_weight`.
#[derive(Debug, Clone)]
pub struct Topology {
    default_edge_weight: f64,
    edge_weights: BTreeMap<String, f64>,
}

impl Default for Topology {
    fn default() -> Self {
        Topology { default_edge_weight: 1.0, edge_weights: BTreeMap::new() }
    }
}

impl Topology {
    pub fn new() -> Topology {
        Topology::default()
    }

    /// Override the weight of the edge above the node named by `label`.
    pub fn set_edge_weight(&mut self, label: &str, weight: f64) {
        assert!(weight >= 0.0);
        self.edge_weights.insert(Label::new(label).0, weight);
    }

    /// Total weight of the edges above `label`'s nodes deeper than
    /// `from_depth`. Edge keys are label *prefixes*, so lookups slice
    /// the original string instead of joining components — this path
    /// runs once per (CU input, pilot, replica) in the scheduler and
    /// must not allocate.
    fn suffix_weight(&self, label: &Label, from_depth: usize) -> f64 {
        let s = label.0.as_str();
        if s.is_empty() {
            return 0.0;
        }
        if self.edge_weights.is_empty() {
            // Fast path: every edge weighs the default.
            return (label.depth() - from_depth) as f64 * self.default_edge_weight;
        }
        let mut w = 0.0;
        let mut depth = 0usize;
        let ends = s.match_indices('/').map(|(i, _)| i).chain(std::iter::once(s.len()));
        for end in ends {
            depth += 1;
            if depth > from_depth {
                w += *self.edge_weights.get(&s[..end]).unwrap_or(&self.default_edge_weight);
            }
        }
        w
    }

    /// Tree distance between two labels: the weighted number of hops up
    /// from each label to their lowest common ancestor.
    pub fn distance(&self, a: &Label, b: &Label) -> f64 {
        let common = a.common_prefix_len(b);
        self.suffix_weight(a, common) + self.suffix_weight(b, common)
    }

    /// Affinity in (0, 1]: 1 for identical labels, decreasing with
    /// distance. The paper: "the smaller the distance between two
    /// resources, the larger the affinity".
    pub fn affinity(&self, a: &Label, b: &Label) -> f64 {
        1.0 / (1.0 + self.distance(a, b))
    }

    /// Of `candidates`, those with maximal affinity to `target`.
    pub fn closest<'a>(&self, target: &Label, candidates: &'a [Label]) -> Vec<&'a Label> {
        if candidates.is_empty() {
            return vec![];
        }
        let best = candidates
            .iter()
            .map(|c| self.affinity(target, c))
            .fold(f64::MIN, f64::max);
        candidates
            .iter()
            .filter(|c| (self.affinity(target, c) - best).abs() < 1e-12)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l(s: &str) -> Label {
        Label::new(s)
    }

    #[test]
    fn label_components_and_prefix() {
        let a = l("us-east/tacc/lonestar");
        assert_eq!(a.components(), vec!["us-east", "tacc", "lonestar"]);
        assert_eq!(a.common_prefix_len(&l("us-east/tacc/stampede")), 2);
        assert_eq!(a.common_prefix_len(&l("eu/surfsara")), 0);
        assert!(a.within(&l("us-east/tacc")));
        assert!(a.within(&a));
        assert!(!a.within(&l("us-east/purdue")));
        assert!(l("").within(&l("")));
    }

    #[test]
    fn distance_is_symmetric_zero_on_self() {
        let t = Topology::new();
        let a = l("us-east/tacc/lonestar");
        let b = l("us-east/purdue/condor");
        assert_eq!(t.distance(&a, &a), 0.0);
        assert_eq!(t.distance(&a, &b), t.distance(&b, &a));
        // lonestar->tacc->us-east (2 edges) + us-east->purdue->condor (2)
        assert_eq!(t.distance(&a, &b), 4.0);
        // Same site, different machine: 1 up + 1 down.
        assert_eq!(t.distance(&a, &l("us-east/tacc/stampede")), 2.0);
    }

    #[test]
    fn affinity_ordering_matches_paper_model() {
        let t = Topology::new();
        let lonestar = l("us-east/tacc/lonestar");
        let same = t.affinity(&lonestar, &lonestar);
        let same_site = t.affinity(&lonestar, &l("us-east/tacc/stampede"));
        let same_region = t.affinity(&lonestar, &l("us-east/purdue/condor"));
        let far = t.affinity(&lonestar, &l("eu/surfsara/grid"));
        assert!(same > same_site && same_site > same_region && same_region > far);
        assert_eq!(same, 1.0);
    }

    #[test]
    fn weighted_edges_change_distance() {
        let mut t = Topology::new();
        // Make the WAN hop to EU expensive.
        t.set_edge_weight("eu", 10.0);
        let a = l("us-east/tacc/lonestar");
        let eu = l("eu/surfsara");
        // 3 edges up from lonestar (weight 1 each) + down: "eu" (10) + "eu/surfsara" (1).
        assert_eq!(t.distance(&a, &eu), 3.0 + 10.0 + 1.0);
    }

    #[test]
    fn closest_picks_max_affinity() {
        let t = Topology::new();
        let target = l("osg/purdue");
        let cands = vec![l("osg/purdue"), l("osg/cornell"), l("xsede/tacc/lonestar")];
        let best = t.closest(&target, &cands);
        assert_eq!(best, vec![&cands[0]]);
        // Ties: two equally-far candidates are both returned.
        let cands2 = vec![l("osg/cornell"), l("osg/tacc")];
        assert_eq!(t.closest(&target, &cands2).len(), 2);
    }

    #[test]
    fn triangle_inequality_property() {
        crate::prop::check_default(
            |rng| {
                let mk = |rng: &mut crate::rng::Rng| {
                    let depth = crate::prop::gen::usize_in(rng, 1, 4);
                    let parts: Vec<String> =
                        (0..depth).map(|d| format!("n{}", rng.below(3 + d as u64))).collect();
                    Label::new(&parts.join("/"))
                };
                (mk(rng), mk(rng), mk(rng))
            },
            |(a, b, c)| {
                let t = Topology::new();
                let ab = t.distance(a, b);
                let bc = t.distance(b, c);
                let ac = t.distance(a, c);
                if ac <= ab + bc + 1e-9 {
                    Ok(())
                } else {
                    Err(format!("triangle violated: d({a},{c})={ac} > {ab}+{bc}"))
                }
            },
        );
    }
}
