//! Execution-mode engine: pluggable data-staging / replication
//! policies over the Pilot-Data substrate.
//!
//! The paper's evaluation turns on the claim that one coordination
//! substrate supports *interchangeable* data-management strategies
//! ("flexible execution modes enabled by Pilot-Data", §6; the P* model
//! frames them as policies over a common coordination element). This
//! module makes that claim concrete: an [`ExecutionMode`] policy
//! decides **when data moves** — the mechanics (transfer pricing, flow
//! registration, replica bookkeeping, scheduler integration) stay in
//! the shared substrate, so swapping a mode never touches the
//! scheduler, the event layer, or the storage model.
//!
//! Three policies ship with the crate:
//!
//! * [`OnDemand`] — data moves only when a Compute-Unit is dispatched
//!   and its agent stages the inputs (§4.2's pull model). This is the
//!   reference mode: it issues **no** proactive actions, so a run under
//!   `OnDemand` is bit-identical to the pre-engine hard-wired path
//!   (property-tested in `experiments::modes`).
//! * [`PreStage`] — eager push at submit: a Data-Unit carrying an
//!   affinity label fans out to one Pilot-Data per distinct resource
//!   label inside that affinity subtree, so compute anywhere in the
//!   subtree finds a local replica (the Fig. 9 scenario-3/4 shape,
//!   automated). DUs without an affinity label behave on-demand.
//! * [`AutoReplicate`] — background N-replica maintenance driven by
//!   the scheduler's affinity index ([`ManagerState`]'s
//!   `pilots_by_label`): whenever a DU lands, a pilot activates, or a
//!   replica is lost (capacity eviction or a storage outage delivered
//!   through the coordination event layer), the policy tops the DU
//!   back up to N replicas on the scratch Pilot-Data of live pilots,
//!   preferring sites hosting the most pilots.
//!
//! Policies return [`StageAction`]s; the sim driver
//! ([`crate::experiments::simdrive::SimSystem`]) dispatches them as
//! priced transfers and the wall-clock service applies the same
//! [`ModeKind`] semantics to its local Pilot-Data set
//! ([`crate::service::PilotSystem::set_execution_mode`]). Capacity
//! pressure is real in both: every placement goes through the
//! quota-checked [`crate::storage::simstore::SimStore::try_place`],
//! so an aggressive policy faces LRU eviction instead of an infinite
//! disk.
//!
//! # Selecting a mode
//!
//! ```
//! use pilot_data::config::paper_testbed;
//! use pilot_data::datamgmt::{self, ModeKind};
//! use pilot_data::experiments::simdrive::SimSystem;
//! use pilot_data::topology::Label;
//! use pilot_data::unit::{DataUnitDescription, FileRef};
//! use pilot_data::util::Bytes;
//!
//! let mut sys = SimSystem::new(paper_testbed(), 7)
//!     .with_mode(datamgmt::make(ModeKind::PreStage));
//! // A reference dataset pinned to the TACC subtree: PreStage pushes
//! // it to every distinct TACC site as soon as the upload lands.
//! let du = sys
//!     .upload_du(
//!         &DataUnitDescription {
//!             name: "reference".into(),
//!             files: vec![FileRef::sized("ref.fa", Bytes::gb(2))],
//!             affinity: Some(Label::new("xsede/tacc")),
//!         },
//!         "lonestar-scratch",
//!     )
//!     .unwrap();
//! sys.run().unwrap();
//! // Lonestar (the upload target) plus Stampede (pre-staged).
//! assert_eq!(sys.tb.store.replica_count(&du), 2);
//! # assert!(sys.tb.store.has_replica(&du, "stampede-scratch"));
//! ```

use crate::pilot::{ManagerState, PilotState};
use crate::storage::simstore::SimStore;
use crate::topology::{Label, Topology};
use std::collections::BTreeSet;

/// Which execution mode to run — the serializable selector shared by
/// the sim driver, the wall-clock service, experiments, and benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModeKind {
    /// Stage inputs at CU dispatch (the reference pull model).
    OnDemand,
    /// Eager push of affinity-labelled DUs at submit.
    PreStage,
    /// Background N-replica maintenance with outage repair.
    AutoReplicate {
        /// Target replica count per DU.
        replicas: u32,
    },
}

impl ModeKind {
    /// Stable display/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            ModeKind::OnDemand => "on-demand",
            ModeKind::PreStage => "pre-stage",
            ModeKind::AutoReplicate { .. } => "auto-replicate",
        }
    }

    /// The three modes compared by `experiments::modes` and
    /// `benches/modes_compare` (auto-replication targets 2 copies).
    pub fn all() -> [ModeKind; 3] {
        [ModeKind::OnDemand, ModeKind::PreStage, ModeKind::AutoReplicate { replicas: 2 }]
    }
}

impl std::fmt::Display for ModeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One proactive data movement requested by a policy: replicate `du`
/// onto `dst_pd` (the driver picks the closest source replica and
/// prices the transfer on the shared network).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageAction {
    pub du: String,
    pub dst_pd: String,
}

/// Why a replica disappeared. Policies repair `Outage` losses but
/// deliberately ignore `Evicted` ones: a capacity eviction means the
/// site is full — re-pushing the same bytes would evict something
/// else and thrash forever, so the pressure signal is left standing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossCause {
    /// Removed by the storage-capacity model to make room.
    Evicted,
    /// Lost to a Pilot-Data storage outage.
    Outage,
}

impl LossCause {
    /// Wire name used on the coordination store's loss channel.
    pub fn wire_name(self) -> &'static str {
        match self {
            LossCause::Evicted => "evicted",
            LossCause::Outage => "outage",
        }
    }

    pub fn from_wire(s: &str) -> Option<LossCause> {
        match s {
            "evicted" => Some(LossCause::Evicted),
            "outage" => Some(LossCause::Outage),
            _ => None,
        }
    }
}

/// Read-only world view handed to a policy when it plans: the topology
/// (for affinity math), the storage state (replicas, quotas, outages),
/// the manager state (DU descriptions, the pilot fleet and its
/// `pilots_by_label` affinity index), the agents' scratch Pilot-Data,
/// and the replication transfers already in flight (so policies do not
/// double-issue).
pub struct DataCtx<'a> {
    pub topo: &'a Topology,
    pub store: &'a SimStore,
    pub state: &'a ManagerState,
    /// `(pilot id, scratch pd name)` in pilot-id (creation) order —
    /// includes every non-terminal pilot.
    pub pilot_scratch: &'a [(String, String)],
    /// Replication transfers in flight as `(du, dst pd)`.
    pub in_flight: &'a BTreeSet<(String, String)>,
}

impl<'a> DataCtx<'a> {
    /// Is a transfer of `du` toward `pd` already running?
    fn pending(&self, du: &str, pd: &str) -> bool {
        self.in_flight.contains(&(du.to_string(), pd.to_string()))
    }

    /// Labels already covered for `du`: resident replicas plus
    /// in-flight destinations.
    fn covered_labels(&self, du: &str) -> BTreeSet<String> {
        let mut seen: BTreeSet<String> = self
            .store
            .replicas(du)
            .into_iter()
            .map(|p| p.endpoint.label.0.clone())
            .collect();
        for (d, pd) in self.in_flight.iter() {
            if d == du {
                if let Ok(p) = self.store.pd(pd) {
                    seen.insert(p.endpoint.label.0.clone());
                }
            }
        }
        seen
    }
}

/// A pluggable staging/replication policy. Hooks are invoked by the
/// drivers at data-plane events; each returns the proactive transfers
/// it wants started. Implementations must be deterministic functions
/// of the [`DataCtx`] — the sim's reproducibility (and the
/// `OnDemand`-equals-reference property) depends on it.
pub trait ExecutionMode: Send + Sync {
    fn name(&self) -> &'static str;

    /// A passive policy never returns actions from any hook. The
    /// drivers use this to skip assembling the [`DataCtx`] snapshot
    /// (a per-event allocation) on hot paths — [`OnDemand`] is the
    /// passive reference; proactive policies keep the default `false`.
    fn is_passive(&self) -> bool {
        false
    }

    /// A replica of `du` just landed on `pd` (upload, replication, or
    /// repair transfer completing).
    fn on_du_available(&self, du: &str, pd: &str, ctx: &DataCtx) -> Vec<StageAction>;

    /// A pilot just became Active (its scratch PD is now a useful
    /// replication target).
    fn on_pilot_active(&self, pilot: &str, ctx: &DataCtx) -> Vec<StageAction>;

    /// A replica of `du` on `pd` was lost — capacity eviction or a
    /// storage outage, delivered through the coordination event layer.
    /// See [`LossCause`] for why policies treat the two differently.
    fn on_replica_lost(&self, du: &str, pd: &str, cause: LossCause, ctx: &DataCtx)
        -> Vec<StageAction>;

    /// A Pilot-Data came (back) online empty — `Ev::PdUp` after an
    /// outage, announced on the `pd:data:avail:` channel. Proactive
    /// policies re-balance onto the recovered storage; the default is
    /// inert so passive policies (and test stubs) need not care.
    fn on_pd_up(&self, _pd: &str, _ctx: &DataCtx) -> Vec<StageAction> {
        Vec::new()
    }
}

/// Build the policy object for a [`ModeKind`].
pub fn make(kind: ModeKind) -> Box<dyn ExecutionMode> {
    match kind {
        ModeKind::OnDemand => Box::new(OnDemand),
        ModeKind::PreStage => Box::new(PreStage),
        ModeKind::AutoReplicate { replicas } => Box::new(AutoReplicate { replicas }),
    }
}

/// The reference policy: stage at CU dispatch, never proactively.
/// Every hook returns no actions, so the driver's event stream (and
/// its RNG draws) are exactly those of the pre-engine hard-wired path.
pub struct OnDemand;

impl ExecutionMode for OnDemand {
    fn name(&self) -> &'static str {
        "on-demand"
    }
    fn is_passive(&self) -> bool {
        true
    }
    fn on_du_available(&self, _du: &str, _pd: &str, _ctx: &DataCtx) -> Vec<StageAction> {
        Vec::new()
    }
    fn on_pilot_active(&self, _pilot: &str, _ctx: &DataCtx) -> Vec<StageAction> {
        Vec::new()
    }
    fn on_replica_lost(
        &self,
        _du: &str,
        _pd: &str,
        _cause: LossCause,
        _ctx: &DataCtx,
    ) -> Vec<StageAction> {
        Vec::new()
    }
}

/// Eager push at submit: fan an affinity-labelled DU out to one PD per
/// distinct resource label within its affinity subtree (skipping
/// labels already covered, down PDs, and PDs without capacity). The
/// per-label dedup is what keeps e.g. two Lonestar-resident PDs from
/// both receiving a copy — one local replica per site is enough for
/// data-local scheduling.
pub struct PreStage;

impl PreStage {
    fn plan(&self, du: &str, ctx: &DataCtx) -> Vec<StageAction> {
        let Some(d) = ctx.state.dus.get(du) else { return Vec::new() };
        let Some(aff) = d.description().affinity.clone() else { return Vec::new() };
        let size = d.size();
        let mut covered = ctx.covered_labels(du);
        let mut out = Vec::new();
        // BTreeMap name order: deterministic target choice per label.
        for p in ctx.store.pds() {
            if !p.endpoint.label.within(&aff)
                || covered.contains(&p.endpoint.label.0)
                || ctx.store.pd_is_down(&p.name)
                || ctx.pending(du, &p.name)
                || !ctx.store.can_fit(&p.name, size)
            {
                continue;
            }
            covered.insert(p.endpoint.label.0.clone());
            out.push(StageAction { du: du.to_string(), dst_pd: p.name.clone() });
        }
        out
    }
}

impl ExecutionMode for PreStage {
    fn name(&self) -> &'static str {
        "pre-stage"
    }
    fn on_du_available(&self, du: &str, _pd: &str, ctx: &DataCtx) -> Vec<StageAction> {
        self.plan(du, ctx)
    }
    fn on_pilot_active(&self, _pilot: &str, _ctx: &DataCtx) -> Vec<StageAction> {
        Vec::new() // pre-staging is a submit-time decision
    }
    fn on_replica_lost(
        &self,
        du: &str,
        _pd: &str,
        cause: LossCause,
        ctx: &DataCtx,
    ) -> Vec<StageAction> {
        match cause {
            // Re-cover the lost label if it is still in the subtree.
            LossCause::Outage => self.plan(du, ctx),
            // Capacity pressure: leave the signal standing.
            LossCause::Evicted => Vec::new(),
        }
    }
    fn on_pd_up(&self, _pd: &str, ctx: &DataCtx) -> Vec<StageAction> {
        // A site returned: re-push every affinity DU whose subtree the
        // recovered PD may now re-cover (plan() itself skips labels
        // still covered elsewhere).
        let mut out = Vec::new();
        for du in ctx.state.dus.keys() {
            out.extend(self.plan(du, ctx));
        }
        out
    }
}

/// Background N-replica maintenance: keep every DU at `replicas`
/// copies, placed on the scratch Pilot-Data of live pilots — the
/// candidates come from the agents' homes and are ranked by how many
/// pilots the manager's `pilots_by_label` affinity index registers at
/// the candidate's site (most compute first, then PD name for
/// determinism). Lost replicas (eviction, outage) are repaired the
/// same way.
///
/// On a testbed with heterogeneous
/// [`crate::storage::BackendProfile`]s the ranking becomes
/// cost-aware: the same candidate pool is ordered by the target
/// backend's ingest penalty for this DU's bytes (fixed latency +
/// dollars at [`crate::storage::simstore::DOLLAR_WEIGHT_S`] seconds
/// per dollar + capped wire seconds) first, with the pilot count as
/// the tiebreak — so a busy site behind an expensive object store
/// loses to a slightly quieter node-local disk. Uniform profiles take
/// the original pilot-count sort verbatim (bit-identical).
pub struct AutoReplicate {
    pub replicas: u32,
}

impl AutoReplicate {
    fn top_up(&self, du: &str, ctx: &DataCtx) -> Vec<StageAction> {
        let Some(d) = ctx.state.dus.get(du) else { return Vec::new() };
        let size = d.size();
        let have = ctx.store.replica_count(du);
        let pending = ctx.in_flight.iter().filter(|(d, _)| d == du).count();
        let mut need = (self.replicas as usize).saturating_sub(have + pending);
        if need == 0 {
            return Vec::new();
        }
        // Candidate targets: scratch PDs of non-terminal pilots,
        // deduped, ranked by (pilots at the PD's label desc, name asc).
        let mut seen_pd: BTreeSet<&str> = BTreeSet::new();
        let mut candidates: Vec<(usize, &str)> = Vec::new();
        for (pilot, scratch) in ctx.pilot_scratch.iter() {
            let alive = ctx
                .state
                .pilots
                .get(pilot)
                .map(|p| !p.state.is_terminal())
                .unwrap_or(false);
            if !alive || !seen_pd.insert(scratch.as_str()) {
                continue;
            }
            let Ok(p) = ctx.store.pd(scratch) else { continue };
            if ctx.store.has_replica(du, scratch)
                || ctx.store.pd_is_down(scratch)
                || ctx.pending(du, scratch)
                || !ctx.store.can_fit(scratch, size)
            {
                continue;
            }
            let weight = ctx
                .state
                .pilots_at_label(&p.endpoint.label)
                .iter()
                .filter(|id| {
                    ctx.state
                        .pilots
                        .get(id.as_str())
                        .map(|p| p.state == PilotState::Active || p.state == PilotState::Queued)
                        .unwrap_or(false)
                })
                .count();
            candidates.push((weight, scratch.as_str()));
        }
        if ctx.store.heterogeneous() {
            // Cost-aware order (see the struct docs): backend ingest
            // penalty asc, then pilot count desc, then name asc. Only
            // the sort key changes — eligibility stayed identical.
            let bytes = size.as_u64();
            let penalty = |pd: &str| -> f64 {
                let Ok(p) = ctx.store.pd(pd) else { return f64::INFINITY };
                let prof = &p.profile;
                let cap_s =
                    prof.bandwidth_cap.map_or(0.0, |c| bytes as f64 / c.max(1e-6));
                prof.fixed_latency_s
                    + crate::storage::simstore::DOLLAR_WEIGHT_S * prof.dollars_for(bytes)
                    + cap_s
            };
            candidates.sort_by(|a, b| {
                penalty(a.1)
                    .total_cmp(&penalty(b.1))
                    .then(b.0.cmp(&a.0))
                    .then(a.1.cmp(b.1))
            });
        } else {
            candidates.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(b.1)));
        }
        let mut out = Vec::new();
        for (_, pd) in candidates {
            if need == 0 {
                break;
            }
            out.push(StageAction { du: du.to_string(), dst_pd: pd.to_string() });
            need -= 1;
        }
        out
    }
}

impl ExecutionMode for AutoReplicate {
    fn name(&self) -> &'static str {
        "auto-replicate"
    }
    fn on_du_available(&self, du: &str, _pd: &str, ctx: &DataCtx) -> Vec<StageAction> {
        self.top_up(du, ctx)
    }
    fn on_pilot_active(&self, _pilot: &str, ctx: &DataCtx) -> Vec<StageAction> {
        // A new site appeared: re-examine every DU (BTreeMap id order).
        let mut out = Vec::new();
        for du in ctx.state.dus.keys() {
            out.extend(self.top_up(du, ctx));
        }
        out
    }
    fn on_replica_lost(
        &self,
        du: &str,
        _pd: &str,
        cause: LossCause,
        ctx: &DataCtx,
    ) -> Vec<StageAction> {
        match cause {
            LossCause::Outage => self.top_up(du, ctx),
            // See LossCause: repairing an eviction would thrash the
            // full site.
            LossCause::Evicted => Vec::new(),
        }
    }
    fn on_pd_up(&self, _pd: &str, ctx: &DataCtx) -> Vec<StageAction> {
        // Recovered storage is a fresh (empty) target: top every DU
        // back up, exactly like a newly active pilot site.
        let mut out = Vec::new();
        for du in ctx.state.dus.keys() {
            out.extend(self.top_up(du, ctx));
        }
        out
    }
}

/// Rank replication target PDs for the wall-clock service's local
/// mode: affinity of each candidate's label to `origin` (descending,
/// bitwise-stable f64 compare), then PD id. Shared pure helper so the
/// service's [`ModeKind`] application and tests agree on order.
pub fn rank_targets_by_affinity(
    topo: &Topology,
    origin: &Label,
    candidates: &mut Vec<(String, Label)>,
) {
    candidates.sort_by(|a, b| {
        let fa = topo.affinity_interned(&a.1, origin);
        let fb = topo.affinity_interned(&b.1, origin);
        fb.partial_cmp(&fa).unwrap().then(a.0.cmp(&b.0))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pilot::{PilotCompute, PilotComputeDescription};
    use crate::storage::Endpoint;
    use crate::unit::{DataUnit, DataUnitDescription, FileRef};
    use crate::util::Bytes;

    fn store_with(pds: &[(&str, &str)]) -> SimStore {
        let mut s = SimStore::new();
        for (name, label) in pds {
            s.add_pd(name, Endpoint::new(&format!("ssh://{name}/x"), label).unwrap());
        }
        s
    }

    fn du_with_affinity(st: &mut ManagerState, gb: u64, affinity: Option<&str>) -> String {
        st.add_du(DataUnit::new(DataUnitDescription {
            name: "d".into(),
            files: vec![FileRef::sized("f", Bytes::gb(gb))],
            affinity: affinity.map(Label::new),
        }))
    }

    fn pilot_at(st: &mut ManagerState, label: &str, state: PilotState) -> String {
        let mut p = PilotCompute::new(PilotComputeDescription {
            service_url: "batch://m".into(),
            cores: 4,
            walltime_s: 1e6,
            affinity: Some(Label::new(label)),
        });
        p.state = state;
        st.add_pilot(p)
    }

    #[test]
    fn on_demand_never_acts() {
        let topo = Topology::new();
        let store = store_with(&[("a", "osg/a")]);
        let mut st = ManagerState::new();
        let du = du_with_affinity(&mut st, 1, Some("osg"));
        let in_flight = BTreeSet::new();
        let scratch = Vec::new();
        let ctx = DataCtx {
            topo: &topo,
            store: &store,
            state: &st,
            pilot_scratch: &scratch,
            in_flight: &in_flight,
        };
        let m = OnDemand;
        assert!(m.on_du_available(&du, "a", &ctx).is_empty());
        assert!(m.on_pilot_active("p", &ctx).is_empty());
        assert!(m.on_replica_lost(&du, "a", LossCause::Outage, &ctx).is_empty());
    }

    #[test]
    fn prestage_fans_out_one_pd_per_label_in_subtree() {
        let topo = Topology::new();
        let mut store = store_with(&[
            ("ls-go", "xsede/tacc/lonestar"), // same label as ls-scratch: dedup
            ("ls-scratch", "xsede/tacc/lonestar"),
            ("st-scratch", "xsede/tacc/stampede"),
            ("tr-scratch", "xsede/sdsc/trestles"), // outside the subtree
        ]);
        let mut st = ManagerState::new();
        let du = du_with_affinity(&mut st, 2, Some("xsede/tacc"));
        store.register_du(&du, Bytes::gb(2), 1);
        store.place(&du, "ls-scratch").unwrap();
        let in_flight = BTreeSet::new();
        let scratch = Vec::new();
        let ctx = DataCtx {
            topo: &topo,
            store: &store,
            state: &st,
            pilot_scratch: &scratch,
            in_flight: &in_flight,
        };
        let actions = PreStage.on_du_available(&du, "ls-scratch", &ctx);
        // Lonestar label already covered; stampede gets one copy;
        // trestles is outside the affinity subtree.
        assert_eq!(
            actions,
            vec![StageAction { du: du.clone(), dst_pd: "st-scratch".into() }]
        );
        // A DU without affinity never pre-stages.
        let du2 = du_with_affinity(&mut st, 1, None);
        store.register_du(&du2, Bytes::gb(1), 1);
        store.place(&du2, "ls-scratch").unwrap();
        let ctx = DataCtx {
            topo: &topo,
            store: &store,
            state: &st,
            pilot_scratch: &scratch,
            in_flight: &in_flight,
        };
        assert!(PreStage.on_du_available(&du2, "ls-scratch", &ctx).is_empty());
    }

    #[test]
    fn prestage_skips_in_flight_and_full_targets() {
        let topo = Topology::new();
        let mut store = store_with(&[
            ("ls", "xsede/tacc/lonestar"),
            ("st", "xsede/tacc/stampede"),
            ("tiny", "xsede/tacc/wrangler"),
        ]);
        store.set_quota("tiny", Some(Bytes::gb(1))).unwrap();
        let mut st = ManagerState::new();
        let du = du_with_affinity(&mut st, 2, Some("xsede/tacc"));
        store.register_du(&du, Bytes::gb(2), 1);
        store.place(&du, "ls").unwrap();
        let mut in_flight = BTreeSet::new();
        in_flight.insert((du.clone(), "st".to_string()));
        let scratch = Vec::new();
        let ctx = DataCtx {
            topo: &topo,
            store: &store,
            state: &st,
            pilot_scratch: &scratch,
            in_flight: &in_flight,
        };
        // st is in flight (label covered), tiny cannot fit 2 GiB.
        assert!(PreStage.on_du_available(&du, "ls", &ctx).is_empty());
    }

    #[test]
    fn auto_replicate_tops_up_on_pilot_sites() {
        let topo = Topology::new();
        let mut store = store_with(&[
            ("ls-scratch", "xsede/tacc/lonestar"),
            ("st-scratch", "xsede/tacc/stampede"),
            ("tr-scratch", "xsede/sdsc/trestles"),
        ]);
        let mut st = ManagerState::new();
        let p1 = pilot_at(&mut st, "xsede/tacc/stampede", PilotState::Active);
        let p2 = pilot_at(&mut st, "xsede/sdsc/trestles", PilotState::Active);
        pilot_at(&mut st, "xsede/tacc/stampede", PilotState::Active); // 2nd stampede pilot
        let du = du_with_affinity(&mut st, 2, None);
        store.register_du(&du, Bytes::gb(2), 1);
        store.place(&du, "ls-scratch").unwrap();
        let in_flight = BTreeSet::new();
        let scratch = vec![
            (p1.clone(), "st-scratch".to_string()),
            (p2.clone(), "tr-scratch".to_string()),
        ];
        let ctx = DataCtx {
            topo: &topo,
            store: &store,
            state: &st,
            pilot_scratch: &scratch,
            in_flight: &in_flight,
        };
        // Target 2: one more replica; stampede wins (2 pilots > 1).
        let m = AutoReplicate { replicas: 2 };
        assert_eq!(
            m.on_du_available(&du, "ls-scratch", &ctx),
            vec![StageAction { du: du.clone(), dst_pd: "st-scratch".into() }]
        );
        // Target 3: both sites.
        let m3 = AutoReplicate { replicas: 3 };
        assert_eq!(m3.on_du_available(&du, "ls-scratch", &ctx).len(), 2);
        // In-flight copies count toward the target: nothing re-issued.
        let mut in_flight = BTreeSet::new();
        in_flight.insert((du.clone(), "st-scratch".to_string()));
        let ctx = DataCtx {
            topo: &topo,
            store: &store,
            state: &st,
            pilot_scratch: &scratch,
            in_flight: &in_flight,
        };
        assert!(m.on_du_available(&du, "ls-scratch", &ctx).is_empty());
    }

    /// With heterogeneous backend profiles the top-up ranking flips
    /// from pilot count to backend ingest penalty: a quieter
    /// node-local site beats a busier site behind a priced object
    /// store. (The uniform case keeps the pilot-count order — covered
    /// by `auto_replicate_tops_up_on_pilot_sites`.)
    #[test]
    fn auto_replicate_cost_ranking_prefers_cheap_backends() {
        use crate::storage::BackendProfile;
        let topo = Topology::new();
        let mut store = store_with(&[
            ("ls-scratch", "xsede/tacc/lonestar"),
            ("st-scratch", "xsede/tacc/stampede"),
            ("tr-scratch", "xsede/sdsc/trestles"),
        ]);
        // Stampede's scratch is an expensive object store; trestles
        // sits on free node-local disk.
        store.set_profile("st-scratch", BackendProfile::object_store()).unwrap();
        store.set_profile("tr-scratch", BackendProfile::node_local()).unwrap();
        let mut st = ManagerState::new();
        let p1 = pilot_at(&mut st, "xsede/tacc/stampede", PilotState::Active);
        let p2 = pilot_at(&mut st, "xsede/sdsc/trestles", PilotState::Active);
        pilot_at(&mut st, "xsede/tacc/stampede", PilotState::Active); // 2nd stampede pilot
        let du = du_with_affinity(&mut st, 2, None);
        store.register_du(&du, Bytes::gb(2), 1);
        store.place(&du, "ls-scratch").unwrap();
        let in_flight = BTreeSet::new();
        let scratch = vec![
            (p1.clone(), "st-scratch".to_string()),
            (p2.clone(), "tr-scratch".to_string()),
        ];
        let ctx = DataCtx {
            topo: &topo,
            store: &store,
            state: &st,
            pilot_scratch: &scratch,
            in_flight: &in_flight,
        };
        // Pilot count alone would pick stampede (2 pilots > 1); the
        // priced ranking routes the copy to the free local disk.
        let m = AutoReplicate { replicas: 2 };
        assert_eq!(
            m.on_du_available(&du, "ls-scratch", &ctx),
            vec![StageAction { du: du.clone(), dst_pd: "tr-scratch".into() }]
        );
        // Replicas:3 still fills both sites — pricing reorders, it
        // never shrinks the candidate pool.
        let m3 = AutoReplicate { replicas: 3 };
        assert_eq!(m3.on_du_available(&du, "ls-scratch", &ctx).len(), 2);
    }

    #[test]
    fn auto_replicate_repair_skips_down_pds() {
        let topo = Topology::new();
        let mut store = store_with(&[
            ("ls-scratch", "xsede/tacc/lonestar"),
            ("st-scratch", "xsede/tacc/stampede"),
            ("tr-scratch", "xsede/sdsc/trestles"),
        ]);
        let mut st = ManagerState::new();
        let p1 = pilot_at(&mut st, "xsede/tacc/stampede", PilotState::Active);
        let p2 = pilot_at(&mut st, "xsede/sdsc/trestles", PilotState::Active);
        let du = du_with_affinity(&mut st, 2, None);
        store.register_du(&du, Bytes::gb(2), 1);
        store.place(&du, "ls-scratch").unwrap();
        // Stampede's storage is down: repair must route to trestles.
        store.set_pd_down("st-scratch", true);
        let in_flight = BTreeSet::new();
        let scratch = vec![
            (p1.clone(), "st-scratch".to_string()),
            (p2.clone(), "tr-scratch".to_string()),
        ];
        let ctx = DataCtx {
            topo: &topo,
            store: &store,
            state: &st,
            pilot_scratch: &scratch,
            in_flight: &in_flight,
        };
        let m = AutoReplicate { replicas: 2 };
        assert_eq!(
            m.on_replica_lost(&du, "st-scratch", LossCause::Outage, &ctx),
            vec![StageAction { du: du.clone(), dst_pd: "tr-scratch".into() }]
        );
        // A capacity eviction is NOT repaired (anti-thrash rule).
        assert!(m.on_replica_lost(&du, "st-scratch", LossCause::Evicted, &ctx).is_empty());
        assert_eq!(LossCause::from_wire("outage"), Some(LossCause::Outage));
        assert_eq!(LossCause::from_wire("gone"), None);
    }

    #[test]
    fn pd_up_rebalances_proactive_modes_only() {
        let topo = Topology::new();
        let mut store = store_with(&[
            ("ls-scratch", "xsede/tacc/lonestar"),
            ("st-scratch", "xsede/tacc/stampede"),
        ]);
        let mut st = ManagerState::new();
        let p1 = pilot_at(&mut st, "xsede/tacc/stampede", PilotState::Active);
        let du = du_with_affinity(&mut st, 2, Some("xsede/tacc"));
        store.register_du(&du, Bytes::gb(2), 1);
        store.place(&du, "ls-scratch").unwrap();
        let in_flight = BTreeSet::new();
        let scratch = vec![(p1.clone(), "st-scratch".to_string())];
        let ctx = DataCtx {
            topo: &topo,
            store: &store,
            state: &st,
            pilot_scratch: &scratch,
            in_flight: &in_flight,
        };
        // Stampede just recovered (empty): both proactive modes re-fill
        // it; the passive reference does nothing (default hook).
        let want = vec![StageAction { du: du.clone(), dst_pd: "st-scratch".into() }];
        assert_eq!(AutoReplicate { replicas: 2 }.on_pd_up("st-scratch", &ctx), want);
        assert_eq!(PreStage.on_pd_up("st-scratch", &ctx), want);
        assert!(OnDemand.on_pd_up("st-scratch", &ctx).is_empty());
    }

    #[test]
    fn mode_kind_names_roundtrip() {
        for kind in ModeKind::all() {
            assert_eq!(make(kind).name(), kind.name());
        }
        assert_eq!(format!("{}", ModeKind::PreStage), "pre-stage");
    }
}
