//! Minimal property-based testing harness (proptest is unavailable
//! offline).
//!
//! [`check`] runs a property over many seeded random cases; on failure it
//! reports the case index and seed so the failure is exactly
//! reproducible (`Rng::new(seed)` regenerates the input). Generators are
//! plain closures over [`Rng`], composed with ordinary Rust.

use crate::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct Config {
    pub cases: u32,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xB16_B00B5 }
    }
}

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with the
/// reproducing seed on the first failing case.
pub fn check<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {case_seed:#x}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Shorthand: run with default config.
pub fn check_default<T: std::fmt::Debug>(
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    check(Config::default(), gen, prop)
}

/// Generator helpers.
pub mod gen {
    use crate::rng::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo).max(1) as u64) as usize
    }

    pub fn vec_f64(rng: &mut Rng, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| rng.range_f64(lo, hi)).collect()
    }

    pub fn ident(rng: &mut Rng, prefix: &str) -> String {
        format!("{prefix}{}", rng.below(1_000_000))
    }

    /// Random ASCII string (printable subset including escapes-relevant
    /// chars) — used e.g. by the JSON roundtrip property.
    pub fn ascii_string(rng: &mut Rng, max_len: usize) -> String {
        let len = rng.below(max_len as u64 + 1) as usize;
        (0..len)
            .map(|_| {
                let c = rng.below(96) as u8 + 0x20;
                c as char
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_default(
            |rng| gen::vec_f64(rng, 10, -5.0, 5.0),
            |v| {
                if v.len() == 10 {
                    Ok(())
                } else {
                    Err("len".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check_default(
            |rng| rng.below(100),
            |n| if *n < 1000 { Err(format!("forced failure n={n}")) } else { Ok(()) },
        );
    }

    /// ISSUE 2 satellite: the event-driven sim driver (targeted,
    /// subtree-pruned wakeups fed by the store's queue-namespace
    /// subscription) must produce **bit-identical placement traces**
    /// to the broadcast reference driver ("wake every pilot on every
    /// event" — the polling-era semantics) on randomized workloads.
    /// Trace = per-CU (submission index, machine, staging start/end,
    /// staging and compute seconds) in completion order, plus the
    /// makespan; every skipped wakeup must therefore have been a
    /// provable no-op.
    #[test]
    fn evented_simdrive_matches_broadcast_traces() {
        use crate::config::paper_testbed;
        use crate::experiments::simdrive::{SimSystem, WakeupMode};
        use crate::util::Bytes;
        use crate::workload::bwa_ensemble;

        type Trace = (Vec<(usize, String, f64, f64, f64, f64)>, f64);

        fn run_one(
            mode: WakeupMode,
            seed: u64,
            pilots: &[(&'static str, &'static str, u32)],
            tasks: usize,
            chunk_gb: u64,
        ) -> Result<Trace, String> {
            let es = |e: anyhow::Error| e.to_string();
            let mut sys = SimSystem::new(paper_testbed(), seed).with_wakeups(mode);
            let ens = bwa_ensemble(tasks, Bytes::gb(chunk_gb), Bytes::gb(8));
            let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").map_err(es)?;
            let mut chunks = Vec::new();
            for c in &ens.read_chunks {
                chunks.push(sys.upload_du(c, "lonestar-scratch").map_err(es)?);
            }
            sys.run().map_err(es)?; // land the data
            for (machine, scratch, cores) in pilots {
                sys.submit_pilot(machine, *cores, scratch).map_err(es)?;
            }
            let mut submitted = Vec::new();
            for chunk in &chunks {
                let mut cud = ens.cu_template.clone();
                cud.input_data = vec![ref_du.clone(), chunk.clone()];
                submitted.push(sys.submit_cu(cud).map_err(es)?);
            }
            sys.run().map_err(es)?;
            if !sys.state.workload_finished() {
                return Err(format!("workload not finished under {mode:?}"));
            }
            let trace = sys
                .metrics
                .cu_records
                .iter()
                .map(|r| {
                    let idx = submitted
                        .iter()
                        .position(|id| *id == r.cu)
                        .ok_or_else(|| format!("unknown cu {}", r.cu))?;
                    Ok((idx, r.machine.clone(), r.t_start, r.t_end, r.staging_s, r.compute_s))
                })
                .collect::<Result<Vec<_>, String>>()?;
            Ok((trace, sys.makespan()))
        }

        crate::prop::check(
            Config { cases: 10, seed: 0xD1CE },
            |rng| {
                let mut pilots: Vec<(&'static str, &'static str, u32)> =
                    vec![("lonestar", "lonestar-scratch", 4 + 4 * rng.below(3) as u32)];
                if rng.chance(0.6) {
                    pilots.push(("stampede", "stampede-scratch", 4 + 4 * rng.below(3) as u32));
                }
                if rng.chance(0.3) {
                    pilots.push(("lonestar", "lonestar-scratch", 4));
                }
                (rng.next_u64(), pilots, 1 + rng.below(6) as usize, 1 + rng.below(3))
            },
            |(seed, pilots, tasks, chunk_gb)| {
                let evented =
                    run_one(WakeupMode::Evented, *seed, pilots, *tasks, *chunk_gb)?;
                let broadcast =
                    run_one(WakeupMode::Broadcast, *seed, pilots, *tasks, *chunk_gb)?;
                if evented != broadcast {
                    return Err(format!(
                        "placement traces diverge:\n evented:   {evented:?}\n broadcast: {broadcast:?}"
                    ));
                }
                Ok(())
            },
        );
    }

    /// ISSUE 3 tentpole: under simulated time a multi-slot pilot agent
    /// is a chain of per-slot `TryPull` events — one CU dispatched per
    /// event, the follow-up front-scheduled (`SlotMode::PerSlot`) —
    /// while the reference `SlotMode::Batch` drains all slots in one
    /// handler loop (the pre-multi-slot, single-event shape; with a
    /// pool of size 1 the two are the same machine by construction).
    /// The chain must be invisible: **bit-identical placement traces**
    /// on randomized workloads. The per-slot run is also audited:
    /// per-queue FIFO pop order against the store's own push events
    /// (1-core workloads: no requeues, so pop order must equal push
    /// order exactly), and no pilot ever exceeding `cores` concurrent
    /// CUs.
    #[test]
    fn per_slot_driver_matches_batch_reference_traces() {
        use crate::config::paper_testbed;
        use crate::coordination::keys;
        use crate::experiments::simdrive::{SimSystem, SlotMode};
        use crate::util::Bytes;
        use crate::workload::bwa_ensemble;
        use std::collections::BTreeMap;

        type Trace = (Vec<(usize, String, f64, f64, f64, f64)>, f64);

        struct SlotAudit {
            /// (queue key, cu id) per rpush on a pilot queue, in order.
            pushes: Vec<(String, String)>,
            /// (pilot, cu, from_own) per pull, in order.
            pulls: Vec<(String, String, bool)>,
            /// pilot id -> peak concurrent busy slots.
            max_busy: BTreeMap<String, u32>,
            /// pilot id -> cores.
            cores: BTreeMap<String, u32>,
        }

        fn run_one(
            mode: SlotMode,
            seed: u64,
            pilots: &[(&'static str, &'static str, u32)],
            tasks: usize,
            chunk_gb: u64,
            one_core: bool,
        ) -> Result<(Trace, SlotAudit), String> {
            let es = |e: anyhow::Error| e.to_string();
            let mut sys = SimSystem::new(paper_testbed(), seed).with_slot_mode(mode);
            sys.pull_log = Some(Vec::new());
            let push_rx = sys.store.subscribe_prefix(keys::PILOT_QUEUE_PREFIX);
            let ens = bwa_ensemble(tasks, Bytes::gb(chunk_gb), Bytes::gb(8));
            let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").map_err(es)?;
            let mut chunks = Vec::new();
            for c in &ens.read_chunks {
                chunks.push(sys.upload_du(c, "lonestar-scratch").map_err(es)?);
            }
            sys.run().map_err(es)?; // land the data
            let mut cores = BTreeMap::new();
            for (machine, scratch, n) in pilots {
                let id = sys.submit_pilot(machine, *n, scratch).map_err(es)?;
                cores.insert(id, *n);
            }
            sys.run().map_err(es)?; // activate pilots
            let mut submitted = Vec::new();
            for chunk in &chunks {
                let mut cud = ens.cu_template.clone();
                if one_core {
                    cud.cores = 1;
                }
                cud.input_data = vec![ref_du.clone(), chunk.clone()];
                submitted.push(sys.submit_cu(cud).map_err(es)?);
            }
            sys.run().map_err(es)?;
            if !sys.state.workload_finished() {
                return Err(format!("workload not finished under {mode:?}"));
            }
            let trace = sys
                .metrics
                .cu_records
                .iter()
                .map(|r| {
                    let idx = submitted
                        .iter()
                        .position(|id| *id == r.cu)
                        .ok_or_else(|| format!("unknown cu {}", r.cu))?;
                    Ok((idx, r.machine.clone(), r.t_start, r.t_end, r.staging_s, r.compute_s))
                })
                .collect::<Result<Vec<_>, String>>()?;
            let audit = SlotAudit {
                pushes: push_rx.try_iter().map(|e| (e.key, e.payload)).collect(),
                pulls: sys.pull_log.take().unwrap_or_default(),
                max_busy: sys.max_busy.clone(),
                cores,
            };
            Ok(((trace, sys.makespan()), audit))
        }

        crate::prop::check(
            Config { cases: 8, seed: 0x510_7 },
            |rng| {
                let mut pilots: Vec<(&'static str, &'static str, u32)> =
                    vec![("lonestar", "lonestar-scratch", 4 + 4 * rng.below(3) as u32)];
                if rng.chance(0.6) {
                    pilots.push(("stampede", "stampede-scratch", 4 + 4 * rng.below(3) as u32));
                }
                if rng.chance(0.3) {
                    // Pool of size 1 (with 1-core CUs): the per-slot
                    // chain degenerates to the single-slot reference.
                    pilots.push(("lonestar", "lonestar-scratch", 1));
                }
                (
                    rng.next_u64(),
                    pilots,
                    1 + rng.below(6) as usize,
                    1 + rng.below(3),
                    rng.chance(0.6),
                )
            },
            |(seed, pilots, tasks, chunk_gb, one_core)| {
                let (per_slot, audit) =
                    run_one(SlotMode::PerSlot, *seed, pilots, *tasks, *chunk_gb, *one_core)?;
                let (batch, _) =
                    run_one(SlotMode::Batch, *seed, pilots, *tasks, *chunk_gb, *one_core)?;
                if per_slot != batch {
                    return Err(format!(
                        "placement traces diverge:\n per-slot: {per_slot:?}\n batch:    {batch:?}"
                    ));
                }
                // No pilot ever exceeds its core count in concurrent
                // CU slots.
                for (pilot, peak) in &audit.max_busy {
                    let cores = audit.cores.get(pilot).copied().unwrap_or(0);
                    if *peak > cores {
                        return Err(format!(
                            "pilot {pilot} peaked at {peak} busy slots with {cores} cores"
                        ));
                    }
                }
                // Per-queue FIFO pop order: with 1-core CUs nothing is
                // ever requeued, so each pilot queue's pop sequence
                // must equal its push sequence exactly.
                if *one_core {
                    let mut pushed: BTreeMap<String, Vec<String>> = BTreeMap::new();
                    for (key, cu) in &audit.pushes {
                        let pilot = key
                            .strip_prefix(crate::coordination::keys::PILOT_QUEUE_PREFIX)
                            .ok_or_else(|| format!("non-pilot queue key {key}"))?;
                        pushed.entry(pilot.to_string()).or_default().push(cu.clone());
                    }
                    let mut popped: BTreeMap<String, Vec<String>> = BTreeMap::new();
                    for (pilot, cu, from_own) in &audit.pulls {
                        if *from_own {
                            popped.entry(pilot.clone()).or_default().push(cu.clone());
                        }
                    }
                    if pushed != popped {
                        return Err(format!(
                            "own-queue FIFO violated:\n pushed: {pushed:?}\n popped: {popped:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// ISSUE 4 (interned data plane): experiment outputs must stay
    /// deterministic per seed through the id-based network engine — a
    /// hash-map-iteration-order leak anywhere in the path memo / arena
    /// would show up here as run-to-run drift. Together with the
    /// bitwise equivalence properties (id vs string reference in
    /// `topology`, `net`, and `simstore`), this is what pins fig7/fig8
    /// outputs to their pre-refactor traces.
    #[test]
    fn fig7_fig8_outputs_deterministic_post_interning() {
        let render = |tables: &[crate::metrics::Table]| -> Vec<String> {
            tables.iter().map(|t| t.render()).collect()
        };
        let f7a = crate::experiments::fig7::run(42).unwrap();
        let f7b = crate::experiments::fig7::run(42).unwrap();
        assert_eq!(render(&f7a), render(&f7b), "fig7 output drifted between runs");
        let f8a = crate::experiments::fig8::run(7).unwrap();
        let f8b = crate::experiments::fig8::run(7).unwrap();
        assert_eq!(render(&f8a), render(&f8b), "fig8 output drifted between runs");
    }

    /// ISSUE 6 tentpole: with every failure rate zeroed, the in-DES
    /// retry engine (one event per transfer attempt, backoff in
    /// simulated time) must be **bit-identical** to the seed's
    /// statistical `attempt_transfer` shortcut it replaced — same RNG
    /// draws, same event times, same placements, same bytes — on
    /// randomized two-site workloads. Fault handling must cost nothing
    /// when nothing faults.
    #[test]
    fn fault_free_in_des_retry_matches_aggregate_reference_traces() {
        use crate::config::paper_testbed;
        use crate::experiments::simdrive::SimSystem;
        use crate::util::Bytes;
        use crate::workload::bwa_ensemble;

        type Trace = (Vec<(usize, String, f64, f64, f64, f64)>, f64, u64);

        fn run_one(
            aggregate: bool,
            seed: u64,
            pilots: &[(&'static str, &'static str, u32)],
            tasks: usize,
            chunk_gb: u64,
        ) -> Result<Trace, String> {
            let es = |e: anyhow::Error| e.to_string();
            let mut sys = SimSystem::new(paper_testbed(), seed);
            if aggregate {
                sys = sys.with_aggregate_retry_reference();
            }
            sys.zero_transfer_faults();
            let ens = bwa_ensemble(tasks, Bytes::gb(chunk_gb), Bytes::gb(8));
            // Reference on a remote SRM: CU stagings cross the wire.
            let ref_du = sys.upload_du(&ens.reference, "osg-srm").map_err(es)?;
            let mut chunks = Vec::new();
            for c in &ens.read_chunks {
                chunks.push(sys.upload_du(c, "lonestar-scratch").map_err(es)?);
            }
            sys.run().map_err(es)?; // land the data
            for (machine, scratch, cores) in pilots {
                sys.submit_pilot(machine, *cores, scratch).map_err(es)?;
            }
            let mut submitted = Vec::new();
            for chunk in &chunks {
                let mut cud = ens.cu_template.clone();
                cud.input_data = vec![ref_du.clone(), chunk.clone()];
                submitted.push(sys.submit_cu(cud).map_err(es)?);
            }
            sys.run().map_err(es)?;
            if !sys.state.workload_finished() {
                return Err("workload not finished".into());
            }
            let trace = sys
                .metrics
                .cu_records
                .iter()
                .map(|r| {
                    let idx = submitted
                        .iter()
                        .position(|id| *id == r.cu)
                        .ok_or_else(|| format!("unknown cu {}", r.cu))?;
                    Ok((idx, r.machine.clone(), r.t_start, r.t_end, r.staging_s, r.compute_s))
                })
                .collect::<Result<Vec<_>, String>>()?;
            Ok((trace, sys.makespan(), sys.bytes_moved().as_u64()))
        }

        crate::prop::check(
            Config { cases: 8, seed: 0x0DE5_FA17 },
            |rng| {
                let mut pilots: Vec<(&'static str, &'static str, u32)> =
                    vec![("lonestar", "lonestar-scratch", 4 + 4 * rng.below(3) as u32)];
                if rng.chance(0.6) {
                    pilots.push(("stampede", "stampede-scratch", 4 + 4 * rng.below(3) as u32));
                }
                (rng.next_u64(), pilots, 1 + rng.below(5) as usize, 1 + rng.below(3))
            },
            |(seed, pilots, tasks, chunk_gb)| {
                let in_des = run_one(false, *seed, pilots, *tasks, *chunk_gb)?;
                let aggregate = run_one(true, *seed, pilots, *tasks, *chunk_gb)?;
                if in_des != aggregate {
                    return Err(format!(
                        "fault-free traces diverge:\n in-des:    {in_des:?}\n aggregate: {aggregate:?}"
                    ));
                }
                Ok(())
            },
        );
    }

    /// ISSUE 6 satellite: randomized chaos schedules (pilot kills, a
    /// PD down→up cycle, lossy links) against a two-site workload.
    /// Whenever at least one pilot and one replica of every input DU
    /// survive — guaranteed here by never targeting the lonestar pilot
    /// or its scratch — the run must still satisfy the end-to-end
    /// invariants: the workload completes, no CU is lost or completed
    /// twice, no pilot ever exceeds its core count, and every network
    /// flow drains. ISSUE 7 rerun: every case runs on **both** DES
    /// queue backends (the calendar-queue wheel and the retained heap
    /// reference), proving the engine swap leaves the fault lifecycle
    /// unchanged; the two runs must also agree on completion counts
    /// and final sim time exactly. ISSUE 10 rerun: a random half of the
    /// cases run on a **mixed-backend** testbed (node-local Lonestar
    /// scratch, object-store Stampede scratch), so the heterogeneous
    /// pricing path — per-attempt latency, bandwidth caps, dollar
    /// accrual — is exercised under the same chaos, and the two queue
    /// backends must additionally agree on dollars spent bit-for-bit.
    #[test]
    fn chaos_runs_preserve_end_to_end_invariants() {
        use crate::config::paper_testbed;
        use crate::experiments::simdrive::SimSystem;
        use crate::faults::ChaosPlan;
        use crate::simtime::QueueBackend;
        use crate::storage::BackendProfile;
        use crate::util::Bytes;
        use crate::workload::bwa_ensemble;

        fn run_under(
            backend: QueueBackend,
            seed: u64,
            tasks: usize,
            survivor_cores: u32,
            victim_cores: u32,
            intensity: f64,
            mixed: bool,
        ) -> Result<(usize, u64, f64, f64), String> {
            let es = |e: anyhow::Error| format!("{e} [{backend:?}]");
            let mut tb = paper_testbed();
            if mixed {
                tb.store
                    .set_profile("lonestar-scratch", BackendProfile::node_local())
                    .map_err(es)?;
                tb.store
                    .set_profile("stampede-scratch", BackendProfile::object_store())
                    .map_err(es)?;
            }
            let mut sys = SimSystem::new(tb, seed).with_sim_backend(backend);
            let ens = bwa_ensemble(tasks, Bytes::gb(1), Bytes::gb(8));
            let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").map_err(es)?;
            let mut chunks = Vec::new();
            for c in &ens.read_chunks {
                chunks.push(sys.upload_du(c, "lonestar-scratch").map_err(es)?);
            }
            sys.run().map_err(es)?; // land the data
            let mut cores = std::collections::BTreeMap::new();
            let p1 = sys
                .submit_pilot("lonestar", survivor_cores, "lonestar-scratch")
                .map_err(es)?;
            cores.insert(p1.clone(), survivor_cores);
            let p2 = sys
                .submit_pilot("stampede", victim_cores, "stampede-scratch")
                .map_err(es)?;
            cores.insert(p2.clone(), victim_cores);
            for chunk in &chunks {
                let mut cud = ens.cu_template.clone();
                cud.input_data = vec![ref_du.clone(), chunk.clone()];
                sys.submit_cu(cud).map_err(es)?;
            }
            // Chaos may only touch the stampede side: the lonestar
            // pilot and the scratch holding every input DU survive.
            let plan = ChaosPlan::seeded(
                seed ^ 0xBAD,
                intensity,
                &[p2.clone()],
                &["stampede-scratch".to_string()],
                &["xsede/tacc/stampede".to_string()],
                20_000.0,
            );
            sys.apply_chaos(&plan);
            sys.run().map_err(es)?;
            if !sys.state.workload_finished() {
                return Err(format!("workload did not finish under chaos [{backend:?}]"));
            }
            let done = sys.state.count_cu_state(crate::unit::CuState::Done);
            if done != tasks {
                return Err(format!("{done}/{tasks} CUs done — CUs lost [{backend:?}]"));
            }
            let mut seen = std::collections::BTreeSet::new();
            for r in &sys.metrics.cu_records {
                if !seen.insert(r.cu.clone()) {
                    return Err(format!("CU {} completed twice [{backend:?}]", r.cu));
                }
            }
            for (pilot, peak) in &sys.max_busy {
                let c = cores.get(pilot).copied().unwrap_or(0);
                if *peak > c {
                    return Err(format!(
                        "pilot {pilot} peaked at {peak} busy slots with {c} cores [{backend:?}]"
                    ));
                }
            }
            if sys.tb.net.total_live_flows() != 0 {
                return Err(format!(
                    "{} network flows leaked [{backend:?}]",
                    sys.tb.net.total_live_flows()
                ));
            }
            Ok((done, sys.sim.processed(), sys.sim.now(), sys.dollars_spent()))
        }

        crate::prop::check(
            Config { cases: 8, seed: 0xC4A0_5 },
            |rng| {
                (
                    rng.next_u64(),
                    1 + rng.below(5) as usize,          // tasks
                    4 + 4 * rng.below(3) as u32,        // survivor cores
                    4 + 4 * rng.below(2) as u32,        // victim cores
                    rng.range_f64(0.3, 1.0),            // chaos intensity
                    rng.chance(0.5),                    // mixed-backend testbed
                )
            },
            |&(seed, tasks, survivor_cores, victim_cores, intensity, mixed)| {
                let wheel = run_under(
                    QueueBackend::Wheel,
                    seed,
                    tasks,
                    survivor_cores,
                    victim_cores,
                    intensity,
                    mixed,
                )?;
                let heap = run_under(
                    QueueBackend::Heap,
                    seed,
                    tasks,
                    survivor_cores,
                    victim_cores,
                    intensity,
                    mixed,
                )?;
                if wheel.0 != heap.0
                    || wheel.1 != heap.1
                    || wheel.2.to_bits() != heap.2.to_bits()
                    || wheel.3.to_bits() != heap.3.to_bits()
                {
                    return Err(format!(
                        "backends diverge under chaos (mixed={mixed}): wheel (done, events, t_end, dollars) = {wheel:?}, heap = {heap:?}"
                    ));
                }
                // A uniform testbed must never accrue dollars; the
                // mixed one prices any wire transfer that touches the
                // object-store scratch.
                if !mixed && wheel.3 != 0.0 {
                    return Err(format!("uniform testbed accrued ${}", wheel.3));
                }
                Ok(())
            },
        );
    }

    /// ISSUE 7 tentpole: the whole sim driver — fault lifecycle,
    /// per-slot chains, staging, wakeup protocol — replayed on the
    /// calendar-queue wheel vs the retained heap reference must yield
    /// **bit-identical placement traces** on randomized multi-pilot
    /// workloads. The simtime unit property proves the engines agree
    /// on synthetic schedules; this one proves it end to end.
    #[test]
    fn wheel_driver_matches_heap_reference_traces() {
        use crate::config::paper_testbed;
        use crate::experiments::simdrive::SimSystem;
        use crate::simtime::QueueBackend;
        use crate::util::Bytes;
        use crate::workload::bwa_ensemble;

        type Trace = (Vec<(usize, String, f64, f64, f64, f64)>, f64);

        fn run_one(
            backend: QueueBackend,
            seed: u64,
            pilots: &[(&'static str, &'static str, u32)],
            tasks: usize,
            chunk_gb: u64,
        ) -> Result<Trace, String> {
            let es = |e: anyhow::Error| e.to_string();
            let mut sys = SimSystem::new(paper_testbed(), seed).with_sim_backend(backend);
            let ens = bwa_ensemble(tasks, Bytes::gb(chunk_gb), Bytes::gb(8));
            let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").map_err(es)?;
            let mut chunks = Vec::new();
            for c in &ens.read_chunks {
                chunks.push(sys.upload_du(c, "lonestar-scratch").map_err(es)?);
            }
            sys.run().map_err(es)?; // land the data
            for (machine, scratch, cores) in pilots {
                sys.submit_pilot(machine, *cores, scratch).map_err(es)?;
            }
            let mut submitted = Vec::new();
            for chunk in &chunks {
                let mut cud = ens.cu_template.clone();
                cud.input_data = vec![ref_du.clone(), chunk.clone()];
                submitted.push(sys.submit_cu(cud).map_err(es)?);
            }
            sys.run().map_err(es)?;
            if !sys.state.workload_finished() {
                return Err(format!("workload not finished on {backend:?}"));
            }
            let trace = sys
                .metrics
                .cu_records
                .iter()
                .map(|r| {
                    let idx = submitted
                        .iter()
                        .position(|id| *id == r.cu)
                        .ok_or_else(|| format!("unknown cu {}", r.cu))?;
                    Ok((idx, r.machine.clone(), r.t_start, r.t_end, r.staging_s, r.compute_s))
                })
                .collect::<Result<Vec<_>, String>>()?;
            Ok((trace, sys.makespan()))
        }

        crate::prop::check(
            Config { cases: 8, seed: 0x8EE1 },
            |rng| {
                let mut pilots: Vec<(&'static str, &'static str, u32)> =
                    vec![("lonestar", "lonestar-scratch", 4 + 4 * rng.below(3) as u32)];
                if rng.chance(0.6) {
                    pilots.push(("stampede", "stampede-scratch", 4 + 4 * rng.below(3) as u32));
                }
                if rng.chance(0.3) {
                    pilots.push(("lonestar", "lonestar-scratch", 4));
                }
                (rng.next_u64(), pilots, 1 + rng.below(6) as usize, 1 + rng.below(3))
            },
            |(seed, pilots, tasks, chunk_gb)| {
                let wheel = run_one(QueueBackend::Wheel, *seed, pilots, *tasks, *chunk_gb)?;
                let heap = run_one(QueueBackend::Heap, *seed, pilots, *tasks, *chunk_gb)?;
                if wheel != heap {
                    return Err(format!(
                        "placement traces diverge:\n wheel: {wheel:?}\n heap:  {heap:?}"
                    ));
                }
                Ok(())
            },
        );
    }

    /// ISSUE 10 tentpole: heterogeneous backends and delay scheduling
    /// must be a perfect off-switch. A system with a **zero**
    /// locality-wait budget and explicitly applied **uniform** backend
    /// profiles on both scratches must produce **bit-identical**
    /// placement traces, makespan, and wire bytes to the plain
    /// pre-backend scheduler on randomized two-site workloads: the
    /// wait ledger records nothing at `Some(0.0)`, a uniform profile
    /// keeps `SimStore::heterogeneous()` false so no pricing or
    /// ranking path diverges, and `dollars_spent` stays exactly 0.
    #[test]
    fn zero_wait_uniform_profiles_match_seed_scheduler_traces() {
        use crate::config::paper_testbed;
        use crate::experiments::simdrive::SimSystem;
        use crate::storage::BackendProfile;
        use crate::util::Bytes;
        use crate::workload::bwa_ensemble;

        type Trace = (Vec<(usize, String, f64, f64, f64, f64)>, f64, u64);

        fn run_one(
            backends_on: bool,
            seed: u64,
            pilots: &[(&'static str, &'static str, u32)],
            tasks: usize,
            chunk_gb: u64,
        ) -> Result<(Trace, f64), String> {
            let es = |e: anyhow::Error| e.to_string();
            let mut tb = paper_testbed();
            if backends_on {
                // Uniform (default-equal) profiles: the store must not
                // flip into heterogeneous pricing.
                tb.store
                    .set_profile("lonestar-scratch", BackendProfile::parallel_fs())
                    .map_err(es)?;
                tb.store
                    .set_profile("stampede-scratch", BackendProfile::parallel_fs())
                    .map_err(es)?;
            }
            let mut sys = SimSystem::new(tb, seed);
            if backends_on {
                sys = sys.with_locality_wait(0.0);
            }
            let ens = bwa_ensemble(tasks, Bytes::gb(chunk_gb), Bytes::gb(8));
            let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").map_err(es)?;
            let mut chunks = Vec::new();
            for c in &ens.read_chunks {
                chunks.push(sys.upload_du(c, "lonestar-scratch").map_err(es)?);
            }
            sys.run().map_err(es)?; // land the data
            for (machine, scratch, cores) in pilots {
                sys.submit_pilot(machine, *cores, scratch).map_err(es)?;
            }
            let mut submitted = Vec::new();
            for chunk in &chunks {
                let mut cud = ens.cu_template.clone();
                cud.input_data = vec![ref_du.clone(), chunk.clone()];
                submitted.push(sys.submit_cu(cud).map_err(es)?);
            }
            sys.run().map_err(es)?;
            if !sys.state.workload_finished() {
                return Err(format!("workload not finished (backends_on={backends_on})"));
            }
            let trace = sys
                .metrics
                .cu_records
                .iter()
                .map(|r| {
                    let idx = submitted
                        .iter()
                        .position(|id| *id == r.cu)
                        .ok_or_else(|| format!("unknown cu {}", r.cu))?;
                    Ok((idx, r.machine.clone(), r.t_start, r.t_end, r.staging_s, r.compute_s))
                })
                .collect::<Result<Vec<_>, String>>()?;
            Ok(((trace, sys.makespan(), sys.bytes_moved().as_u64()), sys.dollars_spent()))
        }

        crate::prop::check(
            Config { cases: 8, seed: 0xBAC_EAD },
            |rng| {
                let mut pilots: Vec<(&'static str, &'static str, u32)> =
                    vec![("lonestar", "lonestar-scratch", 4 + 4 * rng.below(3) as u32)];
                if rng.chance(0.6) {
                    pilots.push(("stampede", "stampede-scratch", 4 + 4 * rng.below(3) as u32));
                }
                if rng.chance(0.3) {
                    pilots.push(("lonestar", "lonestar-scratch", 4));
                }
                (rng.next_u64(), pilots, 1 + rng.below(6) as usize, 1 + rng.below(3))
            },
            |(seed, pilots, tasks, chunk_gb)| {
                let (with, dollars) = run_one(true, *seed, pilots, *tasks, *chunk_gb)?;
                let (without, _) = run_one(false, *seed, pilots, *tasks, *chunk_gb)?;
                if with != without {
                    return Err(format!(
                        "zero-wait uniform run diverges from seed scheduler:\n on:  {with:?}\n off: {without:?}"
                    ));
                }
                if dollars != 0.0 {
                    return Err(format!("uniform profiles accrued ${dollars}"));
                }
                Ok(())
            },
        );
    }

    /// ISSUE 7 tentpole (driver batching): submitting a workload
    /// through the bulk [`SimSystem::submit_cus`] path — placements
    /// first, then one deduplicated wakeup drain — must be
    /// **trace-identical** to the per-CU `submit_cu` loop it
    /// accelerates. Every wakeup the loop would have scheduled lands at
    /// the same instant, so the dropped duplicates must all have been
    /// provable no-ops.
    #[test]
    fn bulk_cu_submission_matches_per_cu_reference_traces() {
        use crate::config::paper_testbed;
        use crate::experiments::simdrive::SimSystem;
        use crate::util::Bytes;
        use crate::workload::bwa_ensemble;

        type Trace = (Vec<(usize, String, f64, f64, f64, f64)>, f64);

        fn run_one(
            bulk: bool,
            seed: u64,
            pilots: &[(&'static str, &'static str, u32)],
            tasks: usize,
            chunk_gb: u64,
        ) -> Result<Trace, String> {
            let es = |e: anyhow::Error| e.to_string();
            let mut sys = SimSystem::new(paper_testbed(), seed);
            let ens = bwa_ensemble(tasks, Bytes::gb(chunk_gb), Bytes::gb(8));
            let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").map_err(es)?;
            let mut chunks = Vec::new();
            for c in &ens.read_chunks {
                chunks.push(sys.upload_du(c, "lonestar-scratch").map_err(es)?);
            }
            sys.run().map_err(es)?; // land the data
            for (machine, scratch, cores) in pilots {
                sys.submit_pilot(machine, *cores, scratch).map_err(es)?;
            }
            let descrs: Vec<_> = chunks
                .iter()
                .map(|chunk| {
                    let mut cud = ens.cu_template.clone();
                    cud.input_data = vec![ref_du.clone(), chunk.clone()];
                    cud
                })
                .collect();
            let submitted = if bulk {
                sys.submit_cus(descrs).map_err(es)?
            } else {
                let mut ids = Vec::new();
                for d in descrs {
                    ids.push(sys.submit_cu(d).map_err(es)?);
                }
                ids
            };
            sys.run().map_err(es)?;
            if !sys.state.workload_finished() {
                return Err(format!("workload not finished (bulk={bulk})"));
            }
            let trace = sys
                .metrics
                .cu_records
                .iter()
                .map(|r| {
                    let idx = submitted
                        .iter()
                        .position(|id| *id == r.cu)
                        .ok_or_else(|| format!("unknown cu {}", r.cu))?;
                    Ok((idx, r.machine.clone(), r.t_start, r.t_end, r.staging_s, r.compute_s))
                })
                .collect::<Result<Vec<_>, String>>()?;
            Ok((trace, sys.makespan()))
        }

        crate::prop::check(
            Config { cases: 8, seed: 0xB17C_0DE },
            |rng| {
                let mut pilots: Vec<(&'static str, &'static str, u32)> =
                    vec![("lonestar", "lonestar-scratch", 4 + 4 * rng.below(3) as u32)];
                if rng.chance(0.6) {
                    pilots.push(("stampede", "stampede-scratch", 4 + 4 * rng.below(3) as u32));
                }
                if rng.chance(0.3) {
                    pilots.push(("lonestar", "lonestar-scratch", 4));
                }
                (rng.next_u64(), pilots, 1 + rng.below(6) as usize, 1 + rng.below(3))
            },
            |(seed, pilots, tasks, chunk_gb)| {
                let bulk = run_one(true, *seed, pilots, *tasks, *chunk_gb)?;
                let loop_ = run_one(false, *seed, pilots, *tasks, *chunk_gb)?;
                if bulk != loop_ {
                    return Err(format!(
                        "placement traces diverge:\n bulk: {bulk:?}\n loop: {loop_:?}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn json_roundtrip_property() {
        use crate::json::{parse, Json};
        check_default(
            |rng| {
                let mut obj = Json::obj();
                for i in 0..gen::usize_in(rng, 0, 6) {
                    obj = obj.set(
                        &format!("k{i}"),
                        Json::Str(gen::ascii_string(rng, 24)),
                    );
                }
                obj
            },
            |v| {
                let back = parse(&v.to_string_compact()).map_err(|e| e.to_string())?;
                if &back == v {
                    Ok(())
                } else {
                    Err(format!("roundtrip mismatch: {back}"))
                }
            },
        );
    }
}
