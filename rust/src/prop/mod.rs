//! Minimal property-based testing harness (proptest is unavailable
//! offline).
//!
//! [`check`] runs a property over many seeded random cases; on failure it
//! reports the case index and seed so the failure is exactly
//! reproducible (`Rng::new(seed)` regenerates the input). Generators are
//! plain closures over [`Rng`], composed with ordinary Rust.

use crate::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct Config {
    pub cases: u32,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xB16_B00B5 }
    }
}

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with the
/// reproducing seed on the first failing case.
pub fn check<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {case_seed:#x}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Shorthand: run with default config.
pub fn check_default<T: std::fmt::Debug>(
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    check(Config::default(), gen, prop)
}

/// Generator helpers.
pub mod gen {
    use crate::rng::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo).max(1) as u64) as usize
    }

    pub fn vec_f64(rng: &mut Rng, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| rng.range_f64(lo, hi)).collect()
    }

    pub fn ident(rng: &mut Rng, prefix: &str) -> String {
        format!("{prefix}{}", rng.below(1_000_000))
    }

    /// Random ASCII string (printable subset including escapes-relevant
    /// chars) — used e.g. by the JSON roundtrip property.
    pub fn ascii_string(rng: &mut Rng, max_len: usize) -> String {
        let len = rng.below(max_len as u64 + 1) as usize;
        (0..len)
            .map(|_| {
                let c = rng.below(96) as u8 + 0x20;
                c as char
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_default(
            |rng| gen::vec_f64(rng, 10, -5.0, 5.0),
            |v| {
                if v.len() == 10 {
                    Ok(())
                } else {
                    Err("len".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check_default(
            |rng| rng.below(100),
            |n| if *n < 1000 { Err(format!("forced failure n={n}")) } else { Ok(()) },
        );
    }

    #[test]
    fn json_roundtrip_property() {
        use crate::json::{parse, Json};
        check_default(
            |rng| {
                let mut obj = Json::obj();
                for i in 0..gen::usize_in(rng, 0, 6) {
                    obj = obj.set(
                        &format!("k{i}"),
                        Json::Str(gen::ascii_string(rng, 24)),
                    );
                }
                obj
            },
            |v| {
                let back = parse(&v.to_string_compact()).map_err(|e| e.to_string())?;
                if &back == v {
                    Ok(())
                } else {
                    Err(format!("roundtrip mismatch: {back}"))
                }
            },
        );
    }
}
