//! Alignment runtime — executes the AOT-lowered alignment pipeline
//! from the rust hot path.
//!
//! `make artifacts` runs python once at build time to lower the
//! JAX/Pallas pipeline and write `artifacts/manifest.json` (shapes per
//! artifact). At run time the rust binary is self-contained: the
//! manifest drives batching, and [`Runtime::align`] executes the exact
//! pipeline semantics of `python/compile/kernels/ref.py` — stride-4
//! seed-lattice scoring, best-window selection, then a Smith-Waterman
//! extension (match +2, mismatch −1, linear gap −1, local alignment) —
//! as a native kernel. Python is never on the task path.
//!
//! The previous revision drove these artifacts through a PJRT CPU
//! client via the `xla` crate; that dependency cannot be vendored into
//! this offline build, so the native kernel (bit-compatible with the
//! reference oracle the Pallas kernels are tested against) is the
//! execution engine. Because it is plain `Send + Sync` data, the old
//! dedicated-inference-thread plumbing collapses: [`RuntimeServer`] /
//! [`RuntimeHandle`] keep their public API but are now thin `Arc`
//! wrappers, and executing a batch no longer copies the window set
//! (the old channel protocol forced a `windows.clone()` per batch).

use crate::json::Json;
use crate::service::{ExecResult, Executor};
use crate::unit::ComputeUnitDescription;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

/// Shape info for one artifact, from `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub file: String,
    pub entry: String,
    /// (B, L, W, Lw) for align artifacts.
    pub b: usize,
    pub l: usize,
    pub w: usize,
    pub lw: usize,
}

/// The alignment scoring kernels — a faithful rust port of the
/// reference oracles in `python/compile/kernels/ref.py` (which are the
/// correctness ground truth for the Pallas kernels).
pub mod kernel {
    /// Match reward (shared by seed counting tie-breaks and SW).
    pub const MATCH: f32 = 2.0;
    pub const MISMATCH: f32 = -1.0;
    /// Linear gap penalty (subtracted).
    pub const GAP: f32 = 1.0;
    /// Seed-phase shift lattice stride: candidate placements of the
    /// read are evaluated every `SHIFT_STRIDE` bases in the window.
    pub const SHIFT_STRIDE: usize = 4;

    /// Seed scores for one read against one window: the best count of
    /// positionally matching bases over all stride-lattice placements.
    pub fn seed_score(read: &[f32], window: &[f32]) -> f32 {
        let l = read.len();
        let lw = window.len();
        debug_assert!(lw >= l);
        let mut best = f32::NEG_INFINITY;
        let mut k = 0;
        while k + l <= lw {
            let mut matches = 0u32;
            for i in 0..l {
                if read[i] == window[k + i] {
                    matches += 1;
                }
            }
            best = best.max(matches as f32);
            k += SHIFT_STRIDE;
        }
        best
    }

    /// Index of the best-seeded window for each read (first max wins,
    /// matching `argmax` in the reference pipeline).
    pub fn best_windows(
        reads: &[f32],
        windows: &[f32],
        b: usize,
        l: usize,
        w: usize,
        lw: usize,
    ) -> Vec<usize> {
        (0..b)
            .map(|r| {
                let read = &reads[r * l..(r + 1) * l];
                let mut best_i = 0;
                let mut best_s = f32::NEG_INFINITY;
                for wi in 0..w {
                    let s = seed_score(read, &windows[wi * lw..(wi + 1) * lw]);
                    if s > best_s {
                        best_s = s;
                        best_i = wi;
                    }
                }
                best_i
            })
            .collect()
    }

    /// Smith-Waterman local-alignment score of one read/window pair
    /// (two-row DP; scores clamp at 0, result is the matrix maximum).
    pub fn sw_score(read: &[f32], window: &[f32]) -> f32 {
        let lw = window.len();
        let mut prev = vec![0f32; lw + 1];
        let mut cur = vec![0f32; lw + 1];
        let mut best = 0f32;
        for &rb in read {
            for j in 1..=lw {
                let s = if rb == window[j - 1] { MATCH } else { MISMATCH };
                let h = (prev[j - 1] + s).max(prev[j] - GAP).max(cur[j - 1] - GAP).max(0.0);
                cur[j] = h;
                if h > best {
                    best = h;
                }
            }
            std::mem::swap(&mut prev, &mut cur);
            cur[0] = 0.0;
        }
        best
    }

    /// The full per-batch pipeline: seed → select best window → SW
    /// extend. Returns `(scores, best_window)` of length `b`, with the
    /// window index encoded as f32 exactly like the AOT module output.
    pub fn align_pipeline(
        reads: &[f32],
        windows: &[f32],
        b: usize,
        l: usize,
        w: usize,
        lw: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let best = best_windows(reads, windows, b, l, w, lw);
        let scores = (0..b)
            .map(|r| sw_score(&reads[r * l..(r + 1) * l], &windows[best[r] * lw..(best[r] + 1) * lw]))
            .collect();
        (scores, best.iter().map(|&i| i as f32).collect())
    }
}

/// A loaded artifact set: manifest-driven shapes + the native kernels.
pub struct Runtime {
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    dir: PathBuf,
}

impl Runtime {
    /// Open an artifact directory.
    pub fn open(dir: impl Into<PathBuf>) -> anyhow::Result<Runtime> {
        let dir = dir.into();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} — run `make artifacts` first: {e}",
                manifest_path.display()
            )
        })?;
        let manifest = crate::json::parse(&text)?;
        let mut artifacts = BTreeMap::new();
        if let Some(Json::Obj(arts)) = manifest.get("artifacts") {
            for (file, info) in arts {
                let shapes = info.get("shapes").cloned().unwrap_or(Json::obj());
                artifacts.insert(
                    file.clone(),
                    ArtifactInfo {
                        file: file.clone(),
                        entry: info.str_field("entry").unwrap_or("?").to_string(),
                        b: shapes.u64_field_or("B", 0) as usize,
                        l: shapes.u64_field_or("L", 0) as usize,
                        w: shapes.u64_field_or("W", 0) as usize,
                        lw: shapes.u64_field_or("Lw", 0) as usize,
                    },
                );
            }
        }
        Ok(Runtime { artifacts, dir })
    }

    /// The artifact directory this runtime was opened on.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Artifact info by file name.
    pub fn info(&self, name: &str) -> anyhow::Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))
    }

    /// Execute an align artifact: `reads` is row-major (B, L) f32 base
    /// codes, `windows` (W, Lw). Returns (scores, best_window), each of
    /// length B.
    pub fn align(
        &self,
        name: &str,
        reads: &[f32],
        windows: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let info = self.info(name)?;
        anyhow::ensure!(
            reads.len() == info.b * info.l,
            "reads len {} != B*L {}",
            reads.len(),
            info.b * info.l
        );
        anyhow::ensure!(
            windows.len() == info.w * info.lw,
            "windows len {} != W*Lw {}",
            windows.len(),
            info.w * info.lw
        );
        Ok(kernel::align_pipeline(reads, windows, info.b, info.l, info.w, info.lw))
    }
}

/// Owner of the shared [`Runtime`]. Retained for API compatibility
/// with the PJRT revision (which needed a dedicated inference thread);
/// the native kernels are `Send + Sync`, so this is now a plain `Arc`
/// owner and [`RuntimeHandle`]s execute on the calling thread.
pub struct RuntimeServer {
    rt: Arc<Runtime>,
}

impl RuntimeServer {
    /// Load the artifact directory; fails fast if it is missing.
    pub fn spawn(dir: impl Into<PathBuf>) -> anyhow::Result<RuntimeServer> {
        let dir = dir.into();
        anyhow::ensure!(
            dir.join("manifest.json").exists(),
            "no artifacts at {} — run `make artifacts`",
            dir.display()
        );
        Ok(RuntimeServer { rt: Arc::new(Runtime::open(dir)?) })
    }

    pub fn handle(&self) -> RuntimeHandle {
        RuntimeHandle { rt: self.rt.clone() }
    }
}

/// Cheap, cloneable, `Send + Sync` client used by the pilot agents —
/// one shared artifact set for every Compute-Unit.
#[derive(Clone)]
pub struct RuntimeHandle {
    rt: Arc<Runtime>,
}

impl RuntimeHandle {
    pub fn align(
        &self,
        name: &str,
        reads: Vec<f32>,
        windows: Vec<f32>,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        self.rt.align(name, &reads, &windows)
    }

    /// Borrowing variant: lets batch loops reuse one window buffer
    /// without cloning it per call.
    pub fn align_ref(
        &self,
        name: &str,
        reads: &[f32],
        windows: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        self.rt.align(name, reads, windows)
    }

    pub fn info(&self, name: &str) -> anyhow::Result<ArtifactInfo> {
        self.rt.info(name).cloned()
    }
}

/// File format helpers for read/window payloads inside Data-Units:
/// little-endian f32 arrays with a 16-byte header (magic, rows, cols).
pub mod payload {
    pub const MAGIC: u32 = 0x50443146; // "PD1F"

    pub fn encode(rows: u32, cols: u32, data: &[f32]) -> Vec<u8> {
        assert_eq!(data.len(), rows as usize * cols as usize);
        let mut out = Vec::with_capacity(16 + data.len() * 4);
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&rows.to_le_bytes());
        out.extend_from_slice(&cols.to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes());
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    pub fn decode(bytes: &[u8]) -> anyhow::Result<(u32, u32, Vec<f32>)> {
        anyhow::ensure!(bytes.len() >= 16, "payload too short");
        let word = |i: usize| u32::from_le_bytes(bytes[i..i + 4].try_into().unwrap());
        anyhow::ensure!(word(0) == MAGIC, "bad payload magic");
        let (rows, cols) = (word(4), word(8));
        let n = rows as usize * cols as usize;
        anyhow::ensure!(bytes.len() == 16 + n * 4, "payload size mismatch");
        let mut data = Vec::with_capacity(n);
        for i in 0..n {
            data.push(f32::from_le_bytes(bytes[16 + i * 4..20 + i * 4].try_into().unwrap()));
        }
        Ok((rows, cols, data))
    }
}

/// The local-mode CU executor: reads `reads.pd1` and `windows.pd1`
/// from the sandbox, batches through the align artifact, writes
/// `scores.csv` (read_index, best_window, score).
pub struct AlignExecutor {
    handle: RuntimeHandle,
    artifact: String,
}

impl AlignExecutor {
    pub fn new(server: &RuntimeServer, artifact: &str) -> AlignExecutor {
        AlignExecutor { handle: server.handle(), artifact: artifact.to_string() }
    }
}

impl Executor for AlignExecutor {
    fn execute(&self, _cu: &ComputeUnitDescription, sandbox: &Path) -> anyhow::Result<ExecResult> {
        let t0 = Instant::now();
        let reads_bytes = std::fs::read(sandbox.join("reads.pd1"))?;
        let windows_bytes = std::fs::read(sandbox.join("windows.pd1"))?;
        let (n_reads, l, reads) = payload::decode(&reads_bytes)?;
        let (w, lw, windows) = payload::decode(&windows_bytes)?;
        let info = self.handle.info(&self.artifact)?;
        anyhow::ensure!(l as usize == info.l, "read length {l} != artifact L {}", info.l);
        anyhow::ensure!(w as usize == info.w && lw as usize == info.lw, "window shape mismatch");

        let mut csv = String::from("read,best_window,score\n");
        let bl = info.b * info.l;
        let mut batch = vec![0f32; bl];
        let mut idx = 0usize;
        while idx < n_reads as usize {
            // Assemble one batch, padding the tail with the last read.
            for r in 0..info.b {
                let src = (idx + r).min(n_reads as usize - 1);
                batch[r * info.l..(r + 1) * info.l]
                    .copy_from_slice(&reads[src * info.l..(src + 1) * info.l]);
            }
            let (scores, best) = self.handle.align_ref(&self.artifact, &batch, &windows)?;
            for r in 0..info.b {
                let global = idx + r;
                if global >= n_reads as usize {
                    break;
                }
                csv.push_str(&format!("{global},{},{}\n", best[r] as i64, scores[r]));
            }
            idx += info.b;
        }
        std::fs::write(sandbox.join("scores.csv"), &csv)?;
        Ok(ExecResult { stdout: format!("aligned {n_reads} reads"), compute_s: t0.elapsed().as_secs_f64() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root.
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn payload_roundtrip() {
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let bytes = payload::encode(3, 4, &data);
        let (r, c, back) = payload::decode(&bytes).unwrap();
        assert_eq!((r, c), (3, 4));
        assert_eq!(back, data);
        assert!(payload::decode(&bytes[..10]).is_err());
        let mut corrupt = bytes.clone();
        corrupt[0] ^= 0xFF;
        assert!(payload::decode(&corrupt).is_err());
    }

    #[test]
    fn sw_kernel_matches_reference_scoring() {
        // Perfect local match: MATCH * len.
        let read: Vec<f32> = vec![0.0, 1.0, 2.0, 3.0];
        assert_eq!(kernel::sw_score(&read, &read), 8.0);
        // One mismatch in the middle: best local alignment keeps both
        // flanks: 3 matches + 1 mismatch = 3*2 - 1 = 5.
        let win: Vec<f32> = vec![0.0, 1.0, 3.0, 3.0];
        assert_eq!(kernel::sw_score(&read, &win), 5.0);
        // Disjoint alphabets: nothing aligns locally.
        let far: Vec<f32> = vec![9.0; 4];
        assert_eq!(kernel::sw_score(&read, &far), 0.0);
        // A gap: read planted with one extra base in the window.
        let gapped: Vec<f32> = vec![0.0, 1.0, 9.0, 2.0, 3.0];
        // 4 matches - 1 gap = 8 - 1 = 7.
        assert_eq!(kernel::sw_score(&read, &gapped), 7.0);
    }

    #[test]
    fn seed_lattice_finds_planted_read() {
        let l = 8;
        let lw = 16;
        let mut rng = crate::rng::Rng::new(3);
        let read: Vec<f32> = (0..l).map(|_| rng.below(4) as f32).collect();
        // Window 1 carries the read at lattice offset 4; window 0 is
        // noise from a disjoint alphabet.
        let w0: Vec<f32> = (0..lw).map(|_| 4.0 + rng.below(4) as f32).collect();
        let mut w1: Vec<f32> = (0..lw).map(|_| 4.0 + rng.below(4) as f32).collect();
        w1[4..4 + l].copy_from_slice(&read);
        let mut windows = w0.clone();
        windows.extend_from_slice(&w1);
        assert_eq!(kernel::seed_score(&read, &w1), l as f32);
        assert_eq!(kernel::best_windows(&read, &windows, 1, l, 2, lw), vec![1]);
        let (scores, best) = kernel::align_pipeline(&read, &windows, 1, l, 2, lw);
        assert_eq!(best, vec![1.0]);
        assert_eq!(scores, vec![kernel::MATCH * l as f32]);
    }

    #[test]
    fn runtime_loads_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let info = rt.info("align_small.hlo.txt").unwrap();
        assert_eq!((info.b, info.l, info.w, info.lw), (8, 32, 8, 64));
        assert!(rt.info("nope.hlo.txt").is_err());
    }

    #[test]
    fn align_small_executes_and_finds_planted_read() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let info = rt.info("align_small.hlo.txt").unwrap().clone();
        let mut rng = crate::rng::Rng::new(11);
        let reads: Vec<f32> =
            (0..info.b * info.l).map(|_| rng.below(4) as f32).collect();
        let mut windows: Vec<f32> =
            (0..info.w * info.lw).map(|_| rng.below(4) as f32).collect();
        // Plant read r into window r's prefix.
        for r in 0..info.b.min(info.w) {
            for i in 0..info.l {
                windows[r * info.lw + i] = reads[r * info.l + i];
            }
        }
        let (scores, best) = rt.align("align_small.hlo.txt", &reads, &windows).unwrap();
        for r in 0..info.b {
            assert_eq!(best[r] as usize, r, "read {r} picked window {}", best[r]);
            // Perfect match: MATCH * L = 2 * 32.
            assert!((scores[r] - 64.0).abs() < 1e-3, "score {}", scores[r]);
        }
    }

    #[test]
    fn align_rejects_bad_shapes() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let rt = Runtime::open(artifacts_dir()).unwrap();
        assert!(rt.align("align_small.hlo.txt", &[0.0; 10], &[0.0; 10]).is_err());
    }
}
