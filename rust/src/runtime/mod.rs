//! PJRT runtime — loads the AOT-compiled JAX/Pallas artifacts and
//! executes them from the rust hot path.
//!
//! `make artifacts` runs python once at build time; afterwards the rust
//! binary is self-contained: `HloModuleProto::from_text_file` parses
//! the HLO text, the PJRT CPU client compiles it, and Compute-Units
//! execute the alignment pipeline through [`Runtime::align`] with no
//! python anywhere on the task path.

use crate::json::Json;
use crate::service::{ExecResult, Executor};
use crate::unit::ComputeUnitDescription;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

/// Shape info for one artifact, from `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub file: String,
    pub entry: String,
    /// (B, L, W, Lw) for align artifacts.
    pub b: usize,
    pub l: usize,
    pub w: usize,
    pub lw: usize,
}

/// A loaded, compiled artifact set.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: Mutex<BTreeMap<String, xla::PjRtLoadedExecutable>>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    dir: PathBuf,
}

impl Runtime {
    /// Open an artifact directory (compiles lazily on first use).
    pub fn open(dir: impl Into<PathBuf>) -> anyhow::Result<Runtime> {
        let dir = dir.into();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} — run `make artifacts` first: {e}",
                manifest_path.display()
            )
        })?;
        let manifest = crate::json::parse(&text)?;
        let mut artifacts = BTreeMap::new();
        if let Some(Json::Obj(arts)) = manifest.get("artifacts") {
            for (file, info) in arts {
                let shapes = info.get("shapes").cloned().unwrap_or(Json::obj());
                artifacts.insert(
                    file.clone(),
                    ArtifactInfo {
                        file: file.clone(),
                        entry: info.str_field("entry").unwrap_or("?").to_string(),
                        b: shapes.u64_field_or("B", 0) as usize,
                        l: shapes.u64_field_or("L", 0) as usize,
                        w: shapes.u64_field_or("W", 0) as usize,
                        lw: shapes.u64_field_or("Lw", 0) as usize,
                    },
                );
            }
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
        Ok(Runtime { client, exes: Mutex::new(BTreeMap::new()), artifacts, dir })
    }

    /// Artifact info by file name.
    pub fn info(&self, name: &str) -> anyhow::Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))
    }

    fn ensure_compiled(&self, name: &str) -> anyhow::Result<()> {
        let mut exes = self.exes.lock().unwrap();
        if exes.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
        exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an align artifact: `reads` is row-major (B, L) f32 base
    /// codes, `windows` (W, Lw). Returns (scores, best_window), each of
    /// length B.
    pub fn align(
        &self,
        name: &str,
        reads: &[f32],
        windows: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let info = self.info(name)?.clone();
        anyhow::ensure!(
            reads.len() == info.b * info.l,
            "reads len {} != B*L {}",
            reads.len(),
            info.b * info.l
        );
        anyhow::ensure!(
            windows.len() == info.w * info.lw,
            "windows len {} != W*Lw {}",
            windows.len(),
            info.w * info.lw
        );
        self.ensure_compiled(name)?;
        let exes = self.exes.lock().unwrap();
        let exe = &exes[name];
        let x = xla::Literal::vec1(reads).reshape(&[info.b as i64, info.l as i64])?;
        let y = xla::Literal::vec1(windows).reshape(&[info.w as i64, info.lw as i64])?;
        let result = exe.execute::<xla::Literal>(&[x, y])?[0][0].to_literal_sync()?;
        let (scores, best) = result.to_tuple2()?;
        Ok((scores.to_vec::<f32>()?, best.to_vec::<f32>()?))
    }

    /// Execute the seed artifact: one-hot inputs, (B, W) output.
    pub fn seed(
        &self,
        name: &str,
        reads_oh: &[f32],
        windows_oh: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        let info = self.info(name)?.clone();
        self.ensure_compiled(name)?;
        let exes = self.exes.lock().unwrap();
        let exe = &exes[name];
        let x = xla::Literal::vec1(reads_oh).reshape(&[info.b as i64, info.l as i64, 4])?;
        let y = xla::Literal::vec1(windows_oh).reshape(&[info.w as i64, info.l as i64, 4])?;
        let result = exe.execute::<xla::Literal>(&[x, y])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// File format helpers for read/window payloads inside Data-Units:
/// little-endian f32 arrays with a 16-byte header (magic, rows, cols).
pub mod payload {
    pub const MAGIC: u32 = 0x50443146; // "PD1F"

    pub fn encode(rows: u32, cols: u32, data: &[f32]) -> Vec<u8> {
        assert_eq!(data.len(), rows as usize * cols as usize);
        let mut out = Vec::with_capacity(16 + data.len() * 4);
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&rows.to_le_bytes());
        out.extend_from_slice(&cols.to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes());
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    pub fn decode(bytes: &[u8]) -> anyhow::Result<(u32, u32, Vec<f32>)> {
        anyhow::ensure!(bytes.len() >= 16, "payload too short");
        let word = |i: usize| u32::from_le_bytes(bytes[i..i + 4].try_into().unwrap());
        anyhow::ensure!(word(0) == MAGIC, "bad payload magic");
        let (rows, cols) = (word(4), word(8));
        let n = rows as usize * cols as usize;
        anyhow::ensure!(bytes.len() == 16 + n * 4, "payload size mismatch");
        let mut data = Vec::with_capacity(n);
        for i in 0..n {
            data.push(f32::from_le_bytes(bytes[16 + i * 4..20 + i * 4].try_into().unwrap()));
        }
        Ok((rows, cols, data))
    }
}

/// PJRT handles are `Rc`-based and must stay on one thread; the
/// [`RuntimeServer`] owns the [`Runtime`] on a dedicated inference
/// thread and serves align requests over a channel. [`RuntimeHandle`]
/// is the `Send + Sync` client the pilot agents use — one compiled
/// executable per model variant, shared by every Compute-Unit.
enum RtReq {
    Align {
        name: String,
        reads: Vec<f32>,
        windows: Vec<f32>,
        resp: std::sync::mpsc::Sender<anyhow::Result<(Vec<f32>, Vec<f32>)>>,
    },
    Info {
        name: String,
        resp: std::sync::mpsc::Sender<anyhow::Result<ArtifactInfo>>,
    },
    Shutdown,
}

/// Client handle to the runtime server thread (cloneable, Send+Sync).
pub struct RuntimeHandle {
    tx: Mutex<std::sync::mpsc::Sender<RtReq>>,
}

/// The server: owns the PJRT client + executables on its own thread.
pub struct RuntimeServer {
    join: Option<std::thread::JoinHandle<()>>,
    tx: std::sync::mpsc::Sender<RtReq>,
}

impl RuntimeServer {
    /// Spawn the inference thread; fails fast if the artifact dir is
    /// missing.
    pub fn spawn(dir: impl Into<PathBuf>) -> anyhow::Result<RuntimeServer> {
        let dir = dir.into();
        anyhow::ensure!(
            dir.join("manifest.json").exists(),
            "no artifacts at {} — run `make artifacts`",
            dir.display()
        );
        let (tx, rx) = std::sync::mpsc::channel::<RtReq>();
        let join = std::thread::Builder::new().name("pjrt-runtime".into()).spawn(move || {
            let rt = match Runtime::open(&dir) {
                Ok(rt) => rt,
                Err(e) => {
                    // Fail every request with the open error.
                    while let Ok(req) = rx.recv() {
                        match req {
                            RtReq::Align { resp, .. } => {
                                let _ = resp.send(Err(anyhow::anyhow!("runtime open failed: {e}")));
                            }
                            RtReq::Info { resp, .. } => {
                                let _ = resp.send(Err(anyhow::anyhow!("runtime open failed: {e}")));
                            }
                            RtReq::Shutdown => break,
                        }
                    }
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    RtReq::Align { name, reads, windows, resp } => {
                        let _ = resp.send(rt.align(&name, &reads, &windows));
                    }
                    RtReq::Info { name, resp } => {
                        let _ = resp.send(rt.info(&name).cloned());
                    }
                    RtReq::Shutdown => break,
                }
            }
        })?;
        Ok(RuntimeServer { join: Some(join), tx })
    }

    pub fn handle(&self) -> RuntimeHandle {
        RuntimeHandle { tx: Mutex::new(self.tx.clone()) }
    }
}

impl Drop for RuntimeServer {
    fn drop(&mut self) {
        let _ = self.tx.send(RtReq::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl RuntimeHandle {
    pub fn align(
        &self,
        name: &str,
        reads: Vec<f32>,
        windows: Vec<f32>,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let (resp, rx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(RtReq::Align { name: name.to_string(), reads, windows, resp })
            .map_err(|_| anyhow::anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("runtime thread dropped request"))?
    }

    pub fn info(&self, name: &str) -> anyhow::Result<ArtifactInfo> {
        let (resp, rx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(RtReq::Info { name: name.to_string(), resp })
            .map_err(|_| anyhow::anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("runtime thread dropped request"))?
    }
}

/// The local-mode CU executor: reads `reads.pd1` and `windows.pd1`
/// from the sandbox, batches through the align artifact, writes
/// `scores.csv` (read_index, best_window, score).
pub struct AlignExecutor {
    handle: RuntimeHandle,
    artifact: String,
}

impl AlignExecutor {
    pub fn new(server: &RuntimeServer, artifact: &str) -> AlignExecutor {
        AlignExecutor { handle: server.handle(), artifact: artifact.to_string() }
    }
}

impl Executor for AlignExecutor {
    fn execute(&self, _cu: &ComputeUnitDescription, sandbox: &Path) -> anyhow::Result<ExecResult> {
        let t0 = Instant::now();
        let reads_bytes = std::fs::read(sandbox.join("reads.pd1"))?;
        let windows_bytes = std::fs::read(sandbox.join("windows.pd1"))?;
        let (n_reads, l, reads) = payload::decode(&reads_bytes)?;
        let (w, lw, windows) = payload::decode(&windows_bytes)?;
        let info = self.handle.info(&self.artifact)?;
        anyhow::ensure!(l as usize == info.l, "read length {l} != artifact L {}", info.l);
        anyhow::ensure!(w as usize == info.w && lw as usize == info.lw, "window shape mismatch");

        let mut csv = String::from("read,best_window,score\n");
        let bl = info.b * info.l;
        let mut idx = 0usize;
        while idx < n_reads as usize {
            // Assemble one batch, padding the tail with the last read.
            let mut batch = vec![0f32; bl];
            for r in 0..info.b {
                let src = (idx + r).min(n_reads as usize - 1);
                batch[r * info.l..(r + 1) * info.l]
                    .copy_from_slice(&reads[src * info.l..(src + 1) * info.l]);
            }
            let (scores, best) = self.handle.align(&self.artifact, batch, windows.clone())?;
            for r in 0..info.b {
                let global = idx + r;
                if global >= n_reads as usize {
                    break;
                }
                csv.push_str(&format!("{global},{},{}\n", best[r] as i64, scores[r]));
            }
            idx += info.b;
        }
        std::fs::write(sandbox.join("scores.csv"), &csv)?;
        Ok(ExecResult { stdout: format!("aligned {n_reads} reads"), compute_s: t0.elapsed().as_secs_f64() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root.
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn payload_roundtrip() {
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let bytes = payload::encode(3, 4, &data);
        let (r, c, back) = payload::decode(&bytes).unwrap();
        assert_eq!((r, c), (3, 4));
        assert_eq!(back, data);
        assert!(payload::decode(&bytes[..10]).is_err());
        let mut corrupt = bytes.clone();
        corrupt[0] ^= 0xFF;
        assert!(payload::decode(&corrupt).is_err());
    }

    #[test]
    fn runtime_loads_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let info = rt.info("align_small.hlo.txt").unwrap();
        assert_eq!((info.b, info.l, info.w, info.lw), (8, 32, 8, 64));
        assert!(rt.info("nope.hlo.txt").is_err());
    }

    #[test]
    fn align_small_executes_and_finds_planted_read() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let info = rt.info("align_small.hlo.txt").unwrap().clone();
        let mut rng = crate::rng::Rng::new(11);
        let reads: Vec<f32> =
            (0..info.b * info.l).map(|_| rng.below(4) as f32).collect();
        let mut windows: Vec<f32> =
            (0..info.w * info.lw).map(|_| rng.below(4) as f32).collect();
        // Plant read r into window r's prefix.
        for r in 0..info.b.min(info.w) {
            for i in 0..info.l {
                windows[r * info.lw + i] = reads[r * info.l + i];
            }
        }
        let (scores, best) = rt.align("align_small.hlo.txt", &reads, &windows).unwrap();
        for r in 0..info.b {
            assert_eq!(best[r] as usize, r, "read {r} picked window {}", best[r]);
            // Perfect match: MATCH * L = 2 * 32.
            assert!((scores[r] - 64.0).abs() < 1e-3, "score {}", scores[r]);
        }
    }

    #[test]
    fn align_rejects_bad_shapes() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let rt = Runtime::open(artifacts_dir()).unwrap();
        assert!(rt.align("align_small.hlo.txt", &[0.0; 10], &[0.0; 10]).is_err());
    }
}
