//! Workload scheduling — the affinity-aware data/compute co-placement
//! scheduler of paper §5, plus baseline strategies used by the ablation
//! benches.
//!
//! The affinity scheduler implements the paper's algorithm verbatim:
//!
//! 1. find the Pilot that best fulfils the CU's requested affinity and
//!    the location of its input data;
//! 2. if such a Pilot exists and has an empty slot, place the CU in
//!    that pilot's queue;
//! 3. if delayed scheduling is active, wait `n` seconds and re-check
//!    whether the preferred Pilot has a free slot;
//! 4. otherwise place the CU in the global queue, to be pulled by the
//!    first Pilot with an available slot.
//!
//! The scheduler is a plug-able component ([`Scheduler`] trait) "and
//! can be replaced if desired".
//!
//! # Incremental context (perf)
//!
//! A placement decision needs three views of the world: the pilot
//! fleet, the DU→replica-location map, and per-pilot queue depths. The
//! seed implementation rebuilt the latter two from scratch for every
//! CU — O(pilots + DUs·replicas) per decision, with a coordination
//! store `llen` (and a `format!`-allocated key) per pilot. Those views
//! now live *inside* [`ManagerState`] as indexes maintained on each
//! mutation (`note_replica`, `note_queue_push/pop`), and
//! [`SchedContext::from_state`] assembles a context in O(1) by
//! borrowing them. The ranking loop itself computes each candidate's
//! data score and effective slots exactly once (the seed recomputed
//! effective slots inside the sort comparator) and borrows affinity
//! labels instead of cloning them per pilot.
//!
//! Decisions are bit-identical to the rebuild-per-decision
//! implementation; `indexed_context_matches_rebuilt_context` (property
//! test below) checks that on randomized manager states.

use crate::pilot::ManagerState;
use crate::topology::{Label, Topology};
use crate::unit::ComputeUnit;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Where the scheduler decided to put a CU.
#[derive(Debug, Clone, PartialEq)]
pub enum Placement {
    /// Enqueue on a specific pilot's agent queue.
    Pilot(String),
    /// Enqueue on the global queue (any agent may pull it).
    Global,
    /// Delayed scheduling: re-evaluate after this many seconds, hoping
    /// the preferred (data-local) pilot frees a slot.
    Delay(f64),
    /// No pilot can ever satisfy the CU's constraints.
    Unschedulable(String),
}

/// Read-only context handed to the scheduler: the manager state, the
/// physical location labels of every DU's replicas, and current
/// per-pilot queue depths (so placement accounts for work already
/// bound to a pilot, not just its busy slots).
pub struct SchedContext<'a> {
    pub topo: &'a Topology,
    pub state: &'a ManagerState,
    /// DU id -> labels of Pilot-Data currently holding a full replica.
    pub du_locations: &'a BTreeMap<String, Vec<Label>>,
    /// Pilot id -> CUs waiting in its agent-specific queue.
    pub queue_depth: &'a BTreeMap<String, usize>,
    /// Optional storage headroom per resource label: free bytes on the
    /// roomiest live quota'd Pilot-Data at that label. Labels with an
    /// unbounded (quota-less) PD are absent — they never fill. When
    /// present, [`SchedContext::data_score`] zeroes the score of any
    /// candidate whose pending stage-ins cannot fit, so nearly-full
    /// sites stop attracting placements whose staging would be
    /// rejected. `None` (the [`SchedContext::from_state`] default)
    /// disables the gate and keeps decisions bit-identical to the
    /// capacity-blind scheduler.
    pub capacity: Option<&'a BTreeMap<Label, u64>>,
    /// Current time in seconds, used by delay scheduling
    /// ([`AffinityScheduler::locality_wait_s`]) to meter a CU's
    /// locality-wait budget. The sim driver passes its simclock
    /// ([`SchedContext::with_now`]); the wall-clock service leaves the
    /// [`SchedContext::from_state`] default of `0.0`, which freezes the
    /// budget clock and makes the scheduler fall back to skip
    /// counting.
    pub now: f64,
}

impl<'a> SchedContext<'a> {
    /// Assemble a context in O(1) from the manager's incrementally
    /// maintained indexes (replica locations, live queue depths).
    pub fn from_state(topo: &'a Topology, state: &'a ManagerState) -> SchedContext<'a> {
        SchedContext {
            topo,
            state,
            du_locations: state.du_locations(),
            queue_depth: state.queue_depths(),
            capacity: None,
            now: 0.0,
        }
    }

    /// Attach a per-label storage-headroom map (see the `capacity`
    /// field) to enable capacity-aware scoring.
    pub fn with_capacity(mut self, capacity: &'a BTreeMap<Label, u64>) -> SchedContext<'a> {
        self.capacity = Some(capacity);
        self
    }

    /// Set the scheduler's clock (see the `now` field): the sim driver
    /// passes its simtime so locality-wait deadlines expire exactly.
    pub fn with_now(mut self, now: f64) -> SchedContext<'a> {
        self.now = now;
        self
    }

    /// Effective open capacity of a pilot in cores: free slots minus
    /// cores spoken for by CUs already queued on it (approximated with
    /// the current CU's core count).
    fn effective_slots(&self, p: &crate::pilot::PilotCompute, cu_cores: u32) -> i64 {
        let queued = *self.queue_depth.get(&p.id).unwrap_or(&0) as i64;
        p.free_slots() as i64 - queued * cu_cores.max(1) as i64
    }

    /// Pilots eligible for this CU: alive (not terminal) and within the
    /// CU's affinity constraint, with enough total cores. When a
    /// constraint is present, candidates come from the manager's
    /// `pilots_by_label` index via a label-subtree range scan
    /// ([`ManagerState::pilots_within`]) instead of a full fleet walk —
    /// the index returns sorted ids, so the candidate order (and hence
    /// every tie-break downstream) is identical to the `values()` scan.
    fn eligible_pilots(&self, cu: &ComputeUnit) -> Vec<&crate::pilot::PilotCompute> {
        let min_cores = cu.description.cores.max(1);
        match &cu.description.affinity {
            Some(constraint) => self
                .state
                .pilots_within(constraint)
                .into_iter()
                .filter_map(|id| self.state.pilots.get(id))
                .filter(|p| !p.state.is_terminal())
                .filter(|p| p.description.cores >= min_cores)
                .collect(),
            None => self
                .state
                .pilots
                .values()
                .filter(|p| !p.state.is_terminal())
                .filter(|p| p.description.cores >= min_cores)
                .collect(),
        }
    }

    /// Data-affinity score of running `cu` on a pilot at `label`:
    /// size-weighted affinity to the closest replica of each input DU.
    /// Higher is better; DUs with no replica yet contribute 0.
    ///
    /// Affinities go through the topology's interned-id walk
    /// ([`Topology::affinity_interned`]): one full-string hash per
    /// label, then integer LCA math — this runs once per (CU input,
    /// candidate pilot) on every placement decision.
    pub fn data_score(&self, cu: &ComputeUnit, label: &Label) -> f64 {
        let headroom = self.capacity.and_then(|m| m.get(label)).copied();
        let mut score = 0.0;
        let mut need: u64 = 0;
        for du in &cu.description.input_data {
            let Some(locs) = self.du_locations.get(du) else { continue };
            let best = locs
                .iter()
                .map(|l| self.topo.affinity_interned(label, l))
                .fold(0.0, f64::max);
            let size = self
                .state
                .dus
                .get(du)
                .map(|d| d.size().as_f64())
                .unwrap_or(1.0)
                .max(1.0);
            score += best * size.ln_1p();
            // Inputs without a replica at exactly this label would have
            // to be staged in — they consume local headroom.
            if headroom.is_some() && !locs.contains(label) {
                need = need.saturating_add(
                    self.state.dus.get(du).map(|d| d.size().as_u64()).unwrap_or(0),
                );
            }
        }
        // Capacity gate: a site whose quota cannot absorb the pending
        // stage-ins must not attract the placement (its staging would
        // be rejected at dispatch).
        if let Some(free) = headroom {
            if need > free {
                return 0.0;
            }
        }
        score
    }
}

/// Pluggable scheduling strategy.
pub trait Scheduler: Send + Sync {
    fn name(&self) -> &'static str;
    fn place(&self, cu: &ComputeUnit, ctx: &SchedContext) -> Placement;
}

/// The paper's affinity-aware scheduler (§5) with optional delayed
/// scheduling.
///
/// # Delay scheduling (locality wait)
///
/// With [`AffinityScheduler::locality_wait_s`] set, a CU whose best
/// [`SchedContext::data_score`] pilot is busy *waits* instead of
/// accepting a remote slot: `place` returns [`Placement::Delay`] and
/// the driver re-invokes it later. The wait is a hard per-CU budget
/// metered on [`SchedContext::now`]: the first waiting decision records
/// the start time, subsequent re-placements return the *remaining*
/// budget, and once `now` reaches `start + locality_wait_s` the CU
/// falls through to the normal non-local path (global queue or the
/// constrained subtree's best pilot) — waiting can therefore never
/// deadlock an otherwise-servable CU. Drivers with no simclock (the
/// wall-clock service leaves `now` at `0.0`) fall back to counting
/// re-placement skips against [`AffinityScheduler::max_delay_rounds`].
/// A budget of `Some(0.0)` records nothing and decides exactly like
/// `None` — that equivalence is what the bit-identity oracle property
/// pins.
pub struct AffinityScheduler {
    /// Seconds to wait for a slot on the preferred pilot before falling
    /// back to the global queue. `None` disables delayed scheduling.
    pub delay_s: Option<f64>,
    /// Consecutive delays already spent per CU (so delay is bounded).
    delays_spent: Mutex<BTreeMap<String, u32>>,
    /// Max delay rounds before giving up on locality.
    pub max_delay_rounds: u32,
    /// Locality-wait budget (seconds) for delay scheduling; `None`
    /// disables it (the pre-budget behavior).
    pub locality_wait_s: Option<f64>,
    /// Per-CU wait ledger: (budget start time, re-placement skips so
    /// far). Entries exist only while a CU is actively waiting.
    wait_started: Mutex<BTreeMap<String, (f64, u32)>>,
}

impl AffinityScheduler {
    pub fn new(delay_s: Option<f64>) -> AffinityScheduler {
        AffinityScheduler {
            delay_s,
            delays_spent: Mutex::new(BTreeMap::new()),
            max_delay_rounds: 3,
            locality_wait_s: None,
            wait_started: Mutex::new(BTreeMap::new()),
        }
    }

    /// Enable delay scheduling with the given locality-wait budget.
    pub fn with_locality_wait(mut self, wait_s: Option<f64>) -> AffinityScheduler {
        self.locality_wait_s = wait_s;
        self
    }
}

impl Scheduler for AffinityScheduler {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn place(&self, cu: &ComputeUnit, ctx: &SchedContext) -> Placement {
        let eligible = ctx.eligible_pilots(cu);
        if eligible.is_empty() {
            return match &cu.description.affinity {
                Some(c) => Placement::Unschedulable(format!(
                    "no pilot within affinity constraint '{c}' can fit {} cores",
                    cu.description.cores
                )),
                None => Placement::Unschedulable(format!(
                    "no pilot can fit {} cores",
                    cu.description.cores
                )),
            };
        }

        // Step 1: rank by data score, tie-break by effective open
        // capacity (free slots minus queued work) then id for
        // determinism. Score and slots are computed once per candidate,
        // not inside the comparator.
        let cores = cu.description.cores.max(1);
        let mut ranked: Vec<(f64, i64, &crate::pilot::PilotCompute)> = eligible
            .iter()
            .map(|p| (ctx.data_score(cu, p.affinity_ref()), ctx.effective_slots(p, cores), *p))
            .collect();
        ranked.sort_by(|a, b| {
            b.0.partial_cmp(&a.0).unwrap().then(b.1.cmp(&a.1)).then(a.2.id.cmp(&b.2.id))
        });
        let (best_score, best_slots, best) = (ranked[0].0, ranked[0].1, ranked[0].2);

        // No data affinity anywhere and no constraint: let the global
        // queue load-balance (step 4 fast path).
        if best_score <= 0.0 && cu.description.affinity.is_none() {
            return Placement::Global;
        }

        // Step 2: preferred pilot is active with an open slot that is
        // not already spoken for by queued work.
        if best.has_free_slot(cu.description.cores) && best_slots >= cores as i64 {
            self.delays_spent.lock().unwrap().remove(&cu.id);
            self.wait_started.lock().unwrap().remove(&cu.id);
            return Placement::Pilot(best.id.clone());
        }

        // Step 2.5: delay scheduling — the data-local pilot is busy, so
        // spend the locality-wait budget before accepting a non-local
        // slot. Only engages when the CU actually has data somewhere
        // (`best_score > 0.0`); score-less CUs gain nothing by waiting.
        if let Some(w) = self.locality_wait_s {
            if best_score > 0.0 {
                let mut waits = self.wait_started.lock().unwrap();
                match waits.get(&cu.id).copied() {
                    None => {
                        // A zero budget records nothing and falls
                        // through — exactly the `None` decision path
                        // (the bit-identity oracle).
                        if w > 0.0 {
                            waits.insert(cu.id.clone(), (ctx.now, 0));
                            return Placement::Delay(w);
                        }
                    }
                    Some((start, skips)) => {
                        // Float-exact expiry: the driver re-places at
                        // `start + w`, and this comparison recomputes
                        // the same expression.
                        let deadline = start + w;
                        if ctx.now >= deadline {
                            // Budget exhausted: fall through to the
                            // non-local path — never deadlock.
                            waits.remove(&cu.id);
                        } else if skips + 1 >= self.max_delay_rounds {
                            // Wall-clock fallback: a frozen clock
                            // (`now` stuck at 0.0) can never reach the
                            // deadline, so skip counting bounds the
                            // wait instead.
                            waits.remove(&cu.id);
                        } else {
                            waits.insert(cu.id.clone(), (start, skips + 1));
                            return Placement::Delay(deadline - ctx.now);
                        }
                    }
                }
            }
        }

        // Step 3: delayed scheduling.
        if let Some(d) = self.delay_s {
            let mut spent = self.delays_spent.lock().unwrap();
            let n = spent.entry(cu.id.clone()).or_insert(0);
            if *n < self.max_delay_rounds {
                *n += 1;
                return Placement::Delay(d);
            }
        }

        // Step 4: global queue (or pin to the constrained subtree's
        // least-loaded pilot when a constraint exists — the global
        // queue is unconstrained).
        if cu.description.affinity.is_some() {
            return Placement::Pilot(best.id.clone());
        }
        Placement::Global
    }
}

/// Baseline: ignore data locality entirely; first pilot with a free
/// slot, else the global queue.
pub struct DataUnawareScheduler;

impl Scheduler for DataUnawareScheduler {
    fn name(&self) -> &'static str {
        "data-unaware"
    }

    fn place(&self, cu: &ComputeUnit, ctx: &SchedContext) -> Placement {
        let eligible = ctx.eligible_pilots(cu);
        if eligible.is_empty() {
            return Placement::Unschedulable("no eligible pilot".into());
        }
        for p in eligible {
            if p.has_free_slot(cu.description.cores) {
                return Placement::Pilot(p.id.clone());
            }
        }
        Placement::Global
    }
}

/// Baseline: cycle through eligible pilots regardless of load or data.
pub struct RoundRobinScheduler {
    counter: AtomicUsize,
}

impl Default for RoundRobinScheduler {
    fn default() -> Self {
        RoundRobinScheduler { counter: AtomicUsize::new(0) }
    }
}

impl Scheduler for RoundRobinScheduler {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn place(&self, cu: &ComputeUnit, ctx: &SchedContext) -> Placement {
        let eligible = ctx.eligible_pilots(cu);
        if eligible.is_empty() {
            return Placement::Unschedulable("no eligible pilot".into());
        }
        let i = self.counter.fetch_add(1, Ordering::Relaxed) % eligible.len();
        Placement::Pilot(eligible[i].id.clone())
    }
}

/// Baseline: uniformly random eligible pilot (seeded, deterministic).
pub struct RandomScheduler {
    rng: Mutex<crate::rng::Rng>,
}

impl RandomScheduler {
    pub fn new(seed: u64) -> RandomScheduler {
        RandomScheduler { rng: Mutex::new(crate::rng::Rng::new(seed)) }
    }
}

impl Scheduler for RandomScheduler {
    fn name(&self) -> &'static str {
        "random"
    }

    fn place(&self, cu: &ComputeUnit, ctx: &SchedContext) -> Placement {
        let eligible = ctx.eligible_pilots(cu);
        if eligible.is_empty() {
            return Placement::Unschedulable("no eligible pilot".into());
        }
        let i = self.rng.lock().unwrap().below(eligible.len() as u64) as usize;
        Placement::Pilot(eligible[i].id.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pilot::{PilotCompute, PilotComputeDescription, PilotState};
    use crate::unit::{ComputeUnit, ComputeUnitDescription, DataUnit, DataUnitDescription, FileRef};
    use crate::util::Bytes;

    fn mk_pilot(st: &mut ManagerState, cores: u32, affinity: &str, state: PilotState) -> String {
        let mut p = PilotCompute::new(PilotComputeDescription {
            service_url: "batch://m".into(),
            cores,
            walltime_s: 1e6,
            affinity: Some(Label::new(affinity)),
        });
        p.state = state;
        st.add_pilot(p)
    }

    fn mk_du(st: &mut ManagerState, size: Bytes) -> String {
        st.add_du(DataUnit::new(DataUnitDescription {
            name: "d".into(),
            files: vec![FileRef::sized("f", size)],
            affinity: None,
        }))
    }

    fn mk_cu(input: Vec<String>, affinity: Option<&str>) -> ComputeUnit {
        ComputeUnit::new(ComputeUnitDescription {
            executable: "x".into(),
            cores: 1,
            input_data: input,
            affinity: affinity.map(Label::new),
            ..Default::default()
        })
    }

    #[test]
    fn affinity_scheduler_prefers_data_local_pilot() {
        let mut st = ManagerState::new();
        let p_far = mk_pilot(&mut st, 8, "osg/cornell", PilotState::Active);
        let p_near = mk_pilot(&mut st, 8, "xsede/tacc/lonestar", PilotState::Active);
        let du = mk_du(&mut st, Bytes::gb(8));
        let mut locs = BTreeMap::new();
        locs.insert(du.clone(), vec![Label::new("xsede/tacc/lonestar")]);
        let topo = Topology::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 0.0 };
        let cu = mk_cu(vec![du], None);
        let sched = AffinityScheduler::new(None);
        assert_eq!(sched.place(&cu, &ctx), Placement::Pilot(p_near.clone()));
        let _ = p_far;
    }

    #[test]
    fn no_data_no_constraint_goes_global() {
        let mut st = ManagerState::new();
        mk_pilot(&mut st, 8, "osg/cornell", PilotState::Active);
        let topo = Topology::new();
        let locs = BTreeMap::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 0.0 };
        let sched = AffinityScheduler::new(None);
        assert_eq!(sched.place(&mk_cu(vec![], None), &ctx), Placement::Global);
    }

    #[test]
    fn constraint_filters_pilots() {
        let mut st = ManagerState::new();
        mk_pilot(&mut st, 8, "osg/cornell", PilotState::Active);
        let p_x = mk_pilot(&mut st, 8, "xsede/tacc/lonestar", PilotState::Active);
        let topo = Topology::new();
        let locs = BTreeMap::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 0.0 };
        let sched = AffinityScheduler::new(None);
        let cu = mk_cu(vec![], Some("xsede"));
        assert_eq!(sched.place(&cu, &ctx), Placement::Pilot(p_x));
        let impossible = mk_cu(vec![], Some("ec2/us-west"));
        assert!(matches!(sched.place(&impossible, &ctx), Placement::Unschedulable(_)));
    }

    #[test]
    fn oversized_cu_is_unschedulable() {
        let mut st = ManagerState::new();
        mk_pilot(&mut st, 2, "x", PilotState::Active);
        let topo = Topology::new();
        let locs = BTreeMap::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 0.0 };
        let mut cu = mk_cu(vec![], None);
        cu.description.cores = 16;
        assert!(matches!(
            AffinityScheduler::new(None).place(&cu, &ctx),
            Placement::Unschedulable(_)
        ));
    }

    /// Multi-slot pilots report occupancy through shared `busy_slots`
    /// (updated at every dispatch/completion edge); the scheduler's
    /// free-slot filtering must track it: a full data-local pilot
    /// overflows new work to the global queue, and placement binds
    /// again the moment a slot frees.
    #[test]
    fn busy_multi_slot_pilot_overflows_to_global_until_a_slot_frees() {
        let mut st = ManagerState::new();
        let near = mk_pilot(&mut st, 4, "xsede/tacc/lonestar", PilotState::Active);
        mk_pilot(&mut st, 4, "osg/cornell", PilotState::Active);
        let du = mk_du(&mut st, Bytes::gb(8));
        let mut locs = BTreeMap::new();
        locs.insert(du.clone(), vec![Label::new("xsede/tacc/lonestar")]);
        for (id, l) in &locs {
            for label in l {
                st.note_replica(id, label);
            }
        }
        let topo = Topology::new();
        let sched = AffinityScheduler::new(None);
        let cu = mk_cu(vec![du], None);
        // All four slots busy — as a 4-worker agent pool reports while
        // running four CUs.
        st.pilots.get_mut(&near).unwrap().busy_slots = 4;
        {
            let ctx = SchedContext::from_state(&topo, &st);
            assert_eq!(sched.place(&cu, &ctx), Placement::Global);
        }
        // One CU completes -> a slot frees -> data-local binding again.
        st.pilots.get_mut(&near).unwrap().busy_slots = 3;
        let ctx = SchedContext::from_state(&topo, &st);
        assert_eq!(sched.place(&cu, &ctx), Placement::Pilot(near));
    }

    /// ISSUE 6 satellite: with a capacity map attached, a nearly-full
    /// site stops attracting placements whose stage-ins cannot fit —
    /// the next-best replica site wins instead. Without the map the
    /// decision is the capacity-blind one.
    #[test]
    fn capacity_gate_redirects_placement_away_from_full_sites() {
        let mut st = ManagerState::new();
        let p_full = mk_pilot(&mut st, 8, "xsede/tacc/stampede", PilotState::Active);
        let p_roomy = mk_pilot(&mut st, 8, "xsede/tacc/lonestar", PilotState::Active);
        let du = mk_du(&mut st, Bytes::gb(8));
        let mut locs = BTreeMap::new();
        // Stampede holds the only replica, so it wins the score
        // outright when capacity is ignored.
        locs.insert(du.clone(), vec![Label::new("xsede/tacc/stampede")]);
        let topo = Topology::new();
        let depth = BTreeMap::new();
        let sched = AffinityScheduler::new(None);
        let cu = mk_cu(vec![du.clone()], None);
        let blind = SchedContext {
            topo: &topo,
            state: &st,
            du_locations: &locs,
            queue_depth: &depth,
            capacity: None,
            now: 0.0,
        };
        assert_eq!(sched.place(&cu, &blind), Placement::Pilot(p_full.clone()));
        // Stampede's scratch has 1 GiB of headroom left; lonestar is
        // quota'd but roomy. Stampede holds the replica (no stage-in
        // needed) so it still wins: the gate only fires on *missing*
        // local replicas.
        let mut cap = BTreeMap::new();
        cap.insert(Label::new("xsede/tacc/stampede"), Bytes::gb(1).as_u64());
        cap.insert(Label::new("xsede/tacc/lonestar"), Bytes::gb(100).as_u64());
        let gated = SchedContext {
            topo: &topo,
            state: &st,
            du_locations: &locs,
            queue_depth: &depth,
            capacity: Some(&cap),
            now: 0.0,
        };
        assert_eq!(sched.place(&cu, &gated), Placement::Pilot(p_full.clone()));
        // Now the replica lives only on lonestar: stampede would have
        // to stage 8 GiB into 1 GiB of headroom — its score gates to
        // zero and lonestar (local replica, plenty of room) wins.
        locs.insert(du.clone(), vec![Label::new("xsede/tacc/lonestar")]);
        let gated = SchedContext {
            topo: &topo,
            state: &st,
            du_locations: &locs,
            queue_depth: &depth,
            capacity: Some(&cap),
            now: 0.0,
        };
        assert_eq!(sched.place(&cu, &gated), Placement::Pilot(p_roomy));
        let _ = p_full;
    }

    #[test]
    fn delayed_scheduling_waits_then_gives_up() {
        let mut st = ManagerState::new();
        let near = mk_pilot(&mut st, 1, "xsede/tacc/lonestar", PilotState::Active);
        st.pilots.get_mut(&near).unwrap().busy_slots = 1; // full
        mk_pilot(&mut st, 8, "osg/cornell", PilotState::Active);
        let du = mk_du(&mut st, Bytes::gb(4));
        let mut locs = BTreeMap::new();
        locs.insert(du.clone(), vec![Label::new("xsede/tacc/lonestar")]);
        let topo = Topology::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 0.0 };
        let sched = AffinityScheduler::new(Some(30.0));
        let cu = mk_cu(vec![du], None);
        // max_delay_rounds delays, then fall back to global.
        assert_eq!(sched.place(&cu, &ctx), Placement::Delay(30.0));
        assert_eq!(sched.place(&cu, &ctx), Placement::Delay(30.0));
        assert_eq!(sched.place(&cu, &ctx), Placement::Delay(30.0));
        assert_eq!(sched.place(&cu, &ctx), Placement::Global);
    }

    /// Busy data-local pilot + roomy remote pilot + one replica on the
    /// local site: the canonical delay-scheduling scenario.
    fn wait_scenario() -> (ManagerState, String, BTreeMap<String, Vec<Label>>) {
        let mut st = ManagerState::new();
        let near = mk_pilot(&mut st, 1, "xsede/tacc/lonestar", PilotState::Active);
        st.pilots.get_mut(&near).unwrap().busy_slots = 1; // full
        mk_pilot(&mut st, 8, "osg/cornell", PilotState::Active);
        let du = mk_du(&mut st, Bytes::gb(4));
        let mut locs = BTreeMap::new();
        locs.insert(du.clone(), vec![Label::new("xsede/tacc/lonestar")]);
        (st, du, locs)
    }

    /// ISSUE 10 tentpole oracle: a zero locality-wait budget records
    /// nothing and decides exactly like no budget at all.
    #[test]
    fn zero_locality_wait_is_the_no_wait_path() {
        let (st, du, locs) = wait_scenario();
        let topo = Topology::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 0.0 };
        let plain = AffinityScheduler::new(None);
        let zero = AffinityScheduler::new(None).with_locality_wait(Some(0.0));
        let cu = mk_cu(vec![du], None);
        for _ in 0..4 {
            assert_eq!(zero.place(&cu, &ctx), plain.place(&cu, &ctx));
        }
        assert_eq!(zero.place(&cu, &ctx), Placement::Global);
    }

    /// With a simclock, a waiting CU parks for the remaining budget on
    /// every re-place and accepts a remote slot exactly at the
    /// deadline.
    #[test]
    fn locality_wait_parks_then_accepts_remote_at_the_deadline() {
        let (st, du, locs) = wait_scenario();
        let topo = Topology::new();
        let depth = BTreeMap::new();
        let at = |now: f64| SchedContext {
            topo: &topo,
            state: &st,
            du_locations: &locs,
            queue_depth: &depth,
            capacity: None,
            now,
        };
        let sched = AffinityScheduler::new(None).with_locality_wait(Some(60.0));
        let cu = mk_cu(vec![du], None);
        assert_eq!(sched.place(&cu, &at(0.0)), Placement::Delay(60.0));
        // Mid-budget re-place returns the *remaining* budget.
        assert_eq!(sched.place(&cu, &at(20.0)), Placement::Delay(40.0));
        // At the deadline the budget is spent: non-local placement.
        assert_eq!(sched.place(&cu, &at(60.0)), Placement::Global);
        // The ledger was cleared: a fresh submission waits again.
        assert_eq!(sched.place(&cu, &at(100.0)), Placement::Delay(60.0));
    }

    /// With a frozen clock (the wall-clock service leaves `now` at
    /// 0.0), skip counting bounds the wait instead of the deadline.
    #[test]
    fn locality_wait_skip_count_bounds_wall_clock_waiting() {
        let (st, du, locs) = wait_scenario();
        let topo = Topology::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 0.0 };
        let sched = AffinityScheduler::new(None).with_locality_wait(Some(60.0));
        let cu = mk_cu(vec![du], None);
        // max_delay_rounds re-places, then fall back to global.
        assert_eq!(sched.place(&cu, &ctx), Placement::Delay(60.0));
        assert_eq!(sched.place(&cu, &ctx), Placement::Delay(60.0));
        assert_eq!(sched.place(&cu, &ctx), Placement::Delay(60.0));
        assert_eq!(sched.place(&cu, &ctx), Placement::Global);
    }

    /// A slot freeing on the preferred pilot ends the wait immediately
    /// and clears the ledger.
    #[test]
    fn locality_wait_releases_when_the_local_slot_frees() {
        let (mut st, du, locs) = wait_scenario();
        let topo = Topology::new();
        let depth = BTreeMap::new();
        let sched = AffinityScheduler::new(None).with_locality_wait(Some(60.0));
        let cu = mk_cu(vec![du], None);
        {
            let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 0.0 };
            assert_eq!(sched.place(&cu, &ctx), Placement::Delay(60.0));
        }
        let near = st.pilots.values().find(|p| p.busy_slots == 1).unwrap().id.clone();
        st.pilots.get_mut(&near).unwrap().busy_slots = 0;
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 10.0 };
        assert_eq!(sched.place(&cu, &ctx), Placement::Pilot(near.clone()));
        // Ledger cleared: refilling the pilot starts a fresh budget.
        st.pilots.get_mut(&near).unwrap().busy_slots = 1;
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 10.0 };
        assert_eq!(sched.place(&cu, &ctx), Placement::Delay(60.0));
    }

    /// ISSUE 10 tentpole properties: the locality-wait budget is never
    /// exceeded (every promised wakeup lands at or before the
    /// deadline), the frozen-clock skip counter never exceeds
    /// `max_delay_rounds` delays, and waiting never deadlocks an
    /// otherwise-servable CU — at or past the deadline the decision is
    /// always non-Delay.
    #[test]
    fn locality_wait_budget_bound_and_no_deadlock_property() {
        crate::prop::check_default(
            |rng| {
                let w = rng.range_f64(0.1, 120.0);
                let frozen = rng.chance(0.3);
                let n = crate::prop::gen::usize_in(rng, 1, 10);
                let steps: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 60.0)).collect();
                (w, frozen, steps)
            },
            |(w, frozen, steps)| {
                let (st, du, locs) = wait_scenario();
                let topo = Topology::new();
                let depth = BTreeMap::new();
                let sched = AffinityScheduler::new(None).with_locality_wait(Some(*w));
                let cu = mk_cu(vec![du], None);
                let mut now = 0.0;
                let mut start: Option<f64> = None;
                let mut delays_seen = 0u32;
                for (i, dt) in steps.iter().enumerate() {
                    if !*frozen {
                        now += dt;
                    }
                    let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now };
                    match sched.place(&cu, &ctx) {
                        Placement::Delay(d) => {
                            delays_seen += 1;
                            let s = *start.get_or_insert(now);
                            if now + d > s + w + 1e-9 {
                                return Err(format!(
                                    "step {i}: wakeup past deadline: {now}+{d} > {s}+{w}"
                                ));
                            }
                            if delays_seen > sched.max_delay_rounds && *frozen {
                                return Err(format!("step {i}: frozen-clock skip bound exceeded"));
                            }
                        }
                        Placement::Global => {
                            // Legitimate give-up; the ledger is clear,
                            // so the next round starts a fresh budget.
                            start = None;
                            delays_seen = 0;
                        }
                        other => return Err(format!("step {i}: unexpected {other:?}")),
                    }
                    if let Some(s) = start {
                        if now >= s + w {
                            // No deadlock: past the deadline the CU
                            // must be serviced immediately.
                            let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now };
                            if matches!(sched.place(&cu, &ctx), Placement::Delay(_)) {
                                return Err(format!(
                                    "step {i}: Delay at/after deadline ({now} >= {s}+{w})"
                                ));
                            }
                            start = None;
                            delays_seen = 0;
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn data_unaware_takes_first_free() {
        let mut st = ManagerState::new();
        let a = mk_pilot(&mut st, 2, "a", PilotState::Active);
        mk_pilot(&mut st, 2, "b", PilotState::Active);
        let topo = Topology::new();
        let locs = BTreeMap::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 0.0 };
        let cu = mk_cu(vec![], None);
        assert_eq!(DataUnawareScheduler.place(&cu, &ctx), Placement::Pilot(a));
    }

    #[test]
    fn round_robin_cycles() {
        let mut st = ManagerState::new();
        let a = mk_pilot(&mut st, 2, "a", PilotState::Active);
        let b = mk_pilot(&mut st, 2, "b", PilotState::Active);
        let topo = Topology::new();
        let locs = BTreeMap::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 0.0 };
        let sched = RoundRobinScheduler::default();
        let cu = mk_cu(vec![], None);
        let p1 = sched.place(&cu, &ctx);
        let p2 = sched.place(&cu, &ctx);
        let p3 = sched.place(&cu, &ctx);
        assert_ne!(p1, p2);
        assert_eq!(p1, p3);
        assert!(matches!(p1, Placement::Pilot(ref x) if *x == a || *x == b));
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let mut st = ManagerState::new();
        for i in 0..5 {
            mk_pilot(&mut st, 2, &format!("site{i}"), PilotState::Active);
        }
        let topo = Topology::new();
        let locs = BTreeMap::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 0.0 };
        let cu = mk_cu(vec![], None);
        let seq = |seed| {
            let s = RandomScheduler::new(seed);
            (0..10).map(|_| s.place(&cu, &ctx)).collect::<Vec<_>>()
        };
        assert_eq!(seq(9), seq(9));
        assert_ne!(seq(9), seq(10));
    }

    /// The incremental indexes must be *invisible* to the scheduler:
    /// placements from a context assembled via `SchedContext::from_state`
    /// must equal placements from maps rebuilt from scratch out of the
    /// same mutation log.
    #[test]
    fn indexed_context_matches_rebuilt_context() {
        crate::prop::check_default(
            |rng| {
                let sites = ["osg/a", "osg/b", "xsede/tacc/ls", "xsede/tacc/st", "ec2/east"];
                let n_pilots = crate::prop::gen::usize_in(rng, 1, 6);
                let pilots: Vec<(u32, String, bool, u32)> = (0..n_pilots)
                    .map(|_| {
                        (
                            1 + rng.below(16) as u32,
                            rng.choose(&sites).to_string(),
                            rng.chance(0.8),
                            rng.below(4) as u32,
                        )
                    })
                    .collect();
                let n_dus = crate::prop::gen::usize_in(rng, 0, 5);
                let dus: Vec<(u64, Vec<String>)> = (0..n_dus)
                    .map(|_| {
                        let n_repl = rng.below(3);
                        (
                            1 + rng.below(64),
                            (0..n_repl).map(|_| rng.choose(&sites).to_string()).collect(),
                        )
                    })
                    .collect();
                let n_ops = crate::prop::gen::usize_in(rng, 0, 20);
                let qops: Vec<(usize, bool)> = (0..n_ops)
                    .map(|_| (rng.below(n_pilots as u64) as usize, rng.chance(0.7)))
                    .collect();
                let n_cus = crate::prop::gen::usize_in(rng, 1, 8);
                let cus: Vec<(u32, Option<String>, Vec<usize>)> = (0..n_cus)
                    .map(|_| {
                        (
                            1 + rng.below(4) as u32,
                            if rng.chance(0.3) {
                                Some(rng.choose(&sites).to_string())
                            } else {
                                None
                            },
                            if n_dus == 0 {
                                Vec::new()
                            } else {
                                (0..rng.below(3)).map(|_| rng.below(n_dus as u64) as usize).collect()
                            },
                        )
                    })
                    .collect();
                let delay = rng.chance(0.5);
                (pilots, dus, qops, cus, delay)
            },
            |(pilots, dus, qops, cus, delay)| {
                let mut st = ManagerState::new();
                let mut pilot_ids = Vec::new();
                for (cores, site, active, busy) in pilots {
                    let id = mk_pilot(
                        &mut st,
                        *cores,
                        site,
                        if *active { PilotState::Active } else { PilotState::Queued },
                    );
                    st.pilots.get_mut(&id).unwrap().busy_slots = (*busy).min(*cores);
                    pilot_ids.push(id);
                }
                // Apply the mutation log to the live indexes AND to
                // hand-rebuilt maps (the seed implementation's shape).
                let mut expected_locs: BTreeMap<String, Vec<Label>> = BTreeMap::new();
                let mut du_ids = Vec::new();
                for (gb, labels) in dus {
                    let id = mk_du(&mut st, Bytes::gb(*gb));
                    for l in labels {
                        let lab = Label::new(l);
                        st.note_replica(&id, &lab);
                        let e = expected_locs.entry(id.clone()).or_default();
                        if !e.contains(&lab) {
                            e.push(lab);
                        }
                    }
                    du_ids.push(id);
                }
                let mut expected_depth: BTreeMap<String, usize> = BTreeMap::new();
                for (pi, push) in qops {
                    let id = &pilot_ids[*pi];
                    if *push {
                        st.note_queue_push(id);
                        *expected_depth.entry(id.clone()).or_insert(0) += 1;
                    } else {
                        st.note_queue_pop(id);
                        if let Some(d) = expected_depth.get_mut(id) {
                            *d = d.saturating_sub(1);
                        }
                    }
                }
                let topo = Topology::new();
                let delay_s = if *delay { Some(30.0) } else { None };
                let sched_indexed = AffinityScheduler::new(delay_s);
                let sched_rebuilt = AffinityScheduler::new(delay_s);
                for (cores, aff, inputs) in cus {
                    let input: Vec<String> =
                        inputs.iter().map(|i| du_ids[*i].clone()).collect();
                    let mut cu = mk_cu(input, aff.as_deref());
                    cu.description.cores = *cores;
                    let ctx_indexed = SchedContext::from_state(&topo, &st);
                    let ctx_rebuilt = SchedContext {
                        topo: &topo,
                        state: &st,
                        du_locations: &expected_locs,
                        queue_depth: &expected_depth,
                        capacity: None,
                        now: 0.0,
                    };
                    let a = sched_indexed.place(&cu, &ctx_indexed);
                    let b = sched_rebuilt.place(&cu, &ctx_rebuilt);
                    if a != b {
                        return Err(format!("indexed {a:?} != rebuilt {b:?} for cu {}", cu.id));
                    }
                }
                Ok(())
            },
        );
    }

    /// Constraint filtering through the `pilots_by_label` subtree index
    /// must select exactly the pilots (in exactly the order) the
    /// full-fleet filter would.
    #[test]
    fn subtree_pruned_eligibility_matches_full_scan() {
        crate::prop::check_default(
            |rng| {
                let sites = [
                    "osg", "osg/a", "osg/a/deep", "osg/ab", "xsede/tacc/ls", "xsede/tacc",
                    "ec2/east", "",
                ];
                let n = crate::prop::gen::usize_in(rng, 0, 12);
                let pilots: Vec<(u32, String, bool)> = (0..n)
                    .map(|_| {
                        (
                            1 + rng.below(8) as u32,
                            rng.choose(&sites).to_string(),
                            rng.chance(0.8),
                        )
                    })
                    .collect();
                let constraints: Vec<(String, u32)> = (0..6)
                    .map(|_| (rng.choose(&sites).to_string(), 1 + rng.below(8) as u32))
                    .collect();
                (pilots, constraints)
            },
            |(pilots, constraints)| {
                let mut st = ManagerState::new();
                for (cores, site, active) in pilots {
                    mk_pilot(
                        &mut st,
                        *cores,
                        site,
                        if *active { PilotState::Active } else { PilotState::Done },
                    );
                }
                let topo = Topology::new();
                let locs = BTreeMap::new();
                let depth = BTreeMap::new();
                let ctx = SchedContext {
                    topo: &topo,
                    state: &st,
                    du_locations: &locs,
                    queue_depth: &depth,
                    capacity: None,
                    now: 0.0,
                };
                for (site, cores) in constraints {
                    let mut cu = mk_cu(vec![], Some(site.as_str()));
                    cu.description.cores = *cores;
                    let indexed: Vec<String> =
                        ctx.eligible_pilots(&cu).iter().map(|p| p.id.clone()).collect();
                    let constraint = Label::new(site);
                    let brute: Vec<String> = st
                        .pilots
                        .values()
                        .filter(|p| !p.state.is_terminal())
                        .filter(|p| p.description.cores >= cu.description.cores.max(1))
                        .filter(|p| p.affinity_ref().within(&constraint))
                        .map(|p| p.id.clone())
                        .collect();
                    if indexed != brute {
                        return Err(format!(
                            "constraint '{site}': index {indexed:?} != brute {brute:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn scheduler_placement_property_every_cu_gets_decision() {
        crate::prop::check_default(
            |rng| {
                // Random pilots + random CUs; property: place() never
                // panics and returns Pilot only for existing pilots.
                let n_pilots = crate::prop::gen::usize_in(rng, 1, 6);
                let n_cus = crate::prop::gen::usize_in(rng, 1, 10);
                let sites = ["osg/a", "osg/b", "xsede/tacc/ls", "ec2/east"];
                let pilots: Vec<(u32, String, bool)> = (0..n_pilots)
                    .map(|_| {
                        (
                            1 + rng.below(16) as u32,
                            rng.choose(&sites).to_string(),
                            rng.chance(0.8),
                        )
                    })
                    .collect();
                let cus: Vec<(u32, Option<String>)> = (0..n_cus)
                    .map(|_| {
                        (
                            1 + rng.below(4) as u32,
                            if rng.chance(0.3) {
                                Some(rng.choose(&sites).to_string())
                            } else {
                                None
                            },
                        )
                    })
                    .collect();
                (pilots, cus)
            },
            |(pilots, cus)| {
                let mut st = ManagerState::new();
                for (cores, site, active) in pilots {
                    mk_pilot(
                        &mut st,
                        *cores,
                        site,
                        if *active { PilotState::Active } else { PilotState::Queued },
                    );
                }
                let topo = Topology::new();
                let locs = BTreeMap::new();
                let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth, capacity: None, now: 0.0 };
                let sched = AffinityScheduler::new(None);
                for (cores, aff) in cus {
                    let mut cu = mk_cu(vec![], aff.as_deref());
                    cu.description.cores = *cores;
                    match sched.place(&cu, &ctx) {
                        Placement::Pilot(id) => {
                            if !st.pilots.contains_key(&id) {
                                return Err(format!("placed on unknown pilot {id}"));
                            }
                        }
                        Placement::Global | Placement::Delay(_) | Placement::Unschedulable(_) => {}
                    }
                }
                Ok(())
            },
        );
    }
}
