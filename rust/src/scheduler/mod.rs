//! Workload scheduling — the affinity-aware data/compute co-placement
//! scheduler of paper §5, plus baseline strategies used by the ablation
//! benches.
//!
//! The affinity scheduler implements the paper's algorithm verbatim:
//!
//! 1. find the Pilot that best fulfils the CU's requested affinity and
//!    the location of its input data;
//! 2. if such a Pilot exists and has an empty slot, place the CU in
//!    that pilot's queue;
//! 3. if delayed scheduling is active, wait `n` seconds and re-check
//!    whether the preferred Pilot has a free slot;
//! 4. otherwise place the CU in the global queue, to be pulled by the
//!    first Pilot with an available slot.
//!
//! The scheduler is a plug-able component ([`Scheduler`] trait) "and
//! can be replaced if desired".

use crate::pilot::ManagerState;
use crate::topology::{Label, Topology};
use crate::unit::ComputeUnit;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Where the scheduler decided to put a CU.
#[derive(Debug, Clone, PartialEq)]
pub enum Placement {
    /// Enqueue on a specific pilot's agent queue.
    Pilot(String),
    /// Enqueue on the global queue (any agent may pull it).
    Global,
    /// Delayed scheduling: re-evaluate after this many seconds, hoping
    /// the preferred (data-local) pilot frees a slot.
    Delay(f64),
    /// No pilot can ever satisfy the CU's constraints.
    Unschedulable(String),
}

/// Read-only context handed to the scheduler: the manager state, the
/// physical location labels of every DU's replicas, and current
/// per-pilot queue depths (so placement accounts for work already
/// bound to a pilot, not just its busy slots).
pub struct SchedContext<'a> {
    pub topo: &'a Topology,
    pub state: &'a ManagerState,
    /// DU id -> labels of Pilot-Data currently holding a full replica.
    pub du_locations: &'a BTreeMap<String, Vec<Label>>,
    /// Pilot id -> CUs waiting in its agent-specific queue.
    pub queue_depth: &'a BTreeMap<String, usize>,
}

impl<'a> SchedContext<'a> {
    /// Effective open capacity of a pilot in cores: free slots minus
    /// cores spoken for by CUs already queued on it (approximated with
    /// the current CU's core count).
    fn effective_slots(&self, p: &crate::pilot::PilotCompute, cu_cores: u32) -> i64 {
        let queued = *self.queue_depth.get(&p.id).unwrap_or(&0) as i64;
        p.free_slots() as i64 - queued * cu_cores.max(1) as i64
    }

    /// Pilots eligible for this CU: alive (not terminal) and within the
    /// CU's affinity constraint, with enough total cores.
    fn eligible_pilots(&self, cu: &ComputeUnit) -> Vec<&crate::pilot::PilotCompute> {
        self.state
            .pilots
            .values()
            .filter(|p| !p.state.is_terminal())
            .filter(|p| p.description.cores >= cu.description.cores.max(1))
            .filter(|p| match &cu.description.affinity {
                Some(constraint) => p.affinity().within(constraint),
                None => true,
            })
            .collect()
    }

    /// Data-affinity score of running `cu` on a pilot at `label`:
    /// size-weighted affinity to the closest replica of each input DU.
    /// Higher is better; DUs with no replica yet contribute 0.
    pub fn data_score(&self, cu: &ComputeUnit, label: &Label) -> f64 {
        let mut score = 0.0;
        for du in &cu.description.input_data {
            let Some(locs) = self.du_locations.get(du) else { continue };
            let best = locs
                .iter()
                .map(|l| self.topo.affinity(label, l))
                .fold(0.0, f64::max);
            let size = self
                .state
                .dus
                .get(du)
                .map(|d| d.size().as_f64())
                .unwrap_or(1.0)
                .max(1.0);
            score += best * size.ln_1p();
        }
        score
    }
}

/// Pluggable scheduling strategy.
pub trait Scheduler: Send + Sync {
    fn name(&self) -> &'static str;
    fn place(&self, cu: &ComputeUnit, ctx: &SchedContext) -> Placement;
}

/// The paper's affinity-aware scheduler (§5) with optional delayed
/// scheduling.
pub struct AffinityScheduler {
    /// Seconds to wait for a slot on the preferred pilot before falling
    /// back to the global queue. `None` disables delayed scheduling.
    pub delay_s: Option<f64>,
    /// Consecutive delays already spent per CU (so delay is bounded).
    delays_spent: Mutex<BTreeMap<String, u32>>,
    /// Max delay rounds before giving up on locality.
    pub max_delay_rounds: u32,
}

impl AffinityScheduler {
    pub fn new(delay_s: Option<f64>) -> AffinityScheduler {
        AffinityScheduler { delay_s, delays_spent: Mutex::new(BTreeMap::new()), max_delay_rounds: 3 }
    }
}

impl Scheduler for AffinityScheduler {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn place(&self, cu: &ComputeUnit, ctx: &SchedContext) -> Placement {
        let eligible = ctx.eligible_pilots(cu);
        if eligible.is_empty() {
            return match &cu.description.affinity {
                Some(c) => Placement::Unschedulable(format!(
                    "no pilot within affinity constraint '{c}' can fit {} cores",
                    cu.description.cores
                )),
                None => Placement::Unschedulable(format!(
                    "no pilot can fit {} cores",
                    cu.description.cores
                )),
            };
        }

        // Step 1: rank by data score, tie-break by effective open
        // capacity (free slots minus queued work) then id for
        // determinism.
        let mut ranked: Vec<_> = eligible
            .iter()
            .map(|p| (ctx.data_score(cu, &p.affinity()), *p))
            .collect();
        let cores = cu.description.cores.max(1);
        ranked.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap()
                .then(ctx.effective_slots(b.1, cores).cmp(&ctx.effective_slots(a.1, cores)))
                .then(a.1.id.cmp(&b.1.id))
        });
        let (best_score, best) = (&ranked[0].0, ranked[0].1);

        // No data affinity anywhere and no constraint: let the global
        // queue load-balance (step 4 fast path).
        if *best_score <= 0.0 && cu.description.affinity.is_none() {
            return Placement::Global;
        }

        // Step 2: preferred pilot is active with an open slot that is
        // not already spoken for by queued work.
        if best.has_free_slot(cu.description.cores)
            && ctx.effective_slots(best, cores) >= cores as i64
        {
            self.delays_spent.lock().unwrap().remove(&cu.id);
            return Placement::Pilot(best.id.clone());
        }

        // Step 3: delayed scheduling.
        if let Some(d) = self.delay_s {
            let mut spent = self.delays_spent.lock().unwrap();
            let n = spent.entry(cu.id.clone()).or_insert(0);
            if *n < self.max_delay_rounds {
                *n += 1;
                return Placement::Delay(d);
            }
        }

        // Step 4: global queue (or pin to the constrained subtree's
        // least-loaded pilot when a constraint exists — the global
        // queue is unconstrained).
        if cu.description.affinity.is_some() {
            return Placement::Pilot(best.id.clone());
        }
        Placement::Global
    }
}

/// Baseline: ignore data locality entirely; first pilot with a free
/// slot, else the global queue.
pub struct DataUnawareScheduler;

impl Scheduler for DataUnawareScheduler {
    fn name(&self) -> &'static str {
        "data-unaware"
    }

    fn place(&self, cu: &ComputeUnit, ctx: &SchedContext) -> Placement {
        for p in ctx.eligible_pilots(cu) {
            if p.has_free_slot(cu.description.cores) {
                return Placement::Pilot(p.id.clone());
            }
        }
        if ctx.eligible_pilots(cu).is_empty() {
            return Placement::Unschedulable("no eligible pilot".into());
        }
        Placement::Global
    }
}

/// Baseline: cycle through eligible pilots regardless of load or data.
pub struct RoundRobinScheduler {
    counter: AtomicUsize,
}

impl Default for RoundRobinScheduler {
    fn default() -> Self {
        RoundRobinScheduler { counter: AtomicUsize::new(0) }
    }
}

impl Scheduler for RoundRobinScheduler {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn place(&self, cu: &ComputeUnit, ctx: &SchedContext) -> Placement {
        let eligible = ctx.eligible_pilots(cu);
        if eligible.is_empty() {
            return Placement::Unschedulable("no eligible pilot".into());
        }
        let i = self.counter.fetch_add(1, Ordering::Relaxed) % eligible.len();
        Placement::Pilot(eligible[i].id.clone())
    }
}

/// Baseline: uniformly random eligible pilot (seeded, deterministic).
pub struct RandomScheduler {
    rng: Mutex<crate::rng::Rng>,
}

impl RandomScheduler {
    pub fn new(seed: u64) -> RandomScheduler {
        RandomScheduler { rng: Mutex::new(crate::rng::Rng::new(seed)) }
    }
}

impl Scheduler for RandomScheduler {
    fn name(&self) -> &'static str {
        "random"
    }

    fn place(&self, cu: &ComputeUnit, ctx: &SchedContext) -> Placement {
        let eligible = ctx.eligible_pilots(cu);
        if eligible.is_empty() {
            return Placement::Unschedulable("no eligible pilot".into());
        }
        let i = self.rng.lock().unwrap().below(eligible.len() as u64) as usize;
        Placement::Pilot(eligible[i].id.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pilot::{PilotCompute, PilotComputeDescription, PilotState};
    use crate::unit::{ComputeUnit, ComputeUnitDescription, DataUnit, DataUnitDescription, FileRef};
    use crate::util::Bytes;

    fn mk_pilot(st: &mut ManagerState, cores: u32, affinity: &str, state: PilotState) -> String {
        let mut p = PilotCompute::new(PilotComputeDescription {
            service_url: "batch://m".into(),
            cores,
            walltime_s: 1e6,
            affinity: Some(Label::new(affinity)),
        });
        p.state = state;
        st.add_pilot(p)
    }

    fn mk_du(st: &mut ManagerState, size: Bytes) -> String {
        st.add_du(DataUnit::new(DataUnitDescription {
            name: "d".into(),
            files: vec![FileRef::sized("f", size)],
            affinity: None,
        }))
    }

    fn mk_cu(input: Vec<String>, affinity: Option<&str>) -> ComputeUnit {
        ComputeUnit::new(ComputeUnitDescription {
            executable: "x".into(),
            cores: 1,
            input_data: input,
            affinity: affinity.map(Label::new),
            ..Default::default()
        })
    }

    #[test]
    fn affinity_scheduler_prefers_data_local_pilot() {
        let mut st = ManagerState::new();
        let p_far = mk_pilot(&mut st, 8, "osg/cornell", PilotState::Active);
        let p_near = mk_pilot(&mut st, 8, "xsede/tacc/lonestar", PilotState::Active);
        let du = mk_du(&mut st, Bytes::gb(8));
        let mut locs = BTreeMap::new();
        locs.insert(du.clone(), vec![Label::new("xsede/tacc/lonestar")]);
        let topo = Topology::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth };
        let cu = mk_cu(vec![du], None);
        let sched = AffinityScheduler::new(None);
        assert_eq!(sched.place(&cu, &ctx), Placement::Pilot(p_near.clone()));
        let _ = p_far;
    }

    #[test]
    fn no_data_no_constraint_goes_global() {
        let mut st = ManagerState::new();
        mk_pilot(&mut st, 8, "osg/cornell", PilotState::Active);
        let topo = Topology::new();
        let locs = BTreeMap::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth };
        let sched = AffinityScheduler::new(None);
        assert_eq!(sched.place(&mk_cu(vec![], None), &ctx), Placement::Global);
    }

    #[test]
    fn constraint_filters_pilots() {
        let mut st = ManagerState::new();
        mk_pilot(&mut st, 8, "osg/cornell", PilotState::Active);
        let p_x = mk_pilot(&mut st, 8, "xsede/tacc/lonestar", PilotState::Active);
        let topo = Topology::new();
        let locs = BTreeMap::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth };
        let sched = AffinityScheduler::new(None);
        let cu = mk_cu(vec![], Some("xsede"));
        assert_eq!(sched.place(&cu, &ctx), Placement::Pilot(p_x));
        let impossible = mk_cu(vec![], Some("ec2/us-west"));
        assert!(matches!(sched.place(&impossible, &ctx), Placement::Unschedulable(_)));
    }

    #[test]
    fn oversized_cu_is_unschedulable() {
        let mut st = ManagerState::new();
        mk_pilot(&mut st, 2, "x", PilotState::Active);
        let topo = Topology::new();
        let locs = BTreeMap::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth };
        let mut cu = mk_cu(vec![], None);
        cu.description.cores = 16;
        assert!(matches!(
            AffinityScheduler::new(None).place(&cu, &ctx),
            Placement::Unschedulable(_)
        ));
    }

    #[test]
    fn delayed_scheduling_waits_then_gives_up() {
        let mut st = ManagerState::new();
        let near = mk_pilot(&mut st, 1, "xsede/tacc/lonestar", PilotState::Active);
        st.pilots.get_mut(&near).unwrap().busy_slots = 1; // full
        mk_pilot(&mut st, 8, "osg/cornell", PilotState::Active);
        let du = mk_du(&mut st, Bytes::gb(4));
        let mut locs = BTreeMap::new();
        locs.insert(du.clone(), vec![Label::new("xsede/tacc/lonestar")]);
        let topo = Topology::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth };
        let sched = AffinityScheduler::new(Some(30.0));
        let cu = mk_cu(vec![du], None);
        // max_delay_rounds delays, then fall back to global.
        assert_eq!(sched.place(&cu, &ctx), Placement::Delay(30.0));
        assert_eq!(sched.place(&cu, &ctx), Placement::Delay(30.0));
        assert_eq!(sched.place(&cu, &ctx), Placement::Delay(30.0));
        assert_eq!(sched.place(&cu, &ctx), Placement::Global);
    }

    #[test]
    fn data_unaware_takes_first_free() {
        let mut st = ManagerState::new();
        let a = mk_pilot(&mut st, 2, "a", PilotState::Active);
        mk_pilot(&mut st, 2, "b", PilotState::Active);
        let topo = Topology::new();
        let locs = BTreeMap::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth };
        let cu = mk_cu(vec![], None);
        assert_eq!(DataUnawareScheduler.place(&cu, &ctx), Placement::Pilot(a));
    }

    #[test]
    fn round_robin_cycles() {
        let mut st = ManagerState::new();
        let a = mk_pilot(&mut st, 2, "a", PilotState::Active);
        let b = mk_pilot(&mut st, 2, "b", PilotState::Active);
        let topo = Topology::new();
        let locs = BTreeMap::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth };
        let sched = RoundRobinScheduler::default();
        let cu = mk_cu(vec![], None);
        let p1 = sched.place(&cu, &ctx);
        let p2 = sched.place(&cu, &ctx);
        let p3 = sched.place(&cu, &ctx);
        assert_ne!(p1, p2);
        assert_eq!(p1, p3);
        assert!(matches!(p1, Placement::Pilot(ref x) if *x == a || *x == b));
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let mut st = ManagerState::new();
        for i in 0..5 {
            mk_pilot(&mut st, 2, &format!("site{i}"), PilotState::Active);
        }
        let topo = Topology::new();
        let locs = BTreeMap::new();
        let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth };
        let cu = mk_cu(vec![], None);
        let seq = |seed| {
            let s = RandomScheduler::new(seed);
            (0..10).map(|_| s.place(&cu, &ctx)).collect::<Vec<_>>()
        };
        assert_eq!(seq(9), seq(9));
        assert_ne!(seq(9), seq(10));
    }

    #[test]
    fn scheduler_placement_property_every_cu_gets_decision() {
        crate::prop::check_default(
            |rng| {
                // Random pilots + random CUs; property: place() never
                // panics and returns Pilot only for existing pilots.
                let n_pilots = crate::prop::gen::usize_in(rng, 1, 6);
                let n_cus = crate::prop::gen::usize_in(rng, 1, 10);
                let sites = ["osg/a", "osg/b", "xsede/tacc/ls", "ec2/east"];
                let pilots: Vec<(u32, String, bool)> = (0..n_pilots)
                    .map(|_| {
                        (
                            1 + rng.below(16) as u32,
                            rng.choose(&sites).to_string(),
                            rng.chance(0.8),
                        )
                    })
                    .collect();
                let cus: Vec<(u32, Option<String>)> = (0..n_cus)
                    .map(|_| {
                        (
                            1 + rng.below(4) as u32,
                            if rng.chance(0.3) {
                                Some(rng.choose(&sites).to_string())
                            } else {
                                None
                            },
                        )
                    })
                    .collect();
                (pilots, cus)
            },
            |(pilots, cus)| {
                let mut st = ManagerState::new();
                for (cores, site, active) in pilots {
                    mk_pilot(
                        &mut st,
                        *cores,
                        site,
                        if *active { PilotState::Active } else { PilotState::Queued },
                    );
                }
                let topo = Topology::new();
                let locs = BTreeMap::new();
                let depth = BTreeMap::new();
        let ctx = SchedContext { topo: &topo, state: &st, du_locations: &locs, queue_depth: &depth };
                let sched = AffinityScheduler::new(None);
                for (cores, aff) in cus {
                    let mut cu = mk_cu(vec![], aff.as_deref());
                    cu.description.cores = *cores;
                    match sched.place(&cu, &ctx) {
                        Placement::Pilot(id) => {
                            if !st.pilots.contains_key(&id) {
                                return Err(format!("placed on unknown pilot {id}"));
                            }
                        }
                        Placement::Global | Placement::Delay(_) | Placement::Unschedulable(_) => {}
                    }
                }
                Ok(())
            },
        );
    }
}
