//! The simulation driver: the full Pilot system (manager, agents,
//! scheduler, queues, storage) running inside the discrete-event
//! engine against the calibrated testbed.
//!
//! This is the same coordinator logic as the local-mode services —
//! identical scheduler, state machines and coordination store — driven
//! by simulated time so the paper's hour-scale production-DCI
//! experiments replay in milliseconds, deterministically per seed.
//!
//! Perf shape: every queue touch goes through per-pilot interned
//! [`Key`] handles (no `format!` per event), the scheduler context is
//! assembled in O(1) from [`ManagerState`]'s incremental indexes, and
//! every transfer start is **one walk of the interned network path**:
//! `SimStore::staging_cost_flow` prices the transfer and registers its
//! flow in a single [`crate::net::Network::begin_flow_priced_id`] call
//! (the seed walked the string-keyed path twice — `transfer_cost`,
//! then `begin_flow` — per DU upload, replication, and agent
//! stage-in). Agent wakeups are **event-driven**: the driver holds a pattern
//! subscription on the store's queue namespace
//! ([`Store::subscribe_prefix`]) and translates each queue event into
//! a targeted `TryPull` — a push onto one pilot's queue wakes that
//! pilot, global-queue work wakes only ready pilots (active, free
//! slot, staging headroom), and a DU arrival wakes exactly the
//! eligible pilots in the replica label's subtree (via the
//! `pilots_by_label` index). The single-threaded discrete-event engine
//! cannot block an OS thread, so the store's wall-clock blocking pops
//! map here to scheduled wakeup events in simulated time (see
//! [`crate::coordination::events`] on deadline semantics under
//! simtime). [`WakeupMode::Broadcast`] keeps the seed's
//! O(pilots × events) wake-everyone reference semantics alive for the
//! trace-equivalence property test.
//!
//! **Multi-slot agents under simtime:** the wall-clock service runs
//! one worker thread per pilot slot, all parked in the same blocking
//! pop. The deterministic image of that pool is
//! [`SlotMode::PerSlot`] (default): each `TryPull` event is *one
//! slot's* pull — it dispatches at most one CU and, on success,
//! front-schedules the next `TryPull` of the chain
//! ([`crate::simtime::Sim::schedule_front`]), so the whole pool drains
//! before any other same-time event interleaves, exactly like the
//! reference [`SlotMode::Batch`] loop (property-tested bit-identical;
//! see `prop::per_slot_driver_matches_batch_reference_traces`).

use crate::config::Testbed;
use crate::coordination::events::Event;
use crate::coordination::{keys, Key, Store};
use crate::datamgmt::{DataCtx, ExecutionMode, LossCause, OnDemand, StageAction};
use crate::faults::{attempt_transfer, ChaosPlan, RetryPolicy};
use crate::metrics::{CuRecord, RunMetrics, TimelineEvent};
use crate::net::FlowHandle;
use crate::pilot::{agent_pull_tracked, ManagerState, PilotCompute, PilotComputeDescription, PilotState};
use crate::rng::Rng;
use crate::scheduler::{AffinityScheduler, Placement, SchedContext, Scheduler};
use crate::simtime::Sim;
use crate::storage::simstore::{PlaceOutcome, TransferCost};
use crate::topology::Label;
use crate::unit::{ComputeUnit, ComputeUnitDescription, CuState, DataUnit, DataUnitDescription, DuState};
use crate::util::Bytes;
use crate::workload::task_runtime_s;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

/// Events of the simulated pilot system.
#[derive(Debug)]
pub enum Ev {
    /// Pilot finished waiting in the batch queue.
    PilotActive { pilot: String },
    /// A DU transfer attempt into a PD finished. `attempt` is 1-based;
    /// a failed attempt with budget left re-issues via [`Ev::DuRetry`]
    /// (under [`RetryStyle::InDes`]) instead of failing the DU.
    DuStaged { du: String, pd: String, flow: Option<FlowHandle>, ok: bool, attempt: u32 },
    /// Re-issue a failed DU transfer after its backoff elapsed in
    /// simulated time. The source replica is re-resolved at fire time
    /// — it may have moved (or vanished) during the backoff.
    DuRetry { du: String, pd: String, attempt: u32 },
    /// Ask a pilot's agent to try pulling work.
    TryPull { pilot: String },
    /// CU input staging finished. `attempt` is the CU's 1-based
    /// dispatch epoch (every `begin_staging` bumps it): an event whose
    /// epoch is stale — the CU was re-dispatched while this staging
    /// was in flight (pilot loss) — is dropped after ending its flow.
    CuStaged { cu: String, flow: Option<FlowHandle>, ok: bool, attempt: u32 },
    /// CU compute finished.
    CuDone { cu: String },
    /// Delayed-scheduling re-evaluation.
    Reschedule { cu: String },
    /// Pilot hit its walltime limit (or was killed by fault injection).
    PilotExpired { pilot: String },
    /// Pilot died hard mid-run (node crash, agent kill): same teardown
    /// as expiry but the pilot ends [`PilotState::Failed`] and its
    /// in-flight CUs count against the re-dispatch bound.
    PilotFailed { pilot: String },
    /// A Pilot-Data's storage went down (fault injection): its
    /// replicas are lost and the execution-mode engine repairs them
    /// through the event layer.
    PdDown { pd: String },
    /// A downed Pilot-Data's storage came back (empty, quota intact):
    /// availability is published on the event layer and the active
    /// execution mode re-balances onto the recovered capacity.
    PdUp { pd: String },
    /// Open-loop arrival: one tenant's next stochastic submission is
    /// due (see [`crate::workload::openloop`]). The handler asks the
    /// generator for the arrival's batch, pre-places any DUs it
    /// brings, feeds the CUs through [`SimSystem::submit_cus`], and
    /// schedules the tenant's next arrival.
    ArrivalDue { tenant: usize },
}

/// How failed transfer attempts are modeled (see `faults` module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetryStyle {
    /// Default: every attempt is its own DES event. A failed attempt
    /// pays partial wire time until the failure is detected, releases
    /// its flow, waits [`RetryPolicy::backoff_for`] in simulated time,
    /// and re-issues from a freshly resolved source.
    InDes,
    /// The seed's statistical shortcut: the whole attempt sequence
    /// collapses into one [`attempt_transfer`] outcome whose wasted
    /// time pads the single completion event. Kept as the oracle for
    /// the fault-free bit-identity property — with zero failure rates
    /// both styles consume the same RNG draws and schedule the same
    /// events.
    Aggregate,
}

/// Where a pilot's agent runs: its machine and scratch Pilot-Data.
/// Shared behind an `Arc` so per-event lookups don't clone two strings.
pub struct PilotHome {
    pub machine: String,
    pub scratch: String,
}

/// How queue/data events become agent wakeups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WakeupMode {
    /// Event-driven (default): queue events wake the targeted pilot
    /// (own-queue push) or the ready subset (global work); DU arrivals
    /// wake eligible pilots in the replica label's subtree. Skipped
    /// pilots would have processed their wakeup as a no-op.
    Evented,
    /// Reference semantics: every wake broadcasts `TryPull` to every
    /// pilot — the seed's O(pilots × events) shape, kept so the
    /// property suite can assert the evented driver produces
    /// bit-identical placement traces.
    Broadcast,
}

/// How a pilot's slots consume `TryPull` events (the simtime mapping
/// of the multi-slot agent pool; see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotMode {
    /// One CU dispatched per `TryPull`; a successful dispatch
    /// front-schedules the chain's next `TryPull` at the same instant
    /// — one event per worker slot, the DES image of N pool workers
    /// waking one after another (default).
    PerSlot,
    /// Reference: a single `TryPull` drains every free slot in one
    /// handler loop (the pre-multi-slot shape), kept for the
    /// trace-equivalence property test.
    Batch,
}

/// The simulated pilot system.
pub struct SimSystem {
    pub sim: Sim<Ev>,
    pub tb: Testbed,
    pub state: ManagerState,
    pub store: Store,
    pub scheduler: Box<dyn Scheduler>,
    pub rng: Rng,
    pub metrics: RunMetrics,
    pub retry: RetryPolicy,
    /// pilot id -> where its agent runs.
    pilot_home: BTreeMap<String, Arc<PilotHome>>,
    /// machine -> pilot ids homed there (sorted, so iteration matches a
    /// filtered `pilot_home` scan bit-for-bit) — the per-machine index
    /// behind `machine_sharers`, which runs on every `CuStaged`.
    machine_pilots: BTreeMap<String, BTreeSet<String>>,
    /// pilot id -> interned agent-queue key (minted once per pilot).
    qkeys: BTreeMap<String, Key>,
    /// Interned global-queue key.
    global_q: Key,
    /// How failed transfer attempts are modeled (see [`RetryStyle`]).
    pub retry_style: RetryStyle,
    /// Remote input DUs staged per CU (empty = all inputs were
    /// co-located): decides staging-slot accounting and which DUs a
    /// quota'd scratch PD must admit at staging completion.
    staged_remote: BTreeMap<String, Vec<String>>,
    /// Count of CU staging attempts that failed (each re-queues the CU
    /// through the scheduler until `max_requeues`).
    pub staging_failures: u32,
    /// Failed transfer attempts that were re-issued in simulated time
    /// ([`RetryStyle::InDes`] only).
    pub transfer_retries: u32,
    /// Pilots lost to hard failures ([`Ev::PilotFailed`]).
    pub pilot_failures: u32,
    /// Per-CU count of re-dispatches forced by pilot loss (expiry or
    /// hard failure while the CU was staging/running).
    pub redispatches: BTreeMap<String, u32>,
    /// Max pilot-loss re-dispatches before a CU is failed permanently.
    pub max_redispatches: u32,
    /// Max CUs a pilot's agent will stage remotely at once (BigJob
    /// agents throttle staging; this is what limits how fast a
    /// non-data-local pilot can drain the global queue — Fig. 11 sc. 2).
    pub max_concurrent_staging: u32,
    /// Per-pilot remote stagings in flight.
    staging_in_flight: BTreeMap<String, u32>,
    /// Staging re-queues per CU; bounded to avoid spinning forever on
    /// inputs that can never materialize.
    requeues: BTreeMap<String, u32>,
    /// Dispatch epoch per CU (bumped at every `begin_staging`): the
    /// staleness guard for `CuStaged` events of a superseded dispatch.
    dispatch_epoch: BTreeMap<String, u32>,
    /// Max staging retries before a CU is failed permanently.
    pub max_requeues: u32,
    /// Schedule automatic PilotExpired events at each machine's
    /// walltime limit (off by default: most experiments end well
    /// inside the 48 h limits; `kill_pilot_at` is always available).
    pub enforce_walltime: bool,
    /// How store events become agent wakeups (see [`WakeupMode`]).
    pub wakeups: WakeupMode,
    /// How `TryPull` events map to pilot slots (see [`SlotMode`]).
    pub slots: SlotMode,
    /// Peak concurrent busy slots ever observed per pilot — the
    /// multi-slot invariant surface (`max_busy[p] ≤ cores(p)`,
    /// asserted by the property suite).
    pub max_busy: BTreeMap<String, u32>,
    /// Optional pop audit: `(pilot, cu, from_own_queue)` per pull, in
    /// pull order. `Some` only when a test enables it — per-queue FIFO
    /// pop-order assertions read this.
    pub pull_log: Option<Vec<(String, String, bool)>>,
    /// Pattern subscription on the queue namespace: every rpush in the
    /// store lands here and is translated into sim wakeups by
    /// `drain_queue_events`.
    queue_events: std::sync::mpsc::Receiver<Event>,
    /// The staging/replication policy (see [`crate::datamgmt`]).
    /// `None` is the seed's hard-wired path with no engine dispatch at
    /// all — kept as the reference for the `OnDemand`-equivalence
    /// property test; the default is `Some(OnDemand)`.
    mode: Option<Box<dyn ExecutionMode>>,
    /// Replication transfers in flight as `(du, dst pd)` — the
    /// policies' double-issue guard.
    repl_in_flight: BTreeSet<(String, String)>,
    /// Subscription on the data-plane loss channel
    /// (`keys::DATA_LOST_PREFIX`): capacity evictions and PD outages
    /// publish here, and `drain_data_events` turns each loss into the
    /// policy's repair actions — outage repair rides the event layer.
    data_events: std::sync::mpsc::Receiver<Event>,
    /// Total bytes sent over the wire (DU uploads/replications + remote
    /// CU stage-ins) — the mode-comparison cost metric.
    bytes_moved: u64,
    /// Cumulative egress+ingress dollars for every wire transfer,
    /// priced by the endpoints' [`crate::storage::BackendProfile`]s.
    /// Stays exactly 0.0 on a uniform testbed (the store's
    /// `transfer_dollars` is gated on `heterogeneous()`), so the
    /// bit-identity oracles never see a float drift from it.
    dollars_spent: f64,
    /// Placements rejected by the storage-capacity model (PD full of
    /// pinned/last replicas, or down).
    pub capacity_rejections: u32,
    /// Feed per-label storage headroom to the scheduler (default).
    /// `false` keeps the capacity-blind decisions for A/B comparisons;
    /// testbeds without quotas are identical either way.
    pub capacity_aware_scheduling: bool,
    /// While set, push sites skip their per-push wakeup drain; the
    /// batch entry point ([`SimSystem::submit_cus`]) runs one
    /// deduplicated drain at the end instead.
    defer_wakeups: bool,
    /// Hard event budget for [`SimSystem::run`] — guards against
    /// accidental infinite self-rescheduling. Scale sweeps raise it.
    pub event_budget: u64,
    /// Open-loop arrival engine (`None`: closed-batch workloads).
    /// Installed by [`SimSystem::start_open_loop`].
    open_loop: Option<crate::workload::openloop::OpenLoopRun>,
    /// Uniform multiplier range applied to every CU runtime (the BWA
    /// runtime variance behind the paper's Fig. 12 error bars).
    /// `(1.0, 1.0)` yields exactly the cost model's runtime — the
    /// M/M/c validation needs undistorted exponential service. The
    /// draw is consumed either way, so changing the range never shifts
    /// the RNG stream shape.
    pub runtime_variance: (f64, f64),
    /// Record queueing telemetry into `metrics.series`: waiting-CU
    /// backlog sampled at each open-loop arrival instant
    /// (`queue_depth`) and per-pilot busy-slot step series
    /// (`busy:<pilot>`). Off by default so closed-batch experiments
    /// and the scale sweep don't pay the sampling cost.
    pub queueing_telemetry: bool,
}

impl SimSystem {
    pub fn new(tb: Testbed, seed: u64) -> SimSystem {
        let store = Store::new();
        let queue_events = store.subscribe_prefix(keys::QUEUE_PREFIX);
        let data_events = store.subscribe_prefix(keys::DATA_LOST_PREFIX);
        SimSystem {
            sim: Sim::new(),
            tb,
            state: ManagerState::new(),
            store,
            scheduler: Box::new(AffinityScheduler::new(None)),
            rng: Rng::new(seed),
            metrics: RunMetrics::default(),
            retry: RetryPolicy::default(),
            pilot_home: BTreeMap::new(),
            machine_pilots: BTreeMap::new(),
            qkeys: BTreeMap::new(),
            global_q: keys::global_queue_key().clone(),
            retry_style: RetryStyle::InDes,
            staged_remote: BTreeMap::new(),
            staging_failures: 0,
            transfer_retries: 0,
            pilot_failures: 0,
            redispatches: BTreeMap::new(),
            max_redispatches: 16,
            max_concurrent_staging: 4,
            staging_in_flight: BTreeMap::new(),
            requeues: BTreeMap::new(),
            dispatch_epoch: BTreeMap::new(),
            max_requeues: 24,
            enforce_walltime: false,
            wakeups: WakeupMode::Evented,
            slots: SlotMode::PerSlot,
            max_busy: BTreeMap::new(),
            pull_log: None,
            queue_events,
            mode: Some(Box::new(OnDemand)),
            repl_in_flight: BTreeSet::new(),
            data_events,
            bytes_moved: 0,
            dollars_spent: 0.0,
            capacity_rejections: 0,
            capacity_aware_scheduling: true,
            defer_wakeups: false,
            event_budget: 2_000_000,
            open_loop: None,
            runtime_variance: (0.75, 1.40),
            queueing_telemetry: false,
        }
    }

    /// Select the DES queue backend (default: the calendar-queue
    /// wheel). Must be called before anything is scheduled — the
    /// property suites use [`crate::simtime::QueueBackend::Heap`] to
    /// rerun whole end-to-end workloads on the reference engine.
    pub fn with_sim_backend(mut self, backend: crate::simtime::QueueBackend) -> SimSystem {
        assert!(
            self.sim.pending() == 0 && self.sim.processed() == 0,
            "select the sim backend before scheduling events"
        );
        self.sim = Sim::with_backend(backend);
        self
    }

    pub fn with_scheduler(mut self, s: Box<dyn Scheduler>) -> SimSystem {
        self.scheduler = s;
        self
    }

    /// Select the execution mode (default: [`OnDemand`]).
    pub fn with_mode(mut self, mode: Box<dyn ExecutionMode>) -> SimSystem {
        self.mode = Some(mode);
        self
    }

    /// Reference configuration: no engine dispatch at all — the seed's
    /// hard-wired staging path, kept so the property suite can assert
    /// `OnDemand` is a bit-identical no-op wrapper around it.
    pub fn with_seed_staging_reference(mut self) -> SimSystem {
        self.mode = None;
        self
    }

    /// Reference configuration: keep the seed's statistical retry
    /// shortcut (see [`RetryStyle::Aggregate`]) — the fault-free
    /// bit-identity oracle for the in-DES retry path.
    pub fn with_aggregate_retry_reference(mut self) -> SimSystem {
        self.retry_style = RetryStyle::Aggregate;
        self
    }

    /// Name of the active execution mode.
    pub fn mode_name(&self) -> &'static str {
        self.mode.as_ref().map(|m| m.name()).unwrap_or("reference")
    }

    /// Total pilot-loss re-dispatches across all CUs.
    pub fn total_redispatches(&self) -> u32 {
        self.redispatches.values().sum()
    }

    /// Zero every protocol failure rate in the testbed: fault-free
    /// runs for byte-exact accounting tests and the bit-identity
    /// properties (link failure rates default to zero already).
    pub fn zero_transfer_faults(&mut self) {
        let names: Vec<String> = self.tb.store.pds().map(|p| p.name.clone()).collect();
        for n in names {
            let _ = self.tb.store.set_failure_rate(&n, 0.0);
        }
    }

    /// Total bytes moved over the wire so far (uploads, replications,
    /// remote stage-ins).
    pub fn bytes_moved(&self) -> Bytes {
        Bytes(self.bytes_moved)
    }

    /// Cumulative backend dollars charged for wire transfers so far
    /// (0.0 on a uniform testbed — see [`crate::storage::BackendProfile`]).
    pub fn dollars_spent(&self) -> f64 {
        self.dollars_spent
    }

    /// Enable delay scheduling with the given locality-wait budget by
    /// installing a fresh [`AffinityScheduler`]. The budget is spent in
    /// simulated time: a CU whose best data score has no free local
    /// slot parks for up to `wait_s` seconds before accepting a remote
    /// placement. `with_locality_wait(0.0)` is the bit-identity
    /// reference — the scheduler takes the no-wait path unchanged.
    pub fn with_locality_wait(mut self, wait_s: f64) -> SimSystem {
        self.scheduler =
            Box::new(AffinityScheduler::new(None).with_locality_wait(Some(wait_s)));
        self
    }

    /// Structural counters from the event-wheel backend (all-zero under
    /// the heap reference) — per-run queue behaviour that, unlike
    /// process-global VmHWM, stays attributable when many systems run
    /// concurrently (`experiments::sweep` cells, `experiments::scale`
    /// tiers).
    pub fn queue_stats(&self) -> crate::simtime::QueueStats {
        self.sim.queue_stats()
    }

    pub fn with_wakeups(mut self, mode: WakeupMode) -> SimSystem {
        self.wakeups = mode;
        self
    }

    pub fn with_slot_mode(mut self, mode: SlotMode) -> SimSystem {
        self.slots = mode;
        self
    }

    /// Submit a Pilot-Compute to a machine's batch queue; becomes
    /// Active after the sampled T_Q. `scratch_pd` is where its local
    /// data lands (must exist in the testbed SimStore).
    pub fn submit_pilot(
        &mut self,
        machine: &str,
        cores: u32,
        scratch_pd: &str,
    ) -> anyhow::Result<String> {
        let m = self.tb.batch.machine(machine)?.clone();
        self.tb.store.pd(scratch_pd)?;
        let wait = self.tb.batch.submit(machine, cores, &mut self.rng)?;
        let mut pilot = PilotCompute::new(PilotComputeDescription {
            service_url: format!("batch://{machine}"),
            cores,
            walltime_s: m.walltime_limit,
            affinity: Some(m.label.clone()),
        });
        pilot.transition(PilotState::Queued)?;
        let id = pilot.id.clone();
        self.state.add_pilot(pilot);
        self.pilot_home.insert(
            id.clone(),
            Arc::new(PilotHome { machine: machine.to_string(), scratch: scratch_pd.to_string() }),
        );
        self.machine_pilots.entry(machine.to_string()).or_default().insert(id.clone());
        self.qkeys.insert(id.clone(), keys::pilot_queue_key(&id));
        self.metrics.set_scalar(&format!("tq:{id}"), wait);
        self.sim.schedule(wait, Ev::PilotActive { pilot: id.clone() });
        if self.enforce_walltime && m.walltime_limit.is_finite() {
            self.sim
                .schedule(wait + m.walltime_limit, Ev::PilotExpired { pilot: id.clone() });
        }
        Ok(id)
    }

    /// Fault injection: kill a pilot at a given sim time; its running
    /// and queued CUs are re-queued globally (the paper observed
    /// wall-time-limit kills during the Fig. 11 runs).
    pub fn kill_pilot_at(&mut self, pilot: &str, at_s: f64) {
        let at_s = at_s.max(self.sim.now());
        self.sim.schedule_at(at_s, Ev::PilotExpired { pilot: pilot.to_string() });
    }

    /// Fault injection: hard-fail a pilot at a given sim time (node
    /// crash rather than walltime). Same CU teardown as expiry, but
    /// the pilot ends [`PilotState::Failed`] and each orphaned CU's
    /// re-dispatch counts against `max_redispatches`.
    pub fn fail_pilot_at(&mut self, pilot: &str, at_s: f64) {
        let at_s = at_s.max(self.sim.now());
        self.sim.schedule_at(at_s, Ev::PilotFailed { pilot: pilot.to_string() });
    }

    /// Fault injection: bring a downed Pilot-Data back at a given sim
    /// time (empty, quota intact). No-op if it is up at fire time.
    pub fn recover_pd_at(&mut self, pd: &str, at_s: f64) {
        let at_s = at_s.max(self.sim.now());
        self.sim.schedule_at(at_s, Ev::PdUp { pd: pd.to_string() });
    }

    /// Install a whole chaos schedule: pilot kills, PD down/up cycles,
    /// and per-link transfer failure rates (see
    /// [`crate::faults::ChaosPlan`]). Fault times already past fire
    /// immediately (the injection helpers clamp to the current
    /// instant), so a plan may be installed at any point in a run.
    pub fn apply_chaos(&mut self, plan: &ChaosPlan) {
        for (pilot, at) in &plan.pilot_kills {
            self.fail_pilot_at(pilot, *at);
        }
        for (pd, at) in &plan.pd_down {
            self.fail_pd_at(pd, *at);
        }
        for (pd, at) in &plan.pd_up {
            self.recover_pd_at(pd, *at);
        }
        for (link, rate) in &plan.link_faults {
            self.tb.net.set_link_failure_rate(link, *rate);
        }
    }

    /// Register a DU and stage it from the gateway into `pd`,
    /// returning the id. Completion is an event; run the sim to let it
    /// land. Records `ts:<du>:<pd>` (T_S) on completion.
    pub fn upload_du(&mut self, descr: &DataUnitDescription, pd: &str) -> anyhow::Result<String> {
        let mut du = DataUnit::new(descr.clone());
        du.transition(DuState::Pending)?;
        let id = du.id.clone();
        self.tb.store.register_du(&id, du.size(), du.file_count());
        self.state.add_du(du);
        let gw_pd = self.gateway_pd()?;
        self.start_transfer_from(&id, &gw_pd, pd, true, 1)?;
        Ok(id)
    }

    /// The Pilot-Data co-located with the submission gateway — the
    /// source for initial uploads.
    fn gateway_pd(&self) -> anyhow::Result<String> {
        let gw = &self.tb.gateway;
        self.tb
            .store
            .pds()
            .find(|p| p.endpoint.label == *gw)
            .map(|p| p.name.clone())
            .ok_or_else(|| {
                anyhow::anyhow!("no Pilot-Data co-located with the gateway '{gw}'")
            })
    }

    /// Register a DU as already resident in `pd` (pre-staged data —
    /// no transfer, no events). Used when the experiment starts with
    /// data in place, as Fig. 11 does on Lonestar.
    pub fn place_du_instant(
        &mut self,
        descr: &DataUnitDescription,
        pd: &str,
    ) -> anyhow::Result<String> {
        let mut du = DataUnit::new(descr.clone());
        du.transition(DuState::Pending)?;
        du.transition(DuState::Running)?;
        let id = du.id.clone();
        self.tb.store.register_du(&id, du.size(), du.file_count());
        // Quota-checked like every other placement; evictions it
        // forces must reach the scheduler index and the loss channel.
        match self.tb.store.try_place(&id, pd)? {
            PlaceOutcome::Placed { evicted } => {
                for (edu, epd) in evicted {
                    let elabel = self.tb.store.pd(&epd)?.endpoint.label.clone();
                    self.note_replica_lost(&edu, &epd, &elabel, LossCause::Evicted);
                }
            }
            PlaceOutcome::NoCapacity => {
                anyhow::bail!("no capacity for pre-staged DU '{id}' on '{pd}'")
            }
        }
        self.note_replica_pd(&id, pd);
        self.state.add_du(du);
        // Pre-staged data is still policy-visible (e.g. auto-replicate
        // tops it up once pilots appear), and the evictions above may
        // need the policy's attention too.
        let actions = self.mode_actions(|m, ctx| m.on_du_available(&id, pd, ctx));
        self.apply_actions(actions);
        self.drain_data_events();
        Ok(id)
    }

    /// Replicate an existing DU to `dst_pd` from its closest replica.
    pub fn replicate(&mut self, du: &str, dst_pd: &str) -> anyhow::Result<()> {
        let dst_label = self.tb.store.pd(dst_pd)?.endpoint.label.clone();
        let src = self
            .tb
            .store
            .closest_replica(&self.tb.topo, du, &dst_label)
            .ok_or_else(|| anyhow::anyhow!("DU '{du}' has no replica to copy from"))?
            .name
            .clone();
        self.start_transfer_from(du, &src, dst_pd, false, 1)
    }

    /// Group replication (iRODS resource group): concurrent transfers
    /// from the group's home server to every member.
    pub fn replicate_group(&mut self, du: &str, group: &str) -> anyhow::Result<()> {
        let members: Vec<String> = self.tb.store.group_members(group)?.to_vec();
        for m in &members {
            if !self.tb.store.has_replica(du, m) {
                self.replicate(du, m)?;
            }
        }
        Ok(())
    }

    fn start_transfer_from(
        &mut self,
        du: &str,
        src_pd: &str,
        dst_pd: &str,
        via_gateway: bool,
        attempt: u32,
    ) -> anyhow::Result<()> {
        if src_pd == dst_pd {
            // Already there: instant success.
            self.sim.schedule(0.0, Ev::DuStaged {
                du: du.to_string(),
                pd: dst_pd.to_string(),
                flow: None,
                ok: true,
                attempt,
            });
            return Ok(());
        }
        let gateway = self.tb.gateway.clone();
        let via = if via_gateway { Some(&gateway) } else { None };
        // One path walk prices the transfer AND registers its flow
        // (the seed walked the path twice: `transfer_cost`, then
        // `begin_flow`). The bandwidth is sampled before the flow's own
        // increment, so the cost is bit-identical to the two-step.
        let (cost, flow) =
            self.tb.store.staging_cost_flow(&mut self.tb.net, du, src_pd, dst_pd, via)?;
        self.tb.store.touch(du, src_pd);
        let size = self.tb.store.du_meta(du)?.0.as_u64();
        let proto_rate = self.tb.store.pd(dst_pd)?.endpoint.params.failure_rate;
        match self.retry_style {
            RetryStyle::Aggregate => {
                self.bytes_moved += size;
                self.dollars_spent += self.tb.store.transfer_dollars(src_pd, dst_pd, size);
                let outcome =
                    attempt_transfer(&mut self.rng, proto_rate, cost.wire_s, self.retry);
                let total = cost.total() + outcome.wasted_s;
                self.sim.schedule(total, Ev::DuStaged {
                    du: du.to_string(),
                    pd: dst_pd.to_string(),
                    flow: Some(flow),
                    ok: outcome.succeeded,
                    attempt: outcome.attempts,
                });
            }
            RetryStyle::InDes => {
                // One attempt, one event. The failure probability
                // composes the destination protocol's rate with the
                // per-link rates along the routed path; a failed
                // attempt is detected partway through the wire leg and
                // pays (and counts) only the bytes sent by then. The
                // DuStaged handler owns backoff and re-issue.
                let src_label = self.tb.store.pd(src_pd)?.endpoint.label.clone();
                let dst_label = self.tb.store.pd(dst_pd)?.endpoint.label.clone();
                let link_rate = self.tb.net.path_failure_rate_labels(&src_label, &dst_label);
                let rate = 1.0 - (1.0 - proto_rate) * (1.0 - link_rate);
                let (elapsed, ok) = if self.rng.chance(rate) {
                    let frac = self.rng.range_f64(0.1, 0.9);
                    let part = (size as f64 * frac) as u64;
                    self.bytes_moved += part;
                    self.dollars_spent += self.tb.store.transfer_dollars(src_pd, dst_pd, part);
                    (cost.setup_s + cost.wire_s * frac, false)
                } else {
                    self.bytes_moved += size;
                    self.dollars_spent += self.tb.store.transfer_dollars(src_pd, dst_pd, size);
                    (cost.total(), true)
                };
                self.sim.schedule(elapsed, Ev::DuStaged {
                    du: du.to_string(),
                    pd: dst_pd.to_string(),
                    flow: Some(flow),
                    ok,
                    attempt,
                });
            }
        }
        Ok(())
    }

    /// Fault injection: take a Pilot-Data's storage down at a given sim
    /// time. Its resident replicas are lost; each loss is published on
    /// the coordination store's data channel and the execution-mode
    /// engine repairs it if the policy calls for replicas.
    pub fn fail_pd_at(&mut self, pd: &str, at_s: f64) {
        let at_s = at_s.max(self.sim.now());
        self.sim.schedule_at(at_s, Ev::PdDown { pd: pd.to_string() });
    }

    /// `(pilot id, scratch pd)` of every non-terminal pilot, in pilot
    /// id (creation) order — the policies' candidate-target list.
    fn pilot_scratch_list(&self) -> Vec<(String, String)> {
        self.pilot_home
            .iter()
            .filter(|(id, _)| {
                self.state.pilots.get(id.as_str()).map_or(false, |p| !p.state.is_terminal())
            })
            .map(|(id, h)| (id.clone(), h.scratch.clone()))
            .collect()
    }

    /// Ask the active policy for actions at a data-plane event. The
    /// hook runs against an immutable [`DataCtx`] snapshot; dispatch
    /// happens after the borrow ends.
    fn mode_actions(
        &self,
        hook: impl FnOnce(&dyn ExecutionMode, &DataCtx) -> Vec<StageAction>,
    ) -> Vec<StageAction> {
        let Some(mode) = self.mode.as_deref() else { return Vec::new() };
        if mode.is_passive() {
            // OnDemand: skip the per-event ctx snapshot entirely — the
            // hook cannot return actions. Keeps the default-mode hot
            // path allocation-free, like the seed.
            return Vec::new();
        }
        let homes = self.pilot_scratch_list();
        let ctx = DataCtx {
            topo: &self.tb.topo,
            store: &self.tb.store,
            state: &self.state,
            pilot_scratch: &homes,
            in_flight: &self.repl_in_flight,
        };
        hook(mode, &ctx)
    }

    /// Dispatch policy actions as priced replication transfers from
    /// each DU's closest live replica. Best-effort: an action whose DU
    /// has no source replica yet (or is already satisfied) is skipped —
    /// a later `DuStaged`/`PdDown` event re-plans.
    fn apply_actions(&mut self, actions: Vec<StageAction>) {
        for a in actions {
            if self.tb.store.has_replica(&a.du, &a.dst_pd)
                || self.repl_in_flight.contains(&(a.du.clone(), a.dst_pd.clone()))
            {
                continue;
            }
            if self.replicate(&a.du, &a.dst_pd).is_ok() {
                self.repl_in_flight.insert((a.du, a.dst_pd));
            }
        }
    }

    /// A replica of `du` at `pd` (label `label`) is gone. Keep the
    /// scheduler's replica-location index honest (drop the label only
    /// when no other PD at that label still holds the DU) and publish
    /// the loss — with its cause — on the event layer for the policy's
    /// repair pass.
    fn note_replica_lost(&mut self, du: &str, pd: &str, label: &Label, cause: LossCause) {
        let still_at_label = self
            .tb
            .store
            .replicas(du)
            .iter()
            .any(|p| p.endpoint.label == *label);
        if !still_at_label {
            self.state.drop_replica(du, label);
        }
        let _ = self.store.publish(
            &format!("{}{du}", keys::DATA_LOST_PREFIX),
            &format!("{pd} {}", cause.wire_name()),
        );
    }

    /// Consume data-plane loss events published since the last drain
    /// and turn each into the policy's repair actions (the data-plane
    /// analogue of `drain_queue_events`). PD names never contain
    /// spaces, so the payload splits unambiguously.
    fn drain_data_events(&mut self) {
        let mut lost: Vec<(String, String, LossCause)> = Vec::new();
        while let Ok(ev) = self.data_events.try_recv() {
            let Some(du) = ev.key.strip_prefix(keys::DATA_LOST_PREFIX) else { continue };
            let Some((pd, cause)) = ev.payload.rsplit_once(' ') else { continue };
            let Some(cause) = LossCause::from_wire(cause) else { continue };
            lost.push((du.to_string(), pd.to_string(), cause));
        }
        for (du, pd, cause) in lost {
            let actions = self.mode_actions(|m, ctx| m.on_replica_lost(&du, &pd, cause, ctx));
            self.apply_actions(actions);
        }
    }

    /// Install an open-loop workload (see [`crate::workload::openloop`])
    /// and schedule every tenant's first arrival. Arrivals are relative
    /// to the current simulated instant; run the sim to let them land.
    /// Each tenant draws from its own [`crate::rng::Rng::stream`] keyed
    /// off `seed` and the tenant name, so a tenant's arrival/demand
    /// sequence is invariant to the rest of the population.
    pub fn start_open_loop(&mut self, spec: crate::workload::openloop::OpenLoopSpec, seed: u64) {
        let t0 = self.sim.now();
        let mut run = crate::workload::openloop::OpenLoopRun::new(spec, seed, t0);
        for tenant in 0..run.tenant_count() {
            let delay = run.first_delay(tenant);
            self.sim.schedule(delay, Ev::ArrivalDue { tenant });
        }
        self.open_loop = Some(run);
    }

    /// Arrivals generated so far by the open-loop engine (0 when none
    /// is installed).
    pub fn open_loop_arrivals(&self) -> u64 {
        self.open_loop.as_ref().map_or(0, |r| r.total_arrivals())
    }

    /// CUs waiting right now: every agent queue plus the global queue
    /// (dispatched/running CUs are no longer waiting).
    pub fn queued_depth(&self) -> usize {
        let own: usize = self.state.queue_depths().values().sum();
        own + self.store.llen_k(&self.global_q).unwrap_or(0)
    }

    /// Sample a pilot's busy-slot level into the telemetry series
    /// (no-op unless `queueing_telemetry` is on). Called at every
    /// busy-slot edge a CU can cause: dispatch, staging failure,
    /// completion.
    fn note_busy(&mut self, now: f64, pilot: &str) {
        if !self.queueing_telemetry {
            return;
        }
        let busy = self.state.pilots[pilot].busy_slots;
        self.metrics.sample_series(&format!("busy:{pilot}"), now, busy as f64);
    }

    /// Submit a CU through the scheduler.
    pub fn submit_cu(&mut self, descr: ComputeUnitDescription) -> anyhow::Result<String> {
        let mut cu = ComputeUnit::new(descr);
        cu.t_submitted = self.sim.now();
        let id = cu.id.clone();
        self.state.add_cu(cu);
        self.place_cu(&id)?;
        Ok(id)
    }

    /// Bulk CU submission: place every CU, then translate the
    /// accumulated queue pushes into wakeups in **one** deduplicated
    /// drain — one `TryPull` per own-queue pilot touched and at most
    /// one ready-fleet scan for global work, instead of a scan per CU.
    ///
    /// Trace-identical to a [`SimSystem::submit_cu`] loop (asserted by
    /// `prop::bulk_cu_submission_matches_per_cu_reference_traces`): no
    /// event fires during submission, so every wakeup lands at the same
    /// instant either way; readiness cannot change between pushes, so
    /// the per-CU loop's later wakeups are exact duplicates of the
    /// first — and a duplicate `TryPull` is a no-op by the time it
    /// fires, because the first-woken pilot pulls until its queue or
    /// its slots are exhausted and every completion reschedules its own
    /// `TryPull`.
    pub fn submit_cus(
        &mut self,
        descrs: Vec<ComputeUnitDescription>,
    ) -> anyhow::Result<Vec<String>> {
        self.defer_wakeups = true;
        let mut ids = Vec::with_capacity(descrs.len());
        let mut failed = None;
        for d in descrs {
            match self.submit_cu(d) {
                Ok(id) => ids.push(id),
                Err(e) => {
                    failed = Some(e);
                    break;
                }
            }
        }
        self.defer_wakeups = false;
        self.drain_queue_events();
        match failed {
            Some(e) => Err(e),
            None => Ok(ids),
        }
    }

    /// Record a new replica location in the manager's scheduler-facing
    /// index (incremental: no per-placement rebuild).
    fn note_replica_pd(&mut self, du: &str, pd: &str) {
        if let Ok(p) = self.tb.store.pd(pd) {
            let label = p.endpoint.label.clone();
            self.state.note_replica(du, &label);
        }
    }

    /// Free bytes on the roomiest live quota'd PD per label — the
    /// scheduler's capacity feed ([`SchedContext::with_capacity`]).
    /// Labels backed by any unbounded live PD are omitted (no
    /// pressure there), and a testbed with no quotas at all returns
    /// `None`: the scheduler stays bit-identical capacity-blind.
    fn capacity_by_label(&self) -> Option<BTreeMap<Label, u64>> {
        // Quota-less testbeds (every experiment before the capacity
        // model, and the synthetic scale sweep) exit in O(1) instead of
        // walking every PD per placement.
        if !self.tb.store.any_quota() {
            return None;
        }
        let mut bounded: BTreeMap<Label, u64> = BTreeMap::new();
        let mut unbounded: BTreeSet<Label> = BTreeSet::new();
        let mut any_quota = false;
        for p in self.tb.store.pds() {
            if self.tb.store.pd_is_down(&p.name) {
                continue;
            }
            match self.tb.store.free_space(&p.name) {
                None => {
                    unbounded.insert(p.endpoint.label.clone());
                }
                Some(free) => {
                    any_quota = true;
                    let e = bounded.entry(p.endpoint.label.clone()).or_insert(0);
                    *e = (*e).max(free.as_u64());
                }
            }
        }
        if !any_quota {
            return None;
        }
        for l in unbounded {
            bounded.remove(&l);
        }
        Some(bounded)
    }

    fn place_cu(&mut self, cu_id: &str) -> anyhow::Result<()> {
        let capacity =
            if self.capacity_aware_scheduling { self.capacity_by_label() } else { None };
        let now = self.sim.now();
        let placement = {
            let cu = &self.state.cus[cu_id];
            let mut ctx = SchedContext::from_state(&self.tb.topo, &self.state).with_now(now);
            if let Some(cap) = capacity.as_ref() {
                ctx = ctx.with_capacity(cap);
            }
            self.scheduler.place(cu, &ctx)
        };
        match placement {
            Placement::Pilot(pilot) => {
                self.state.cus.get_mut(cu_id).unwrap().transition(CuState::Queued)?;
                self.store.rpush_k(&self.qkeys[&pilot], cu_id)?;
                self.state.note_queue_push(&pilot);
                if !self.defer_wakeups {
                    self.drain_queue_events();
                }
            }
            Placement::Global => {
                self.state.cus.get_mut(cu_id).unwrap().transition(CuState::Queued)?;
                self.store.rpush_k(&self.global_q, cu_id)?;
                if !self.defer_wakeups {
                    self.drain_queue_events();
                }
            }
            Placement::Delay(d) => {
                self.state.cus.get_mut(cu_id).unwrap().transition(CuState::Queued)?;
                self.sim.schedule(d, Ev::Reschedule { cu: cu_id.to_string() });
            }
            Placement::Unschedulable(reason) => {
                let cu = self.state.cus.get_mut(cu_id).unwrap();
                cu.transition(CuState::Unschedulable)?;
                cu.error = Some(reason);
            }
        }
        Ok(())
    }

    /// Can this pilot act on a wakeup right now? (Active, a free slot,
    /// and staging headroom — the exact preconditions `try_pull` checks
    /// before touching any queue.)
    fn pilot_ready(&self, p: &PilotCompute) -> bool {
        p.state == PilotState::Active
            && p.free_slots() > 0
            && self.staging_in_flight.get(&p.id).copied().unwrap_or(0) < self.max_concurrent_staging
    }

    /// Reference broadcast (see [`WakeupMode::Broadcast`]): every
    /// pilot gets a `TryPull` regardless of readiness, in id order.
    fn wake_all_pilots(&mut self) {
        let ids: Vec<String> = self.state.pilots.keys().cloned().collect();
        for pilot in ids {
            self.sim.schedule(0.0, Ev::TryPull { pilot });
        }
    }

    /// Targeted replacement for the all-pilots broadcast: wake only
    /// pilots whose `TryPull` would not be an immediate no-op.
    fn wake_ready_pilots(&mut self) {
        if self.wakeups == WakeupMode::Broadcast {
            return self.wake_all_pilots();
        }
        let ids: Vec<String> = self
            .state
            .pilots
            .values()
            .filter(|p| self.pilot_ready(p))
            .map(|p| p.id.clone())
            .collect();
        for pilot in ids {
            self.sim.schedule(0.0, Ev::TryPull { pilot });
        }
    }

    /// Consume the queue events the coordination store published since
    /// the last drain (the sim-side stand-in for a blocking pop: the
    /// single-threaded event engine must not block an OS thread, so
    /// queue activity becomes scheduled wakeups at the current
    /// simulated instant). An own-queue push wakes that pilot; global
    /// work wakes the ready subset. Called at every site that just
    /// pushed work — the push itself is what wakes agents, exactly as
    /// in wall-clock mode.
    fn drain_queue_events(&mut self) {
        let mut own: Vec<String> = Vec::new();
        let mut global_work = false;
        while let Ok(ev) = self.queue_events.try_recv() {
            if let Some(pilot) = ev.key.strip_prefix(keys::PILOT_QUEUE_PREFIX) {
                own.push(pilot.to_string());
            } else if ev.key == keys::GLOBAL_QUEUE {
                global_work = true;
            }
        }
        if self.wakeups == WakeupMode::Broadcast {
            if global_work || !own.is_empty() {
                self.wake_all_pilots();
            }
            return;
        }
        // Per-push drains see at most one pilot here; the batched
        // submission path can accumulate many pushes per pilot — wake
        // each pilot once, in first-push arrival order (stable: later
        // duplicates would fire after the first wakeup anyway and
        // no-op, so dropping them cannot change the trace).
        let mut woken: BTreeSet<&str> = BTreeSet::new();
        for pilot in &own {
            if woken.insert(pilot.as_str()) {
                self.sim.schedule(0.0, Ev::TryPull { pilot: pilot.clone() });
            }
        }
        if global_work {
            self.wake_ready_pilots();
        }
    }

    /// A replica of some DU just landed at `label`. If global work is
    /// waiting, any ready pilot might legitimately grab it — wake them
    /// all. Otherwise only pilots inside the replica label's subtree
    /// can gain from it (everyone else's wakeup would no-op), so prune
    /// candidates with the `pilots_by_label` subtree index.
    fn wake_pilots_for_du(&mut self, label: &Label) {
        if self.wakeups == WakeupMode::Broadcast {
            return self.wake_all_pilots();
        }
        if self.store.llen_k(&self.global_q).unwrap_or(0) > 0 {
            self.wake_ready_pilots();
            return;
        }
        let ids: Vec<String> = self
            .state
            .pilots_within(label)
            .into_iter()
            .filter(|id| self.state.pilots.get(*id).map_or(false, |p| self.pilot_ready(p)))
            .map(str::to_string)
            .collect();
        for pilot in ids {
            self.sim.schedule(0.0, Ev::TryPull { pilot });
        }
    }

    /// Drive the simulation until all events drain. Panics via the
    /// budget guard rather than hanging.
    pub fn run(&mut self) -> anyhow::Result<()> {
        let budget = self.event_budget;
        let mut n = 0u64;
        while let Some((t, ev)) = self.sim.next_event() {
            n += 1;
            anyhow::ensure!(n < budget, "event budget exceeded at {t}");
            self.handle(t.secs(), ev)?;
        }
        Ok(())
    }

    fn handle(&mut self, now: f64, ev: Ev) -> anyhow::Result<()> {
        match ev {
            Ev::PilotActive { pilot } => {
                let home = Arc::clone(&self.pilot_home[&pilot]);
                let p = self.state.pilots.get_mut(&pilot).unwrap();
                if p.state.is_terminal() {
                    // Killed while still waiting in the batch queue
                    // (chaos injection): the activation is stale.
                    return Ok(());
                }
                p.transition(PilotState::Active)?;
                p.t_active = now;
                self.metrics.mark(now, &home.machine, TimelineEvent::PilotActive);
                // A new site is live: an auto-replicating policy may
                // want copies on its scratch PD before work arrives.
                let actions = self.mode_actions(|m, ctx| m.on_pilot_active(&pilot, ctx));
                self.apply_actions(actions);
                self.sim.schedule(0.0, Ev::TryPull { pilot });
            }

            Ev::DuStaged { du, pd, flow, ok, attempt } => {
                if let Some(f) = flow {
                    self.tb.net.end_flow(&f);
                }
                if !ok && self.retry_style == RetryStyle::InDes && attempt < self.retry.max_attempts
                {
                    // Attempt budget left: back off in simulated time,
                    // then re-issue from a freshly resolved source.
                    // The (du, pd) pair stays in `repl_in_flight` so
                    // policies don't double-issue during the backoff.
                    self.transfer_retries += 1;
                    self.sim.schedule(
                        self.retry.backoff_for(attempt.saturating_sub(1)),
                        Ev::DuRetry { du, pd, attempt: attempt + 1 },
                    );
                    return Ok(());
                }
                self.repl_in_flight.remove(&(du.clone(), pd.clone()));
                if ok {
                    // Quota-checked placement: a full (or downed) PD
                    // rejects the replica instead of growing forever,
                    // and making room may evict cold replicas.
                    match self.tb.store.try_place(&du, &pd)? {
                        PlaceOutcome::Placed { evicted } => {
                            self.note_replica_pd(&du, &pd);
                            for (edu, epd) in evicted {
                                let elabel = self.tb.store.pd(&epd)?.endpoint.label.clone();
                                self.note_replica_lost(&edu, &epd, &elabel, LossCause::Evicted);
                            }
                            if let Some(d) = self.state.dus.get_mut(&du) {
                                if d.state == DuState::Pending {
                                    d.transition(DuState::Running)?;
                                }
                            }
                            self.metrics.set_scalar(&format!("staged:{du}:{pd}"), now);
                            // The policy may fan the new replica out
                            // further (pre-stage) or top up a replica
                            // target (auto-replicate); evictions above
                            // may also need repair — both ride the
                            // drained data events / hook actions.
                            let actions =
                                self.mode_actions(|m, ctx| m.on_du_available(&du, &pd, ctx));
                            self.apply_actions(actions);
                            self.drain_data_events();
                            // New data may unlock data-local work: wake
                            // pilots at the replica's label (plus
                            // everyone ready if the global queue holds
                            // work).
                            if let Ok(p) = self.tb.store.pd(&pd) {
                                let label = p.endpoint.label.clone();
                                self.wake_pilots_for_du(&label);
                            }
                        }
                        PlaceOutcome::NoCapacity => {
                            // The bytes crossed the wire but the PD
                            // cannot legally hold them (quota full of
                            // pinned/last replicas, or down).
                            self.capacity_rejections += 1;
                            self.wake_ready_pilots();
                        }
                    }
                } else {
                    // Partial replication (Fig. 8's ~7.5 of 9): the DU
                    // stays usable from other replicas. A failed
                    // transfer changed no schedulable state, but keep
                    // the seed's conservative re-poll of ready agents.
                    self.wake_ready_pilots();
                }
            }

            Ev::DuRetry { du, pd, attempt } => {
                // Re-resolve the source: replicas may have moved (or
                // vanished) during the backoff. A DU with no replica
                // anywhere is an upload still in flight — it retries
                // from the gateway.
                let dst_label = self.tb.store.pd(&pd)?.endpoint.label.clone();
                let gw = self.gateway_pd().ok();
                let src = self
                    .tb
                    .store
                    .closest_replica(&self.tb.topo, &du, &dst_label)
                    .map(|p| p.name.clone())
                    .or(gw.clone());
                match src {
                    Some(src) if !self.tb.store.pd_is_down(&pd) => {
                        let via_gateway = gw.as_deref() == Some(src.as_str());
                        self.start_transfer_from(&du, &src, &pd, via_gateway, attempt)?;
                    }
                    _ => {
                        // No surviving source, or the destination went
                        // down during the backoff: fail permanently.
                        self.sim.schedule(0.0, Ev::DuStaged {
                            du,
                            pd,
                            flow: None,
                            ok: false,
                            attempt: self.retry.max_attempts,
                        });
                    }
                }
            }

            Ev::TryPull { pilot } => {
                if std::env::var("PD_DEBUG_PULL").is_ok() {
                    let p = &self.state.pilots[&pilot];
                    eprintln!(
                        "DBGPULL t={now:.0} pilot={pilot} machine={} state={:?} free={} inflight={} own={} global={}",
                        self.pilot_home[&pilot].machine,
                        p.state,
                        p.free_slots(),
                        self.staging_in_flight.get(&pilot).unwrap_or(&0),
                        self.store.llen_k(&self.qkeys[&pilot]).unwrap_or(0),
                        self.store.llen_k(&self.global_q).unwrap_or(0),
                    );
                }
                self.try_pull(now, &pilot)?;
            }

            Ev::CuStaged { cu, flow, ok, attempt } => {
                if let Some(f) = flow {
                    self.tb.net.end_flow(&f);
                }
                // The pilot may have expired mid-staging (the CU was
                // re-queued), or the CU may already be staging again on
                // another pilot; both leave a stale event — drop it.
                if self.state.cus[&cu].state != CuState::StagingInput
                    || self.dispatch_epoch.get(&cu) != Some(&attempt)
                {
                    return Ok(());
                }
                let pilot_id = self.state.cus[&cu].pilot.clone().unwrap();
                let home = Arc::clone(&self.pilot_home[&pilot_id]);
                let remote_inputs = self.staged_remote.get(&cu).cloned().unwrap_or_default();
                if !remote_inputs.is_empty() {
                    if let Some(n) = self.staging_in_flight.get_mut(&pilot_id) {
                        *n = n.saturating_sub(1);
                    }
                }
                self.sim.schedule(0.0, Ev::TryPull { pilot: pilot_id.clone() });
                if !ok {
                    // Staging failed: free the slots and retry through
                    // the legal `StagingInput → Queued` edge, up to a
                    // bound (inputs that never materialize — e.g. a
                    // permanently failed upload — fail the CU).
                    self.staging_failures += 1;
                    let n = self.requeues.entry(cu.clone()).or_insert(0);
                    *n += 1;
                    let failures = *n;
                    let give_up = failures > self.max_requeues;
                    let c = self.state.cus.get_mut(&cu).unwrap();
                    let cores = c.description.cores.max(1);
                    self.state.pilots.get_mut(&pilot_id).unwrap().busy_slots -= cores;
                    self.note_busy(now, &pilot_id);
                    let c = self.state.cus.get_mut(&cu).unwrap();
                    if give_up {
                        c.error = Some("input staging failed permanently".into());
                        c.transition(CuState::Failed)?;
                    } else {
                        match self.retry_style {
                            RetryStyle::Aggregate => {
                                // Seed semantics: blind immediate push
                                // back onto the global queue.
                                c.transition(CuState::Queued)?;
                                self.store.rpush_k(&self.global_q, &cu)?;
                                self.drain_queue_events();
                            }
                            RetryStyle::InDes => {
                                // Unbind from the (possibly unhealthy)
                                // pilot, back off in simulated time,
                                // then re-place through the scheduler —
                                // which sees the current replica map
                                // and capacity feed, not the one that
                                // produced the failing placement.
                                c.transition(CuState::Queued)?;
                                c.pilot = None;
                                let backoff =
                                    self.retry.backoff_for(failures.saturating_sub(1));
                                self.sim.schedule(backoff, Ev::Reschedule { cu: cu.clone() });
                            }
                        }
                    }
                    return Ok(());
                }
                // Remote inputs landed on the scratch PD. A quota'd
                // scratch must admit them as real residents (possibly
                // evicting cold replicas, possibly refusing outright);
                // unbounded scratch keeps the seed's transient-staging
                // semantics where only the wire time is modeled.
                if self.tb.store.free_space(&home.scratch).is_some() {
                    for du in &remote_inputs {
                        if self.tb.store.has_replica(du, &home.scratch) {
                            continue;
                        }
                        match self.tb.store.try_place(du, &home.scratch)? {
                            PlaceOutcome::Placed { evicted } => {
                                self.note_replica_pd(du, &home.scratch);
                                for (edu, epd) in evicted {
                                    let elabel =
                                        self.tb.store.pd(&epd)?.endpoint.label.clone();
                                    self.note_replica_lost(
                                        &edu,
                                        &epd,
                                        &elabel,
                                        LossCause::Evicted,
                                    );
                                }
                            }
                            PlaceOutcome::NoCapacity => {
                                self.capacity_rejections += 1;
                            }
                        }
                    }
                    self.drain_data_events();
                }
                let m = self.tb.batch.machine(&home.machine)?.clone();
                self.tb.batch.io_begin(&home.machine);
                let cu_cores = self.state.cus[&cu].description.cores.max(1);
                let sharers = self.machine_sharers(&home.machine, cu_cores);
                let fs_share = m.fs_bandwidth.0 / sharers;
                if std::env::var("PD_DEBUG_IO").is_ok() {
                    eprintln!(
                        "DBG t={now:.1} cu={cu} machine={} sharers={sharers:.0} share={:.1}MiB/s",
                        home.machine,
                        fs_share / 1048576.0
                    );
                }
                let c = self.state.cus.get_mut(&cu).unwrap();
                c.staging_s = now - c.t_started_staging;
                c.transition(CuState::Running)?;
                c.t_started_run = now;
                // Remote-staged inputs were already paid on the wire;
                // the run still scans them once from local disk.
                let runtime = task_runtime_s(
                    c.description.cpu_secs_hint,
                    c.description.io_bytes_hint,
                    m.speed_factor,
                    fs_share,
                ) * {
                    let (lo, hi) = self.runtime_variance;
                    // BWA runtime variance (paper Fig. 12 error bars);
                    // (1.0, 1.0) for analytically exact service times.
                    self.rng.range_f64(lo, hi)
                };
                self.metrics.mark(now, &home.machine, TimelineEvent::CuStarted);
                self.sim.schedule(runtime, Ev::CuDone { cu });
            }

            Ev::CuDone { cu } => {
                // Stale event for a CU whose pilot expired mid-run.
                if self.state.cus[&cu].state != CuState::Running {
                    return Ok(());
                }
                let pilot_id = self.state.cus[&cu].pilot.clone().unwrap();
                let home = Arc::clone(&self.pilot_home[&pilot_id]);
                self.tb.batch.io_end(&home.machine);
                let c = self.state.cus.get_mut(&cu).unwrap();
                c.transition(CuState::StagingOutput)?;
                c.transition(CuState::Done)?;
                c.t_finished = now;
                let rec = CuRecord {
                    cu: cu.clone(),
                    machine: home.machine.clone(),
                    t_submitted: c.t_submitted,
                    t_start: c.t_started_staging,
                    t_end: now,
                    staging_s: c.staging_s,
                    compute_s: now - c.t_started_run,
                };
                let cores = c.description.cores.max(1);
                self.metrics.record_cu(rec);
                self.metrics.mark(now, &home.machine, TimelineEvent::CuFinished);
                self.state.pilots.get_mut(&pilot_id).unwrap().busy_slots -= cores;
                self.note_busy(now, &pilot_id);
                self.sim.schedule(0.0, Ev::TryPull { pilot: pilot_id });
            }

            Ev::Reschedule { cu } => {
                if !self.state.cus[&cu].state.is_terminal() {
                    self.place_cu(&cu)?;
                }
            }

            Ev::PilotExpired { pilot } => {
                self.teardown_pilot(&pilot, PilotState::Done)?;
            }

            Ev::PilotFailed { pilot } => {
                let alive = self
                    .state
                    .pilots
                    .get(&pilot)
                    .map_or(false, |p| !p.state.is_terminal());
                if alive {
                    self.pilot_failures += 1;
                }
                self.teardown_pilot(&pilot, PilotState::Failed)?;
            }

            Ev::PdDown { pd } => {
                if self.tb.store.pd_is_down(&pd) {
                    return Ok(()); // idempotent re-delivery
                }
                self.tb.store.set_pd_down(&pd, true);
                let label = self.tb.store.pd(&pd)?.endpoint.label.clone();
                // Every resident replica is lost: force-evict (the
                // outage bypasses the capacity-eviction protections),
                // fix the scheduler's replica index, and publish each
                // loss on the event layer.
                for du in self.tb.store.dus_on(&pd) {
                    self.tb.store.evict(&du, &pd);
                    self.note_replica_lost(&du, &pd, &label, LossCause::Outage);
                }
                // Turn the published losses into the policy's repair
                // transfers (no-op under OnDemand/reference).
                self.drain_data_events();
            }

            Ev::PdUp { pd } => {
                if !self.tb.store.pd_is_down(&pd) {
                    return Ok(()); // never went down, or already recovered
                }
                // The outage evicted every resident replica, so the PD
                // comes back empty with its quota intact.
                self.tb.store.set_pd_down(&pd, false);
                let _ = self
                    .store
                    .publish(&format!("{}{pd}", keys::DATA_AVAIL_PREFIX), "up");
                // Proactive policies re-balance onto the recovered
                // capacity (re-fill replica targets, re-push affinity
                // data); OnDemand/reference ignore it.
                let actions = self.mode_actions(|m, ctx| m.on_pd_up(&pd, ctx));
                self.apply_actions(actions);
                self.drain_data_events();
                // Recovered locality may unlock queued work.
                if let Ok(p) = self.tb.store.pd(&pd) {
                    let label = p.endpoint.label.clone();
                    self.wake_pilots_for_du(&label);
                }
            }

            Ev::ArrivalDue { tenant } => {
                // Take the run out so the generator borrow can't alias
                // the submission path; re-installed before submitting.
                let Some(mut run) = self.open_loop.take() else {
                    return Ok(()); // no open-loop workload installed
                };
                if self.queueing_telemetry {
                    // Arrival-instant backlog sample, taken *before*
                    // this batch joins the queues. Under Poisson
                    // arrivals these samples are PASTA-unbiased
                    // estimates of the time-average queue depth.
                    let depth = self.queued_depth();
                    self.metrics.sample_series("queue_depth", now, depth as f64);
                }
                let batch = run.next_batch(tenant, now);
                if let Some(next_in) = batch.next_in {
                    self.sim.schedule(next_in, Ev::ArrivalDue { tenant });
                }
                self.open_loop = Some(run);
                // The arrival's data lands first (pre-placed, instant),
                // then its minted ids replace the `@i` placeholders in
                // the CUs' inputs.
                let mut du_ids = Vec::with_capacity(batch.dus.len());
                for (descr, pd) in &batch.dus {
                    du_ids.push(self.place_du_instant(descr, pd)?);
                }
                let mut cus = batch.cus;
                for cu in &mut cus {
                    for input in &mut cu.input_data {
                        if let Some(ix) =
                            input.strip_prefix('@').and_then(|s| s.parse::<usize>().ok())
                        {
                            let id = du_ids.get(ix).ok_or_else(|| {
                                anyhow::anyhow!("arrival batch references unknown DU @{ix}")
                            })?;
                            *input = id.clone();
                        }
                    }
                }
                if !cus.is_empty() {
                    self.submit_cus(cus)?;
                }
            }
        }
        Ok(())
    }

    /// Shared teardown for a pilot leaving service (walltime expiry or
    /// hard failure): release its batch cores, re-dispatch in-flight
    /// CUs through the per-CU re-dispatch bound, drain its agent queue
    /// back to the global queue, and reset the bookkeeping.
    fn teardown_pilot(&mut self, pilot: &str, final_state: PilotState) -> anyhow::Result<()> {
        let Some(p) = self.state.pilots.get_mut(pilot) else { return Ok(()) };
        if p.state.is_terminal() {
            return Ok(());
        }
        let was_active = p.state == PilotState::Active;
        p.state = final_state;
        p.busy_slots = 0;
        let home = Arc::clone(&self.pilot_home[pilot]);
        if was_active {
            let cores = self.state.pilots[pilot].description.cores;
            self.tb.batch.release(&home.machine, cores);
        }
        // Re-queue this pilot's in-flight CUs and drain its agent
        // queue back to the global queue. A CU that keeps losing its
        // pilot mid-flight is failed once it exhausts the re-dispatch
        // bound rather than bouncing forever.
        let orphaned: Vec<String> = self
            .state
            .cus
            .values()
            .filter(|c| c.pilot.as_deref() == Some(pilot) && !c.state.is_terminal())
            .map(|c| c.id.clone())
            .collect();
        for cu in orphaned {
            let c = self.state.cus.get_mut(&cu).unwrap();
            if matches!(c.state, CuState::StagingInput | CuState::Running) {
                let n = self.redispatches.entry(cu.clone()).or_insert(0);
                *n += 1;
                if *n > self.max_redispatches {
                    let c = self.state.cus.get_mut(&cu).unwrap();
                    c.error = Some(format!(
                        "re-dispatch bound exceeded after {} pilot losses",
                        self.max_redispatches
                    ));
                    c.transition(CuState::Failed)?;
                } else {
                    c.transition(CuState::Queued)?;
                    c.pilot = None;
                    self.store.rpush_k(&self.global_q, &cu)?;
                }
            }
        }
        while let Some(cu) = self.store.lpop_k(&self.qkeys[pilot])? {
            self.store.rpush_k(&self.global_q, &cu)?;
        }
        self.state.reset_queue_depth(pilot);
        self.staging_in_flight.remove(pilot);
        // The re-queues above published global-queue events; turning
        // them into wakeups is the drain's job.
        self.drain_queue_events();
        Ok(())
    }

    /// Drive one pilot's `TryPull`: in [`SlotMode::Batch`] the handler
    /// loops over every free slot; in [`SlotMode::PerSlot`] it pulls
    /// for one slot and front-schedules the chain's next link, so the
    /// chain drains consecutively (no other same-time event
    /// interleaves) and the two modes dispatch identically.
    fn try_pull(&mut self, now: f64, pilot: &str) -> anyhow::Result<()> {
        match self.slots {
            SlotMode::Batch => {
                while self.try_pull_one(now, pilot)? {}
                Ok(())
            }
            SlotMode::PerSlot => {
                if self.try_pull_one(now, pilot)? {
                    self.sim.schedule_front(Ev::TryPull { pilot: pilot.to_string() });
                }
                Ok(())
            }
        }
    }

    /// One slot's pull attempt. Returns whether a CU was dispatched
    /// (i.e. whether the pool has reason to try the next slot).
    fn try_pull_one(&mut self, now: f64, pilot: &str) -> anyhow::Result<bool> {
        let (can, cores_free) = {
            let p = &self.state.pilots[pilot];
            (p.state == PilotState::Active && p.free_slots() > 0, p.free_slots())
        };
        if !can {
            return Ok(false);
        }
        // Agent-side staging throttle: don't start more concurrent
        // input stagings than the agent can drive.
        if *self.staging_in_flight.get(pilot).unwrap_or(&0) >= self.max_concurrent_staging {
            return Ok(false);
        }
        // Two-queue pull protocol (§4.2), with the queue-depth
        // counter kept in lockstep with the store.
        let Some((cu_id, from_own)) = agent_pull_tracked(&self.store, &self.qkeys[pilot])?
        else {
            return Ok(false);
        };
        if from_own {
            self.state.note_queue_pop(pilot);
        }
        if let Some(log) = self.pull_log.as_mut() {
            log.push((pilot.to_string(), cu_id.clone(), from_own));
        }
        let cu = &self.state.cus[&cu_id];
        let cores = cu.description.cores.max(1);
        if cores > cores_free {
            // Not enough room. `requeue_k` is the silent push-back
            // variant — no queue event, no waiter wakeup: nothing
            // new appeared, and a wake here would livelock
            // (push-back → wake → pop → …).
            if !from_own && cores > self.state.pilots[pilot].description.cores {
                // A global-queue CU this pilot can never fit (own-
                // queue CUs always fit: eligibility filters on
                // total cores). Return it to the global queue for
                // a big-enough pilot — parking it on our own queue
                // would trap it forever, since only we pop that
                // queue.
                self.store.requeue_k(&self.global_q, &cu_id)?;
            } else {
                self.store.requeue_k(&self.qkeys[pilot], &cu_id)?;
                self.state.note_queue_push(pilot);
            }
            return Ok(false);
        }
        self.begin_staging(now, pilot, &cu_id)?;
        Ok(true)
    }

    /// Start input staging for a pulled CU.
    fn begin_staging(&mut self, now: f64, pilot: &str, cu_id: &str) -> anyhow::Result<()> {
        let home = Arc::clone(&self.pilot_home[pilot]);
        let pilot_label = self.tb.batch.machine(&home.machine)?.label.clone();
        let cores = self.state.cus[cu_id].description.cores.max(1);
        self.state.pilots.get_mut(pilot).unwrap().busy_slots += cores;
        self.note_busy(now, pilot);
        let busy = self.state.pilots[pilot].busy_slots;
        let peak = self.max_busy.entry(pilot.to_string()).or_insert(0);
        if busy > *peak {
            *peak = busy;
        }
        {
            let c = self.state.cus.get_mut(cu_id).unwrap();
            c.pilot = Some(pilot.to_string());
            c.t_started_staging = now;
            c.transition(CuState::StagingInput)?;
        }

        // Compute total staging time across input DUs.
        let inputs = self.state.cus[cu_id].description.input_data.clone();
        let mut total = 0.0f64;
        let mut ok = true;
        let mut flow: Option<FlowHandle> = None;
        let mut remote_dus: Vec<String> = Vec::new();
        // Loop-invariant: the scratch PD exists (validated at
        // submit_pilot) and its label decides whether the agent's
        // staging flow can fuse with the cost walk below.
        let scratch_is_pilot =
            self.tb.store.pd(&home.scratch)?.endpoint.label == pilot_label;
        for du in &inputs {
            let Some(src) = self.tb.store.closest_replica(&self.tb.topo, du, &pilot_label) else {
                // Input not materialized anywhere yet — treat as
                // failure; CU re-queues and waits for DuStaged wakeups.
                ok = false;
                continue;
            };
            let src_name = src.name.clone();
            let src_label = src.endpoint.label.clone();
            if src_label == pilot_label {
                // Co-located: logical filesystem link (still an
                // access — keep the replica warm in its PD's LRU).
                self.tb.store.touch(du, &src_name);
                total += 1.0;
            } else {
                remote_dus.push(du.clone());
                // Staging is sequential-read + one protocol stream:
                // the per-flow cap inside `transfer_cost` (e.g. ~20
                // MiB/s scp) is the binding constraint, matching the
                // paper's ~450 s per 9 GB task move. The first remote
                // DU also registers the agent's staging flow — combined
                // with its pricing into one path walk when the flow's
                // endpoint (the pilot machine) is the scratch PD's
                // label, which it is on every calibrated testbed.
                let cost: TransferCost = if flow.is_none() && scratch_is_pilot {
                    let (cost, h) = self.tb.store.staging_cost_flow(
                        &mut self.tb.net,
                        du,
                        &src_name,
                        &home.scratch,
                        None,
                    )?;
                    flow = Some(h);
                    cost
                } else {
                    let cost = self.tb.store.staging_cost(
                        &self.tb.net,
                        du,
                        &src_name,
                        &home.scratch,
                        None,
                    )?;
                    if flow.is_none() {
                        flow = Some(self.tb.net.begin_flow(&src_label, &pilot_label));
                    }
                    cost
                };
                let failure_rate = self.tb.store.pd(&src_name)?.endpoint.params.failure_rate;
                let size = self.tb.store.du_meta(du)?.0.as_u64();
                match self.retry_style {
                    RetryStyle::Aggregate => {
                        let outcome = attempt_transfer(
                            &mut self.rng,
                            failure_rate,
                            cost.wire_s,
                            self.retry,
                        );
                        ok &= outcome.succeeded;
                        total += cost.total() + outcome.wasted_s;
                        self.bytes_moved += size;
                        self.dollars_spent +=
                            self.tb.store.transfer_dollars(&src_name, &home.scratch, size);
                    }
                    RetryStyle::InDes => {
                        // One draw per attempt, composed with the
                        // per-link rates on the staging path. A failed
                        // pull is detected partway through the wire
                        // leg; the retry is the CU-level re-dispatch
                        // (CuStaged's failure path backs off and
                        // re-places through the scheduler), not an
                        // inline loop.
                        let link_rate =
                            self.tb.net.path_failure_rate_labels(&src_label, &pilot_label);
                        let rate = 1.0 - (1.0 - failure_rate) * (1.0 - link_rate);
                        if self.rng.chance(rate) {
                            let frac = self.rng.range_f64(0.1, 0.9);
                            ok = false;
                            total += cost.setup_s + cost.wire_s * frac;
                            let part = (size as f64 * frac) as u64;
                            self.bytes_moved += part;
                            self.dollars_spent +=
                                self.tb.store.transfer_dollars(&src_name, &home.scratch, part);
                        } else {
                            total += cost.total();
                            self.bytes_moved += size;
                            self.dollars_spent +=
                                self.tb.store.transfer_dollars(&src_name, &home.scratch, size);
                        }
                    }
                }
                self.tb.store.touch(du, &src_name);
            }
        }
        let remote = !remote_dus.is_empty();
        self.staged_remote.insert(cu_id.to_string(), remote_dus);
        if remote {
            // Only remote stagings consume agent staging slots; local
            // links are effectively free.
            *self.staging_in_flight.entry(pilot.to_string()).or_insert(0) += 1;
        }
        let epoch = {
            let e = self.dispatch_epoch.entry(cu_id.to_string()).or_insert(0);
            *e += 1;
            *e
        };
        self.sim
            .schedule(total, Ev::CuStaged { cu: cu_id.to_string(), flow, ok, attempt: epoch });
        Ok(())
    }

    /// Makespan of the executed workload.
    pub fn makespan(&self) -> f64 {
        self.metrics.makespan()
    }

    /// Concurrent I/O sharers on a machine: the larger of the batch
    /// I/O counter and the cores-busy estimate across its pilots
    /// (tasks that started in the same event batch all contend even
    /// though the counter ramps sequentially).
    fn machine_sharers(&self, machine: &str, cu_cores: u32) -> f64 {
        let io = self.tb.batch.io_active(machine) as f64;
        // The per-machine index replaces a full `pilot_home` scan (this
        // runs per CuStaged — O(fleet) was quadratic in the scale
        // sweep). The BTreeSet iterates in sorted id order, exactly the
        // order the filtered scan produced, so the f64 sum is
        // bit-identical.
        let busy: f64 = self
            .machine_pilots
            .get(machine)
            .map(|ids| {
                ids.iter()
                    .filter_map(|p| self.state.pilots.get(p))
                    .map(|p| p.busy_slots as f64 / cu_cores.max(1) as f64)
                    .sum()
            })
            .unwrap_or(0.0);
        io.max(busy).max(1.0)
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_testbed;
    use crate::util::Bytes;
    use crate::workload::bwa_ensemble;

    fn small_ensemble() -> crate::workload::BwaEnsemble {
        bwa_ensemble(4, Bytes::gb(1), Bytes::gb(8))
    }

    #[test]
    fn pilot_becomes_active_after_queue_wait() {
        let mut sys = SimSystem::new(paper_testbed(), 1);
        let p = sys.submit_pilot("lonestar", 64, "lonestar-scratch").unwrap();
        sys.run().unwrap();
        assert_eq!(sys.state.pilots[&p].state, PilotState::Active);
        assert!(sys.sim.now() > 0.0, "queue wait must advance time");
    }

    #[test]
    fn du_upload_places_replica_and_records_ts() {
        let mut sys = SimSystem::new(paper_testbed(), 2);
        let ens = small_ensemble();
        let du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
        sys.run().unwrap();
        assert!(sys.tb.store.has_replica(&du, "lonestar-scratch"));
        let t = sys.metrics.scalar(&format!("staged:{du}:lonestar-scratch"));
        assert!(t > 10.0, "8GB upload should take real time, got {t}");
    }

    #[test]
    fn full_bwa_run_completes_all_cus() {
        let mut sys = SimSystem::new(paper_testbed(), 3);
        let ens = small_ensemble();
        let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
        let mut chunk_ids = Vec::new();
        for c in &ens.read_chunks {
            chunk_ids.push(sys.upload_du(c, "lonestar-scratch").unwrap());
        }
        sys.run().unwrap(); // land the data
        sys.submit_pilot("lonestar", 64, "lonestar-scratch").unwrap();
        for chunk in &chunk_ids {
            let mut cud = ens.cu_template.clone();
            cud.input_data = vec![ref_du.clone(), chunk.clone()];
            sys.submit_cu(cud).unwrap();
        }
        sys.run().unwrap();
        assert!(sys.state.workload_finished());
        assert_eq!(sys.state.count_cu_state(CuState::Done), 4);
        assert!(sys.makespan() > 0.0);
        // Data-local staging: every CU should have tiny staging time.
        for r in &sys.metrics.cu_records {
            assert!(r.staging_s < 30.0, "co-located staging was {}", r.staging_s);
        }
    }

    #[test]
    fn remote_input_pays_wire_time() {
        let mut sys = SimSystem::new(paper_testbed(), 4);
        let ens = small_ensemble();
        // Data on OSG SRM; pilot on Lonestar: staging must be remote.
        let ref_du = sys.upload_du(&ens.reference, "osg-srm").unwrap();
        sys.run().unwrap();
        sys.submit_pilot("lonestar", 64, "lonestar-scratch").unwrap();
        let mut cud = ens.cu_template.clone();
        cud.input_data = vec![ref_du];
        sys.submit_cu(cud).unwrap();
        sys.run().unwrap();
        assert!(sys.state.workload_finished());
        let rec = &sys.metrics.cu_records[0];
        assert!(rec.staging_s > 30.0, "remote staging was only {}s", rec.staging_s);
    }

    #[test]
    fn group_replication_is_mostly_complete_under_failures() {
        let mut sys = SimSystem::new(paper_testbed(), 5);
        sys.retry = RetryPolicy::none(); // Fig. 8 has no retries
        let ens = small_ensemble();
        let du = sys.upload_du(&ens.reference, "irods-fnal").unwrap();
        sys.run().unwrap();
        sys.replicate_group(&du, "osgGridFtpGroup").unwrap();
        sys.run().unwrap();
        let n = sys.tb.store.replicas(&du).len();
        assert!((5..=9).contains(&n), "replicas={n}");
    }

    #[test]
    fn pilot_walltime_requeues_running_cus() {
        let mut sys = SimSystem::new(paper_testbed(), 21);
        let ens = small_ensemble();
        let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
        sys.run().unwrap();
        // Two pilots; kill the first early so its CUs re-queue and
        // finish on the second.
        let p1 = sys.submit_pilot("lonestar", 16, "lonestar-scratch").unwrap();
        sys.submit_pilot("stampede", 16, "stampede-scratch").unwrap();
        for chunk_descr in &ens.read_chunks {
            let chunk = sys.upload_du(chunk_descr, "lonestar-scratch").unwrap();
            let mut cud = ens.cu_template.clone();
            cud.input_data = vec![ref_du.clone(), chunk];
            sys.submit_cu(cud).unwrap();
        }
        // Kill p1 shortly after it activates (well before task end).
        sys.kill_pilot_at(&p1, 3000.0);
        sys.run().unwrap();
        assert!(sys.state.workload_finished());
        assert_eq!(sys.state.count_cu_state(CuState::Done), 4);
        assert_eq!(sys.state.pilots[&p1].state, PilotState::Done);
        // At least one CU must have ended up on the surviving pilot.
        let on_stampede = sys
            .metrics
            .cu_records
            .iter()
            .filter(|r| r.machine == "stampede")
            .count();
        assert!(on_stampede >= 1, "records={:?}", sys.metrics.distribution());
    }

    #[test]
    fn expired_pilot_releases_cores() {
        let mut sys = SimSystem::new(paper_testbed(), 22);
        let p = sys.submit_pilot("lonestar", 64, "lonestar-scratch").unwrap();
        assert_eq!(sys.tb.batch.used("lonestar"), 64);
        sys.kill_pilot_at(&p, 10_000.0);
        sys.run().unwrap();
        assert_eq!(sys.tb.batch.used("lonestar"), 0);
    }

    /// A global-queue CU that a small pilot can never fit must go back
    /// to the global queue (not be parked on that pilot's own queue,
    /// which only it pops) so a big-enough pilot can run it.
    #[test]
    fn oversized_global_cu_is_not_trapped_by_a_small_pilot() {
        let mut sys = SimSystem::new(paper_testbed(), 31);
        let ens = small_ensemble();
        let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
        sys.run().unwrap();
        // Small pilot and big pilot; the 8-core CU is eligible only
        // for the big one, but either agent may pull it from the
        // global queue.
        sys.submit_pilot("lonestar", 4, "lonestar-scratch").unwrap();
        sys.submit_pilot("lonestar", 16, "lonestar-scratch").unwrap();
        let mut cud = ens.cu_template.clone();
        cud.cores = 8;
        cud.input_data = vec![ref_du];
        sys.submit_cu(cud).unwrap();
        sys.run().unwrap();
        assert!(sys.state.workload_finished(), "oversized CU trapped on the small pilot");
        assert_eq!(sys.state.count_cu_state(CuState::Done), 1);
    }

    /// The per-slot TryPull chain (multi-slot mapping) must make the
    /// same dispatch decisions as the batch reference loop, and a
    /// pilot must never exceed its core count in concurrent CUs.
    #[test]
    fn per_slot_chain_matches_batch_and_respects_cores() {
        let run = |mode: SlotMode| {
            let mut sys = SimSystem::new(paper_testbed(), 11).with_slot_mode(mode);
            let ens = small_ensemble();
            let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
            let mut chunks = Vec::new();
            for c in &ens.read_chunks {
                chunks.push(sys.upload_du(c, "lonestar-scratch").unwrap());
            }
            sys.run().unwrap();
            let p = sys.submit_pilot("lonestar", 4, "lonestar-scratch").unwrap();
            for chunk in &chunks {
                let mut cud = ens.cu_template.clone();
                cud.input_data = vec![ref_du.clone(), chunk.clone()];
                sys.submit_cu(cud).unwrap();
            }
            sys.run().unwrap();
            assert!(sys.state.workload_finished());
            // 4-core pilot, 2-core CUs: at most 2 concurrent, never
            // above the pilot's core count.
            let peak = sys.max_busy.get(&p).copied().unwrap_or(0);
            assert!(peak <= 4, "{mode:?}: peak busy {peak} > cores");
            assert!(peak >= 2, "{mode:?}: pool never ran concurrently");
            sys.makespan()
        };
        assert_eq!(run(SlotMode::PerSlot), run(SlotMode::Batch));
    }

    /// A PD outage must reach the scheduler's replica-location index:
    /// `data_score` consults `du_locations`, so a label backed only by
    /// the dead PD has to disappear from it (while labels still backed
    /// by another PD survive).
    #[test]
    fn pd_outage_drops_replica_label_from_scheduler_index() {
        let mut sys = SimSystem::new(paper_testbed(), 71);
        let ens = small_ensemble();
        let du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
        sys.run().unwrap();
        sys.replicate(&du, "stampede-scratch").unwrap();
        sys.run().unwrap();
        assert_eq!(sys.state.du_locations()[&du].len(), 2);
        sys.fail_pd_at("lonestar-scratch", sys.sim.now() + 1.0);
        sys.run().unwrap();
        assert!(sys.tb.store.pd_is_down("lonestar-scratch"));
        assert!(!sys.tb.store.has_replica(&du, "lonestar-scratch"));
        assert_eq!(
            sys.state.du_locations()[&du],
            vec![Label::new("xsede/tacc/stampede")],
            "dead PD's label must leave the scheduler index"
        );
    }

    /// A transfer into a PD that cannot legally hold the bytes (quota
    /// smaller than the DU) pays the wire time but is refused at
    /// placement — counted, residents untouched.
    #[test]
    fn capacity_rejection_is_counted_and_evicts_nothing() {
        let mut sys = SimSystem::new(paper_testbed(), 73);
        sys.tb.store.set_quota("stampede-scratch", Some(Bytes::gb(1))).unwrap();
        let ens = small_ensemble(); // 8 GiB reference
        let du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
        sys.run().unwrap();
        assert_eq!(sys.capacity_rejections, 0);
        sys.replicate(&du, "stampede-scratch").unwrap();
        sys.run().unwrap();
        assert!(!sys.tb.store.has_replica(&du, "stampede-scratch"));
        assert_eq!(sys.capacity_rejections, 1);
        assert_eq!(sys.tb.store.used("stampede-scratch"), Bytes::b(0));
        // The replica index never learned the failed placement.
        assert_eq!(sys.state.du_locations()[&du].len(), 1);
    }

    /// Wire-byte accounting: an upload and a remote stage-in count
    /// their DU sizes; co-located staging moves nothing.
    #[test]
    fn bytes_moved_counts_wire_transfers_only() {
        let mut sys = SimSystem::new(paper_testbed(), 77);
        // Exact-byte assertions: a faulty transfer would add partial
        // wire bytes for the failed attempt plus the retry's full copy.
        sys.zero_transfer_faults();
        let ens = small_ensemble();
        let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
        sys.run().unwrap();
        let after_upload = sys.bytes_moved().as_u64();
        assert_eq!(after_upload, Bytes::gb(8).as_u64(), "upload must count its bytes");
        sys.submit_pilot("lonestar", 4, "lonestar-scratch").unwrap();
        let mut cud = ens.cu_template.clone();
        cud.input_data = vec![ref_du.clone()];
        sys.submit_cu(cud).unwrap();
        sys.run().unwrap();
        assert!(sys.state.workload_finished());
        assert_eq!(
            sys.bytes_moved().as_u64(),
            after_upload,
            "co-located staging must not count wire bytes"
        );
        // A remote pilot stages the same DU over the wire.
        sys.submit_pilot("stampede", 4, "stampede-scratch").unwrap();
        let mut cud = ens.cu_template.clone();
        cud.input_data = vec![ref_du.clone()];
        cud.affinity = Some(Label::new("xsede/tacc/stampede"));
        sys.submit_cu(cud).unwrap();
        sys.run().unwrap();
        assert!(sys.state.workload_finished());
        assert_eq!(sys.bytes_moved().as_u64(), after_upload + Bytes::gb(8).as_u64());
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed| {
            let mut sys = SimSystem::new(paper_testbed(), seed);
            let ens = small_ensemble();
            let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
            sys.run().unwrap();
            sys.submit_pilot("lonestar", 16, "lonestar-scratch").unwrap();
            let mut cud = ens.cu_template.clone();
            cud.input_data = vec![ref_du];
            sys.submit_cu(cud).unwrap();
            sys.run().unwrap();
            sys.makespan()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }

    /// The incrementally maintained queue-depth counters must stay in
    /// lockstep with the coordination store's actual queue lengths.
    #[test]
    fn queue_depth_counters_match_store() {
        let mut sys = SimSystem::new(paper_testbed(), 7);
        let ens = small_ensemble();
        let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
        let mut chunks = Vec::new();
        for c in &ens.read_chunks {
            chunks.push(sys.upload_du(c, "lonestar-scratch").unwrap());
        }
        sys.run().unwrap();
        let p = sys.submit_pilot("lonestar", 4, "lonestar-scratch").unwrap();
        sys.run().unwrap(); // pilot reaches Active so placement binds to it
        for chunk in &chunks {
            let mut cud = ens.cu_template.clone();
            cud.input_data = vec![ref_du.clone(), chunk.clone()];
            sys.submit_cu(cud).unwrap();
        }
        // 4-core pilot, 2-core CUs: two CUs bind to the agent queue
        // (effective slots), the rest overflow to the global queue.
        let counter = sys.state.queue_depths().get(&p).copied().unwrap_or(0);
        let actual = sys.store.llen(&keys::pilot_queue(&p)).unwrap();
        assert_eq!(counter, actual, "mid-run counter drift");
        assert_eq!(counter, 2, "effective-slot binding changed");
        sys.run().unwrap();
        assert!(sys.state.workload_finished());
        let counter = sys.state.queue_depths().get(&p).copied().unwrap_or(0);
        let actual = sys.store.llen(&keys::pilot_queue(&p)).unwrap();
        assert_eq!(counter, actual, "post-run counter drift");
        assert_eq!(actual, 0);
        assert_eq!(sys.store.llen(keys::GLOBAL_QUEUE).unwrap(), 0);
    }

    /// A hard pilot failure mid-CU re-dispatches the in-flight CUs to
    /// the surviving pilot (bounded by `max_redispatches`) and leaves
    /// the pilot `Failed`, not `Done`.
    #[test]
    fn pilot_hard_failure_redispatches_in_flight_cus() {
        let mut sys = SimSystem::new(paper_testbed(), 23);
        let ens = small_ensemble();
        let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
        sys.run().unwrap();
        let p1 = sys.submit_pilot("lonestar", 16, "lonestar-scratch").unwrap();
        sys.submit_pilot("stampede", 16, "stampede-scratch").unwrap();
        for chunk_descr in &ens.read_chunks {
            let chunk = sys.upload_du(chunk_descr, "lonestar-scratch").unwrap();
            let mut cud = ens.cu_template.clone();
            cud.input_data = vec![ref_du.clone(), chunk];
            sys.submit_cu(cud).unwrap();
        }
        sys.fail_pilot_at(&p1, 3000.0);
        sys.run().unwrap();
        assert!(sys.state.workload_finished());
        assert_eq!(sys.state.count_cu_state(CuState::Done), 4);
        assert_eq!(sys.state.pilots[&p1].state, PilotState::Failed);
        assert_eq!(sys.pilot_failures, 1);
        assert!(sys.total_redispatches() >= 1, "no CU was re-dispatched");
        let on_stampede = sys
            .metrics
            .cu_records
            .iter()
            .filter(|r| r.machine == "stampede")
            .count();
        assert!(on_stampede >= 1, "records={:?}", sys.metrics.distribution());
    }

    /// A PD down→up cycle under AutoReplicate: the outage drops the
    /// replica, recovery publishes availability and the policy re-fills
    /// the replica target onto the recovered (empty) storage.
    #[test]
    fn pd_down_up_cycle_refills_replicas_on_recovery() {
        use crate::datamgmt::AutoReplicate;
        let mut sys = SimSystem::new(paper_testbed(), 25)
            .with_mode(Box::new(AutoReplicate { replicas: 2 }));
        sys.zero_transfer_faults();
        let ens = small_ensemble();
        let du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
        sys.run().unwrap();
        // Two sites: the policy's only top-up target is stampede.
        sys.submit_pilot("lonestar", 16, "lonestar-scratch").unwrap();
        sys.submit_pilot("stampede", 16, "stampede-scratch").unwrap();
        sys.run().unwrap();
        assert!(sys.tb.store.has_replica(&du, "stampede-scratch"));
        let t = sys.sim.now();
        sys.fail_pd_at("stampede-scratch", t + 10.0);
        sys.run().unwrap();
        // With lonestar the only live site, the loss is irreparable.
        assert_eq!(sys.tb.store.replica_count(&du), 1);
        let t = sys.sim.now();
        sys.recover_pd_at("stampede-scratch", t + 100.0);
        sys.run().unwrap();
        assert!(!sys.tb.store.pd_is_down("stampede-scratch"));
        assert!(
            sys.tb.store.has_replica(&du, "stampede-scratch"),
            "recovery must trigger the policy's re-fill"
        );
        assert_eq!(sys.tb.store.replica_count(&du), 2);
    }

    /// In-DES transfer retries: a link that always fails exhausts the
    /// retry budget inside simulated time, ends every flow cleanly,
    /// and leaves no replica behind.
    #[test]
    fn transfer_retries_run_inside_sim_time_and_end_flows() {
        let mut sys = SimSystem::new(paper_testbed(), 27);
        sys.zero_transfer_faults(); // isolate the injected link fault
        let ens = small_ensemble();
        let du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
        sys.run().unwrap();
        sys.tb.net.set_link_failure_rate("xsede/tacc/stampede", 1.0);
        sys.replicate(&du, "stampede-scratch").unwrap();
        let t0 = sys.sim.now();
        sys.run().unwrap();
        assert!(!sys.tb.store.has_replica(&du, "stampede-scratch"));
        assert_eq!(
            sys.transfer_retries,
            sys.retry.max_attempts - 1,
            "every spare attempt must re-issue"
        );
        assert_eq!(sys.tb.net.total_live_flows(), 0, "failed attempts must end their flows");
        // Partial wire time plus two exponential backoffs elapsed.
        assert!(sys.sim.now() > t0 + sys.retry.backoff_s * 3.0);
    }

    /// Fault-free, the in-DES retry engine must be bit-identical to
    /// the seed's statistical shortcut it replaced: same RNG draws,
    /// same event times, same placements, same bytes.
    #[test]
    fn fault_free_in_des_matches_aggregate_reference() {
        let run = |aggregate: bool| {
            let mut sys = SimSystem::new(paper_testbed(), 33);
            if aggregate {
                sys = sys.with_aggregate_retry_reference();
            }
            sys.zero_transfer_faults();
            let ens = small_ensemble();
            let ref_du = sys.upload_du(&ens.reference, "osg-srm").unwrap();
            let mut chunks = Vec::new();
            for c in &ens.read_chunks {
                chunks.push(sys.upload_du(c, "lonestar-scratch").unwrap());
            }
            sys.run().unwrap();
            sys.submit_pilot("lonestar", 8, "lonestar-scratch").unwrap();
            sys.submit_pilot("stampede", 8, "stampede-scratch").unwrap();
            for chunk in &chunks {
                let mut cud = ens.cu_template.clone();
                cud.input_data = vec![ref_du.clone(), chunk.clone()];
                sys.submit_cu(cud).unwrap();
            }
            sys.run().unwrap();
            assert!(sys.state.workload_finished());
            let trace: Vec<(String, f64, f64, f64)> = sys
                .metrics
                .cu_records
                .iter()
                .map(|r| (r.machine.clone(), r.t_start, r.t_end, r.staging_s))
                .collect();
            (trace, sys.makespan(), sys.bytes_moved().as_u64())
        };
        assert_eq!(run(false), run(true));
    }

    /// Satellite (b) end to end: on a quota-tight site the capacity
    /// feed steers placements away, so staging stops slamming into the
    /// full PD — `capacity_rejections` drops versus the blind run.
    #[test]
    fn capacity_aware_scheduling_cuts_capacity_rejections() {
        let run = |aware: bool| {
            let mut sys = SimSystem::new(paper_testbed(), 35);
            sys.capacity_aware_scheduling = aware;
            sys.zero_transfer_faults();
            sys.tb.store.set_quota("stampede-scratch", Some(Bytes::gb(1))).unwrap();
            let ens = small_ensemble(); // 8 GiB reference
            // Data at the gateway: equidistant from both sites, so the
            // blind tie-break (free slots) prefers the bigger stampede
            // pilot whose 1 GiB scratch can never admit the stage-in.
            let du = sys.upload_du(&ens.reference, "gw68-staging").unwrap();
            sys.run().unwrap();
            sys.submit_pilot("lonestar", 4, "lonestar-scratch").unwrap();
            sys.submit_pilot("stampede", 16, "stampede-scratch").unwrap();
            sys.run().unwrap();
            for _ in 0..2 {
                let mut cud = ens.cu_template.clone();
                cud.input_data = vec![du.clone()];
                sys.submit_cu(cud).unwrap();
            }
            sys.run().unwrap();
            assert!(sys.state.workload_finished());
            sys.capacity_rejections
        };
        let blind = run(false);
        let aware = run(true);
        assert!(blind >= 1, "blind run must hit the quota (got {blind})");
        assert_eq!(aware, 0, "capacity-aware run must avoid the full site");
    }

    /// Acceptance scenario: a two-site workload survives a mid-CU
    /// pilot kill, a PD down→up cycle, and lossy links — every CU
    /// completes exactly once and all flows drain.
    #[test]
    fn chaos_two_site_run_completes_with_zero_lost_cus() {
        use crate::datamgmt::AutoReplicate;
        let mut sys = SimSystem::new(paper_testbed(), 37)
            .with_mode(Box::new(AutoReplicate { replicas: 2 }));
        let ens = small_ensemble();
        let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
        let mut chunks = Vec::new();
        for c in &ens.read_chunks {
            chunks.push(sys.upload_du(c, "lonestar-scratch").unwrap());
        }
        sys.run().unwrap();
        sys.submit_pilot("lonestar", 16, "lonestar-scratch").unwrap();
        let p2 = sys.submit_pilot("stampede", 16, "stampede-scratch").unwrap();
        for chunk in &chunks {
            let mut cud = ens.cu_template.clone();
            cud.input_data = vec![ref_du.clone(), chunk.clone()];
            sys.submit_cu(cud).unwrap();
        }
        let plan = ChaosPlan {
            pilot_kills: vec![(p2.clone(), 4000.0)],
            pd_down: vec![("stampede-scratch".into(), 2000.0)],
            pd_up: vec![("stampede-scratch".into(), 6000.0)],
            link_faults: vec![("xsede/tacc/stampede".into(), 0.2)],
        };
        sys.apply_chaos(&plan);
        sys.run().unwrap();
        assert!(sys.state.workload_finished());
        assert_eq!(sys.state.count_cu_state(CuState::Done), 4, "lost CUs");
        assert_eq!(sys.state.pilots[&p2].state, PilotState::Failed);
        assert_eq!(sys.tb.net.total_live_flows(), 0, "leaked flows");
        // Exactly one completion record per CU.
        let mut seen = std::collections::BTreeSet::new();
        for r in &sys.metrics.cu_records {
            assert!(seen.insert(r.cu.clone()), "CU {} completed twice", r.cu);
        }
        assert_eq!(seen.len(), 4);
    }
}
