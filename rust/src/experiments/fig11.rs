//! Figs. 11, 12, 13: large-scale distributed genome sequencing —
//! 1024 BWA tasks, each consuming 9 GB (8 GB shared reference + 1 GB
//! read chunk), 2 cores per task, on up to three XSEDE machines:
//!
//! 1. Lonestar only (I/O saturation on the shared filesystem);
//! 2. Lonestar + Stampede, no replication (remote tasks must move
//!    9 GB each — only a trickle executes on Stampede);
//! 3. Lonestar + Stampede with up-front reference replication
//!    (Stampede's share jumps to ≈40 % despite an ≈8100 s queue wait);
//! 4. Lonestar + Stampede + Trestles (WAN), replication everywhere —
//!    better than a single machine, worse than scenario 3, with high
//!    per-CU variance (Fig. 13 timeline).

use crate::batch::QueueModel;
use crate::config::paper_testbed;
use crate::experiments::simdrive::SimSystem;
use crate::metrics::{Table, TimelineEvent};
use crate::util::Bytes;
use crate::workload::bwa_ensemble;

pub const SCENARIOS: [&str; 4] = [
    "1: lonestar",
    "2: lonestar+stampede",
    "3: +stampede, replicated",
    "4: 3 machines, replicated",
];

pub struct ScaleResult {
    pub t_total: f64,
    pub distribution: std::collections::BTreeMap<String, usize>,
    pub runtime_stats: std::collections::BTreeMap<String, (f64, f64)>,
    pub metrics: crate::metrics::RunMetrics,
}

/// Run one Fig. 11 scenario. `tasks` is parameterized so benches can
/// run smaller instances with the same shape (paper: 1024).
pub fn run_scenario(scenario: usize, seed: u64, tasks: usize) -> anyhow::Result<ScaleResult> {
    let mut sys = SimSystem::new(paper_testbed(), seed);
    // Stampede's observed queue waits differed wildly between the
    // paper's runs; replay them, scaled to the instance size so small
    // bench/test runs keep the same shape as the 1024-task original.
    let scale = tasks as f64 / FULL_TASKS as f64;
    match scenario {
        2 => sys.tb.batch.set_queue("stampede", QueueModel::with_mean(60.0, 400.0 * scale, 0.7))?,
        3 => sys
            .tb
            .batch
            .set_queue("stampede", QueueModel::with_mean(60.0, 8100.0 * scale, 0.5))?,
        4 => {
            // Fig. 13's run: "Stampede represented a significant
            // bottleneck"; Trestles' queue time fluctuated strongly
            // and its CUs run slowest — they form the straggler tail
            // that puts scenario 4 behind scenario 3.
            sys.tb
                .batch
                .set_queue("stampede", QueueModel::with_mean(60.0, 8100.0 * scale, 0.5))?;
            sys.tb
                .batch
                .set_queue("trestles", QueueModel::with_mean(60.0, 4000.0 * scale, 1.0))?;
            // Loaded Trestles ran CUs much slower than the TACC
            // machines ("the more CUs ... the slower the average
            // runtime of each CU").
            sys.tb.batch.set_speed_factor("trestles", 1.55)?;
        }
        _ => {}
    }
    // BigJob agents drive a couple of remote stagings at a time.
    sys.max_concurrent_staging = 2;

    let ens = bwa_ensemble(tasks, Bytes::gb(tasks as u64), Bytes::gb(8));

    // Data starts resident on Lonestar's scratch (pre-staged).
    let ref_du = sys.place_du_instant(&ens.reference, "lonestar-scratch")?;
    let chunk_dus: Vec<String> = ens
        .read_chunks
        .iter()
        .map(|c| sys.place_du_instant(c, "lonestar-scratch"))
        .collect::<anyhow::Result<Vec<_>>>()?;

    // Up-front replication of the shared reference.
    if scenario >= 3 {
        sys.replicate(&ref_du, "stampede-scratch")?;
    }
    if scenario == 4 {
        sys.replicate(&ref_du, "trestles-scratch")?;
    }
    sys.run()?; // land replication before compute starts (paper: "before the Pilot-Computes and tasks are started")
    let repl_s = sys.sim.now();

    // Pilots: the paper requests a pilot of `tasks` cores (1024) on
    // each machine in play -> at most tasks/2 concurrent 2-core CUs.
    let cores = (tasks as u32).max(8);
    sys.submit_pilot("lonestar", cores, "lonestar-scratch")?;
    if scenario >= 2 {
        sys.submit_pilot("stampede", cores, "stampede-scratch")?;
    }
    if scenario == 4 {
        sys.submit_pilot("trestles", cores, "trestles-scratch")?;
    }

    for chunk in &chunk_dus {
        let mut cud = ens.cu_template.clone();
        cud.input_data = vec![ref_du.clone(), chunk.clone()];
        sys.submit_cu(cud)?;
    }
    sys.run()?;
    anyhow::ensure!(sys.state.workload_finished(), "workload did not finish");
    let mut metrics = sys.metrics.clone();
    metrics.set_scalar("replication_s", repl_s);
    Ok(ScaleResult {
        t_total: metrics.makespan(),
        distribution: metrics.distribution(),
        runtime_stats: metrics.runtime_stats(),
        metrics,
    })
}

/// Default task count for the full reproduction (paper: 1024).
pub const FULL_TASKS: usize = 1024;

pub fn run_fig11(seed: u64) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 11: overall runtime T, 1024 tasks x 9 GB, up to 3 XSEDE machines",
        &["scenario", "T (s)", "lonestar", "stampede", "trestles"],
    );
    for (i, name) in SCENARIOS.iter().enumerate() {
        let r = run_scenario(i + 1, seed, FULL_TASKS)?;
        let d = |m: &str| r.distribution.get(m).copied().unwrap_or(0).to_string();
        t.row(vec![
            name.to_string(),
            format!("{:.0}", r.t_total),
            d("lonestar"),
            d("stampede"),
            d("trestles"),
        ]);
    }
    Ok(vec![t])
}

pub fn run_fig12(seed: u64) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 12: per-machine CU runtimes (mean ± std) and distribution",
        &["scenario", "machine", "tasks", "runtime mean (s)", "runtime std (s)"],
    );
    for (i, name) in SCENARIOS.iter().enumerate() {
        let r = run_scenario(i + 1, seed, FULL_TASKS)?;
        for (machine, count) in &r.distribution {
            let (mean, std) = r.runtime_stats[machine];
            t.row(vec![
                name.to_string(),
                machine.clone(),
                count.to_string(),
                format!("{mean:.0}"),
                format!("{std:.0}"),
            ]);
        }
    }
    Ok(vec![t])
}

pub fn run_fig13(seed: u64) -> anyhow::Result<Vec<Table>> {
    // Scenario 4 timeline, sampled at fixed intervals.
    let r = run_scenario(4, seed, FULL_TASKS)?;
    let m = &r.metrics;
    let active = m.active_curve();
    let machines = ["lonestar", "stampede", "trestles"];
    let finished: Vec<(&str, Vec<(f64, u64)>)> =
        machines.iter().map(|mm| (*mm, m.finished_curve(mm))).collect();
    let horizon = r.t_total.max(1.0);
    let mut t = Table::new(
        "Fig 13: time series, 3-machine run (active CUs + cumulative finished per machine)",
        &["t (s)", "active CUs", "done lonestar", "done stampede", "done trestles"],
    );
    let samples = 24;
    for i in 0..=samples {
        let ts = horizon * i as f64 / samples as f64;
        let active_at = active
            .iter()
            .take_while(|(x, _)| *x <= ts)
            .last()
            .map(|(_, v)| *v)
            .unwrap_or(0);
        let mut row = vec![format!("{ts:.0}"), active_at.to_string()];
        for (_, curve) in &finished {
            let done = curve
                .iter()
                .take_while(|(x, _)| *x <= ts)
                .last()
                .map(|(_, v)| *v)
                .unwrap_or(0);
            row.push(done.to_string());
        }
        t.row(row);
    }
    // Pilot activation times (the Fig. 13 "Pilot N becomes active" marks).
    let mut marks = Table::new("Fig 13 marks: pilot activation times", &["machine", "t_active (s)"]);
    for (ts, who, ev) in &m.timeline {
        if *ev == TimelineEvent::PilotActive {
            marks.row(vec![who.clone(), format!("{ts:.0}")]);
        }
    }
    Ok(vec![t, marks])
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full paper scale (1024 tasks); the sim replays it in well under a
    // second per scenario. Scenario comparisons average a few seeds, as
    // the paper's reported numbers do.
    const N: usize = FULL_TASKS;

    fn avg_t(scenario: usize, seeds: &[u64]) -> f64 {
        seeds
            .iter()
            .map(|s| run_scenario(scenario, *s, N).unwrap().t_total)
            .sum::<f64>()
            / seeds.len() as f64
    }

    const SEEDS: [u64; 3] = [42, 43, 44];

    #[test]
    fn two_machines_beat_one() {
        let one = avg_t(1, &SEEDS);
        let two = avg_t(2, &SEEDS);
        assert!(two < one, "two={two} one={one}");
    }

    #[test]
    fn replication_beats_no_replication_share() {
        let share = |scenario: usize| -> f64 {
            SEEDS
                .iter()
                .map(|s| {
                    let r = run_scenario(scenario, *s, N).unwrap();
                    r.distribution.get("stampede").copied().unwrap_or(0) as f64
                        / r.distribution.values().sum::<usize>() as f64
                })
                .sum::<f64>()
                / SEEDS.len() as f64
        };
        let (s_no, s_yes) = (share(2), share(3));
        // Paper: ~5% without replication vs ~40% with.
        assert!(s_no < 0.15, "no-replication stampede share {s_no}");
        assert!(s_yes > 1.8 * s_no.max(0.01), "share did not improve: {s_no} -> {s_yes}");
        assert!(s_yes > 0.12, "replicated share only {s_yes}");
    }

    #[test]
    fn replication_beats_no_replication_runtime() {
        let t2 = avg_t(2, &SEEDS);
        let t3 = avg_t(3, &SEEDS);
        assert!(t3 < t2, "t3={t3} t2={t2}");
    }

    #[test]
    fn wan_scenario_between_single_and_best() {
        // Paper: scenario 4 is ~6000 s behind the best case (3) but
        // still beats the single-resource run (1).
        let one = avg_t(1, &SEEDS);
        let three = avg_t(3, &SEEDS);
        let wan = avg_t(4, &SEEDS);
        assert!(wan < one, "wan={wan} one={one}");
        assert!(wan > three, "wan={wan} three={three}");
    }

    #[test]
    fn io_contention_slows_single_machine_tasks() {
        // Scenario 1 runs everything concurrently on Lonestar: per-CU
        // runtimes must clearly exceed the uncontended compute time.
        let r = run_scenario(1, 37, N).unwrap();
        let (mean, _) = r.runtime_stats["lonestar"];
        let uncontended = crate::config::bwa_cpu_secs_per_chunk() * 4.0; // 1 GB chunk
        assert!(mean > 1.1 * uncontended, "mean={mean} uncontended={uncontended}");
    }

    #[test]
    fn timeline_has_activity_for_all_three_machines() {
        let r = run_scenario(4, 42, N).unwrap();
        for m in ["lonestar", "stampede", "trestles"] {
            assert!(
                r.metrics.timeline.iter().any(|(_, who, _)| who == m),
                "no timeline events for {m}"
            );
        }
        let curve = r.metrics.active_curve();
        let peak = curve.iter().map(|(_, v)| *v).max().unwrap_or(0);
        assert!(peak > 8, "peak concurrency {peak}");
        // Curve returns to zero at the end.
        assert_eq!(curve.last().unwrap().1, 0);
    }
}
