//! Three-backend comparison (experiment id `backends`): the same
//! two-site workload run with the TACC scratch Pilot-Data mapped onto
//! each storage backend class — parallel filesystem, object store,
//! node-local disk — with and without the scheduler's delay-scheduling
//! locality wait.
//!
//! The scenario is the locality trade-off the paper's heterogeneous
//! follow-ups (Pilot-Abstraction on HPC/Hadoop/Cloud; Hadoop on HPC)
//! evaluate: all input data sits on Lonestar's scratch, and the fleet
//! has more compute than Lonestar alone can serve. Without a locality
//! wait the overflow tasks spill to Stampede and drag the 8 GiB
//! reference across the interconnect per task; with a wait budget they
//! park until Lonestar's slots turn over and run data-local. The
//! backend profile decides what the spilled bytes *cost*: free on
//! parallel-fs/node-local, real dollars (plus a fixed per-attempt
//! latency and a bandwidth cap) on the object store.
//!
//! Per `(backend, wait)` cell the table reports completed CUs,
//! makespan, wire bytes, backend dollars, and mean staging time. The
//! headline invariant — delay scheduling moves fewer bytes at equal
//! 8/8 completion — is asserted by this module's tests and smoked in
//! CI by `benches/backends.rs` (`BENCH_backends.json`).

use crate::config::{paper_testbed, Testbed};
use crate::experiments::simdrive::SimSystem;
use crate::metrics::Table;
use crate::storage::{BackendClass, BackendProfile};
use crate::util::Bytes;
use crate::workload::bwa_ensemble;

/// Number of BWA tasks in the comparison workload.
pub const TASKS: usize = 8;

/// Locality-wait budget (simulated seconds) used by the "wait" rows:
/// generous enough that Lonestar's first task wave (≈1 h of compute)
/// finishes inside it, so parked tasks re-place onto freed local slots
/// instead of giving up and going remote.
pub const WAIT_S: f64 = 7200.0;

/// Map the two TACC scratch PDs onto one backend class. `ParallelFs`
/// applies the uniform default profile, so that row doubles as the
/// bit-identical pre-profile baseline (`SimStore::heterogeneous()`
/// stays false and no pricing path changes).
pub fn apply_backend(tb: &mut Testbed, class: BackendClass) {
    let profile = match class {
        BackendClass::ParallelFs => BackendProfile::parallel_fs(),
        BackendClass::ObjectStore => BackendProfile::object_store(),
        BackendClass::NodeLocal => BackendProfile::node_local(),
    };
    for pd in ["lonestar-scratch", "stampede-scratch"] {
        tb.store.set_profile(pd, profile).expect("testbed scratch PD exists");
    }
}

/// Result of one `(backend, wait)` cell.
pub struct BackendRun {
    pub class: BackendClass,
    /// Locality-wait budget, `None` for the no-wait baseline.
    pub wait_s: Option<f64>,
    pub done: usize,
    pub makespan: f64,
    pub bytes_moved: Bytes,
    pub dollars: f64,
    pub staging_mean: f64,
}

/// Run the two-site overflow workload on one backend class, with or
/// without the locality wait. Transfer faults are zeroed so byte and
/// dollar totals are exact per seed.
pub fn run_case(
    class: BackendClass,
    wait_s: Option<f64>,
    seed: u64,
) -> anyhow::Result<BackendRun> {
    let mut tb = paper_testbed();
    apply_backend(&mut tb, class);
    let mut sys = SimSystem::new(tb, seed);
    if let Some(w) = wait_s {
        sys = sys.with_locality_wait(w);
    }
    sys.zero_transfer_faults();

    // All data lands on Lonestar's scratch: the only data-local site.
    let ens = bwa_ensemble(TASKS, Bytes::gb(2), Bytes::gb(8));
    let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch")?;
    let mut chunk_dus = Vec::new();
    for c in &ens.read_chunks {
        chunk_dus.push(sys.upload_du(c, "lonestar-scratch")?);
    }
    sys.run()?;

    // More fleet than the data site can serve at once: Lonestar fits 4
    // concurrent 2-core tasks, Stampede idles next to it as the
    // remote overflow target.
    sys.submit_pilot("lonestar", 8, "lonestar-scratch")?;
    sys.submit_pilot("stampede", 8, "stampede-scratch")?;
    sys.run()?; // both pilots Active before any CU places

    for chunk in &chunk_dus {
        let mut cud = ens.cu_template.clone();
        cud.cores = 2;
        cud.input_data = vec![ref_du.clone(), chunk.clone()];
        sys.submit_cu(cud)?;
    }
    sys.run()?;
    anyhow::ensure!(
        sys.state.workload_finished(),
        "workload did not finish ({class}, wait {wait_s:?})"
    );

    let staging: Vec<f64> = sys.metrics.cu_records.iter().map(|r| r.staging_s).collect();
    Ok(BackendRun {
        class,
        wait_s,
        done: sys.state.count_cu_state(crate::unit::CuState::Done),
        makespan: sys.metrics.makespan(),
        bytes_moved: sys.bytes_moved(),
        dollars: sys.dollars_spent(),
        staging_mean: crate::util::mean(&staging),
    })
}

/// All six cells: each backend class, no-wait then wait.
pub fn run_all(seed: u64) -> anyhow::Result<Vec<BackendRun>> {
    let mut out = Vec::new();
    for class in [BackendClass::ParallelFs, BackendClass::ObjectStore, BackendClass::NodeLocal] {
        out.push(run_case(class, None, seed)?);
        out.push(run_case(class, Some(WAIT_S), seed)?);
    }
    Ok(out)
}

/// The backend-comparison table (experiment id `backends`).
pub fn run(seed: u64) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Storage backends x delay scheduling: 2-site BWA overflow, 8 tasks + 8 GiB reference",
        &["backend", "wait (s)", "done", "T (s)", "bytes moved", "dollars", "staging mean (s)"],
    );
    for r in run_all(seed)? {
        t.row(vec![
            format!("{}", r.class),
            r.wait_s.map_or("0".to_string(), |w| format!("{w:.0}")),
            format!("{}/{}", r.done, TASKS),
            format!("{:.0}", r.makespan),
            format!("{}", r.bytes_moved),
            format!("{:.2}", r.dollars),
            format!("{:.0}", r.staging_mean),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance invariant behind `BENCH_backends.json`: on the
    /// node-local testbed, delay scheduling completes the same 8/8
    /// tasks while moving strictly fewer bytes than the no-wait
    /// baseline (the parked tasks run data-local instead of dragging
    /// the reference to Stampede).
    #[test]
    fn delay_scheduling_cuts_bytes_at_equal_completion() {
        let base = run_case(BackendClass::NodeLocal, None, 11).unwrap();
        let wait = run_case(BackendClass::NodeLocal, Some(WAIT_S), 11).unwrap();
        assert_eq!(base.done, TASKS, "no-wait baseline must finish 8/8");
        assert_eq!(wait.done, TASKS, "delay-scheduled run must finish 8/8");
        assert!(
            wait.bytes_moved.as_u64() < base.bytes_moved.as_u64(),
            "waiting moved {} bytes, no-wait {} — delay scheduling saved nothing",
            wait.bytes_moved,
            base.bytes_moved
        );
    }

    /// Dollar accounting: the object-store rows pay for every wire
    /// byte that touches a priced endpoint, so the no-wait spill costs
    /// strictly more than the data-local wait run; the free backends
    /// cost exactly 0.
    #[test]
    fn object_store_prices_the_spilled_bytes() {
        let base = run_case(BackendClass::ObjectStore, None, 11).unwrap();
        let wait = run_case(BackendClass::ObjectStore, Some(WAIT_S), 11).unwrap();
        assert!(base.dollars > 0.0, "spilled bytes into a priced store cost nothing");
        assert!(
            wait.dollars < base.dollars,
            "wait run ${} !< no-wait ${}",
            wait.dollars,
            base.dollars
        );
        let free = run_case(BackendClass::ParallelFs, None, 11).unwrap();
        assert_eq!(free.dollars, 0.0, "uniform backend must accrue no dollars");
        let local = run_case(BackendClass::NodeLocal, None, 11).unwrap();
        assert_eq!(local.dollars, 0.0, "node-local backend is unpriced");
    }

    /// The parallel-fs no-wait cell is the uniform baseline: its
    /// profile is the no-op default, so `heterogeneous()` stays false
    /// and the run is byte-identical to a plain unprofiled system.
    #[test]
    fn parallel_fs_cell_matches_the_unprofiled_baseline() {
        let profiled = run_case(BackendClass::ParallelFs, None, 17).unwrap();
        // Same workload, no profile application at all.
        let mut sys = SimSystem::new(paper_testbed(), 17);
        sys.zero_transfer_faults();
        let ens = bwa_ensemble(TASKS, Bytes::gb(2), Bytes::gb(8));
        let ref_du = sys.upload_du(&ens.reference, "lonestar-scratch").unwrap();
        let mut chunks = Vec::new();
        for c in &ens.read_chunks {
            chunks.push(sys.upload_du(c, "lonestar-scratch").unwrap());
        }
        sys.run().unwrap();
        sys.submit_pilot("lonestar", 8, "lonestar-scratch").unwrap();
        sys.submit_pilot("stampede", 8, "stampede-scratch").unwrap();
        sys.run().unwrap();
        for chunk in &chunks {
            let mut cud = ens.cu_template.clone();
            cud.cores = 2;
            cud.input_data = vec![ref_du.clone(), chunk.clone()];
            sys.submit_cu(cud).unwrap();
        }
        sys.run().unwrap();
        assert!(sys.state.workload_finished());
        assert_eq!(profiled.bytes_moved.as_u64(), sys.bytes_moved().as_u64());
        assert_eq!(profiled.makespan.to_bits(), sys.makespan().to_bits());
        assert_eq!(profiled.dollars, 0.0);
    }

    #[test]
    fn backends_table_renders_and_is_deterministic() {
        let a = run(3).unwrap();
        let b = run(3).unwrap();
        assert_eq!(a[0].rows.len(), 6);
        assert_eq!(a[0].render(), b[0].render(), "backends table drifted between runs");
        assert!(a[0].render().contains("object-store"));
        assert!(a[0].render().contains("node-local"));
    }
}
