//! Fig. 8: Using replication on OSG — T_R for (i) iRODS group-based
//! replication over the 9-node osgGridFtpGroup, (ii) iRODS sequential
//! replication over 6 nodes, (iii) SRM sequential replication over 6
//! nodes; dataset sizes 1/2/4 GB. Inset: distribution of per-host T_X
//! for the 4 GB group scenario.
//!
//! Expected shape (paper): group ≪ sequential; sequential SRM <
//! sequential iRODS (iRODS adds management overhead); failures leave
//! ≈7.5 of 9 group members with a replica on average.

use crate::config::{paper_testbed, OSG_SITES};
use crate::experiments::simdrive::SimSystem;
use crate::faults::RetryPolicy;
use crate::metrics::Table;
use crate::unit::{DataUnitDescription, FileRef};
use crate::util::Bytes;

fn dataset(size: Bytes) -> DataUnitDescription {
    DataUnitDescription {
        name: "fig8-dataset".into(),
        files: (0..8).map(|i| FileRef::sized(&format!("part{i}"), Bytes(size.0 / 8))).collect(),
        affinity: None,
    }
}

/// Group replication: seed the central server, then replicate to all
/// group members concurrently. Returns (T_R, replicas achieved, per-host T_X).
pub fn group_replication(seed: u64, size: Bytes) -> anyhow::Result<(f64, usize, Vec<(String, f64)>)> {
    let mut sys = SimSystem::new(paper_testbed(), seed);
    // Seed the central server reliably, then replicate with no retry
    // (the paper's replication runs saw the raw failure rate).
    let du = sys.upload_du(&dataset(size), "irods-fnal")?;
    sys.run()?;
    anyhow::ensure!(sys.tb.store.has_replica(&du, "irods-fnal"), "seed upload failed");
    sys.retry = RetryPolicy::none();
    let t0 = sys.sim.now();
    sys.replicate_group(&du, "osgGridFtpGroup")?;
    sys.run()?;
    let tr = sys.sim.now() - t0;
    let replicas = sys.tb.store.replicas(&du).len();
    let mut per_host = Vec::new();
    for site in OSG_SITES {
        let t = sys.metrics.scalar(&format!("staged:{du}:irods-{site}"));
        // Skip the source host (fnal holds the seed replica, T_X = 0).
        if t.is_finite() && t - t0 > 0.0 {
            per_host.push((site.to_string(), t - t0));
        }
    }
    Ok((tr, replicas, per_host))
}

/// Sequential replication to `n` members of the given backend family
/// ("irods-" or "srm-"): one replica finishes before the next starts.
pub fn sequential_replication(seed: u64, size: Bytes, prefix: &str, n: usize) -> anyhow::Result<f64> {
    let mut sys = SimSystem::new(paper_testbed(), seed);
    let first = format!("{prefix}{}", OSG_SITES[3]); // fnal hosts the source
    let du = sys.upload_du(&dataset(size), &first)?;
    sys.run()?;
    anyhow::ensure!(sys.tb.store.has_replica(&du, &first), "seed upload failed");
    sys.retry = RetryPolicy::none();
    let t0 = sys.sim.now();
    for site in OSG_SITES.iter().filter(|s| **s != OSG_SITES[3]).take(n) {
        sys.replicate(&du, &format!("{prefix}{site}"))?;
        sys.run()?; // sequential: wait for this replica before the next
    }
    Ok(sys.sim.now() - t0)
}

pub fn run(seed: u64) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 8: T_R on OSG (seconds)",
        &["size", "iRODS group (9)", "iRODS sequential (6)", "SRM sequential (6)", "group replicas"],
    );
    for gb in [1u64, 2, 4] {
        let size = Bytes::gb(gb);
        let (grp, replicas, _) = group_replication(seed, size)?;
        let seq_irods = sequential_replication(seed + 1, size, "irods-", 6)?;
        let seq_srm = sequential_replication(seed + 2, size, "srm-", 6)?;
        t.row(vec![
            format!("{size}"),
            format!("{grp:.0}"),
            format!("{seq_irods:.0}"),
            format!("{seq_srm:.0}"),
            format!("{replicas}/9"),
        ]);
    }

    // Inset: per-host T_X distribution for the 4 GB group scenario.
    let (_, _, per_host) = group_replication(seed + 3, Bytes::gb(4))?;
    let mut inset = Table::new(
        "Fig 8 inset: per-host T_X, 4 GB, iRODS group replication",
        &["host", "T_X (s)"],
    );
    for (host, tx) in per_host {
        inset.row(vec![host, format!("{tx:.0}")]);
    }
    Ok(vec![t, inset])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_beats_sequential() {
        let size = Bytes::gb(2);
        let (grp, _, _) = group_replication(21, size).unwrap();
        let seq = sequential_replication(21, size, "irods-", 6).unwrap();
        assert!(grp < seq, "group={grp} sequential={seq}");
    }

    #[test]
    fn srm_sequential_beats_irods_sequential() {
        let size = Bytes::gb(2);
        let irods = sequential_replication(22, size, "irods-", 6).unwrap();
        let srm = sequential_replication(22, size, "srm-", 6).unwrap();
        assert!(srm < irods, "srm={srm} irods={irods}");
    }

    #[test]
    fn group_replication_is_partial_under_failures() {
        // Average over several seeds: with iRODS' 12% per-transfer
        // failure rate (no retry) the group lands most-but-not-all
        // replicas — the paper's ~7.5 of 9.
        let mut total = 0usize;
        let runs = 16;
        for s in 0..runs {
            let (_, n, _) = group_replication(1000 + s, Bytes::gb(1)).unwrap();
            total += n;
        }
        let avg = total as f64 / runs as f64;
        assert!((7.0..=8.8).contains(&avg), "avg replicas = {avg}");
    }

    #[test]
    fn per_host_tx_spreads_with_heterogeneous_links() {
        let (_, _, per_host) = group_replication(23, Bytes::gb(4)).unwrap();
        assert!(per_host.len() >= 6);
        let txs: Vec<f64> = per_host.iter().map(|(_, t)| *t).collect();
        let min = txs.iter().cloned().fold(f64::MAX, f64::min);
        let max = txs.iter().cloned().fold(0.0, f64::max);
        assert!(max / min > 1.5, "expected spread, got {min}..{max}");
    }

    #[test]
    fn fig8_table_renders() {
        let tables = run(77).unwrap();
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].rows.len(), 3);
    }
}
