//! Experiment drivers — one per table/figure of the paper's §6
//! evaluation. Each driver builds the calibrated testbed, replays the
//! experiment in the discrete-event pilot system, and prints/saves the
//! same rows or series the paper reports.
//!
//! | id     | paper result                                        |
//! |--------|-----------------------------------------------------|
//! | table1 | data-cyberinfrastructure capability matrix          |
//! | fig7   | T_S per backend × dataset size                      |
//! | fig8   | T_R group vs sequential replication (+ inset)       |
//! | fig9   | BWA 8 tasks, 5 infrastructure scenarios (+ T_D)     |
//! | fig10  | per-scenario staging vs task runtime                |
//! | fig11  | 1024-task distributed run, 4 scenarios              |
//! | fig12  | per-machine task runtimes + distribution            |
//! | fig13  | timeline of the 3-machine run                       |
//! | modes  | execution-mode comparison (on-demand / pre-stage /  |
//! |        | auto-replicate) on the 2-site workload              |
//!
//! Beyond the paper's own tables, `resilience` sweeps the 2-site
//! workload across chaos intensities (pilot kills, PD down→up cycles,
//! lossy links) and reports the fault-lifecycle cost, and `scale`
//! extends fig11's flat-overhead argument to production fleet sizes
//! (up to 10⁴ pilots / 10⁶ CUs+DUs), reporting DES events/sec, event-
//! wheel counters, and makespan per tier. `openloop` drives the system
//! with generator-based stochastic arrivals and validates the measured
//! queueing behavior (utilization, mean wait, backlog growth) against
//! the Erlang-C closed form per load tier ρ. `sweep` expands a typed
//! parameter grid (mode × sites × quota, with an opt-in storage
//! backend axis, …) into cells executed on a multi-threaded
//! work-stealing pool and runs a simulated-annealing auto-tuner over
//! the same grid. `backends` runs
//! the 2-site overflow workload across the three storage backend
//! classes (parallel-fs / object-store / node-local) with and without
//! the scheduler's delay-scheduling locality wait, reporting bytes
//! moved and backend dollars per cell.

pub mod backends;
pub mod simdrive;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fig11;
pub mod modes;
pub mod openloop;
pub mod resilience;
pub mod scale;
pub mod sweep;
pub mod table1;

use crate::metrics::Table;
use std::path::Path;

/// Run one experiment by id; returns the rendered tables.
pub fn run(id: &str, seed: u64) -> anyhow::Result<Vec<Table>> {
    match id {
        "table1" => table1::run(),
        "fig7" => fig7::run(seed),
        "fig8" => fig8::run(seed),
        "fig9" => fig9::run_fig9(seed),
        "fig10" => fig9::run_fig10(seed),
        "fig11" => fig11::run_fig11(seed),
        "fig12" => fig11::run_fig12(seed),
        "fig13" => fig11::run_fig13(seed),
        "modes" => modes::run(seed),
        "backends" => backends::run(seed),
        "openloop" => openloop::run(seed),
        "resilience" => resilience::run(seed),
        "scale" => scale::run(seed),
        "sweep" => sweep::run(seed),
        other => anyhow::bail!(
            "unknown experiment '{other}' (try table1, fig7, fig8, fig9, fig10, fig11, fig12, fig13, modes, backends, openloop, resilience, scale, sweep)"
        ),
    }
}

pub const ALL: [&str; 14] = [
    "table1",
    "fig7",
    "fig8",
    "fig9",
    "fig10",
    "fig11",
    "fig12",
    "fig13",
    "modes",
    "backends",
    "openloop",
    "resilience",
    "scale",
    "sweep",
];

/// Print tables and persist CSVs under `results/`.
pub fn report(id: &str, tables: &[Table], results_dir: &Path) -> anyhow::Result<()> {
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.render());
        let name = if tables.len() == 1 {
            id.to_string()
        } else {
            format!("{id}_{i}")
        };
        let path = t.save_csv(results_dir, &name)?;
        println!("  [csv] {}\n", path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn unknown_experiment_is_error() {
        assert!(super::run("fig99", 1).is_err());
    }

    #[test]
    fn all_ids_resolve() {
        // Smoke-run the cheap ones here; heavyweight figs have their
        // own module tests.
        for id in ["table1"] {
            assert!(super::run(id, 1).is_ok(), "{id}");
        }
    }
}
