//! Open-loop queueing validation: the DES against the M/M/c closed
//! form.
//!
//! Every other experiment replays a closed batch. This one drives the
//! full pilot system — scheduler, two-queue pull protocol, multi-slot
//! agents, cost model — with generator-driven Poisson arrivals
//! ([`crate::workload::openloop`]) and checks the *measured* queueing
//! behavior against an analytic oracle:
//!
//! * 1 site, one pilot with `c` one-core slots;
//! * Poisson arrivals at rate λ, exponential service at rate μ
//!   (`cpu_secs_hint ~ Exp(1/μ)` on a speed-1.0 machine with no I/O
//!   term and `runtime_variance = (1.0, 1.0)`);
//! * affinity-free compute-only CUs, which the scheduler provably
//!   routes to the single global FIFO queue.
//!
//! That configuration *is* an M/M/c queue, so measured utilization
//! must match ρ = λ/(cμ) and the mean wait-in-queue must match
//! Erlang-C (`W_q = C(c, λ/μ) / (cμ − λ)`) within statistical
//! tolerance — a correctness check of the whole event pipeline that
//! bit-identity properties cannot provide (they would bless a
//! consistently-wrong engine). A ρ > 1 tier must instead show the
//! textbook instability signature: backlog growing linearly at rate
//! λ − cμ for as long as arrivals continue.

use crate::batch::{BatchState, Machine, QueueModel};
use crate::config::Testbed;
use crate::experiments::simdrive::SimSystem;
use crate::metrics::{CuRecord, Table};
use crate::net::{Bandwidth, Network};
use crate::simtime::QueueBackend;
use crate::storage::{simstore::SimStore, Endpoint};
use crate::topology::{Label, Topology};
use crate::util::{mean, percentile};
use crate::workload::openloop::{mmc_mean_wait, OpenLoopSpec, TenantSpec};

/// Single-site testbed for the M/M/c shape: one machine with `c`
/// cores, one quota-less scratch PD, and a near-instant batch queue
/// (the pilot is Active about 2 s in; arrivals start only after).
pub fn mmc_testbed(c: u32) -> Testbed {
    let topo = Topology::new();
    let mut net = Network::new();
    net.set_default_uplink(Bandwidth::mbps(1_000.0));
    let machines = vec![Machine::new("site", "grid/site", c)
        .with_queue(QueueModel::with_mean(0.0, 1.0, 0.1))
        .with_fs_bandwidth(Bandwidth::mbps(100_000.0))];
    let batch = BatchState::new(machines);
    let mut store = SimStore::new();
    store.add_pd("scratch", Endpoint::new("ssh://site/scratch/pd", "grid/site").unwrap());
    let gateway = Label::new("grid/site");
    Testbed { topo, net, batch, store, gateway }
}

/// One M/M/c run's configuration.
#[derive(Debug, Clone)]
pub struct MmcConfig {
    /// Server count: one pilot with `c` one-core slots.
    pub c: u32,
    /// Offered load ρ = λ/(cμ). Values ≥ 1 are legal — that's the
    /// instability tier — but then no analytic wait exists.
    pub rho: f64,
    /// Service rate (1/mean service seconds).
    pub mu: f64,
    /// Total arrivals to generate.
    pub arrivals: u64,
    /// Arrivals discarded from the wait/backlog statistics (transient
    /// warm-up; the run still executes them).
    pub warmup: u64,
    pub seed: u64,
    pub backend: QueueBackend,
}

impl MmcConfig {
    pub fn new(c: u32, rho: f64, mu: f64, arrivals: u64, warmup: u64, seed: u64) -> MmcConfig {
        MmcConfig { c, rho, mu, arrivals, warmup, seed, backend: QueueBackend::Wheel }
    }
}

/// Measured vs analytic results of one M/M/c tier.
#[derive(Debug, Clone)]
pub struct MmcResult {
    pub rho: f64,
    pub lambda: f64,
    pub mu: f64,
    pub c: u32,
    pub arrivals: u64,
    /// Mean wait-in-queue over post-warmup arrivals (T_Q).
    pub measured_wait_mean: f64,
    pub wait_p95: f64,
    /// Erlang-C mean wait; NaN for ρ ≥ 1 (no steady state exists).
    pub analytic_wait_mean: f64,
    /// Busy-slot fraction of the pilot, time-averaged over the arrival
    /// window.
    pub measured_util: f64,
    /// Mean waiting-CU backlog over post-warmup arrival-instant
    /// samples (PASTA).
    pub backlog_mean: f64,
    pub backlog_max: f64,
    /// Mean backlog per quarter of the arrival sequence — the
    /// instability probe: strictly increasing when ρ > 1.
    pub backlog_quarters: [f64; 4],
    pub events: u64,
    pub wall_s: f64,
    pub events_per_sec: f64,
}

/// Run one M/M/c-shaped open-loop tier end to end through the DES and
/// collect the queueing statistics. Every arrival's CU completes
/// before this returns (arrivals are bounded; the backlog drains).
pub fn run_mmc(cfg: &MmcConfig) -> anyhow::Result<MmcResult> {
    anyhow::ensure!(cfg.c > 0 && cfg.mu > 0.0 && cfg.rho > 0.0, "degenerate M/M/c config");
    anyhow::ensure!(cfg.warmup < cfg.arrivals, "warm-up swallows every arrival");
    let lambda = cfg.rho * cfg.c as f64 * cfg.mu;
    let started = std::time::Instant::now();

    let mut sys = SimSystem::new(mmc_testbed(cfg.c), cfg.seed).with_sim_backend(cfg.backend);
    sys.zero_transfer_faults();
    sys.runtime_variance = (1.0, 1.0); // undistorted exponential service
    sys.queueing_telemetry = true;
    sys.event_budget = (cfg.arrivals * 40).max(2_000_000);
    let pilot = sys.submit_pilot("site", cfg.c, "scratch")?;
    sys.run()?; // pilot Active before measurement starts

    let t_open = sys.sim.now();
    let spec = OpenLoopSpec {
        tenants: vec![TenantSpec::poisson("mmc", lambda, 1.0 / cfg.mu)],
        max_arrivals_per_tenant: Some(cfg.arrivals),
        horizon_s: None,
    };
    // The arrival streams key off their own seed space; xor keeps them
    // decoupled from the system stream without a second seed knob.
    sys.start_open_loop(spec, cfg.seed ^ 0x6f70_656e);
    sys.run()?;
    anyhow::ensure!(sys.state.workload_finished(), "open-loop workload did not drain");
    let wall_s = started.elapsed().as_secs_f64().max(1e-9);

    let mut by_submit: Vec<&CuRecord> = sys.metrics.cu_records.iter().collect();
    anyhow::ensure!(
        by_submit.len() as u64 == cfg.arrivals,
        "completed {} of {} arrivals",
        by_submit.len(),
        cfg.arrivals
    );
    // Records land in completion order; the warm-up cut is by
    // submission order.
    by_submit.sort_by(|a, b| a.t_submitted.total_cmp(&b.t_submitted));
    let waits: Vec<f64> =
        by_submit.iter().skip(cfg.warmup as usize).map(|r| r.wait_s()).collect();
    let t_last_arrival = by_submit.last().map(|r| r.t_submitted).unwrap_or(t_open);

    let measured_util = sys
        .metrics
        .get_series(&format!("busy:{pilot}"))
        .map(|s| s.time_weighted_mean(t_open, t_last_arrival))
        .unwrap_or(0.0)
        / cfg.c as f64;

    let depth_pts: Vec<(f64, f64)> = sys
        .metrics
        .get_series("queue_depth")
        .map(|s| s.points().to_vec())
        .unwrap_or_default();
    let depths: Vec<f64> = depth_pts.iter().map(|p| p.1).collect();
    let post_warmup: Vec<f64> = depths.iter().copied().skip(cfg.warmup as usize).collect();
    let q = depths.len() / 4;
    let mut backlog_quarters = [0.0f64; 4];
    for (i, slot) in backlog_quarters.iter_mut().enumerate() {
        let lo = i * q;
        let hi = if i == 3 { depths.len() } else { (i + 1) * q };
        *slot = mean(&depths[lo..hi]);
    }

    let events = sys.sim.processed();
    Ok(MmcResult {
        rho: cfg.rho,
        lambda,
        mu: cfg.mu,
        c: cfg.c,
        arrivals: cfg.arrivals,
        measured_wait_mean: mean(&waits),
        wait_p95: percentile(&waits, 95.0),
        analytic_wait_mean: if cfg.rho < 1.0 {
            mmc_mean_wait(lambda, cfg.mu, cfg.c as usize)
        } else {
            f64::NAN
        },
        measured_util,
        backlog_mean: mean(&post_warmup),
        backlog_max: depths.iter().copied().fold(0.0, f64::max),
        backlog_quarters,
        events,
        wall_s,
        events_per_sec: events as f64 / wall_s,
    })
}

/// Tolerance check for a stable tier: `|measured − analytic|` within a
/// combined relative + absolute band sized for the sampling noise of
/// ~10⁴ autocorrelated waits (≳5σ at ρ = 0.9, far wider than any real
/// engine bug would land).
pub fn validate_stable_tier(r: &MmcResult) -> anyhow::Result<()> {
    anyhow::ensure!(r.rho < 1.0, "validate_stable_tier needs ρ < 1");
    let wait_tol = 0.35 * r.analytic_wait_mean + 1.0;
    let wait_err = (r.measured_wait_mean - r.analytic_wait_mean).abs();
    anyhow::ensure!(
        wait_err <= wait_tol,
        "ρ={}: mean wait {:.2}s vs Erlang-C {:.2}s (tolerance {:.2}s)",
        r.rho,
        r.measured_wait_mean,
        r.analytic_wait_mean,
        wait_tol
    );
    let util_tol = 0.12 * r.rho + 0.04;
    let util_err = (r.measured_util - r.rho).abs();
    anyhow::ensure!(
        util_err <= util_tol,
        "ρ={}: utilization {:.3} vs offered load {:.3} (tolerance {:.3})",
        r.rho,
        r.measured_util,
        r.rho,
        util_tol
    );
    Ok(())
}

/// Default validation shape: c = 4 slots, 60 s mean service.
pub const MMC_SLOTS: u32 = 4;
pub const MMC_MU: f64 = 1.0 / 60.0;
/// Stable tiers validated against Erlang-C, plus the instability tier.
pub const STABLE_TIERS: [f64; 3] = [0.3, 0.6, 0.9];
pub const UNSTABLE_TIER: f64 = 1.5;

/// `exp openloop`: the validation sweep as a table.
pub fn run(seed: u64) -> anyhow::Result<Vec<Table>> {
    run_with(seed, 6_000, 1_000)
}

/// Parameterized sweep used by `run` and the bench/tests.
pub fn run_with(seed: u64, arrivals: u64, warmup: u64) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Open-loop M/M/c validation: measured vs Erlang-C per load tier",
        &[
            "rho", "lambda (1/s)", "arrivals", "util meas", "W_q meas (s)", "W_q Erlang-C (s)",
            "W_q p95 (s)", "backlog mean", "backlog max", "events", "events/s",
        ],
    );
    for rho in STABLE_TIERS.into_iter().chain([UNSTABLE_TIER]) {
        let r = run_mmc(&MmcConfig::new(MMC_SLOTS, rho, MMC_MU, arrivals, warmup, seed))?;
        t.row(vec![
            format!("{rho:.2}"),
            format!("{:.4}", r.lambda),
            r.arrivals.to_string(),
            format!("{:.3}", r.measured_util),
            format!("{:.2}", r.measured_wait_mean),
            if r.analytic_wait_mean.is_finite() {
                format!("{:.2}", r.analytic_wait_mean)
            } else {
                "unstable".into()
            },
            format!("{:.2}", r.wait_p95),
            format!("{:.1}", r.backlog_mean),
            format!("{:.0}", r.backlog_max),
            r.events.to_string(),
            format!("{:.0}", r.events_per_sec),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::openloop::{ArrivalProcess, Dist};

    /// The headline acceptance test: measured utilization and mean
    /// wait match the Erlang-C closed form at every stable tier.
    #[test]
    fn mmc_validation_matches_erlang_c_across_stable_tiers() {
        for rho in STABLE_TIERS {
            let cfg = MmcConfig::new(MMC_SLOTS, rho, MMC_MU, 10_000, 2_000, 42);
            let r = run_mmc(&cfg).unwrap();
            validate_stable_tier(&r).unwrap();
        }
    }

    /// ρ > 1 has no steady state: the backlog must grow monotonically
    /// across the arrival sequence, at roughly the drift λ − cμ.
    #[test]
    fn unstable_tier_grows_backlog_without_bound() {
        let cfg = MmcConfig::new(MMC_SLOTS, UNSTABLE_TIER, MMC_MU, 4_000, 0, 43);
        let r = run_mmc(&cfg).unwrap();
        let q = r.backlog_quarters;
        assert!(
            q[0] < q[1] && q[1] < q[2] && q[2] < q[3],
            "backlog quarters not monotone: {q:?}"
        );
        // Drift check: λ − cμ = cμ(ρ − 1) = 4/60 · 0.5 per second over
        // ~40,000 s of arrivals ⇒ final backlog in the thousands. Even
        // a loose floor separates drift from noise.
        assert!(q[3] > 100.0, "final-quarter backlog too small: {}", q[3]);
        assert!(r.backlog_max > q[3], "max must top the quarter mean");
        assert!(r.analytic_wait_mean.is_nan(), "no analytic wait exists past ρ=1");
    }

    /// Mixed multi-tenant open-loop trace for the determinism tests:
    /// Poisson, deterministic, and diurnal tenants, one of them
    /// carrying heavy-tailed DU payloads.
    fn mixed_trace(backend: QueueBackend, seed: u64) -> (u64, Vec<(String, [u64; 4])>, Vec<Vec<(u64, u64)>>) {
        let mut sys = SimSystem::new(mmc_testbed(8), seed).with_sim_backend(backend);
        sys.zero_transfer_faults();
        sys.runtime_variance = (1.0, 1.0);
        sys.queueing_telemetry = true;
        sys.submit_pilot("site", 8, "scratch").unwrap();
        sys.run().unwrap();
        let spec = OpenLoopSpec {
            tenants: vec![
                TenantSpec::poisson("poisson", 0.05, 40.0),
                TenantSpec {
                    name: "steady".into(),
                    arrivals: ArrivalProcess::Deterministic { rate: 0.02 },
                    service: Dist::LogNormal { mu: 3.0, sigma: 0.8 },
                    batch: 2,
                    cores: 1,
                    du: None,
                },
                TenantSpec {
                    name: "bursty".into(),
                    arrivals: ArrivalProcess::Diurnal {
                        base_rate: 0.03,
                        amplitude: 0.9,
                        period_s: 600.0,
                    },
                    service: Dist::Exp { mean: 30.0 },
                    batch: 1,
                    cores: 2,
                    du: Some((Dist::LogNormal { mu: 16.0, sigma: 1.0 }, "scratch".into())),
                },
            ],
            max_arrivals_per_tenant: Some(60),
            horizon_s: None,
        };
        sys.start_open_loop(spec, seed);
        sys.run().unwrap();
        assert!(sys.state.workload_finished());
        // Ids differ across runs in one process (global counter), so
        // the trace compares times/machines/series, never ids.
        let recs = sys
            .metrics
            .cu_records
            .iter()
            .map(|r| {
                (
                    r.machine.clone(),
                    [
                        r.t_submitted.to_bits(),
                        r.t_start.to_bits(),
                        r.t_end.to_bits(),
                        r.staging_s.to_bits(),
                    ],
                )
            })
            .collect();
        let series = sys
            .metrics
            .series
            .values()
            .map(|s| s.points().iter().map(|&(t, v)| (t.to_bits(), v.to_bits())).collect())
            .collect();
        (sys.sim.processed(), recs, series)
    }

    #[test]
    fn open_loop_traces_are_bit_identical_per_seed() {
        let a = mixed_trace(QueueBackend::Wheel, 7);
        let b = mixed_trace(QueueBackend::Wheel, 7);
        assert_eq!(a, b, "same seed, same backend must be bit-identical");
        let c = mixed_trace(QueueBackend::Wheel, 8);
        assert_ne!(a.1, c.1, "seed must matter");
    }

    #[test]
    fn open_loop_traces_match_across_queue_backends() {
        let wheel = mixed_trace(QueueBackend::Wheel, 11);
        let heap = mixed_trace(QueueBackend::Heap, 11);
        assert_eq!(wheel, heap, "wheel and heap backends must agree bit-for-bit");
    }

    #[test]
    fn validation_table_has_all_tiers() {
        let tables = run_with(1, 400, 50).unwrap();
        assert_eq!(tables.len(), 1);
        // Three stable tiers + the unstable one.
        assert_eq!(tables[0].rows.len(), 4);
        assert!(tables[0].rows[3][5].contains("unstable"));
    }
}
