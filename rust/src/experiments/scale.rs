//! Production-scale sweep: 10⁴ pilots / 10⁶ CUs+DUs through the DES.
//!
//! The paper's Fig. 11 argument is that a pilot-based data/compute
//! plane keeps scheduling overhead flat as task counts grow; the fig11
//! module reproduces it at the paper's 1024-task size. This sweep
//! extends the same driver to the fleet sizes the pilot-job literature
//! frames as "production scale" — up to 10⁴ pilots running 10⁶
//! one-core CUs over 10⁵ co-located DUs — and records what the engine
//! itself does under that load: DES **events/sec**, the event-wheel's
//! **structural counters** ([`crate::simtime::QueueStats`]: now-lane
//! hit rate, rebucket/rewind traffic, slab high-water mark), and
//! workload **makespan** per tier, plus one whole-run **peak RSS**
//! row. (Peak RSS is `VmHWM` — process-global and monotone, so it is
//! deliberately *not* attributed per tier: under concurrent tiers or
//! sweep cells it measures the process, not the workload. The wheel
//! counters are owned by each tier's own queue and stay attributable.)
//!
//! The workload is deliberately synthetic and placement-heavy rather
//! than transfer-heavy: every CU carries a site affinity and its input
//! chunk is pre-placed on that site's scratch PD, so the run exercises
//! the scheduler index path, the queue/wakeup protocol, and the event
//! wheel — not the WAN model. `benches/scale.rs` wraps this module and
//! emits `BENCH_scale.json` (three tiers; `PD_BENCH_QUICK=1` runs a
//! reduced sweep for CI).

use crate::batch::{BatchState, Machine, QueueModel};
use crate::config::Testbed;
use crate::experiments::simdrive::SimSystem;
use crate::metrics::Table;
use crate::net::{Bandwidth, Network};
use crate::storage::{simstore::SimStore, Endpoint};
use crate::topology::{Label, Topology};
use crate::unit::{ComputeUnitDescription, DataUnitDescription, FileRef};
use crate::util::Bytes;

/// Pilots per synthetic site (one machine + one scratch PD each).
pub const PILOTS_PER_SITE: usize = 10;
/// Cores per pilot == 1-core CUs it can run concurrently.
pub const PILOT_CORES: u32 = 100;
/// CUs submitted per pilot (so 10⁴ pilots ⇒ 10⁶ CUs).
pub const CUS_PER_PILOT: usize = 100;
/// CUs sharing one input chunk DU.
pub const CUS_PER_DU: usize = 10;

/// The full sweep: 10², 10³, 10⁴ pilots (10⁴..10⁶ CUs).
pub const FULL_SWEEP: [usize; 3] = [100, 1_000, 10_000];
/// Reduced tiers for CI smoke and `exp scale` (still ≥ 3 fleet sizes).
pub const QUICK_SWEEP: [usize; 3] = [20, 50, 100];

fn site_machine(site: usize) -> String {
    format!("site-{site:04}")
}

fn site_label(site: usize) -> String {
    format!("grid/site-{site:04}")
}

fn site_scratch(site: usize) -> String {
    format!("scratch-{site:04}")
}

/// A synthetic homogeneous grid: `sites` machines under one `grid`
/// trunk, each with `PILOTS_PER_SITE × PILOT_CORES` cores, a fast
/// batch queue, and one quota-less scratch PD. Modeled on
/// [`crate::config::paper_testbed`] but uniform, so sweep timings
/// measure the engine rather than testbed asymmetry.
pub fn scale_testbed(sites: usize) -> Testbed {
    let topo = Topology::new();
    let mut net = Network::new();
    net.set_default_uplink(Bandwidth::mbps(100.0));
    net.set_uplink("grid", Bandwidth::mbps(10_000.0));

    let machines: Vec<Machine> = (0..sites)
        .map(|s| {
            Machine::new(&site_machine(s), &site_label(s), PILOTS_PER_SITE as u32 * PILOT_CORES)
                .with_queue(QueueModel::with_mean(10.0, 60.0, 0.3))
                .with_fs_bandwidth(Bandwidth::mbps(2_000.0))
        })
        .collect();
    let batch = BatchState::new(machines);

    let mut store = SimStore::new();
    for s in 0..sites {
        store.add_pd(
            &site_scratch(s),
            Endpoint::new(&format!("ssh://{}/scratch/pd", site_scratch(s)), &site_label(s))
                .unwrap(),
        );
    }

    // Uploads (unused here — data is pre-placed) route via site 0.
    let gateway = Label::new(&site_label(0));
    Testbed { topo, net, batch, store, gateway }
}

/// One tier of the sweep.
#[derive(Debug, Clone)]
pub struct ScaleRunResult {
    pub pilots: usize,
    pub cus: usize,
    pub dus: usize,
    /// DES events processed end to end.
    pub events: u64,
    /// Wall-clock seconds for the whole tier (build + run).
    pub wall_s: f64,
    pub events_per_sec: f64,
    /// Simulated makespan of the workload.
    pub makespan_s: f64,
    /// Event-wheel structural counters for *this tier's* sim — the
    /// per-tier attribution signal. Unlike `VmHWM` (process-global,
    /// monotone across tiers, and meaningless once tiers or sweep
    /// cells run concurrently), these are owned by the tier's own
    /// queue: slab high-water mark, now-lane hit rate, rebucket and
    /// cursor-rewind traffic.
    pub queue: crate::simtime::QueueStats,
}

/// Process peak resident set (bytes) from `/proc/self/status` VmHWM.
/// Returns 0 on platforms without procfs. **Whole-process** and
/// monotone — report it once per run (the footer row of `exp scale` /
/// the `whole_run` key of `BENCH_scale.json`), never per tier or per
/// concurrent cell.
pub fn peak_rss_bytes() -> u64 {
    if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                if let Ok(kb) = rest.trim().trim_end_matches("kB").trim().parse::<u64>() {
                    return kb * 1024;
                }
            }
        }
    }
    0
}

/// Run one fleet tier: `pilots` pilots (10 per site, 100 cores each),
/// `100 × pilots` one-core CUs with site affinity, inputs pre-placed
/// co-located. Uses the bulk [`SimSystem::submit_cus`] path — the
/// per-CU wakeup drain is the O(fleet²) term this sweep exists to keep
/// out of the driver.
pub fn run_scale(pilots: usize, seed: u64) -> anyhow::Result<ScaleRunResult> {
    anyhow::ensure!(pilots > 0, "need at least one pilot");
    let started = std::time::Instant::now();
    let sites = pilots.div_ceil(PILOTS_PER_SITE);
    let cus = pilots * CUS_PER_PILOT;

    let mut sys = SimSystem::new(scale_testbed(sites), seed);
    sys.zero_transfer_faults();
    sys.event_budget = (cus as u64 * 24 + pilots as u64 * 12).max(4_000_000);

    // Pilots first; run() lands every activation before data/compute.
    let mut remaining = pilots;
    for s in 0..sites {
        let here = remaining.min(PILOTS_PER_SITE);
        remaining -= here;
        for _ in 0..here {
            sys.submit_pilot(&site_machine(s), PILOT_CORES, &site_scratch(s))?;
        }
    }
    sys.run()?;

    // Input chunks: one DU per CUS_PER_DU CUs, resident on the site's
    // scratch (placement-heavy, transfer-free — see the module docs).
    let cus_per_site = CUS_PER_DU * ((cus / sites).max(1) / CUS_PER_DU).max(1);
    let mut site_dus: Vec<Vec<String>> = Vec::with_capacity(sites);
    let mut dus = 0usize;
    for s in 0..sites {
        let n = (cus_per_site / CUS_PER_DU).max(1);
        let mut ids = Vec::with_capacity(n);
        for d in 0..n {
            let descr = DataUnitDescription {
                name: format!("chunk-{s:04}-{d:04}"),
                files: vec![FileRef::sized("reads.fq", Bytes::mb(64))],
                affinity: Some(Label::new(&site_label(s))),
            };
            ids.push(sys.place_du_instant(&descr, &site_scratch(s))?);
            dus += 1;
        }
        site_dus.push(ids);
    }

    // CUs: site-affine, one shared input chunk each, submitted in bulk.
    let mut descrs = Vec::with_capacity(cus);
    for s in 0..sites {
        let here = &site_dus[s];
        let label = Label::new(&site_label(s));
        let n = if s == sites - 1 { cus - cus_per_site * (sites - 1) } else { cus_per_site };
        for k in 0..n {
            descrs.push(ComputeUnitDescription {
                executable: "/bin/synthetic-task".into(),
                arguments: vec![format!("--task={s}:{k}")],
                cores: 1,
                input_data: vec![here[k / CUS_PER_DU % here.len()].clone()],
                output_data: vec![],
                affinity: Some(label.clone()),
                cpu_secs_hint: 600.0,
                io_bytes_hint: Bytes::mb(64),
            });
        }
    }
    let ids = sys.submit_cus(descrs)?;
    anyhow::ensure!(ids.len() == cus, "submitted {} of {cus} CUs", ids.len());
    sys.run()?;
    anyhow::ensure!(sys.state.workload_finished(), "scale workload did not finish");

    let wall_s = started.elapsed().as_secs_f64().max(1e-9);
    let events = sys.sim.processed();
    Ok(ScaleRunResult {
        pilots,
        cus,
        dus,
        events,
        wall_s,
        events_per_sec: events as f64 / wall_s,
        makespan_s: sys.makespan(),
        queue: sys.queue_stats(),
    })
}

/// `exp scale`: the reduced sweep as two tables — per-tier engine
/// behaviour (events/sec plus the tier-owned wheel counters that
/// attribute it), and one whole-run row for the process-global peak
/// RSS (the full 10⁴-pilot sweep runs via `cargo bench --bench scale`).
pub fn run(seed: u64) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Scale sweep: DES throughput vs fleet size (reduced tiers; full sweep in benches/scale.rs)",
        &[
            "pilots",
            "CUs",
            "DUs",
            "events",
            "events/s",
            "makespan (s)",
            "now-hit %",
            "rebuckets",
            "rebucketed",
            "rewinds",
            "slab peak",
        ],
    );
    for pilots in QUICK_SWEEP {
        let r = run_scale(pilots, seed)?;
        t.row(vec![
            r.pilots.to_string(),
            r.cus.to_string(),
            r.dus.to_string(),
            r.events.to_string(),
            format!("{:.0}", r.events_per_sec),
            format!("{:.0}", r.makespan_s),
            format!("{:.1}", r.queue.now_hit_rate() * 100.0),
            r.queue.rebuckets.to_string(),
            r.queue.rebucketed_cells.to_string(),
            r.queue.cursor_rewinds.to_string(),
            r.queue.slab_peak.to_string(),
        ]);
    }
    let mut rss = Table::new(
        "Scale sweep: whole-run process footprint (VmHWM is process-global — not per tier)",
        &["peak RSS (MB)"],
    );
    rss.row(vec![format!("{:.1}", peak_rss_bytes() as f64 / 1.0e6)]);
    Ok(vec![t, rss])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_tier_completes_with_bounded_event_rate() {
        let r = run_scale(20, 42).unwrap();
        assert_eq!(r.pilots, 20);
        assert_eq!(r.cus, 2_000);
        assert_eq!(r.dus, 200);
        assert!(r.events >= r.cus as u64, "events {} < cus", r.events);
        // Flatness surrogate a unit test can assert: the per-CU event
        // count stays bounded (the wall-clock rate itself is hardware-
        // dependent and belongs to the bench).
        let per_cu = r.events as f64 / r.cus as f64;
        assert!(per_cu < 40.0, "events/CU blew up: {per_cu}");
        assert!(r.makespan_s > 0.0);
        // The default backend is the wheel: its per-tier counters are
        // live (pushes counted, slab high-water mark set) — the signal
        // that replaced per-tier VmHWM.
        let q = r.queue;
        assert!(q.now_hits + q.timed_pushes >= r.events, "{q:?}");
        assert!(q.slab_peak > 0, "{q:?}");
        assert!(q.now_hit_rate() > 0.0 && q.now_hit_rate() <= 1.0, "{q:?}");
    }

    #[test]
    fn scale_run_is_deterministic_per_seed() {
        let a = run_scale(20, 7).unwrap();
        let b = run_scale(20, 7).unwrap();
        assert_eq!(a.events, b.events);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        let c = run_scale(20, 8).unwrap();
        assert_ne!(a.makespan_s.to_bits(), c.makespan_s.to_bits(), "seed must matter");
    }

    #[test]
    fn partial_last_site_still_finishes() {
        // 25 pilots → 3 sites (10/10/5); the CU split must cover all
        // 2500 CUs exactly.
        let r = run_scale(25, 3).unwrap();
        assert_eq!(r.pilots, 25);
        assert_eq!(r.cus, 2_500);
    }

    #[test]
    fn peak_rss_reads_on_linux() {
        // Best-effort elsewhere; on Linux (CI + dev) it must be real.
        if std::path::Path::new("/proc/self/status").exists() {
            assert!(peak_rss_bytes() > 0);
        }
    }
}
