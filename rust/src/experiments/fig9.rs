//! Figs. 9 & 10: genome sequencing (BWA, 8 tasks × 256 MB reads +
//! 8 GB shared reference) across five infrastructure configurations:
//!
//! 1. OSG, naive data management (each task pulls all 8.3 GB from the
//!    GW68 submission machine);
//! 2. XSEDE/Lonestar, naive (single 24-core pilot, same remote pulls);
//! 3. OSG + iRODS Pilot-Data, reference replicated to the 9-site
//!    group, compute co-located with data;
//! 4. XSEDE/Lonestar + SSH Pilot-Data on the Lustre scratch,
//!    co-located;
//! 5. Hybrid: input on a Lonestar Pilot-Data, one 12-core Lonestar
//!    pilot + four OSG pilots (the interoperability demo).
//!
//! Expected shape (paper): PD scenarios (3–5) beat naive (1–2);
//! T_D(iRODS) ≫ T_D(SSH) (≈1418 s vs ≈338 s); in scenario 5 the
//! majority of tasks run on Lonestar (paper: ≈4.5 of 8).

use crate::config::{paper_testbed, OSG_SITES};
use crate::experiments::simdrive::SimSystem;
use crate::metrics::{Table, CuRecord};
use crate::util::Bytes;
use crate::workload::bwa_ensemble;

pub const SCENARIOS: [&str; 5] = [
    "1: OSG naive",
    "2: XSEDE naive",
    "3: OSG iRODS PD",
    "4: XSEDE SSH PD",
    "5: hybrid XSEDE+OSG",
];

/// Result of one scenario run.
pub struct ScenarioResult {
    pub t_total: f64,
    pub t_d: f64,
    pub records: Vec<CuRecord>,
    pub distribution: std::collections::BTreeMap<String, usize>,
}

/// Run one Fig. 9 scenario (1-based index).
pub fn run_scenario(scenario: usize, seed: u64) -> anyhow::Result<ScenarioResult> {
    let mut sys = SimSystem::new(paper_testbed(), seed);
    let ens = bwa_ensemble(8, Bytes::gb(2), Bytes::gb(8));

    // ---- Phase 1: data placement (T_D) ----
    let (ref_du, chunk_dus): (String, Vec<String>) = match scenario {
        1 | 2 => {
            // Naive: everything stays on the submission machine.
            let r = sys.upload_du(&ens.reference, "gw68-staging")?;
            let cs = ens
                .read_chunks
                .iter()
                .map(|c| sys.upload_du(c, "gw68-staging"))
                .collect::<anyhow::Result<Vec<_>>>()?;
            (r, cs)
        }
        3 => {
            // iRODS PD: upload to the Fermilab server (reference
            // first, then chunks), replicate the reference across the
            // 9-site group.
            let r = sys.upload_du(&ens.reference, "irods-fnal")?;
            sys.run()?;
            let cs = ens
                .read_chunks
                .iter()
                .map(|c| sys.upload_du(c, "irods-fnal"))
                .collect::<anyhow::Result<Vec<_>>>()?;
            sys.run()?; // land the uploads before fanning out
            sys.replicate_group(&r, "osgGridFtpGroup")?;
            (r, cs)
        }
        4 | 5 => {
            // SSH PD on Lonestar's Lustre scratch (reference first,
            // then the chunks).
            let r = sys.upload_du(&ens.reference, "lonestar-scratch")?;
            sys.run()?;
            let cs = ens
                .read_chunks
                .iter()
                .map(|c| sys.upload_du(c, "lonestar-scratch"))
                .collect::<anyhow::Result<Vec<_>>>()?;
            (r, cs)
        }
        other => anyhow::bail!("scenario {other} out of range 1..=5"),
    };
    sys.run()?;
    let t_d = sys.sim.now();

    // ---- Phase 2: pilots + workload ----
    match scenario {
        1 | 3 => {
            // 8 single-slot OSG pilots across the iRODS-capable sites.
            for site in OSG_SITES.iter().take(8) {
                sys.submit_pilot(&format!("osg-{site}"), 2, &format!("irods-{site}"))?;
            }
        }
        2 => {
            sys.submit_pilot("lonestar", 24, "lonestar-scratch")?;
        }
        4 => {
            sys.submit_pilot("lonestar", 24, "lonestar-scratch")?;
        }
        5 => {
            sys.submit_pilot("lonestar", 12, "lonestar-scratch")?;
            for site in OSG_SITES.iter().take(4) {
                sys.submit_pilot(&format!("osg-{site}"), 2, &format!("irods-{site}"))?;
            }
        }
        _ => unreachable!(),
    }
    for chunk in &chunk_dus {
        let mut cud = ens.cu_template.clone();
        cud.cores = 2;
        cud.input_data = vec![ref_du.clone(), chunk.clone()];
        sys.submit_cu(cud)?;
    }
    sys.run()?;
    anyhow::ensure!(sys.state.workload_finished(), "workload did not finish");

    Ok(ScenarioResult {
        t_total: sys.metrics.makespan(),
        t_d,
        records: sys.metrics.cu_records.clone(),
        distribution: sys.metrics.distribution(),
    })
}

/// Average a scenario over a few seeds (the paper reports averages).
pub fn run_scenario_avg(scenario: usize, seed: u64, reps: u64) -> anyhow::Result<ScenarioResult> {
    // `reps = 0` would divide the averages below by zero and return
    // NaN scenario times — reject it instead of poisoning the table.
    anyhow::ensure!(reps > 0, "run_scenario_avg needs at least one rep");
    let mut results = Vec::new();
    for r in 0..reps {
        results.push(run_scenario(scenario, seed + r * 101)?);
    }
    let n = results.len() as f64;
    let t_total = results.iter().map(|r| r.t_total).sum::<f64>() / n;
    let t_d = results.iter().map(|r| r.t_d).sum::<f64>() / n;
    let mut distribution = std::collections::BTreeMap::new();
    for r in &results {
        for (m, c) in &r.distribution {
            *distribution.entry(m.clone()).or_insert(0) += c;
        }
    }
    let records = results.into_iter().flat_map(|r| r.records).collect();
    Ok(ScenarioResult { t_total, t_d, records, distribution })
}

pub fn run_fig9(seed: u64) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 9: BWA runtimes, 8 tasks x 256 MB reads + 8 GB reference",
        &["scenario", "T (s)", "T_D (s)", "tasks on lonestar"],
    );
    for (i, name) in SCENARIOS.iter().enumerate() {
        let r = run_scenario_avg(i + 1, seed, 3)?;
        let lonestar = *r.distribution.get("lonestar").unwrap_or(&0) as f64 / 3.0;
        t.row(vec![
            name.to_string(),
            format!("{:.0}", r.t_total),
            format!("{:.0}", r.t_d),
            format!("{lonestar:.1}/8"),
        ]);
    }
    Ok(vec![t])
}

pub fn run_fig10(seed: u64) -> anyhow::Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 10: per-task staging (download) vs runtime (seconds, mean over tasks)",
        &["scenario", "staging mean", "staging max", "runtime mean", "runtime max"],
    );
    for (i, name) in SCENARIOS.iter().enumerate() {
        let r = run_scenario(i + 1, seed)?;
        let staging: Vec<f64> = r.records.iter().map(|x| x.staging_s).collect();
        let runtime: Vec<f64> = r.records.iter().map(|x| x.compute_s).collect();
        t.row(vec![
            name.to_string(),
            format!("{:.0}", crate::util::mean(&staging)),
            format!("{:.0}", staging.iter().cloned().fold(0.0, f64::max)),
            format!("{:.0}", crate::util::mean(&runtime)),
            format!("{:.0}", runtime.iter().cloned().fold(0.0, f64::max)),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_reps_is_an_error_not_a_nan() {
        let err = run_scenario_avg(1, 11, 0).unwrap_err().to_string();
        assert!(err.contains("at least one rep"), "unexpected error: {err}");
    }

    #[test]
    fn pd_scenarios_beat_naive() {
        let naive_osg = run_scenario_avg(1, 11, 2).unwrap();
        let pd_osg = run_scenario_avg(3, 11, 2).unwrap();
        assert!(
            pd_osg.t_total < naive_osg.t_total,
            "iRODS PD {} !< naive {}",
            pd_osg.t_total,
            naive_osg.t_total
        );
        let naive_x = run_scenario_avg(2, 11, 2).unwrap();
        let pd_x = run_scenario_avg(4, 11, 2).unwrap();
        assert!(
            pd_x.t_total < naive_x.t_total,
            "SSH PD {} !< naive {}",
            pd_x.t_total,
            naive_x.t_total
        );
    }

    #[test]
    fn td_irods_much_larger_than_td_ssh() {
        // Paper: T_D(iRODS) ≈ 1418 s (upload + 9-site replication),
        // T_D(SSH) ≈ 338 s (upload only).
        let irods = run_scenario(3, 13).unwrap();
        let ssh = run_scenario(4, 13).unwrap();
        assert!(
            irods.t_d > 2.0 * ssh.t_d,
            "t_d irods={} ssh={}",
            irods.t_d,
            ssh.t_d
        );
        assert!(irods.t_d > 600.0 && irods.t_d < 4000.0, "irods t_d={}", irods.t_d);
        assert!(ssh.t_d > 60.0 && ssh.t_d < 1000.0, "ssh t_d={}", ssh.t_d);
    }

    #[test]
    fn staging_dominates_naive_but_not_pd() {
        let naive = run_scenario(1, 17).unwrap();
        let pd = run_scenario(3, 17).unwrap();
        let mean_staging_naive =
            crate::util::mean(&naive.records.iter().map(|r| r.staging_s).collect::<Vec<_>>());
        let mean_staging_pd =
            crate::util::mean(&pd.records.iter().map(|r| r.staging_s).collect::<Vec<_>>());
        assert!(
            mean_staging_naive > 5.0 * mean_staging_pd.max(1.0),
            "naive={mean_staging_naive} pd={mean_staging_pd}"
        );
    }

    #[test]
    fn hybrid_runs_majority_on_lonestar() {
        let r = run_scenario_avg(5, 19, 4).unwrap();
        let lonestar = *r.distribution.get("lonestar").unwrap_or(&0);
        let total: usize = r.distribution.values().sum();
        assert_eq!(total, 32);
        assert!(
            lonestar * 2 > total,
            "lonestar ran {lonestar}/{total}, expected majority"
        );
    }

    #[test]
    fn fig9_and_fig10_tables_render() {
        let t9 = run_fig9(3).unwrap();
        assert_eq!(t9[0].rows.len(), 5);
        let t10 = run_fig10(3).unwrap();
        assert_eq!(t10[0].rows.len(), 5);
    }
}
