//! Fig. 7: Pilot-Data on different infrastructures — time T_S to
//! instantiate a Pilot-Data with a dataset of a given size, for the
//! five backends (SSH, iRODS, SRM, Globus Online, S3), staged from the
//! GW68 submission machine.
//!
//! Expected shape (paper): SRM best (GridFTP); SSH and iRODS acceptable
//! for smaller datasets; Globus Online pays a service overhead visible
//! at small sizes but competitive at volume; S3 scales linearly,
//! limited by the WAN bandwidth to the AWS datacenter.

use crate::config::paper_testbed;
use crate::experiments::simdrive::SimSystem;
use crate::faults::RetryPolicy;
use crate::metrics::Table;
use crate::unit::{DataUnitDescription, FileRef};
use crate::util::Bytes;

/// (display name, destination PD in the testbed).
pub const BACKENDS: [(&str, &str); 5] = [
    ("SSH", "lonestar-scratch"),
    ("iRODS", "irods-fnal"),
    ("SRM", "osg-srm"),
    ("GlobusOnline", "lonestar-go"),
    ("S3", "s3-east"),
];

pub const SIZES_MB: [u64; 4] = [512, 1024, 2048, 4096];

/// Measure T_S for one (backend, size) on a fresh testbed.
pub fn staging_time(seed: u64, pd: &str, size: Bytes, files: u32) -> anyhow::Result<f64> {
    let mut sys = SimSystem::new(paper_testbed(), seed);
    sys.retry = RetryPolicy::default();
    let descr = DataUnitDescription {
        name: "fig7-dataset".into(),
        files: (0..files)
            .map(|i| FileRef::sized(&format!("part{i:03}"), Bytes(size.0 / files as u64)))
            .collect(),
        affinity: None,
    };
    let du = sys.upload_du(&descr, pd)?;
    sys.run()?;
    let t = sys.metrics.scalar(&format!("staged:{du}:{pd}"));
    anyhow::ensure!(t.is_finite(), "staging never completed for {pd}");
    Ok(t)
}

pub fn run(seed: u64) -> anyhow::Result<Vec<Table>> {
    let mut headers = vec!["size".to_string()];
    headers.extend(BACKENDS.iter().map(|(n, _)| n.to_string()));
    let mut t = Table::new(
        "Fig 7: T_S to instantiate a Pilot-Data (seconds, from GW68)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );
    for &mb in &SIZES_MB {
        let size = Bytes::mb(mb);
        let mut row = vec![format!("{}", size)];
        for (i, (_, pd)) in BACKENDS.iter().enumerate() {
            let ts = staging_time(seed + i as u64, pd, size, 16)?;
            row.push(format!("{ts:.1}"));
        }
        t.row(row);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_shape_matches_paper() {
        let size = Bytes::gb(4);
        let ts = |pd: &str| staging_time(100, pd, size, 16).unwrap();
        let (ssh, irods, srm, go, s3) = (
            ts("lonestar-scratch"),
            ts("irods-fnal"),
            ts("osg-srm"),
            ts("lonestar-go"),
            ts("s3-east"),
        );
        // SRM clearly best.
        assert!(srm < ssh && srm < irods && srm < go && srm < s3, "srm={srm} ssh={ssh} irods={irods} go={go} s3={s3}");
        // At 4 GB GO beats SSH (GridFTP underneath).
        assert!(go < ssh, "go={go} ssh={ssh}");
        // S3 is the slowest at volume (WAN-limited).
        assert!(s3 > ssh && s3 > srm, "s3={s3}");
        // iRODS ≈ SSH ballpark (within 2.5x).
        assert!(irods / ssh < 2.5 && ssh / irods < 2.5, "irods={irods} ssh={ssh}");
    }

    #[test]
    fn fig7_small_sizes_favour_ssh_over_go() {
        let size = Bytes::mb(256);
        let ssh = staging_time(7, "lonestar-scratch", size, 4).unwrap();
        let go = staging_time(7, "lonestar-go", size, 4).unwrap();
        assert!(ssh < go, "ssh={ssh} go={go} (GO request overhead must dominate small transfers)");
    }

    #[test]
    fn fig7_s3_scales_linearly() {
        let t1 = staging_time(8, "s3-east", Bytes::gb(1), 8).unwrap();
        let t4 = staging_time(8, "s3-east", Bytes::gb(4), 8).unwrap();
        let ratio = t4 / t1;
        assert!((3.0..5.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn fig7_full_table_renders() {
        let tables = run(42).unwrap();
        assert_eq!(tables[0].rows.len(), SIZES_MB.len());
        assert!(tables[0].render().contains("GlobusOnline"));
    }
}
